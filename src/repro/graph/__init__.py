from repro.graph.topology import resnet50, inception_v3, RESNET50_LAYERS
from repro.graph.etg import build_etg
from repro.graph.executor import GxM
from repro.graph.serving import (CnnInferenceEngine, conv_shapes,
                                 cnn_model_flops, distinct_conv_signatures,
                                 make_buckets, pick_bucket)
