from repro.graph.topology import resnet50, inception_v3, RESNET50_LAYERS
from repro.graph.etg import build_etg
from repro.graph.executor import GxM
