"""GxM executor: runs an ETG forward for both training and inference
serving.  Functional: params are a pytree keyed by node name.

Training: the backward/update passes come from the conv tasks' custom VJPs
(duality + update-pass kernels); BatchNorm uses batch statistics and
contributes running-stat updates.

Inference/serving: BN is folded into the conv epilogue (scale/shift) — the
fused path the paper benchmarks — and ``make_infer`` exposes it as a
jit-able entry point with a donated input buffer and optional data-parallel
``shard_map`` over a mesh.  ``graph/serving.py`` wraps it with bucketed
batching and cache warmup for the CNN serving path (``launch/serve_cnn.py``).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.conv import (conv2d_chain_fwd, conv2d_train, conv2d_fwd,
                             conv2d_q8_fwd)
from repro.graph.etg import ETG, build_etg


def _shard_map():
    from repro.launch.mesh import shard_map_fn
    return shard_map_fn()


def apply_bn_updates(params, stats, bn_momentum):
    """Fold freshly collected batch statistics into the running BN stats —
    in place, on a params tree the caller owns (the post-SGD tree).  Shared
    by the single-device step and the data-parallel step, where ``stats``
    arrives pre-averaged across shards (``train/distributed.py``)."""
    for name, (mu, var) in stats.items():
        params[name]["mean"] = bn_momentum * params[name]["mean"] \
            + (1 - bn_momentum) * mu
        params[name]["var"] = bn_momentum * params[name]["var"] \
            + (1 - bn_momentum) * var
    return params


def _maxpool(x, window, stride, padding):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, window, window, 1),
        (1, stride, stride, 1),
        [(0, 0), (padding, padding), (padding, padding), (0, 0)])


class GxM:
    """Graph execution model over an ETG."""

    def __init__(self, nl, *, impl: str | None = None, fuse: bool = True,
                 num_classes: int = 1000, quantized: bool | None = None):
        from repro import backend as be
        if quantized is None:
            quantized = be.get_quantize() == "int8"
        self.etg: ETG = build_etg(nl, fuse=fuse, quantized=quantized)
        self.impl = impl
        self.num_classes = num_classes
        self.quantized = quantized

    # -- parameter init -----------------------------------------------------
    def init(self, rng, dtype=jnp.float32):
        params = {}
        for t in self.etg.tasks:
            a = t.attrs
            if t.op == "conv":
                rng, k1 = jax.random.split(rng)
                fan_in = a["c"] * a["r"] * a["s"]
                w = jax.random.normal(k1, (a["r"], a["s"], a["c"], a["k"]),
                                      dtype) * math.sqrt(2.0 / fan_in)
                p = {"w": w}
                for kind, attrs in t.fused:
                    if kind == "bn":
                        p["scale"] = jnp.ones((a["k"],), dtype)
                        p["shift"] = jnp.zeros((a["k"],), dtype)
                        p["mean"] = jnp.zeros((a["k"],), dtype)   # running
                        p["var"] = jnp.ones((a["k"],), dtype)     # stats
                    elif kind == "bias":
                        p["bias"] = jnp.zeros((a["k"],), dtype)
                params[t.name] = p
            elif t.op == "bn":  # unfused BN
                params[t.name] = {"scale": jnp.ones((a["k"],), dtype),
                                  "shift": jnp.zeros((a["k"],), dtype),
                                  "mean": jnp.zeros((a["k"],), dtype),
                                  "var": jnp.ones((a["k"],), dtype)}
            elif t.op == "fc":
                rng, k1 = jax.random.split(rng)
                w = jax.random.normal(k1, (a["c"], a["k"]), dtype) \
                    * math.sqrt(1.0 / a["c"])
                params[t.name] = {"w": w, "b": jnp.zeros((a["k"],), dtype)}
        return params

    # -- depth-first chains (DESIGN.md §16) ---------------------------------
    def _task(self, name):
        by_name = getattr(self, "_task_by_name", None)
        if by_name is None:
            by_name = self._task_by_name = {t.name: t for t in self.etg.tasks}
        return by_name[name]

    def _plan_chain(self, ch, params, x):
        """Per-chain fuse/fallback decision at the chain's entry task.
        Returns the band plan, or None to run the chain layer-by-layer:
        quantized chains stay unfused (the q8 kernel has its own banding),
        as do chains whose combined band blows ``REPRO_VMEM_BUDGET`` or
        whose fused traffic would exceed the unfused sum."""
        from repro.tune.measure import chain_traffic
        if any("w_q" in params[name] for name in ch.names):
            return None
        h, w = int(x.shape[1]), int(x.shape[2])
        shapes = []
        for name in ch.names:
            a = self._task(name).attrs
            shapes.append(dict(h=h, w=w, c=a["c"], k=a["k"], r=a["r"],
                               s=a["s"], stride=a["stride"],
                               padding=a["padding"],
                               dtype_bytes=x.dtype.itemsize))
            h = (h + 2 * a["padding"] - a["r"]) // a["stride"] + 1
            w = (w + 2 * a["padding"] - a["s"]) // a["stride"] + 1
        t = chain_traffic(shapes, minibatch=int(x.shape[0]))
        return {"rb": t["rb"]} if t["fused"] else None

    def _chain_layer(self, name, params, get, folded):
        """Assemble one chain layer's kernel+epilogue dict — the same
        BN-fold / bias / residual / relu the unfused inference branch
        passes to ``conv2d_fwd``, so the fused replay is bit-identical."""
        t = self._task(name)
        p = params[name]
        a = t.attrs
        layer = dict(w=p["w"], stride=a["stride"], padding=a["padding"])
        for kind, attrs in t.fused:
            if kind == "bn":
                layer["scale"], layer["shift"] = folded(p)
            elif kind == "bias":
                layer["bias"] = p["bias"]
            elif kind == "relu":
                layer["relu"] = True
            elif kind == "add":
                layer["residual"] = get(attrs["residual"])
        return layer

    # -- forward ------------------------------------------------------------
    def forward(self, params, x, *, train: bool = True,
                collect_stats: bool = False, tap=None):
        """Inference folds the *running* BN statistics into the conv
        epilogue (scale' = g/sqrt(var+eps), shift' = b - g*mean/sqrt(var+eps))
        — the paper's §II-G fused-BN; training uses batch statistics and,
        with ``collect_stats``, also returns them for the running update.

        ``tap(name, inp)`` is called with every conv task's input tensor —
        the calibration hook (``core.quantize.calibrate_network``); it has
        side effects, so run tapped forwards eagerly, not under jit."""
        tensors = {"input": x}
        stats = {}

        def get(name):
            return tensors[name]

        def folded(p):
            inv = jax.lax.rsqrt(p["var"] + 1e-5)
            return p["scale"] * inv, p["shift"] - p["scale"] * p["mean"] * inv

        # depth-first chain fusion (DESIGN.md §16): inference-only, behind
        # the REPRO_CHAIN_FUSION knob; calibration taps need every per-layer
        # input, so a tapped forward always runs layer-by-layer
        from repro import backend as be
        chain_of = {}
        if (not train and tap is None and self.etg.chains
                and be.get_chain_fusion() == "on"):
            for ch in self.etg.chains:
                for pos, name in enumerate(ch.names):
                    chain_of[name] = (ch, pos)
        chain_plans: dict = {}

        for t in self.etg.tasks:
            a = t.attrs
            if t.op == "input":
                continue
            elif t.op == "conv" and t.name in chain_of:
                ch, pos = chain_of[t.name]
                if pos == 0:
                    # decide once per chain, at its entry (the input tensor's
                    # spatial shape is known here): fuse iff the combined
                    # band fits VMEM and fusion is profitable
                    chain_plans[ch.names] = self._plan_chain(
                        ch, params, get(t.inputs[0]))
                plan = chain_plans[ch.names]
                if plan is None:
                    pass                    # fallback: run layer-by-layer
                elif pos < len(ch.names) - 1:
                    continue                # band stays live in the replay
                else:
                    out = conv2d_chain_fwd(
                        get(self._task(ch.names[0]).inputs[0]),
                        [self._chain_layer(n2, params, get, folded)
                         for n2 in ch.names],
                        rb=plan["rb"], impl=self.impl)
                    tensors[t.name] = out
                    if "output_name" in a:
                        tensors[a["output_name"]] = out
                    continue
            if t.op == "conv":
                inp = get(t.inputs[0])
                if tap is not None:
                    tap(t.name, inp)
                p = params[t.name]
                kw = dict(stride=a["stride"], padding=a["padding"])
                scale = shift = bias = residual = None
                relu = False
                for kind, attrs in t.fused:
                    if kind == "bn":
                        scale, shift = p["scale"], p["shift"]
                    elif kind == "bias":
                        bias = p["bias"]
                    elif kind == "relu":
                        relu = True
                    elif kind == "add":
                        residual = get(attrs["residual"])
                if train:
                    if "w_q" in p:
                        raise ValueError(
                            f"conv {t.name} holds quantized weights (w_q); "
                            f"the q8 path is inference-only — train with "
                            f"the f32 params tree")
                    # training path: paper bwd pipeline via custom VJP;
                    # normalization handled outside the kernel (batch stats)
                    y = conv2d_train(inp, p["w"], a["stride"], a["padding"],
                                     self.impl)
                    if scale is not None:
                        mu = y.mean(axis=(0, 1, 2))
                        var = y.var(axis=(0, 1, 2))
                        stats[t.name] = (mu, var)
                        y = (y - mu) * jax.lax.rsqrt(var + 1e-5)
                        y = y * scale + shift
                    if bias is not None:
                        y = y + bias
                    if residual is not None:
                        y = y + residual
                    if relu:
                        y = jnp.maximum(y, 0)
                else:
                    # inference: everything fused into the kernel epilogue,
                    # BN folded from running stats
                    if scale is not None:
                        scale, shift = folded(p)
                    if a.get("kernel_kind") == "q8" and "w_q" in p:
                        # §II-K quantized path: int8 kernel, f32 epilogue.
                        # A q8-marked task with f32 params (no w_q) falls
                        # through to the f32 kernel — the calibration pass.
                        y = conv2d_q8_fwd(inp, p["w_q"],
                                          x_scale=p["x_scale"],
                                          w_scale=p["w_scale"], bias=bias,
                                          scale=scale, shift=shift,
                                          residual=residual, relu=relu,
                                          impl=self.impl, **kw)
                    else:
                        y = conv2d_fwd(inp, p["w"], bias=bias, scale=scale,
                                       shift=shift, residual=residual,
                                       relu=relu, impl=self.impl, **kw)
                out = y
            elif t.op == "bn":
                y = get(t.inputs[0])
                p = params[t.name]
                if train:
                    mu = y.mean(axis=(0, 1, 2))
                    var = y.var(axis=(0, 1, 2))
                    stats[t.name] = (mu, var)
                else:
                    mu, var = p["mean"], p["var"]
                out = (y - mu) * jax.lax.rsqrt(var + 1e-5) * p["scale"] \
                    + p["shift"]
            elif t.op == "relu":
                out = jnp.maximum(get(t.inputs[0]), 0)
            elif t.op == "add":
                out = get(t.inputs[0]) + get(t.inputs[1])
            elif t.op == "split":
                out = get(t.inputs[0])
            elif t.op == "concat":
                out = jnp.concatenate([get(i) for i in t.inputs], axis=-1)
            elif t.op == "maxpool":
                out = _maxpool(get(t.inputs[0]), a["window"], a["stride"],
                               a["padding"])
            elif t.op == "avgpool":
                out = get(t.inputs[0]).mean(axis=(1, 2))
            elif t.op == "fc":
                p = params[t.name]
                out = get(t.inputs[0]) @ p["w"] + p["b"]
            else:
                raise ValueError(f"unknown op {t.op}")
            tensors[t.name] = out
            if "output_name" in a:
                tensors[a["output_name"]] = out
        result = tensors[self.etg.tasks[-1].name]
        if collect_stats:
            return result, stats
        return result

    # -- inference serving entry ---------------------------------------------
    def infer(self, params, x):
        """Inference forward: BN folded from running stats, fused epilogues."""
        return self.forward(params, x, train=False)

    def make_infer(self, *, mesh=None, axis: str = "data",
                   donate_input: bool = True):
        """Jit'd inference entry point for the serving path.

        With ``mesh``, the batch is data-parallel sharded over ``axis`` via
        ``shard_map`` (params replicated); the caller guarantees the batch
        divides the axis size (``graph/serving.py`` buckets do).  The image
        buffer is donated — serving re-pads a fresh batch every step, so the
        executor may reuse its memory for activations.
        """
        fwd = self.infer
        if mesh is not None:
            P = jax.sharding.PartitionSpec
            fwd = _shard_map()(fwd, mesh=mesh, in_specs=(P(), P(axis)),
                               out_specs=P(axis), check_rep=False)
        return jax.jit(fwd, donate_argnums=(1,) if donate_input else ())

    # -- loss / steps ---------------------------------------------------------
    def loss(self, params, batch, *, train=True, collect_stats=False):
        out = self.forward(params, batch["image"], train=train,
                           collect_stats=collect_stats)
        logits, stats = out if collect_stats else (out, None)
        labels = jax.nn.one_hot(batch["label"], logits.shape[-1])
        l = -jnp.mean(jnp.sum(labels * jax.nn.log_softmax(logits), -1))
        if collect_stats:
            return l, stats
        return l

    def sgd_train_step(self, params, batch, lr=0.1, *, bn_momentum=0.9):
        (loss, stats), grads = jax.value_and_grad(
            self.loss, has_aux=True)(params, batch, collect_stats=True)
        new = jax.tree.map(lambda p, g: p - lr * g, params, grads)
        # running BN statistics (non-gradient state)
        apply_bn_updates(new, stats, bn_momentum)
        return new, loss
