"""CNN inference serving machinery over the GxM executor (DESIGN.md §8).

The paper's second half integrates the JIT'd conv kernels into the GxM
graph flow and reports *image throughput*; this module is the deployment
side of that story:

* **Bucketed batching** — requests are padded to a small fixed set of
  batch-size buckets so every bucket hits exactly one jitted, autotune-
  warmed executor.  The bucket set is finite, so the set of (shape ×
  blocking) specializations — and of autotuner cache keys — is finite too.
* **Data-parallel sharding** — each bucket's batch is split across the
  local devices of a ``launch.mesh.make_host_mesh`` mesh via ``shard_map``;
  inference has no cross-batch collectives, so scaling is embarrassing.
* **Warmup** — ``CnnInferenceEngine.warmup`` walks every conv signature of
  the network (shape-inferred from the ETG) and pre-populates both the
  per-shape blocking cache (``repro.tune``) and the jit executable cache
  (AOT lower+compile per bucket), so the request path never tunes,
  traces, or compiles.

``launch/serve_cnn.py`` builds the request queue / scheduler on top.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import backend as be
from repro import tune
from repro.core.blocking import VMEM_BUDGET, conv_blocking
from repro.core.conv import lane_ok


def _out(dim: int, f: int, stride: int, padding: int) -> int:
    return (dim + 2 * padding - f) // stride + 1


def conv_shapes(etg, image_hw) -> list[dict]:
    """Per-conv-task full tuning shapes, inferred by walking the ETG.

    ``etg.kernel_cache`` dedups convs by (c,k,r,s,stride,padding,fused) but
    carries no spatial extent; the tuner key needs (h, w) too, so we run the
    ETG symbolically from the network input size.  Returns one dict per conv
    task (h/w are the conv's *input* plane) with its dedup ``kernel_id``.
    """
    h0, w0 = image_hw
    hw: dict[str, tuple | None] = {"input": (h0, w0)}
    shapes = []
    for t in etg.tasks:
        a = t.attrs
        if t.op == "input":
            hw[t.name] = (h0, w0)
            continue
        src = hw.get(t.inputs[0]) if t.inputs else None
        if t.op == "conv":
            h, w = src
            shapes.append(dict(name=t.name, h=h, w=w, c=a["c"], k=a["k"],
                               r=a["r"], s=a["s"], stride=a["stride"],
                               padding=a["padding"],
                               kernel_id=a.get("kernel_id")))
            res = (_out(h, a["r"], a["stride"], a["padding"]),
                   _out(w, a["s"], a["stride"], a["padding"]))
        elif t.op == "maxpool":
            h, w = src
            res = (_out(h, a["window"], a["stride"], a["padding"]),
                   _out(w, a["window"], a["stride"], a["padding"]))
        elif t.op in ("avgpool", "fc"):
            res = None                      # rank-2 from here on
        else:                               # bn / relu / add / split / concat
            res = src
        hw[t.name] = res
        if "output_name" in a:
            hw[a["output_name"]] = res
    return shapes


def distinct_conv_signatures(shapes: list[dict]) -> list[dict]:
    """Dedup conv shapes down to the tuner key coordinates."""
    seen, out = set(), []
    for sh in shapes:
        sig = (sh["h"], sh["w"], sh["c"], sh["k"], sh["r"], sh["s"],
               sh["stride"], sh["padding"])
        if sig in seen:
            continue
        seen.add(sig)
        out.append({f: sh[f] for f in ("h", "w", "c", "k", "r", "s",
                                       "stride", "padding")})
    return out


def cnn_model_flops(etg, image_hw, batch: int) -> float:
    """Useful model FLOPs of one inference forward: 2·P·Q·K·C·R·S per conv
    plus 2·C·K for the classifier — the numerator of roofline efficiency."""
    total = 0.0
    for sh in conv_shapes(etg, image_hw):
        p = _out(sh["h"], sh["r"], sh["stride"], sh["padding"])
        q = _out(sh["w"], sh["s"], sh["stride"], sh["padding"])
        total += 2.0 * p * q * sh["k"] * sh["c"] * sh["r"] * sh["s"]
    for t in etg.tasks:
        if t.op == "fc":
            total += 2.0 * t.attrs["c"] * t.attrs["k"]
    return total * batch


# -- bucketing ---------------------------------------------------------------

def round_buckets(buckets, num_shards: int) -> tuple[int, ...]:
    """Round every rung up to the next multiple of ``num_shards`` (dedup'd,
    sorted) so a padded batch always splits evenly across the data-parallel
    mesh — a caller-supplied ladder like (2, 6) on 4 shards becomes (4, 8)
    instead of tripping a shard-split assert deep in shard_map."""
    assert num_shards >= 1
    rounded = {-(-int(b) // num_shards) * num_shards for b in buckets}
    assert all(b >= 1 for b in rounded), buckets
    return tuple(sorted(rounded))


def make_buckets(max_batch: int, *, num_shards: int = 1) -> tuple[int, ...]:
    """Geometric bucket ladder; every bucket is a multiple of ``num_shards``
    so a padded batch always splits evenly across the data-parallel mesh."""
    assert max_batch >= 1 and num_shards >= 1
    b, out = num_shards, []
    while b < max_batch:
        out.append(b)
        b *= 2
    out.append(b)
    return round_buckets(out, num_shards)


def pick_bucket(n: int, buckets) -> int:
    """Smallest bucket that fits ``n`` requests (minimal padding).  A batch
    beyond the largest bucket has no executor to run on — silently serving
    it at ``max(buckets)`` would truncate lanes, so it raises; callers
    chunk first (``ImageServer.step`` takes at most ``max(buckets)``)."""
    for b in sorted(buckets):
        if b >= n:
            return b
    raise ValueError(f"batch {n} exceeds largest bucket {max(buckets)}; "
                     f"chunk it first")


class CnnInferenceEngine:
    """Bucketed, sharded, warmup-able inference front-end for one GxM model.

    ``infer(images)`` pads the batch to the minimal bucket, runs the
    AOT-compiled executor for that bucket (data-parallel over ``mesh``'s
    "data" axis when given), and returns only the real lanes' logits —
    padded lanes are all-zero images whose outputs are sliced away and,
    because inference has no cross-batch ops (BN folded from running
    stats), cannot perturb real lanes.
    """

    def __init__(self, gxm, params, *, image_hw=(224, 224), mesh=None,
                 max_batch: int = 32, buckets=None, dtype=jnp.float32,
                 donate_input: bool | None = None,
                 autotune: str | None = "cache",
                 quantized: bool | None = None):
        self.gxm = gxm
        self.params = params
        self.image_hw = tuple(image_hw)
        self.mesh = mesh
        self.dtype = dtype
        # §II-K int8 serving (DESIGN.md §13): None defers to how the GxM was
        # built (its own default is the REPRO_QUANTIZE knob); an explicit
        # True on an f32 GxM re-marks its ETG in place.  ``params`` stays
        # the f32 tree — calibration runs on it; the quantized tree the
        # request path uses is derived at warmup (``calibrate``).
        if quantized is None:
            quantized = bool(getattr(gxm, "quantized", False))
        elif quantized and not getattr(gxm, "quantized", False):
            from repro.graph.etg import quantize_etg
            quantize_etg(gxm.etg)
            gxm.quantized = True
        self.quantized = quantized
        self.qparams = None
        self.act_scales = None
        # mode scoped around every trace/compile so the kernels' blocking
        # lookups see the entries warmup persisted ("cache": warmed winner
        # or analytic fallback — never a behavioral cliff); None defers to
        # the global REPRO_AUTOTUNE knob
        self.autotune = autotune
        from repro.launch.mesh import data_axis_size
        self.num_shards = data_axis_size(mesh) if mesh is not None else 1
        self.buckets = round_buckets(buckets, self.num_shards) if buckets \
            else make_buckets(max_batch, num_shards=self.num_shards)
        if donate_input is None:
            # donation is a no-op (plus a warning) on CPU backends
            donate_input = jax.default_backend() not in ("cpu",)
        self._fn = gxm.make_infer(mesh=mesh, donate_input=donate_input)
        self._compiled: dict[int, object] = {}

    # -- shape / signature plumbing -----------------------------------------
    def local_batch(self, bucket: int) -> int:
        """Per-device batch a bucket lowers to inside shard_map — the
        ``minibatch`` coordinate of the autotuner cache key."""
        return bucket // self.num_shards

    def conv_shapes(self) -> list[dict]:
        return conv_shapes(self.gxm.etg, self.image_hw)

    @property
    def _run_params(self):
        """The params tree the request path runs: the quantized tree once
        calibration produced one, the f32 tree otherwise."""
        if self.quantized and self.qparams is not None:
            return self.qparams
        return self.params

    # -- calibration ---------------------------------------------------------
    def calibrate(self, images=None, *, batches: int = 2, batch: int = 4,
                  seed: int = 0) -> dict:
        """Calibrate per-conv activation scales and build the quantized
        params tree (``core.quantize``).  ``images`` is an iterable of
        (n, H, W, 3) warmup batches; by default ``batches`` synthetic
        batches are drawn from a fixed-seed generator, so calibration is
        deterministic for a given seed.  Returns the scale dict."""
        assert self.quantized, "calibrate() on a non-quantized engine"
        from repro.core.quantize import calibrate_network, quantize_gxm_params
        if images is None:
            rng = np.random.default_rng(seed)
            images = [rng.standard_normal(
                (batch, *self.image_hw, 3)).astype(self.dtype)
                for _ in range(batches)]
        self.act_scales = calibrate_network(self.gxm, self.params, images)
        self.qparams = quantize_gxm_params(self.gxm.etg, self.params,
                                           self.act_scales)
        return self.act_scales

    # -- warmup --------------------------------------------------------------
    def warmup(self, *, autotune: str = "tune", cache=None,
               compile_buckets: bool = True) -> dict:
        """Pre-populate every cache a request would otherwise fall into:

        1. the persistent per-shape blocking cache (``repro.tune``) for every
           distinct conv signature × per-device bucket batch, and
        2. the compiled-executable cache: one AOT lower+compile per bucket
           (which also exercises the ETG's dedup'd ``kernel_cache`` ids),
           traced under this engine's ``autotune`` scope so the blocking
           lookups consult what step 1 just persisted.

        ``cache`` overrides the tuning *store* (tests / inspection); the
        compile-time lookups always read the process default cache
        (``REPRO_TUNE_CACHE``), so pass ``cache`` only together with that
        env override if the compiled blockings must match.  Returns a
        report dict (entry counts, compile seconds per bucket).
        """
        backend = be.resolve(self.gxm.impl)
        sigs = distinct_conv_signatures(self.conv_shapes())
        minibatches = sorted({self.local_batch(b) for b in self.buckets})
        if self.quantized and self.qparams is None:
            self.calibrate()          # deterministic synthetic batches
        # the quantized engine tunes/compiles the "q8" kind at 1 byte/elem;
        # its 4x-smaller bands admit taller rb_p under the same budget
        kind = "q8" if self.quantized else "fwd"
        db = 1 if self.quantized else 4
        report = {
            "conv_signatures": len(sigs),
            "pallas_path_signatures":
                sum(1 for s in sigs if lane_ok(s["c"], s["k"])),
            "kernel_cache_entries": len(self.gxm.etg.kernel_cache),
            "buckets": list(self.buckets),
            "tune_entries": 0,
            "compile_s": {},
            "conv_tiling": be.get_conv_tiling(),
            "vmem_budget": VMEM_BUDGET,
            "quantized": self.quantized,
        }
        if autotune != "off":
            entries = tune.warmup_convs(sigs, minibatches=minibatches,
                                        kinds=(kind,), mode=autotune,
                                        backend=backend, cache=cache,
                                        dtype_bytes=db)
            report["tune_entries"] = sum(1 for e in entries if e["cached"])
        # modeled per-grid-step VMEM high-water mark across the pallas-path
        # signatures (tiled: a row band — independent of image_hw, so large
        # serving buckets cannot blow the budget the way whole planes did)
        ws = [conv_blocking(**sg, dtype_bytes=db, backend=backend,
                            autotune="cache" if autotune != "off" else "off",
                            kind=kind, minibatch=max(minibatches))
              .vmem_bytes
              for sg in sigs if lane_ok(sg["c"], sg["k"])]
        report["max_conv_vmem_bytes"] = max(ws, default=0)
        if compile_buckets:
            for bucket in self.buckets:
                t0 = time.perf_counter()
                self._ensure_compiled(bucket)
                report["compile_s"][bucket] = round(
                    time.perf_counter() - t0, 3)
        return report

    def _autotune_scope(self):
        if self.autotune is None:
            import contextlib
            return contextlib.nullcontext()
        return be.use_autotune(self.autotune)

    def _ensure_compiled(self, bucket: int):
        if bucket not in self._compiled:
            x = jax.ShapeDtypeStruct(
                (bucket, *self.image_hw, 3), self.dtype)
            with self._autotune_scope():
                self._compiled[bucket] = \
                    self._fn.lower(self._run_params, x).compile()
        return self._compiled[bucket]

    def aot_executable(self, bucket: int):
        """Compiled executable for one bucket (rooflines read its HLO)."""
        assert bucket in self.buckets, (bucket, self.buckets)
        return self._ensure_compiled(bucket)

    # -- the request path ----------------------------------------------------
    def infer(self, images):
        """Logits for ``images`` (n, H, W, 3); pads n up to the minimal
        bucket, runs that bucket's warmed executable, slices padding away."""
        x = np.asarray(images, dtype=self.dtype)
        n = x.shape[0]
        if n > max(self.buckets):
            raise ValueError(f"batch {n} exceeds largest bucket "
                             f"{max(self.buckets)}; chunk it first")
        bucket = pick_bucket(n, self.buckets)
        if n < bucket:
            x = np.concatenate(
                [x, np.zeros((bucket - n, *x.shape[1:]), x.dtype)])
        fn = self._compiled.get(bucket)
        if fn is not None:
            return fn(self._run_params, jnp.asarray(x))[:n]
        with self._autotune_scope():      # unwarmed bucket: trace here
            return self._fn(self._run_params, jnp.asarray(x))[:n]
