"""Execution Task Graph construction — the GxM flow of paper Fig. 3.

Parser -> NL  (topology.py builders)
NL Extender   -> adds Split nodes for multi-consumer tensors (tensor
                 distribution fwd / gradient reduction bwd)
Fusion pass   -> conv-epilogue fusion (core.fusion)
Dedupe        -> structurally identical conv shapes share one "kernel
                 generator" entry (the paper's JIT cache)
ETG           -> topologically ordered task list the executor runs.
"""
from __future__ import annotations

import dataclasses

from repro.core.fusion import (Node, detect_chains, fuse_network,
                               fusion_stats)


@dataclasses.dataclass
class ETG:
    tasks: list            # topo-ordered Nodes
    kernel_cache: dict     # conv signature -> cache id (dedup'd JIT entries)
    stats: dict
    chains: list = dataclasses.field(default_factory=list)  # fusion.Chain


def extend_nl(nodes: list[Node]) -> list[Node]:
    """NL Extender: insert explicit Split nodes where a tensor feeds >1
    consumer (fwd: fan-out copy; bwd: gradient sum — autodiff handles the
    reduction, the node marks the communication point for the scheduler).

    Pure: consumer rewiring happens on copies, never on the caller's nodes,
    and the users index is built once up front instead of rescanning the
    whole list per node (the old O(n²) walk)."""
    nodes = [dataclasses.replace(n, inputs=list(n.inputs)) for n in nodes]
    users_of: dict[str, list[Node]] = {}
    for m in nodes:
        for i in set(m.inputs):
            users_of.setdefault(i, []).append(m)
    out = []
    for n in nodes:
        out.append(n)
        users = users_of.get(n.name, [])
        if len(users) > 1 and n.op not in ("input",):
            split = Node(f"{n.name}_split", "split", [n.name],
                         dict(fanout=len(users)))
            out.append(split)
            for u in users:
                u.inputs = [f"{n.name}_split" if i == n.name else i
                            for i in u.inputs]
    return out


def toposort(nodes: list[Node]) -> list[Node]:
    by_name = {n.name: n for n in nodes}
    alias = {}
    for n in nodes:
        if "output_name" in n.attrs:
            alias[n.attrs["output_name"]] = n.name
    resolved = lambda i: alias.get(i, i)
    done, order, visiting = set(), [], set()

    def visit(n):
        if n.name in done:
            return
        if n.name in visiting:
            raise ValueError(f"cycle at {n.name}")
        visiting.add(n.name)
        for i in n.inputs:
            i = resolved(i)
            if i in by_name:
                visit(by_name[i])
        visiting.discard(n.name)
        done.add(n.name)
        order.append(n)

    for n in nodes:
        visit(n)
    return order


def conv_signature(n: Node) -> tuple:
    a = n.attrs
    fused_kinds = tuple(k for k, _ in n.fused)
    # kernel_kind ("f32" | "q8") is part of the signature: the quantized
    # kernel is a different code generator than the f32 one
    return (a["c"], a["k"], a["r"], a["s"], a["stride"], a["padding"],
            fused_kinds, a.get("kernel_kind", "f32"))


def _assign_kernel_ids(tasks: list[Node]) -> dict[tuple, int]:
    # Dedupe: one JIT "code generator" entry per distinct conv signature —
    # the paper's answer to combinatorial kernel explosion.
    cache: dict[tuple, int] = {}
    for t in tasks:
        if t.op == "conv":
            sig = conv_signature(t)
            cache.setdefault(sig, len(cache))
            t.attrs["kernel_id"] = cache[sig]
    return cache


def quantize_etg(etg: ETG) -> ETG:
    """Mark every conv task for the §II-K int8 kernel path and rebuild the
    dedup cache (q8 signatures are distinct code-generator entries).  The
    executor dispatches a task to ``conv2d_q8`` when its params carry
    quantized leaves (``core.quantize.quantize_gxm_params``); a q8-marked
    ETG with f32 params still runs the f32 path — that is what calibration
    relies on."""
    for t in etg.tasks:
        if t.op == "conv":
            t.attrs["kernel_kind"] = "q8"
    etg.kernel_cache = _assign_kernel_ids(etg.tasks)
    return etg


def build_etg(nl: list[Node], *, fuse: bool = True,
              quantized: bool = False) -> ETG:
    enl = extend_nl([dataclasses.replace(n, inputs=list(n.inputs),
                                         attrs=dict(n.attrs),
                                         fused=list(n.fused))
                     for n in nl])
    fused = fuse_network(enl) if fuse else enl
    tasks = toposort(fused)
    if quantized:
        for t in tasks:
            if t.op == "conv":
                t.attrs["kernel_kind"] = "q8"
    cache = _assign_kernel_ids(tasks)
    # depth-first conv->conv chains (DESIGN.md §16): pure metadata — the
    # task list is unchanged; the executor decides per chain (and only with
    # the REPRO_CHAIN_FUSION knob on) whether to run it band-fused
    chains = detect_chains(tasks) if fuse else []
    by_name = {t.name: t for t in tasks}
    for ci, ch in enumerate(chains):
        for pos, name in enumerate(ch.names):
            by_name[name].attrs["chain_id"] = ci
            by_name[name].attrs["chain_pos"] = pos
    stats = fusion_stats(enl, fused)
    stats["chains"] = len(chains)
    stats["chained_convs"] = sum(len(c) for c in chains)
    return ETG(tasks=tasks, kernel_cache=cache, stats=stats, chains=chains)
