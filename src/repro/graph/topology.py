"""DNN topology descriptions (the GxM "Network List").

``resnet50()`` reproduces the paper's benchmark topology; its 20 distinct
convolution shapes (paper Table I) are exported as ``RESNET50_LAYERS`` and
drive the per-layer benchmarks.  ``inception_v3()`` is the paper's second
topology (branchy — exercises the Split-node path of the NL Extender).

A topology is a list of ``core.fusion.Node``; tensors are named by the node
that produces them.
"""
from __future__ import annotations

from repro.core.fusion import Node

# Paper Table I: (C, K, H, W, R, S, stride) per distinct ResNet-50 conv layer.
RESNET50_LAYERS = {
    1:  dict(c=3,    k=64,   h=224, w=224, r=7, s=7, stride=2),
    2:  dict(c=64,   k=256,  h=56,  w=56,  r=1, s=1, stride=1),
    3:  dict(c=64,   k=64,   h=56,  w=56,  r=1, s=1, stride=1),
    4:  dict(c=64,   k=64,   h=56,  w=56,  r=3, s=3, stride=1),
    5:  dict(c=256,  k=64,   h=56,  w=56,  r=1, s=1, stride=1),
    6:  dict(c=256,  k=512,  h=56,  w=56,  r=1, s=1, stride=2),
    7:  dict(c=256,  k=128,  h=56,  w=56,  r=1, s=1, stride=2),
    8:  dict(c=128,  k=128,  h=28,  w=28,  r=3, s=3, stride=1),
    9:  dict(c=128,  k=512,  h=28,  w=28,  r=1, s=1, stride=1),
    10: dict(c=512,  k=128,  h=28,  w=28,  r=1, s=1, stride=1),
    11: dict(c=512,  k=1024, h=28,  w=28,  r=1, s=1, stride=2),
    12: dict(c=512,  k=256,  h=28,  w=28,  r=1, s=1, stride=2),
    13: dict(c=256,  k=256,  h=14,  w=14,  r=3, s=3, stride=1),
    14: dict(c=256,  k=1024, h=14,  w=14,  r=1, s=1, stride=1),
    15: dict(c=1024, k=256,  h=14,  w=14,  r=1, s=1, stride=1),
    16: dict(c=1024, k=2048, h=14,  w=14,  r=1, s=1, stride=2),
    17: dict(c=1024, k=512,  h=14,  w=14,  r=1, s=1, stride=2),
    18: dict(c=512,  k=512,  h=7,   w=7,   r=3, s=3, stride=1),
    19: dict(c=512,  k=2048, h=7,   w=7,   r=1, s=1, stride=1),
    20: dict(c=2048, k=512,  h=7,   w=7,   r=1, s=1, stride=1),
}


def _conv(name, inp, c, k, r, stride, *, pad=None):
    pad = (r // 2) if pad is None else pad
    return Node(name, "conv", [inp],
                dict(c=c, k=k, r=r, s=r, stride=stride, padding=pad))


def _bn(name, inp, k):
    return Node(name, "bn", [inp], dict(k=k))


def _relu(name, inp):
    return Node(name, "relu", [inp], {})


def _bottleneck(nodes, prefix, inp, c_in, c_mid, c_out, stride):
    """ResNet-v1.5 bottleneck: 1x1 -> 3x3(stride) -> 1x1 + projection."""
    n = nodes.append
    n(_conv(f"{prefix}_c1", inp, c_in, c_mid, 1, 1))
    n(_bn(f"{prefix}_b1", f"{prefix}_c1", c_mid))
    n(_relu(f"{prefix}_r1", f"{prefix}_b1"))
    n(_conv(f"{prefix}_c2", f"{prefix}_r1", c_mid, c_mid, 3, stride))
    n(_bn(f"{prefix}_b2", f"{prefix}_c2", c_mid))
    n(_relu(f"{prefix}_r2", f"{prefix}_b2"))
    n(_conv(f"{prefix}_c3", f"{prefix}_r2", c_mid, c_out, 1, 1))
    n(_bn(f"{prefix}_b3", f"{prefix}_c3", c_out))
    skip = inp
    if stride != 1 or c_in != c_out:
        n(_conv(f"{prefix}_proj", inp, c_in, c_out, 1, stride))
        n(_bn(f"{prefix}_projbn", f"{prefix}_proj", c_out))
        skip = f"{prefix}_projbn"
    n(Node(f"{prefix}_add", "add", [f"{prefix}_b3", skip], {}))
    n(_relu(f"{prefix}_out", f"{prefix}_add"))
    return f"{prefix}_out"


def resnet50(num_classes: int = 1000, *, stages=(3, 4, 6, 3)) -> list[Node]:
    nodes: list[Node] = [Node("input", "input", [], dict(c=3))]
    nodes.append(_conv("conv1", "input", 3, 64, 7, 2, pad=3))
    nodes.append(_bn("bn1", "conv1", 64))
    nodes.append(_relu("relu1", "bn1"))
    nodes.append(Node("pool1", "maxpool", ["relu1"],
                      dict(window=3, stride=2, padding=1)))
    x = "pool1"
    c_in = 64
    for si, (blocks, c_mid) in enumerate(zip(stages, (64, 128, 256, 512))):
        c_out = c_mid * 4
        for b in range(blocks):
            stride = 2 if (b == 0 and si > 0) else 1
            x = _bottleneck(nodes, f"s{si}b{b}", x, c_in, c_mid, c_out, stride)
            c_in = c_out
    nodes.append(Node("gap", "avgpool", [x], dict(global_pool=True)))
    nodes.append(Node("fc", "fc", ["gap"], dict(c=c_in, k=num_classes)))
    return nodes


def _inception_block(nodes, prefix, inp, c_in, spec):
    """One Inception-v3-style mixed block; spec maps branch -> channel list."""
    outs = []
    for bname, convs in spec.items():
        x = inp
        c = c_in
        for i, (k, r, stride) in enumerate(convs):
            nm = f"{prefix}_{bname}{i}"
            nodes.append(_conv(nm, x, c, k, r, stride))
            nodes.append(_bn(nm + "bn", nm, k))
            nodes.append(_relu(nm + "rl", nm + "bn"))
            x, c = nm + "rl", k
        outs.append((x, c))
    cname = f"{prefix}_cat"
    nodes.append(Node(cname, "concat", [o for o, _ in outs], {}))
    return cname, sum(c for _, c in outs)


def inception_v3(num_classes: int = 1000) -> list[Node]:
    """Inception-v3 style topology (stem + mixed blocks).  Branch structure
    matches the paper's benchmark usage (multi-consumer tensors -> Split
    nodes in the NL Extender)."""
    nodes: list[Node] = [Node("input", "input", [], dict(c=3))]
    stem = [("stem1", 3, 32, 3, 2), ("stem2", 32, 32, 3, 1),
            ("stem3", 32, 64, 3, 1)]
    x = "input"
    for nm, c, k, r, st in stem:
        nodes.append(_conv(nm, x, c, k, r, st))
        nodes.append(_bn(nm + "bn", nm, k))
        nodes.append(_relu(nm + "rl", nm + "bn"))
        x = nm + "rl"
    nodes.append(Node("pool1", "maxpool", [x],
                      dict(window=3, stride=2, padding=1)))
    x, c = "pool1", 64
    mixed = {
        "b1x1": [(64, 1, 1)],
        "b5x5": [(48, 1, 1), (64, 5, 1)],
        "b3x3": [(64, 1, 1), (96, 3, 1), (96, 3, 1)],
        "bproj": [(32, 1, 1)],
    }
    for i in range(3):
        x, c = _inception_block(nodes, f"mix{i}", x, c, mixed)
    nodes.append(Node("gap", "avgpool", [x], dict(global_pool=True)))
    nodes.append(Node("fc", "fc", ["gap"], dict(c=c, k=num_classes)))
    return nodes
