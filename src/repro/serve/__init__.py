"""Resilient serving fleet over the GxM inference engine (DESIGN.md §15):
``FleetRouter`` + ``Replica`` (deadlines, retries, hedging, eviction +
warm-cache respawn, load shed, degrade-to-int8) and the seeded
``ServeChaosEngine`` fault harness that replays against it."""
from repro.serve.chaos import (FlakyInfer, ReplicaDeath, RequestBurst,
                               ServeChaosEngine, ServeChaosSchedule,
                               SlowReplica)
from repro.serve.fleet import (FleetRouter, Replica, Request,
                               poisson_arrivals)

__all__ = [
    "FlakyInfer", "FleetRouter", "Replica", "ReplicaDeath", "Request",
    "RequestBurst", "ServeChaosEngine", "ServeChaosSchedule", "SlowReplica",
    "poisson_arrivals",
]
