"""Seeded fault injection for the serving fleet (DESIGN.md §15) — the
serving-side sibling of ``train/chaos.py``.

The training harness injects faults per *step*; a serving fleet lives in
continuous time, so every event here fires at a simulated-time instant
``t`` and the ``ServeChaosEngine`` is queried by the ``FleetRouter``'s
discrete-event loop:

  ReplicaDeath   the replica stops answering health pings and never
                 completes in-flight work — detection is the router's
                 health sweep, recovery is eviction + respawn with warm
                 caches re-seeded from the survivors
  SlowReplica    service times multiply by ``factor`` until ``until`` —
                 the straggler hedged requests route around
  FlakyInfer     the replica's next ``times`` dispatches fail after
                 ``cost_s`` of burned service time (transient OOM / flaky
                 accelerator) — the bounded-backoff retry path
  RequestBurst   ``n`` extra arrivals land at once at ``t`` — the
                 admission-control / load-shed / degrade-to-int8 path

``ServeChaosSchedule.generate(seed, ...)`` draws a reproducible schedule
from ``core.simtime.seeded_rng``; the ``REPRO_SERVE_CHAOS`` knob feeds it
from ``launch/serve_cnn.py``.  Replica 0 is never killed (something must
survive to re-seed caches from), and at most ``n_replicas - 1`` deaths are
drawn so the fleet never empties.
"""
from __future__ import annotations

import dataclasses

from repro.core.simtime import seeded_rng


# -- fault vocabulary ---------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ReplicaDeath:
    t: float
    replica: str


@dataclasses.dataclass(frozen=True)
class SlowReplica:
    t: float
    replica: str
    factor: float = 3.0
    until: float | None = None      # recovers at `until` (None = forever)


@dataclasses.dataclass(frozen=True)
class FlakyInfer:
    t: float
    replica: str
    times: int = 1
    cost_s: float = 0.25            # service time burned before the failure


@dataclasses.dataclass(frozen=True)
class RequestBurst:
    t: float
    n: int


_KINDS = ("death", "slow", "flaky", "burst")


@dataclasses.dataclass(frozen=True)
class ServeChaosSchedule:
    events: tuple
    seed: int | None = None

    @staticmethod
    def generate(seed: int, *, horizon_s: float, replicas,
                 kinds=_KINDS, intensity: float = 1.0
                 ) -> "ServeChaosSchedule":
        """~1 event per 20 simulated seconds at unit intensity, bit
        reproducible for a given seed.  Replica 0 is immortal and the
        fleet never empties."""
        replicas = list(replicas)
        rng = seeded_rng(0x5E4E, seed)
        n = max(1, round(horizon_s / 20.0 * intensity))
        mortal = replicas[1:]
        events = []
        for _ in range(n):
            kind = kinds[int(rng.integers(len(kinds)))]
            t = round(float(rng.uniform(0.0, horizon_s)), 3)
            if kind == "death" and mortal:
                events.append(ReplicaDeath(t, mortal.pop(
                    int(rng.integers(len(mortal))))))
            elif kind == "slow" and len(replicas) > 1:
                events.append(SlowReplica(
                    t, replicas[int(rng.integers(1, len(replicas)))],
                    factor=float(2.0 + 2.0 * rng.random()),
                    until=t + float(rng.uniform(5.0, 20.0))))
            elif kind == "flaky":
                events.append(FlakyInfer(
                    t, replicas[int(rng.integers(len(replicas)))],
                    times=int(rng.integers(1, 3))))
            else:
                events.append(RequestBurst(t, n=int(rng.integers(4, 17))))
        return ServeChaosSchedule(
            tuple(sorted(events, key=lambda e: (e.t, repr(e)))), seed=seed)


class ServeChaosEngine:
    """Answers the router's fault queries from a ``ServeChaosSchedule``.

    Stateless in simulated time except for the flaky-infer tokens (each
    ``FlakyInfer`` arms ``times`` one-shot failures once the clock passes
    its ``t``), so a replayed schedule produces identical answers.
    """

    def __init__(self, schedule: ServeChaosSchedule):
        self.schedule = schedule
        self.injected: list[dict] = []
        self._armed_flaky: set[int] = set()
        self._flaky_tokens: dict[str, int] = {}

    # -- router-facing queries ------------------------------------------------

    def is_dead(self, replica: str, t: float, *, born: float = 0.0) -> bool:
        """A death event kills one *incarnation*: a replica respawned at
        ``born`` after the death is a fresh process and starts healthy."""
        return any(isinstance(ev, ReplicaDeath) and ev.replica == replica
                   and born <= ev.t <= t for ev in self.schedule.events)

    def death_times(self) -> dict[str, float]:
        return {ev.replica: ev.t for ev in self.schedule.events
                if isinstance(ev, ReplicaDeath)}

    def slow_factor(self, replica: str, t: float) -> float:
        f = 1.0
        for ev in self.schedule.events:
            if isinstance(ev, SlowReplica) and ev.replica == replica \
                    and ev.t <= t and (ev.until is None or t < ev.until):
                f = max(f, ev.factor)
        return f

    def take_infer_fault(self, replica: str, t: float) -> FlakyInfer | None:
        """Consume one armed flaky-infer token for ``replica`` (None when
        the replica is currently reliable)."""
        for i, ev in enumerate(self.schedule.events):
            if isinstance(ev, FlakyInfer) and ev.t <= t \
                    and i not in self._armed_flaky:
                self._armed_flaky.add(i)
                self._flaky_tokens[ev.replica] = \
                    self._flaky_tokens.get(ev.replica, 0) + ev.times
        if self._flaky_tokens.get(replica, 0) > 0:
            self._flaky_tokens[replica] -= 1
            self.injected.append({"kind": "infer_fault", "t": t,
                                  "replica": replica})
            return next(ev for ev in self.schedule.events
                        if isinstance(ev, FlakyInfer) and ev.replica ==
                        replica and ev.t <= t)
        return None

    def bursts(self) -> list[RequestBurst]:
        return [ev for ev in self.schedule.events
                if isinstance(ev, RequestBurst)]
