"""Resilient multi-replica serving fleet (DESIGN.md §15).

``FleetRouter`` fronts N replicas behind one bounded request queue and a
discrete-event loop over ``core.simtime.SimClock``, so every latency,
detection, and recovery number is a pure function of the seeded arrival +
fault schedule.  Robustness is the headline:

  * **deadlines + bounded-backoff retry** — a failed dispatch (flaky
    accelerator) re-queues on a *different* replica after an exponential
    backoff, bounded by ``max_retries``;
  * **hedged requests** — a dispatch that outlives ``hedge_after_s``
    (straggler replica) gets a clone on an idle replica; the first
    completion wins and the loser is cancelled — the p99-tail policy;
  * **health-checked eviction + respawn** — replicas are pinged on a
    cadence; one silent past ``health_timeout_s`` is evicted (its in-flight
    requests reassigned) and respawned after ``respawn_after_s`` with warm
    blocking caches re-seeded from the surviving replicas'
    ``TuneCache.export_entries`` — a cold respawn would pay
    ``cold_service_s`` on its first dispatch, a re-seeded one does not;
  * **admission control / load shedding** — arrivals beyond ``queue_bound``
    are rejected outright; arrivals beyond the SLO-feasible queue depth
    (the depth that can still drain within the deadline at the live fleet's
    service rate) are *degraded* instead of rejected;
  * **graceful degradation** — degraded requests run the int8 quantized
    twin (PR 7): ``q8_service_factor`` cheaper in the model, the
    ``quantized=True`` twin engine's ``infer`` on the real path.  A request
    whose f32 dispatch would bust its deadline is flipped to the degrade
    path at dispatch time, so every *admitted* request either completes
    within its deadline or was handed to the int8 path — the §15 SLO
    invariant (``slo_handled_rate``).

Replicas are real ``CnnInferenceEngine`` pairs (f32 + quantized twin) in
tests and the ``launch/serve_cnn.py --fleet`` path, and service-time models
in ``benchmarks/serve_fleet_bench.py`` — the router cannot tell the
difference: it charges modeled seconds either way and calls ``infer`` only
when a request actually carries an image.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools

import numpy as np

from repro.core.simtime import SimClock


@dataclasses.dataclass
class Request:
    """One inference request and its lifecycle under the router."""
    rid: int
    t_arrival: float
    deadline_s: float
    image: object = None            # None: modeled request (bench)
    status: str = "queued"          # queued | running | done | shed | failed
    degraded: bool = False          # handed to the int8 twin
    hedged: bool = False
    retries: int = 0
    t_done: float | None = None
    result: object = None           # logits row on the real-engine path
    avoid: set = dataclasses.field(default_factory=set)
    dispatches: list = dataclasses.field(default_factory=list)

    @property
    def latency_s(self) -> float | None:
        return None if self.t_done is None else self.t_done - self.t_arrival

    @property
    def in_deadline(self) -> bool:
        return self.t_done is not None and \
            self.latency_s <= self.deadline_s + 1e-9

    @property
    def slo_handled(self) -> bool:
        """The §15 invariant: completed within deadline, or handed to the
        degrade path (which always admits rather than rejects)."""
        return self.status == "done" and (self.in_deadline or self.degraded)


class Replica:
    """One serving replica: an (optional) real engine pair plus the
    service-time model the router charges.

    ``infer_fn``/``q8_infer_fn`` take an (n, H, W, 3) batch and return
    logits — on the real path these are ``CnnInferenceEngine.infer`` bound
    methods (f32 and the ``quantized=True`` twin).  ``cache`` is the
    replica's ``TuneCache``: the respawn path exports a survivor's entries
    into a fresh replica so it never re-tunes (``cold_service_s`` models
    the first-dispatch tune+compile a cold spawn would pay).
    """

    def __init__(self, name: str, *, infer_fn=None, q8_infer_fn=None,
                 cache=None, service_s: float = 1.0,
                 q8_service_factor: float = 0.55,
                 cold_service_s: float = 0.0):
        self.name = name
        self.infer_fn = infer_fn
        self.q8_infer_fn = q8_infer_fn
        self.cache = cache
        self.service_s = float(service_s)
        self.q8_service_factor = float(q8_service_factor)
        self.cold_service_s = float(cold_service_s)
        self.busy_rid: int | None = None
        self.busy_epoch: int | None = None
        self.dispatched = 0

    # -- warm-cache plumbing (TuneCache payloads) -----------------------------
    def warm_entries(self) -> int:
        return len(self.cache) if self.cache is not None else 0

    def export_warm(self) -> dict:
        return self.cache.export_entries() if self.cache is not None else {}

    def seed_warm(self, payload: dict) -> int:
        if self.cache is None or not payload:
            return 0
        return self.cache.merge_entries(payload, persist=False)

    # -- the service model ----------------------------------------------------
    def service_time(self, *, degraded: bool = False,
                     slow_factor: float = 1.0) -> float:
        s = self.service_s * slow_factor
        if degraded:
            s *= self.q8_service_factor
        if self.dispatched == 0 and self.warm_entries() == 0:
            s += self.cold_service_s      # cold spawn: first dispatch tunes
        return s

    def infer(self, images, *, degraded: bool = False):
        fn = self.q8_infer_fn if degraded and self.q8_infer_fn is not None \
            else self.infer_fn
        return None if fn is None else fn(images)


class FleetRouter:
    """Event-driven router over a replica fleet (module docstring has the
    policy map).  ``run(arrivals)`` replays ``(t, image)`` arrivals (plus
    the chaos schedule's bursts) to completion and returns ``report()``.
    """

    def __init__(self, replicas, *, clock: SimClock | None = None,
                 chaos=None, deadline_s: float = 6.0, queue_bound: int = 32,
                 slo_depth: int | None = None, hedge_after_s: float | None = None,
                 max_retries: int = 3, backoff_s: float = 0.25,
                 health_every_s: float = 1.0, health_timeout_s: float = 2.5,
                 respawn_after_s: float = 4.0, degrade: bool = True,
                 replica_factory=None, burst_image_fn=None):
        self.live: dict[str, Replica] = {r.name: r for r in replicas}
        assert self.live, "a fleet needs at least one replica"
        self.clock = clock or SimClock()
        self.chaos = chaos
        self.deadline_s = float(deadline_s)
        self.queue_bound = int(queue_bound)
        self._slo_depth_override = slo_depth
        self.hedge_after_s = 1.5 * max(r.service_s for r in replicas) \
            if hedge_after_s is None else hedge_after_s
        self.max_retries = int(max_retries)
        self.backoff_s = float(backoff_s)
        self.health_every_s = float(health_every_s)
        self.health_timeout_s = float(health_timeout_s)
        self.respawn_after_s = float(respawn_after_s)
        self.degrade_enabled = bool(degrade)
        self.replica_factory = replica_factory
        self.burst_image_fn = burst_image_fn
        self.queue: list[int] = []            # FIFO of queued rids
        self.requests: dict[int, Request] = {}
        self.last_ok: dict[str, float] = {n: 0.0 for n in self.live}
        self.born: dict[str, float] = {n: 0.0 for n in self.live}
        self.events: list[dict] = []
        self.evictions = 0
        self.respawns = 0
        self.hedges = 0
        self.reseeded_entries = 0
        self._heap: list[tuple] = []
        self._seq = itertools.count()
        self._rids = itertools.count()
        self._epochs = itertools.count(1)
        self._health_armed = False

    # -- bookkeeping ----------------------------------------------------------

    def event(self, kind: str, **fields) -> None:
        self.events.append({"kind": kind, "t": round(self.clock.time(), 6),
                            **fields})

    def _push(self, t: float, kind: str, data=None) -> None:
        heapq.heappush(self._heap, (t, next(self._seq), kind, data))

    def _slo_depth(self) -> int:
        """Queue depth still drainable within the deadline at the live
        fleet's f32 service rate; deeper arrivals get the degrade path."""
        if self._slo_depth_override is not None:
            return self._slo_depth_override
        if not self.live:
            return 0
        svc = sum(r.service_s for r in self.live.values()) / len(self.live)
        return max(1, int((self.deadline_s / svc - 1.0) * len(self.live)))

    def _outstanding(self) -> bool:
        return any(r.status in ("queued", "running")
                   for r in self.requests.values())

    def _arm_health(self) -> None:
        if not self._health_armed and self._outstanding():
            self._health_armed = True
            self._push(self.clock.time() + self.health_every_s, "health")

    # -- the event loop -------------------------------------------------------

    def run(self, arrivals) -> dict:
        """Replay ``(t, image)`` arrivals plus the chaos bursts; returns
        ``report()``.  Deterministic: the heap orders ties by push
        sequence, and every decision reads only simulated time."""
        for t, image in arrivals:
            self._push(float(t), "arrival", image)
        if self.chaos is not None:
            for b in self.chaos.bursts():
                for i in range(b.n):
                    image = self.burst_image_fn(i) \
                        if self.burst_image_fn is not None else None
                    self._push(float(b.t), "arrival", image)
        handlers = {"arrival": self._on_arrival, "complete": self._on_complete,
                    "fault": self._on_fault, "retry": self._on_retry,
                    "hedge": self._on_hedge, "health": self._on_health,
                    "respawn": self._on_respawn}
        while self._heap:
            t, _, kind, data = heapq.heappop(self._heap)
            self.clock.advance_to(t)
            handlers[kind](t, data)
        return self.report()

    # -- admission ------------------------------------------------------------

    def _on_arrival(self, t: float, image) -> None:
        req = Request(next(self._rids), t, self.deadline_s, image=image)
        self.requests[req.rid] = req
        if len(self.queue) >= self.queue_bound:
            req.status = "shed"
            self.event("shed", rid=req.rid, queue_depth=len(self.queue))
            return
        if self.degrade_enabled and len(self.queue) >= self._slo_depth():
            req.degraded = True
            self.event("degrade_admission", rid=req.rid,
                       queue_depth=len(self.queue))
        self.queue.append(req.rid)
        self._dispatch(t)
        self._arm_health()

    # -- dispatch -------------------------------------------------------------

    def _idle(self) -> list[Replica]:
        return [r for r in self.live.values() if r.busy_rid is None]

    def _dispatch(self, t: float) -> None:
        while self.queue:
            idle = self._idle()
            if not idle:
                return
            rid = self.queue.pop(0)
            req = self.requests[rid]
            preferred = [r for r in idle if r.name not in req.avoid] or idle
            # least-loaded first, name as the deterministic tiebreak
            rep = min(preferred, key=lambda r: (r.dispatched, r.name))
            self._start(req, rep, t)

    def _start(self, req: Request, rep: Replica, t: float,
               hedge: bool = False) -> None:
        slow = self.chaos.slow_factor(rep.name, t) if self.chaos else 1.0
        if self.degrade_enabled and not req.degraded and \
                t + rep.service_time(slow_factor=slow) > \
                req.t_arrival + req.deadline_s:
            # the f32 path would bust the deadline: hand to the int8 twin
            req.degraded = True
            self.event("degrade_deadline", rid=req.rid, replica=rep.name)
        svc = rep.service_time(degraded=req.degraded, slow_factor=slow)
        epoch = next(self._epochs)
        rep.busy_rid, rep.busy_epoch = req.rid, epoch
        rep.dispatched += 1
        req.status = "running"
        req.dispatches.append((rep.name, epoch))
        fault = self.chaos.take_infer_fault(rep.name, t) \
            if self.chaos else None
        if fault is not None:
            self._push(t + fault.cost_s, "fault", (rep.name, req.rid, epoch))
        elif self.chaos is not None and self._dead(rep.name, t):
            pass        # dispatched into a dead replica: hangs until evicted
        else:
            self._push(t + svc, "complete", (rep.name, req.rid, epoch))
        if not hedge and self.hedge_after_s is not None:
            self._push(t + self.hedge_after_s, "hedge",
                       (rep.name, req.rid, epoch))

    def _dead(self, name: str, t: float) -> bool:
        return self.chaos is not None and \
            self.chaos.is_dead(name, t, born=self.born[name])

    def _stale(self, name: str, epoch: int) -> bool:
        rep = self.live.get(name)
        return rep is None or rep.busy_epoch != epoch

    # -- completions / failures ----------------------------------------------

    def _on_complete(self, t: float, data) -> None:
        name, rid, epoch = data
        if self._stale(name, epoch):
            return
        if self._dead(name, t):
            return      # died mid-service: the result never made it out
        rep = self.live[name]
        rep.busy_rid = rep.busy_epoch = None
        req = self.requests[rid]
        req.status, req.t_done = "done", t
        if req.image is not None:
            logits = rep.infer(np.asarray(req.image)[None],
                               degraded=req.degraded)
            req.result = None if logits is None else np.asarray(logits)[0]
        # a hedged twin may still be running the same request: cancel it
        for other, oe in req.dispatches:
            if other != name and not self._stale(other, oe):
                twin = self.live[other]
                twin.busy_rid = twin.busy_epoch = None
                self.event("hedge_cancel", rid=rid, replica=other)
        self._dispatch(t)

    def _on_fault(self, t: float, data) -> None:
        name, rid, epoch = data
        if self._stale(name, epoch):
            return
        rep = self.live[name]
        rep.busy_rid = rep.busy_epoch = None
        self._requeue(self.requests[rid], t, failed_on=name, backoff=True)
        self._dispatch(t)

    def _requeue(self, req: Request, t: float, *, failed_on: str,
                 backoff: bool) -> None:
        """Bounded retry on a different replica (flaky infer / eviction)."""
        if req.status == "done":
            return
        req.retries += 1
        req.avoid.add(failed_on)
        if req.retries > self.max_retries:
            req.status = "failed"
            self.event("retries_exhausted", rid=req.rid)
            return
        req.status = "queued"
        if backoff:
            delay = self.backoff_s * (2 ** (req.retries - 1))
            self.event("retry_backoff", rid=req.rid, replica=failed_on,
                       delay_s=round(delay, 6))
            self._push(t + delay, "retry", req.rid)
        else:
            self.queue.insert(0, req.rid)

    def _on_retry(self, t: float, rid: int) -> None:
        req = self.requests[rid]
        if req.status != "queued" or rid in self.queue:
            return
        self.queue.insert(0, rid)       # retries go to the head: oldest first
        self._dispatch(t)

    # -- hedging --------------------------------------------------------------

    def _on_hedge(self, t: float, data) -> None:
        name, rid, epoch = data
        req = self.requests[rid]
        if req.status != "running" or self._stale(name, epoch):
            return
        idle = [r for r in self._idle()
                if r.name != name and r.name not in req.avoid]
        if not idle:
            return
        rep = min(idle, key=lambda r: (r.dispatched, r.name))
        req.hedged = True
        self.hedges += 1
        self.event("hedge", rid=rid, slow=name, to=rep.name)
        self._start(req, rep, t, hedge=True)

    # -- health / eviction / respawn ------------------------------------------

    def _on_health(self, t: float, _) -> None:
        self._health_armed = False
        for name in list(self.live):
            if self._dead(name, t):
                if t - self.last_ok[name] > self.health_timeout_s:
                    self._evict(name, t)
            else:
                self.last_ok[name] = t
        self._dispatch(t)
        self._arm_health()

    def _evict(self, name: str, t: float) -> None:
        rep = self.live.pop(name)
        self.evictions += 1
        self.event("eviction", replica=name,
                   silent_s=round(t - self.last_ok[name], 6))
        if rep.busy_rid is not None:
            req = self.requests[rep.busy_rid]
            rep.busy_rid = rep.busy_epoch = None
            # reassign unless a hedged twin is still live on another replica
            still_running = any(not self._stale(n, e)
                                for n, e in req.dispatches)
            if req.status == "running" and not still_running:
                self._requeue(req, t, failed_on=name, backoff=False)
                self.event("reassign", rid=req.rid, replica=name)
        if self.replica_factory is not None:
            self._push(t + self.respawn_after_s, "respawn", name)

    def _on_respawn(self, t: float, name: str) -> None:
        rep = self.replica_factory(name)
        donors = sorted(self.live.values(),
                        key=lambda r: (-r.warm_entries(), r.name))
        n = rep.seed_warm(donors[0].export_warm()) if donors else 0
        self.reseeded_entries += n
        self.respawns += 1
        self.event("respawn", replica=name, reseeded_entries=n,
                   warm=bool(n))
        self.live[name] = rep
        self.last_ok[name] = t
        self.born[name] = t
        self._dispatch(t)
        self._arm_health()

    # -- the scorecard --------------------------------------------------------

    def report(self) -> dict:
        reqs = list(self.requests.values())
        offered = len(reqs)
        shed = sum(1 for r in reqs if r.status == "shed")
        admitted = offered - shed
        done = [r for r in reqs if r.status == "done"]
        in_deadline = sum(1 for r in done if r.in_deadline)
        degraded_done = sum(1 for r in done if r.degraded)
        lat_ms = sorted(1e3 * r.latency_s for r in done)
        pct = (lambda p: round(float(np.percentile(lat_ms, p)), 3)) \
            if lat_ms else (lambda p: None)
        return {
            "offered": offered,
            "admitted": admitted,
            "shed": shed,
            "completed": len(done),
            "failed": sum(1 for r in reqs if r.status == "failed"),
            "in_deadline": in_deadline,
            "degraded_completed": degraded_done,
            "hedges": self.hedges,
            "retries": sum(r.retries for r in reqs),
            "evictions": self.evictions,
            "respawns": self.respawns,
            "reseeded_entries": self.reseeded_entries,
            "goodput": round(in_deadline / max(offered, 1), 6),
            "shed_rate": round(shed / max(offered, 1), 6),
            "degrade_rate": round(degraded_done / max(admitted, 1), 6),
            "slo_handled_rate": round(
                sum(1 for r in reqs if r.slo_handled) / max(admitted, 1), 6),
            "p50_ms": pct(50),
            "p99_ms": pct(99),
            "max_ms": round(lat_ms[-1], 3) if lat_ms else None,
            "sim_time_s": round(self.clock.time(), 6),
            "events": list(self.events),
        }


def poisson_arrivals(seed: int, *, n: int, rate_per_s: float,
                     t0: float = 0.0) -> list[tuple[float, None]]:
    """Seeded Poisson-process arrival schedule (exponential gaps) — the
    open-loop traffic model the bench replays."""
    from repro.core.simtime import seeded_rng
    rng = seeded_rng(0xA881, seed)
    gaps = rng.exponential(1.0 / rate_per_s, size=n)
    t, out = t0, []
    for g in gaps:
        t += float(g)
        out.append((round(t, 6), None))
    return out
