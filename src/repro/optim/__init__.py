from repro.optim.adamw import AdamW, adamw  # noqa: F401
from repro.optim.compress import compress_int8, decompress_int8  # noqa: F401
