"""AdamW with memory knobs for the 100B+ archs:

  * ``state_dtype`` — keep m/v in bf16 (halves optimizer HBM);
  * ``factored``    — Adafactor-style factored second moment for matrices
    (row+col accumulators instead of the full v tensor);
  * optimizer state inherits the parameter sharding (ZeRO-1 comes free:
    when params are FSDP-sharded the states are too).

Functional API: ``opt.init(params) -> state``; ``opt.update(grads, state,
params, lr) -> (new_params, new_state)``.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamW:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    state_dtype: Any = jnp.float32
    factored: bool = False
    factored_min_size: int = 128

    def _is_factored(self, p):
        return (self.factored and p.ndim >= 2
                and p.shape[-1] >= self.factored_min_size
                and p.shape[-2] >= self.factored_min_size)

    def init(self, params):
        def leaf(p):
            m = jnp.zeros_like(p, dtype=self.state_dtype)
            if self._is_factored(p):
                vr = jnp.zeros(p.shape[:-1], jnp.float32)
                vc = jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
                return {"m": m, "vr": vr, "vc": vc}
            return {"m": m, "v": jnp.zeros_like(p, dtype=self.state_dtype)}
        return {"mu": jax.tree.map(leaf, params),
                "count": jnp.zeros((), jnp.int32)}

    def update(self, grads, state, params, lr):
        count = state["count"] + 1
        b1c = 1 - self.b1 ** count.astype(jnp.float32)
        b2c = 1 - self.b2 ** count.astype(jnp.float32)

        def leaf(g, s, p):
            g32 = g.astype(jnp.float32)
            m = self.b1 * s["m"].astype(jnp.float32) + (1 - self.b1) * g32
            if "v" in s:
                v = self.b2 * s["v"].astype(jnp.float32) \
                    + (1 - self.b2) * g32 * g32
                vhat = v / b2c
                ns = {"m": m.astype(self.state_dtype),
                      "v": v.astype(self.state_dtype)}
            else:
                g2 = g32 * g32
                vr = self.b2 * s["vr"] + (1 - self.b2) * g2.mean(axis=-1)
                vc = self.b2 * s["vc"] + (1 - self.b2) * g2.mean(axis=-2)
                # rank-1 reconstruction (Adafactor)
                denom = jnp.maximum(vr.mean(axis=-1, keepdims=True), 1e-30)
                vhat = (vr[..., None] * vc[..., None, :]
                        / denom[..., None]) / b2c
                ns = {"m": m.astype(self.state_dtype), "vr": vr, "vc": vc}
            upd = (m / b1c) / (jnp.sqrt(vhat) + self.eps)
            upd = upd + self.weight_decay * p.astype(jnp.float32)
            newp = (p.astype(jnp.float32) - lr * upd).astype(p.dtype)
            return newp, ns

        flat_p, treedef = jax.tree.flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_s = treedef.flatten_up_to(state["mu"])
        out = [leaf(g, s, p) for g, s, p in zip(flat_g, flat_s, flat_p)]
        new_params = treedef.unflatten([o[0] for o in out])
        new_mu = treedef.unflatten([o[1] for o in out])
        return new_params, {"mu": new_mu, "count": count}


def adamw(**kw) -> AdamW:
    return AdamW(**kw)


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: (g * scale).astype(g.dtype), grads), gn
