"""Error-feedback int8 gradient compression for the DP all-reduce.

Per-tensor symmetric quantization with a residual ("error feedback")
accumulator: the quantization error of step t is added back to the gradient
of step t+1, preserving convergence (1-bit-Adam / EF-SGD lineage).  The
all-reduce then moves 1/4 of the bytes — this is the cluster-scale
counterpart of the paper's §II-K reduced-precision kernels (same trick,
applied to the wire instead of the FMA).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def compress_int8(g, residual=None):
    """-> (q int8, scale f32 scalar, new_residual)."""
    g32 = g.astype(jnp.float32)
    if residual is not None:
        g32 = g32 + residual
    scale = jnp.max(jnp.abs(g32)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
    new_residual = g32 - q.astype(jnp.float32) * scale
    return q, scale, new_residual


def decompress_int8(q, scale, dtype=jnp.float32):
    return (q.astype(jnp.float32) * scale).astype(dtype)


def compressed_psum_tree(grads, axis_name: str, residuals):
    """Leaf-wise ``compressed_psum`` over a gradient pytree: returns the
    de-quantized *mean* gradient tree and the new per-shard residual tree.
    This is the reduction the data-parallel CNN train step inserts between
    the update pass and the optimizer when ``REPRO_GRAD_COMPRESS=int8``
    (``train/distributed.py``)."""
    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_r = treedef.flatten_up_to(residuals)
    out_g, out_r = [], []
    for g, r in zip(flat_g, flat_r):
        gq, nr = compressed_psum(g, axis_name, r)
        out_g.append(gq)
        out_r.append(nr)
    return (jax.tree_util.tree_unflatten(treedef, out_g),
            jax.tree_util.tree_unflatten(treedef, out_r))


def fold_residual(residual, new_shards: int):
    """Re-shard an error-feedback residual tree onto a narrower data axis.

    Residual leaves carry a leading ``(n_shards,)`` axis (one error
    accumulator per shard).  Elastic re-scale must preserve the *total*
    un-applied gradient mass — sum-fold groups of old shards into each new
    shard (old width divisible by new), else collapse everything into shard
    0 and zero the rest."""
    def fold(r):
        old = r.shape[0]
        if old == new_shards:
            return r
        if old % new_shards == 0:
            return r.reshape(new_shards, old // new_shards,
                             *r.shape[1:]).sum(axis=1)
        total = r.sum(axis=0, keepdims=True)
        pad = jnp.zeros((new_shards - 1, *r.shape[1:]), r.dtype)
        return jnp.concatenate([total, pad], axis=0)
    return jax.tree.map(fold, residual)


def compressed_psum(g, axis_name: str, residual=None):
    """Quantize -> psum(int32 accumulate) -> dequantize, with error
    feedback.  All shards must quantize against a COMMON scale (the pmax of
    local scales) or the int32 sum mixes units.  Used inside shard_map'd
    train steps (tested in tests/test_distributed.py)."""
    g32 = g.astype(jnp.float32)
    if residual is not None:
        g32 = g32 + residual
    local_scale = jnp.max(jnp.abs(g32)) / 127.0 + 1e-12
    scale = jax.lax.pmax(local_scale, axis_name)     # agree before quantizing
    q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
    new_res = g32 - q.astype(jnp.float32) * scale
    acc = jax.lax.psum(q.astype(jnp.int32), axis_name)
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
    return (acc.astype(jnp.float32) * scale / n).astype(g.dtype), new_res
