"""Deterministic, shardable, restartable data pipeline.

Key property for fault tolerance: batches are a pure function of
``(seed, global_step)`` — restoring a checkpoint at step S resumes the
*exact* token stream at S+1, on any data-parallel layout (each host slices
its shard of the global batch by rank).  This is the "data-pipeline cursor"
half of checkpoint/restart; no iterator state needs serializing beyond the
step counter.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class SyntheticLMData:
    """Zipf-distributed token stream with next-token structure (the model
    can actually learn it — used by convergence tests and examples)."""
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_shards: int = 1
    shard: int = 0

    def batch_at(self, step: int) -> dict:
        assert self.global_batch % self.n_shards == 0
        local = self.global_batch // self.n_shards
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, self.shard]))
        # Markov-ish stream: token_{i+1} = f(token_i) with noise, so there
        # is learnable signal for the convergence tests.
        base = rng.zipf(1.5, size=(local, self.seq_len + 1)) % self.vocab
        drift = (np.arange(self.seq_len + 1)[None, :] * 7) % self.vocab
        toks = ((base + drift) % self.vocab).astype(np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


@dataclasses.dataclass
class SyntheticImageData:
    """NHWC image batches in the GxM contract ({"image", "label"}), same
    pure ``(seed, step)`` -> batch contract as the LM pipelines — the data
    cursor the chaos-recovery tests replay through the DP CNN step."""
    hw: int
    n_classes: int
    global_batch: int
    channels: int = 3
    seed: int = 0
    n_shards: int = 1
    shard: int = 0

    def batch_at(self, step: int) -> dict:
        assert self.global_batch % self.n_shards == 0
        local = self.global_batch // self.n_shards
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, self.shard]))
        labels = rng.integers(self.n_classes,
                              size=(local,)).astype(np.int32)
        x = rng.standard_normal(
            (local, self.hw, self.hw, self.channels)).astype(np.float32)
        # class-dependent mean shift: learnable signal for convergence tests
        x += (labels[:, None, None, None].astype(np.float32)
              / self.n_classes - 0.5)
        return {"image": x, "label": labels}

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


@dataclasses.dataclass
class TokenFileData:
    """Memory-mapped flat token file (uint16/uint32), deterministic chunk
    shuffle per epoch; same (seed, step) -> batch contract."""
    path: str
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_shards: int = 1
    shard: int = 0
    dtype: str = "uint16"

    def __post_init__(self):
        self._data = np.memmap(self.path, dtype=self.dtype, mode="r")
        self._n_chunks = (len(self._data) - 1) // self.seq_len

    def batch_at(self, step: int) -> dict:
        local = self.global_batch // self.n_shards
        per_epoch = max(self._n_chunks // self.global_batch, 1)
        epoch, pos = divmod(step, per_epoch)
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, epoch]))
        perm = rng.permutation(self._n_chunks)
        start = pos * self.global_batch + self.shard * local
        idx = perm[start:start + local] % self._n_chunks
        rows = np.stack([
            self._data[i * self.seq_len:i * self.seq_len + self.seq_len + 1]
            for i in idx]).astype(np.int32) % self.vocab
        return {"tokens": rows[:, :-1], "labels": rows[:, 1:]}


def make_pipeline(cfg, *, seq_len: int, global_batch: int, seed: int = 0,
                  n_shards: int = 1, shard: int = 0, path: str | None = None):
    if path:
        return TokenFileData(path, cfg.vocab, seq_len, global_batch,
                             seed=seed, n_shards=n_shards, shard=shard)
    return SyntheticLMData(cfg.vocab, seq_len, global_batch, seed=seed,
                           n_shards=n_shards, shard=shard)
