from repro.data.pipeline import SyntheticLMData, TokenFileData, make_pipeline  # noqa: F401
