from repro.data.pipeline import (SyntheticImageData, SyntheticLMData,  # noqa: F401
                                 TokenFileData, make_pipeline)
