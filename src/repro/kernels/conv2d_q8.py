"""Quantized direct-convolution kernel — paper §II-K as a *kernel*, not
just weight storage.

The paper's 4VNNIW path takes int16 inputs, multiplies into int32
accumulators, and manages accumulation-chain length to avoid overflow; the
output stays 32-bit (so output-side bandwidth does not improve — their
measured 1.6x, not 2x).  TPU analog: int8 activations and weights feed the
MXU's 8-bit path, accumulate in int32, and the per-channel scales are
applied once in the epilogue.  Overflow management maps to the int32
accumulator width: the worst-case chain here is R*S*C * 127*127 which for
R=S=3, C=2048 is ~3e8 << 2^31 — checked statically below (the paper had to
*restrict* chain length for int16 accumulation into 32 bits; int8->int32
gives us the headroom for free, which is exactly why serving stacks picked
int8).

The kernel is tiled exactly like ``conv2d_direct``: a (N, K_b, P_b, Q_b,
C_b) grid streaming only the (RB_P-1)*stride + R row band per step via
unblocked BlockSpec index_maps, with an *int32* VMEM scratch accumulated
across C-block visits (init on the first visit, dequant + fused §II-G
epilogue + store on the last).  int8 bands are 4x smaller than f32 ones, so
``core.blocking.conv_working_set(kind="q8")`` lets RB_P grow ~4x under the
same VMEM budget.  The two per-channel scales are premultiplied into one
(1, K) f32 ``deq`` input before launch, so the epilogue arithmetic — and
therefore the output bits — are identical between the tiled and
whole-plane kernels: int32 accumulation is associative, and both paths
compute ``acc.astype(f32) * deq`` with the same single rounding.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.conv2d_direct import (FuseSpec, _epilogue, _grid_layout,
                                         _unpack_fuse_refs, pad_input)


def _check_overflow(r: int, s: int, c: int) -> None:
    # static overflow check (the §II-K chain-length discipline)
    assert r * s * c * 127 * 127 < 2 ** 31, "int32 accumulator overflow"


def _kernel_q8_tiled(x_ref, w_ref, deq_ref, *refs, fuse: FuseSpec, rb_p: int,
                     rb_q: int, stride: int, r: int, s: int, c_axis: int,
                     out_dtype):
    """One microkernel invocation on a streamed int8 row band: accumulate one
    C-block into the int32 scratch; init on the first visit, dequantize +
    fused epilogue + store on the last (FLAG_INIT/FLAG_EPILOGUE, static)."""
    refs, acc_ref = refs[:-1], refs[-1]
    bias_ref, scale_ref, shift_ref, res_ref, o_ref = \
        _unpack_fuse_refs(refs, fuse)

    ci = pl.program_id(c_axis)
    c_b = pl.num_programs(c_axis)

    @pl.when(ci == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    c_blk = x_ref.shape[-1]
    k_blk = w_ref.shape[-1]
    acc = jnp.zeros((rb_p * rb_q, k_blk), dtype=jnp.int32)
    for rr in range(r):
        for ss in range(s):
            xs = x_ref[0, pl.dslice(rr, rb_p, stride),
                       pl.dslice(ss, rb_q, stride), :]   # (rb_p, rb_q, c_blk)
            a = xs.reshape(rb_p * rb_q, c_blk)
            # int8 x int8 -> int32 accumulate (the 4VNNIW analog)
            acc += jax.lax.dot(a.astype(jnp.int32),
                               w_ref[rr, ss, :, :].astype(jnp.int32),
                               preferred_element_type=jnp.int32)
    acc_ref[...] += acc

    @pl.when(ci == c_b - 1)
    def _finish():
        # dequantize once, while the tile is hot in VMEM, then the f32
        # §II-G chain — bit-identical to the whole-plane kernel's epilogue
        out = acc_ref[...].astype(jnp.float32) * deq_ref[0, :]
        out = _epilogue(out, fuse, bias_ref, scale_ref, shift_ref, res_ref,
                        rb_p * rb_q, k_blk, jnp.float32)
        o_ref[0] = out.reshape(rb_p, rb_q, k_blk).astype(out_dtype)


def _kernel_q8_whole(x_ref, w_ref, deq_ref, *refs, fuse: FuseSpec, rb_p: int,
                     q: int, stride: int, r: int, s: int, p_axis: int,
                     out_dtype):
    """Legacy microkernel: whole padded int8 plane resident, row selection via
    the P-block program id (kept for A/B benchmarking vs the tiled path)."""
    bias_ref, scale_ref, shift_ref, res_ref, o_ref = \
        _unpack_fuse_refs(refs, fuse)

    pb = pl.program_id(p_axis)
    c = x_ref.shape[-1]
    k_blk = w_ref.shape[-1]
    acc = jnp.zeros((rb_p * q, k_blk), dtype=jnp.int32)
    row0 = pb * rb_p * stride
    for rr in range(r):
        for ss in range(s):
            xs = x_ref[0, pl.dslice(row0 + rr, rb_p, stride),
                       pl.dslice(ss, q, stride), :]
            a = xs.reshape(rb_p * q, c)
            acc += jax.lax.dot(a.astype(jnp.int32),
                               w_ref[rr, ss, :, :].astype(jnp.int32),
                               preferred_element_type=jnp.int32)
    out = acc.astype(jnp.float32) * deq_ref[0, :]
    out = _epilogue(out, fuse, bias_ref, scale_ref, shift_ref, res_ref,
                    rb_p * q, k_blk, jnp.float32)
    o_ref[0] = out.reshape(rb_p, q, k_blk).astype(out_dtype)


def conv2d_q8(x_q, w_q, *, x_scale, w_scale, stride: int = 1,
              padding: int = 0, bias=None, scale=None, shift=None,
              residual=None, relu: bool = False, rb_p: int = 8,
              k_blk: int | None = None, c_blk: int | None = None,
              rb_q: int | None = None, order: str = "nkpc",
              whole_plane: bool | None = None, out_dtype=jnp.float32,
              interpret: bool = False):
    """Quantized direct conv fwd.  x_q: (N,H,W,C) int8; w_q: (R,S,C,K) int8;
    x_scale: scalar f32 per-tensor activation scale; w_scale: (K,) f32
    per-output-channel.  -> (N,P,Q,K) out_dtype (f32 by default — output
    bandwidth stays 32-bit, the paper's reason 1.6x != 4x).

    Blocking kwargs mirror ``conv2d_direct`` (`rb_p`/`rb_q` register block,
    `k_blk` MXU N-tile, `c_blk` C-block accumulated in int32 VMEM scratch,
    `order` the §II-C grid order); `whole_plane` selects the legacy untiled
    kernel (default: the ``repro.backend`` conv-tiling knob).  The optional
    bias / folded-BN scale+shift / residual / relu epilogue is applied in
    f32 *after* dequantization.
    """
    assert x_q.dtype == jnp.int8 and w_q.dtype == jnp.int8
    n, h, wdt, c = x_q.shape
    r, s, _, k = w_q.shape
    _check_overflow(r, s, c)
    p = (h + 2 * padding - r) // stride + 1
    q = (wdt + 2 * padding - s) // stride + 1
    rb_p = min(rb_p, p)
    rb_q = q if rb_q in (None, 0) else min(rb_q, q)
    k_blk = k_blk or min(k, 128)
    c_blk = c if c_blk in (None, 0) else c_blk
    assert k % k_blk == 0, (k, k_blk)
    assert c % c_blk == 0, (c, c_blk)
    if whole_plane is None:
        from repro import backend as be
        whole_plane = be.get_conv_tiling() == "whole"

    fuse = FuseSpec(bias=bias is not None, bn=scale is not None,
                    residual=residual is not None, relu=relu)
    if fuse.bn:
        assert shift is not None

    # premultiplied dequant scales: one (1, K) f32 row, identical math on
    # both kernel paths (tiled ≡ whole-plane bit-exactness depends on this)
    deq = (jnp.reshape(x_scale, ()).astype(jnp.float32)
           * w_scale.reshape(1, k).astype(jnp.float32))

    if whole_plane:
        return _conv2d_q8_whole_plane(
            x_q, w_q, deq, fuse=fuse, stride=stride, padding=padding,
            bias=bias, scale=scale, shift=shift, residual=residual,
            rb_p=rb_p, k_blk=k_blk, p=p, q=q, r=r, s=s, n=n, k=k, c=c,
            out_dtype=out_dtype, interpret=interpret)

    p_b = math.ceil(p / rb_p)
    q_b = math.ceil(q / rb_q)
    k_b = k // k_blk
    c_b = c // c_blk

    xp = pad_input(x_q, padding=padding, stride=stride, rb_p=rb_p, r=r, p=p,
                   rb_q=rb_q, s=s, q=q)
    band_h = (rb_p - 1) * stride + r
    band_w = (rb_q - 1) * stride + s
    grid, axis = _grid_layout(order, n=n, k_b=k_b, p_b=p_b, q_b=q_b, c_b=c_b)
    an, ak, ap, aq, ac = (axis[d] for d in "nkpqc")

    in_specs = [
        pl.BlockSpec((1, band_h, band_w, c_blk),
                     lambda *i: (i[an], i[ap] * rb_p * stride,
                                 i[aq] * rb_q * stride, i[ac] * c_blk),
                     indexing_mode=pl.unblocked),
        pl.BlockSpec((r, s, c_blk, k_blk),
                     lambda *i: (0, 0, i[ac], i[ak])),
        pl.BlockSpec((1, k_blk), lambda *i: (0, i[ak])),     # deq scales
    ]
    args = [xp, w_q, deq]
    if fuse.bias:
        in_specs.append(pl.BlockSpec((1, k_blk), lambda *i: (0, i[ak])))
        args.append(bias.reshape(1, k))
    if fuse.bn:
        in_specs.append(pl.BlockSpec((1, k_blk), lambda *i: (0, i[ak])))
        in_specs.append(pl.BlockSpec((1, k_blk), lambda *i: (0, i[ak])))
        args.extend([scale.reshape(1, k), shift.reshape(1, k)])
    if fuse.residual:
        in_specs.append(pl.BlockSpec((1, rb_p, rb_q, k_blk),
                                     lambda *i: (i[an], i[ap], i[aq], i[ak])))
        args.append(residual)

    kern = functools.partial(_kernel_q8_tiled, fuse=fuse, rb_p=rb_p,
                             rb_q=rb_q, stride=stride, r=r, s=s, c_axis=ac,
                             out_dtype=out_dtype)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, rb_p, rb_q, k_blk),
                               lambda *i: (i[an], i[ap], i[aq], i[ak])),
        out_shape=jax.ShapeDtypeStruct((n, p, q, k), out_dtype),
        scratch_shapes=[pltpu.VMEM((rb_p * rb_q, k_blk), jnp.int32)],
        interpret=interpret,
    )(*args)


def _conv2d_q8_whole_plane(x_q, w_q, deq, *, fuse, stride, padding, bias,
                           scale, shift, residual, rb_p, k_blk, p, q, r, s,
                           n, k, c, out_dtype, interpret):
    """The pre-refactor kernel: whole padded int8 plane per image in VMEM,
    C and Q unblocked, grid (N, K_b, P_b)."""
    xp = pad_input(x_q, padding=padding, stride=stride, rb_p=rb_p, r=r, p=p)
    hp, wp = xp.shape[1], xp.shape[2]
    grid = (n, k // k_blk, math.ceil(p / rb_p))

    in_specs = [
        pl.BlockSpec((1, hp, wp, c), lambda ni, ki, pi: (ni, 0, 0, 0)),
        pl.BlockSpec((r, s, c, k_blk), lambda ni, ki, pi: (0, 0, 0, ki)),
        pl.BlockSpec((1, k_blk), lambda ni, ki, pi: (0, ki)),
    ]
    args = [xp, w_q, deq]
    if fuse.bias:
        in_specs.append(pl.BlockSpec((1, k_blk), lambda ni, ki, pi: (0, ki)))
        args.append(bias.reshape(1, k))
    if fuse.bn:
        in_specs.append(pl.BlockSpec((1, k_blk), lambda ni, ki, pi: (0, ki)))
        in_specs.append(pl.BlockSpec((1, k_blk), lambda ni, ki, pi: (0, ki)))
        args.extend([scale.reshape(1, k), shift.reshape(1, k)])
    if fuse.residual:
        in_specs.append(pl.BlockSpec((1, rb_p, q, k_blk),
                                     lambda ni, ki, pi: (ni, pi, 0, ki)))
        args.append(residual)

    kern = functools.partial(_kernel_q8_whole, fuse=fuse, rb_p=rb_p, q=q,
                             stride=stride, r=r, s=s, p_axis=2,
                             out_dtype=out_dtype)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, rb_p, q, k_blk),
                               lambda ni, ki, pi: (ni, pi, 0, ki)),
        out_shape=jax.ShapeDtypeStruct((n, p, q, k), out_dtype),
        interpret=interpret,
    )(*args)


def quantize_conv_inputs(x, w):
    """Symmetric per-tensor activation scale + per-K-channel weight scales
    (the standard inference calibration)."""
    x_scale = jnp.max(jnp.abs(x)).astype(jnp.float32) / 127.0 + 1e-12
    x_q = jnp.clip(jnp.round(x / x_scale), -127, 127).astype(jnp.int8)
    w_scale = jnp.max(jnp.abs(w), axis=(0, 1, 2)).astype(jnp.float32) \
        / 127.0 + 1e-12
    w_q = jnp.clip(jnp.round(w / w_scale), -127, 127).astype(jnp.int8)
    return x_q, w_q, x_scale, w_scale
