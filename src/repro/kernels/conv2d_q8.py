"""Quantized direct-convolution kernel — paper §II-K as a *kernel*, not
just weight storage.

The paper's 4VNNIW path takes int16 inputs, multiplies into int32
accumulators, and manages accumulation-chain length to avoid overflow; the
output stays 32-bit (so output-side bandwidth does not improve — their
measured 1.6x, not 2x).  TPU analog: int8 activations and weights feed the
MXU's 8-bit path, accumulate in int32, and the per-channel scales are
applied once in the epilogue.  Overflow management maps to the int32
accumulator width: the worst-case chain here is R*S*C * 127*127 which for
R=S=3, C=2048 is ~3e8 << 2^31 — checked statically below (the paper had to
*restrict* chain length for int16 accumulation into 32 bits; int8->int32
gives us the headroom for free, which is exactly why serving stacks picked
int8).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.conv2d_direct import pad_input


def _kernel(x_ref, w_ref, sx_ref, sw_ref, o_ref, *, rb_p: int, q: int,
            stride: int, r: int, s: int, relu: bool, out_dtype):
    pb = pl.program_id(2)
    c = x_ref.shape[-1]
    k_blk = w_ref.shape[-1]
    acc = jnp.zeros((rb_p * q, k_blk), dtype=jnp.int32)
    row0 = pb * rb_p * stride
    for rr in range(r):
        for ss in range(s):
            xs = x_ref[0, pl.dslice(row0 + rr, rb_p, stride),
                       pl.dslice(ss, q, stride), :]
            a = xs.reshape(rb_p * q, c)
            wb = w_ref[rr, ss, :, :]
            # int8 x int8 -> int32 accumulate (the 4VNNIW analog)
            acc += jax.lax.dot(a.astype(jnp.int32), wb.astype(jnp.int32),
                               preferred_element_type=jnp.int32)
    # epilogue: apply the scales once, while the tile is hot in VMEM
    out = acc.astype(jnp.float32) * sx_ref[0, 0] * sw_ref[0, :]
    if relu:
        out = jnp.maximum(out, 0)
    o_ref[0] = out.reshape(rb_p, q, k_blk).astype(out_dtype)


def conv2d_q8(x_q, w_q, *, x_scale, w_scale, stride: int = 1,
              padding: int = 0, relu: bool = False, rb_p: int = 8,
              k_blk: int | None = None, out_dtype=jnp.float32,
              interpret: bool = False):
    """x_q: (N,H,W,C) int8; w_q: (R,S,C,K) int8; x_scale: scalar f32;
    w_scale: (K,) f32 per-output-channel.  -> (N,P,Q,K) out_dtype."""
    assert x_q.dtype == jnp.int8 and w_q.dtype == jnp.int8
    n, h, wdt, c = x_q.shape
    r, s, _, k = w_q.shape
    # static overflow check (the §II-K chain-length discipline)
    assert r * s * c * 127 * 127 < 2 ** 31, "int32 accumulator overflow"
    p = (h + 2 * padding - r) // stride + 1
    q = (wdt + 2 * padding - s) // stride + 1
    rb_p = min(rb_p, p)
    k_blk = k_blk or min(k, 128)
    assert k % k_blk == 0

    xp = pad_input(x_q, padding=padding, stride=stride, rb_p=rb_p, r=r, p=p)
    hp, wp = xp.shape[1], xp.shape[2]
    grid = (n, k // k_blk, math.ceil(p / rb_p))

    kern = functools.partial(_kernel, rb_p=rb_p, q=q, stride=stride, r=r,
                             s=s, relu=relu, out_dtype=out_dtype)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, hp, wp, c), lambda ni, ki, pi: (ni, 0, 0, 0)),
            pl.BlockSpec((r, s, c, k_blk), lambda ni, ki, pi: (0, 0, 0, ki)),
            pl.BlockSpec((1, 1), lambda ni, ki, pi: (0, 0)),
            pl.BlockSpec((1, k_blk), lambda ni, ki, pi: (0, ki)),
        ],
        out_specs=pl.BlockSpec((1, rb_p, q, k_blk),
                               lambda ni, ki, pi: (ni, pi, 0, ki)),
        out_shape=jax.ShapeDtypeStruct((n, p, q, k), out_dtype),
        interpret=interpret,
    )(xp, w_q, jnp.reshape(x_scale, (1, 1)).astype(jnp.float32),
      w_scale.reshape(1, k).astype(jnp.float32))


def quantize_conv_inputs(x, w):
    """Symmetric per-tensor activation scale + per-K-channel weight scales
    (the standard inference calibration)."""
    x_scale = jnp.max(jnp.abs(x)).astype(jnp.float32) / 127.0 + 1e-12
    x_q = jnp.clip(jnp.round(x / x_scale), -127, 127).astype(jnp.int8)
    w_scale = jnp.max(jnp.abs(w), axis=(0, 1, 2)).astype(jnp.float32) \
        / 127.0 + 1e-12
    w_q = jnp.clip(jnp.round(w / w_scale), -127, 127).astype(jnp.int8)
    return x_q, w_q, x_scale, w_scale
