"""Pure-jnp oracles for every Pallas kernel in this package.

These are the ground truth used by tests (``assert_allclose`` against
``interpret=True`` kernel runs) and by the CPU dry-run path (the XLA-native
implementation that the 512-device lowering uses — Mosaic kernels only lower
on real TPUs).

Conventions (TPU adaptation of the paper's blocked layouts, see DESIGN.md §2):
  activations  : NHWC   (C innermost = lane dimension)
  weights      : RSCK   (K innermost = lane dimension)
  conv output  : NPQK
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


# ---------------------------------------------------------------------------
# Direct convolution (paper §II-A..D)
# ---------------------------------------------------------------------------

def conv2d(x, w, *, stride: int = 1, padding: int = 0,
           accum_dtype=jnp.float32):
    """Forward conv. x: (N,H,W,C), w: (R,S,C,K) -> (N,P,Q,K)."""
    out = lax.conv_general_dilated(
        x.astype(accum_dtype), w.astype(accum_dtype),
        window_strides=(stride, stride),
        padding=[(padding, padding), (padding, padding)],
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    return out.astype(x.dtype)


def conv2d_fused(x, w, *, stride: int = 1, padding: int = 0,
                 bias=None, scale=None, shift=None, residual=None,
                 relu: bool = False, accum_dtype=jnp.float32):
    """Conv with the paper's §II-G fused epilogue:
    O = act(scale * conv(x,w) + shift + bias [+ residual]).

    ``scale``/``shift`` fold an inference-mode batchnorm; ``bias`` is the conv
    bias; ``residual`` is an eltwise skip-connection add; ``relu`` the
    activation.  All optional, composable — exactly the L() fusion set.
    """
    out = lax.conv_general_dilated(
        x.astype(accum_dtype), w.astype(accum_dtype),
        window_strides=(stride, stride),
        padding=[(padding, padding), (padding, padding)],
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    if scale is not None:
        out = out * scale.astype(accum_dtype)
    if shift is not None:
        out = out + shift.astype(accum_dtype)
    if bias is not None:
        out = out + bias.astype(accum_dtype)
    if residual is not None:
        out = out + residual.astype(accum_dtype)
    if relu:
        out = jnp.maximum(out, 0)
    return out.astype(x.dtype)


def conv2d_bwd_data(do, w, *, stride: int = 1, padding: int = 0,
                    input_hw, in_channels=None, accum_dtype=jnp.float32):
    """dI from dO and W (paper §II-I).  do: (N,P,Q,K), w: (R,S,C,K).

    Oracle = exact VJP of the forward reference (autodiff ground truth);
    the *kernel* path implements the paper's duality transform and is
    validated against this.
    """
    n = do.shape[0]
    r, s, c, _ = w.shape
    h, wdt = input_hw
    x0 = jnp.zeros((n, h, wdt, c), dtype=accum_dtype)
    _, vjp = jax.vjp(
        lambda x: conv2d(x, w.astype(accum_dtype), stride=stride,
                         padding=padding, accum_dtype=accum_dtype), x0)
    (di,) = vjp(do.astype(accum_dtype))
    return di.astype(do.dtype)


def conv2d_bwd_weights(x, do, *, stride: int = 1, padding: int = 0,
                       filter_rs=None, accum_dtype=jnp.float32):
    """dW from I and dO (paper §II-J).  Returns (R,S,C,K).

    Oracle = exact VJP of the forward reference w.r.t. the weights.
    `filter_rs` disambiguates the filter size for strided convs.
    """
    n, h, wdt, c = x.shape
    _, p, q, k = do.shape
    if filter_rs is not None:
        r, s = filter_rs
    else:
        r = h + 2 * padding - (p - 1) * stride
        s = wdt + 2 * padding - (q - 1) * stride
    w0 = jnp.zeros((r, s, c, k), dtype=accum_dtype)
    _, vjp = jax.vjp(
        lambda w: conv2d(x.astype(accum_dtype), w, stride=stride,
                         padding=padding, accum_dtype=accum_dtype), w0)
    (dw,) = vjp(do.astype(accum_dtype))
    return dw.astype(x.dtype)


# ---------------------------------------------------------------------------
# Fused blocked matmul (LM hot path; paper's small-GEMM chain generalized)
# ---------------------------------------------------------------------------

def matmul_fused(a, b, *, bias=None, act: str = "none",
                 residual=None, accum_dtype=jnp.float32):
    """act(a @ b + bias [+ residual]).  a: (M,K), b: (K,N)."""
    out = jnp.dot(a.astype(accum_dtype), b.astype(accum_dtype),
                  preferred_element_type=accum_dtype)
    if bias is not None:
        out = out + bias.astype(accum_dtype)
    if residual is not None:
        out = out + residual.astype(accum_dtype)
    if act == "relu":
        out = jnp.maximum(out, 0)
    elif act == "gelu":
        out = jax.nn.gelu(out)
    elif act == "silu":
        out = jax.nn.silu(out)
    elif act != "none":
        raise ValueError(act)
    return out.astype(a.dtype)


# ---------------------------------------------------------------------------
# Depthwise causal conv1d (Mamba mixer; the one conv on an assigned-arch path)
# ---------------------------------------------------------------------------

def conv1d_causal(x, w, *, bias=None, act: str = "silu"):
    """x: (B,L,D), w: (KW,D) depthwise causal; left-pad KW-1."""
    kw, d = w.shape
    xp = jnp.pad(x, ((0, 0), (kw - 1, 0), (0, 0)))
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(kw):
        out = out + xp[:, i:i + x.shape[1], :].astype(jnp.float32) * w[i].astype(jnp.float32)
    if bias is not None:
        out = out + bias.astype(jnp.float32)
    if act == "silu":
        out = jax.nn.silu(out)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Blocked (flash-style) attention
# ---------------------------------------------------------------------------

def attention(q, k, v, *, causal: bool = True, scale=None,
              accum_dtype=jnp.float32):
    """q: (B,Hq,L,Dh), k/v: (B,Hkv,L,Dh), GQA by head repeat. -> (B,Hq,L,Dh)."""
    b, hq, l, dh = q.shape
    hkv = k.shape[1]
    if scale is None:
        scale = dh ** -0.5
    if hkv != hq:
        rep = hq // hkv
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(accum_dtype),
                        k.astype(accum_dtype)) * scale
    if causal:
        mask = jnp.tril(jnp.ones((l, l), dtype=bool))
        logits = jnp.where(mask[None, None], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", probs, v.astype(accum_dtype))
    return out.astype(q.dtype)


def attention_chunked(q, k, v, *, causal: bool = True, scale=None,
                      chunk: int = 512, accum_dtype=jnp.float32):
    """Memory-efficient attention: lax.map over query chunks, with the chunk
    body rematerialized — peak memory O(chunk × L) instead of O(L²).  This
    is the XLA-native flash formulation used by the 512-device dry-run (the
    Pallas kernel is the TPU version of the same blocking)."""
    b, hq, l, dh = q.shape
    hkv = k.shape[1]
    if scale is None:
        scale = dh ** -0.5
    if hkv != hq:
        rep = hq // hkv
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    chunk = min(chunk, l)
    if l % chunk:
        return attention(q, k, v, causal=causal, scale=scale,
                         accum_dtype=accum_dtype)
    n = l // chunk
    qc = q.reshape(b, hq, n, chunk, dh).transpose(2, 0, 1, 3, 4)

    kpos = jnp.arange(l)

    @jax.checkpoint
    def body(args):
        qi, i = args
        logits = jnp.einsum("bhqd,bhkd->bhqk", qi.astype(accum_dtype),
                            k.astype(accum_dtype)) * scale
        if causal:
            qpos = i * chunk + jnp.arange(chunk)
            mask = qpos[:, None] >= kpos[None, :]
            logits = jnp.where(mask[None, None], logits, -1e30)
        probs = jax.nn.softmax(logits, axis=-1)
        return jnp.einsum("bhqk,bhkd->bhqd", probs,
                          v.astype(accum_dtype)).astype(q.dtype)

    oc = jax.lax.map(body, (qc, jnp.arange(n)))
    return oc.transpose(1, 2, 0, 3, 4).reshape(b, hq, l, dh)


# ---------------------------------------------------------------------------
# Grouped matmul for MoE dispatch (kernel-streams analog, paper §II-H)
# ---------------------------------------------------------------------------

def moe_gmm(tokens, weights, group_sizes):
    """Grouped matmul.  tokens: (T, D) sorted by expert; weights: (E, D, F);
    group_sizes: (E,) ints summing to T.  Row t uses expert e(t)."""
    t, d = tokens.shape
    e, _, f = weights.shape
    ends = jnp.cumsum(group_sizes)
    starts = ends - group_sizes
    row = jnp.arange(t)
    # expert id per row
    eid = jnp.sum(row[:, None] >= ends[None, :], axis=1)
    w_per_row = weights[eid]                       # (T, D, F)
    out = jnp.einsum("td,tdf->tf", tokens.astype(jnp.float32),
                     w_per_row.astype(jnp.float32))
    return out.astype(tokens.dtype)
