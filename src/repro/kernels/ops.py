"""Public jit'd wrappers for every kernel, with backend dispatch.

``impl=None`` resolves through ``repro.backend`` ("xla" reference path,
"interpret" Pallas-on-CPU validation, "pallas" real TPU lowering).  Each
wrapper applies the ``core.blocking`` heuristics — the paper's §II-D
"JIT the right microkernel for the layer at hand".
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro import backend as be
from repro.core.blocking import conv_blocking, matmul_blocking
from repro.kernels import ref
from repro.kernels.attention import flash_attention as _flash
from repro.kernels.conv1d_causal import conv1d_causal as _conv1d
from repro.kernels.conv2d_direct import conv2d_direct as _conv2d
from repro.kernels.matmul_fused import matmul_fused as _matmul
from repro.kernels.moe_gmm import moe_gmm as _moe_gmm, route_dryrun

# conv2d / conv2d_train wrappers live in core.conv (they carry the custom
# VJP); re-export for a single import surface.
from repro.core.conv import conv2d_fwd as conv2d, conv2d_train  # noqa: F401


def matmul(a, b, *, bias=None, act="none", residual=None, impl=None):
    impl = be.resolve(impl)
    m, k = a.shape
    n = b.shape[1]
    if impl == "xla":     # before the blocking choice: no tuner work to waste
        return ref.matmul_fused(a, b, bias=bias, act=act, residual=residual)
    blk = matmul_blocking(m, n, k, dtype_bytes=a.dtype.itemsize, backend=impl)
    ok = (m % blk.bm == 0) and (n % blk.bn == 0) and (k % blk.bk == 0)
    if not ok:
        return ref.matmul_fused(a, b, bias=bias, act=act, residual=residual)
    return _matmul(a, b, bias=bias, act=act, residual=residual, bm=blk.bm,
                   bn=blk.bn, bk=blk.bk, interpret=(impl == "interpret"))


def conv1d(x, w, *, bias=None, act="silu", impl=None):
    impl = be.resolve(impl)
    d = x.shape[-1]
    if impl == "xla" or d % 8 != 0:
        return ref.conv1d_causal(x, w, bias=bias, act=act)
    return _conv1d(x, w, bias=bias, act=act, d_blk=min(d, 128),
                   interpret=(impl == "interpret"))


def attention(q, k, v, *, causal=True, scale=None, impl=None):
    impl = be.resolve(impl)
    l = q.shape[2]
    bq = bk = min(l, 128)
    if impl == "xla" or l % bq != 0:
        if l >= 1024:   # O(chunk·L) memory — the dry-run/TPU-faithful path
            return ref.attention_chunked(q, k, v, causal=causal, scale=scale)
        return ref.attention(q, k, v, causal=causal, scale=scale)
    return _flash(q, k, v, causal=causal, scale=scale, bq=bq, bk=bk,
                  interpret=(impl == "interpret"))


def moe_grouped_matmul(tokens, weights, tile_eid, *, impl=None, bm=128):
    impl = be.resolve(impl)
    t, d = tokens.shape
    e, _, f = weights.shape
    if impl == "xla":
        sizes = jnp.bincount(tile_eid, length=e) * bm
        return ref.moe_gmm(tokens, weights, sizes)
    return _moe_gmm(tokens, weights, tile_eid, bm=bm,
                    bn=min(f, 128), bk=min(d, 512),
                    interpret=(impl == "interpret"))
