"""Depthwise causal conv1d Pallas kernel (Mamba mixer — the one convolution
on an assigned-architecture hot path; see DESIGN.md §5).

The paper's direct-conv recipe degenerates nicely here: feature maps are the
lane dimension (D innermost), the filter loop (KW taps, typically 4) is the
statically-unrolled small-kernel chain, and the "register block" is a
(L, D_blk) tile.  Left-padding happens once outside the kernel so in-kernel
reads are static slices (the boundary-variant problem of §II-H vanishes).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, w_ref, b_ref, o_ref, *, kw: int, l: int, act: str):
    d_blk = x_ref.shape[-1]
    acc = jnp.zeros((l, d_blk), dtype=jnp.float32)
    for i in range(kw):
        acc += x_ref[0, pl.dslice(i, l), :].astype(jnp.float32) * \
            w_ref[i, :].astype(jnp.float32)
    acc += b_ref[0, :].astype(jnp.float32)
    if act == "silu":
        acc = jax.nn.silu(acc)
    o_ref[0] = acc.astype(o_ref.dtype)


def conv1d_causal(x, w, *, bias=None, act: str = "silu", d_blk: int = 128,
                  interpret: bool = False):
    """x: (B,L,D), w: (KW,D) depthwise causal -> (B,L,D)."""
    b, l, d = x.shape
    kw, _ = w.shape
    d_blk = min(d_blk, d)
    assert d % d_blk == 0
    if bias is None:
        bias = jnp.zeros((d,), x.dtype)
    xp = jnp.pad(x, ((0, 0), (kw - 1, 0), (0, 0)))

    kern = functools.partial(_kernel, kw=kw, l=l, act=act)
    return pl.pallas_call(
        kern,
        grid=(b, d // d_blk),
        in_specs=[
            pl.BlockSpec((1, l + kw - 1, d_blk), lambda bi, di: (bi, 0, di)),
            pl.BlockSpec((kw, d_blk), lambda bi, di: (0, di)),
            pl.BlockSpec((1, d_blk), lambda bi, di: (0, di)),
        ],
        out_specs=pl.BlockSpec((1, l, d_blk), lambda bi, di: (bi, 0, di)),
        out_shape=jax.ShapeDtypeStruct((b, l, d), x.dtype),
        interpret=interpret,
    )(xp, w, bias.reshape(1, d))
