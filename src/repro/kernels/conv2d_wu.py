"""Weight-gradient ("update pass") Pallas kernel — paper §II-J / Algorithm 9.

Each grid step computes the contribution of one (image, row-block) to a full
(R, S, C, K_blk) weight-gradient tile: for every static (r, s) it performs the
small GEMM  dW[r,s] += X_rs^T @ dO_tile  with M=C, N=K_blk, K=B_P*Q — the
transpose-free analog of the paper's VLENxVLEN microkernel (on the MXU the
contraction runs over the pixel block, so the "register blocking up to VLEN"
becomes a (C, K_blk) accumulator tile resident in VMEM).

Accumulation across (n, p_b) uses the Pallas revisiting-output pattern: the
output block index is constant over the (n, p_b) sweep, the tile stays in
VMEM, and we zero-init on the first visit.  The cross-chip part of the
paper's §II-J reduction trade-off (shared dW vs. per-thread copies) lives in
``core/wu_strategy.py``.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.conv2d_direct import pad_input


def _kernel(x_ref, do_ref, o_ref, *, b_p: int, q: int, stride: int,
            r: int, s: int, accum_dtype):
    n_i = pl.program_id(1)
    pb = pl.program_id(2)

    @pl.when(jnp.logical_and(n_i == 0, pb == 0))
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    c = x_ref.shape[-1]
    k_blk = do_ref.shape[-1]
    g = do_ref[0].reshape(b_p * q, k_blk).astype(accum_dtype)
    row0 = pb * b_p * stride
    for rr in range(r):
        for ss in range(s):
            xs = x_ref[0, pl.dslice(row0 + rr, b_p, stride),
                       pl.dslice(ss, q, stride), :]           # (b_p, q, c)
            a = xs.reshape(b_p * q, c).astype(accum_dtype)
            # dW[r,s] += A^T @ G : contract over the pixel block.
            upd = jax.lax.dot_general(
                a, g, (((0,), (0,)), ((), ())),
                preferred_element_type=accum_dtype)           # (c, k_blk)
            o_ref[rr, ss, :, :] += upd


def conv2d_wu(x, do, *, stride: int = 1, padding: int = 0,
              filter_rs: tuple[int, int], b_p: int = 7,
              k_blk: int | None = None, accum_dtype=jnp.float32,
              interpret: bool = False):
    """dW (R,S,C,K) from x (N,H,W,C) and dO (N,P,Q,K).

    `b_p` is the paper's B_P spatial blocking of the update pass; B_Q is the
    full row.  Requires P % b_p == 0 (the blocking heuristic only proposes
    divisors — the paper likewise picks blockings "depending on the layer
    characteristics").
    """
    n, h, wdt, c = x.shape
    _, p, q, k = do.shape
    r, s = filter_rs
    b_p = min(b_p, p)
    assert p % b_p == 0, (p, b_p)
    if k_blk is None:
        k_blk = min(k, 128)
    assert k % k_blk == 0

    xp = pad_input(x, padding=padding, stride=stride, rb_p=b_p, r=r, p=p)
    hp, wp = xp.shape[1], xp.shape[2]
    p_b = p // b_p
    k_b = k // k_blk
    grid = (k_b, n, p_b)   # output tile constant over the (n, p_b) sweep

    kern = functools.partial(_kernel, b_p=b_p, q=q, stride=stride, r=r, s=s,
                             accum_dtype=accum_dtype)
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, hp, wp, c), lambda ki, ni, pi: (ni, 0, 0, 0)),
            pl.BlockSpec((1, b_p, q, k_blk), lambda ki, ni, pi: (ni, pi, 0, ki)),
        ],
        out_specs=pl.BlockSpec((r, s, c, k_blk),
                               lambda ki, ni, pi: (0, 0, 0, ki)),
        out_shape=jax.ShapeDtypeStruct((r, s, c, k), accum_dtype),
        interpret=interpret,
    )(xp, do)
    return out.astype(x.dtype)
