"""Weight-gradient ("update pass") Pallas kernel — paper §II-J / Algorithm 9.

Each grid step computes the contribution of one (image, row-block, col-block)
to a (R, S, C_blk, K_blk) weight-gradient tile: for every static (r, s) it
performs the small GEMM  dW[r,s] += X_rs^T @ dO_tile  with M=C_blk, N=K_blk,
K=B_P*B_Q — the transpose-free analog of the paper's VLENxVLEN microkernel
(on the MXU the contraction runs over the pixel block, so the "register
blocking up to VLEN" becomes a (C_blk, K_blk) accumulator tile resident in
VMEM).

Tiled (default, the PR-3 forward discipline brought to the update pass):

  * the grid is ``(K_b, C_b, N, P_b, Q_b)`` — the dW tile index depends only
    on the two outer axes, so the Pallas revisiting-output pattern keeps one
    (r, s, C_blk, K_blk) f32 tile in VMEM across the whole (n, p, q) sweep,
    zero-initialized on the first visit of each (k, c) block pair;
  * the input BlockSpec streams only the ``(b_p-1)*stride + r`` row band
    (x ``(rb_q-1)*stride + s`` columns x C_blk channels) each step actually
    reads, via unblocked index_maps over the padded plane — the VMEM working
    set is independent of H*W (``core.blocking.conv_working_set``);
  * P and Q use ceil-div grids: the dO tail block's out-of-range rows/cols
    are masked to zero in-kernel (loads of a tail input block are allowed but
    carry garbage), so every layer schedules — no ``P % b_p == 0``
    restriction, the 224x224 7x7 stem included.

The pre-refactor variant that shipped the **entire padded input plane per
image** into VMEM at every grid step (and could not block C or Q, and
required ``b_p | P``) is kept as ``whole_plane=True`` (knob:
``REPRO_CONV_TILING=whole`` / ``repro.backend.set_conv_tiling``) for A/B
benchmarking — ``benchmarks/bwd_wu_layers.py`` writes the comparison to
``BENCH_bwd_wu.json``.

The cross-chip part of the paper's §II-J reduction trade-off (shared dW vs.
per-thread copies) lives in ``core/wu_strategy.py``.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.conv2d_direct import pad_input


def _kernel_tiled(x_ref, do_ref, o_ref, *, b_p: int, rb_q: int, stride: int,
                  r: int, s: int, p: int, q: int, accum_dtype):
    """One band-streamed update-pass step: accumulate this (n, p, q) block's
    contribution into the resident (r, s, C_blk, K_blk) dW tile."""
    ni = pl.program_id(2)
    pb = pl.program_id(3)
    qb = pl.program_id(4)

    first = jnp.logical_and(jnp.logical_and(ni == 0, pb == 0), qb == 0)

    @pl.when(first)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    c_blk = x_ref.shape[-1]
    k_blk = do_ref.shape[-1]
    g = do_ref[0].astype(accum_dtype)                 # (b_p, rb_q, k_blk)
    if p % b_p or q % rb_q:
        # ceil-div tail: the dO block read past (P, Q) is garbage — zero it
        # so it contributes nothing to the accumulation (the fwd kernel's
        # masked-store trick is not available here: dO is an *input*).
        rows = pb * b_p + jax.lax.broadcasted_iota(jnp.int32, (b_p, rb_q), 0)
        cols = qb * rb_q + jax.lax.broadcasted_iota(jnp.int32, (b_p, rb_q), 1)
        g = jnp.where(((rows < p) & (cols < q))[..., None], g, 0)
    g = g.reshape(b_p * rb_q, k_blk)
    for rr in range(r):
        for ss in range(s):
            xs = x_ref[0, pl.dslice(rr, b_p, stride),
                       pl.dslice(ss, rb_q, stride), :]    # (b_p, rb_q, c_blk)
            a = xs.reshape(b_p * rb_q, c_blk).astype(accum_dtype)
            # dW[r,s] += A^T @ G : contract over the pixel block.
            o_ref[rr, ss, :, :] += jax.lax.dot_general(
                a, g, (((0,), (0,)), ((), ())),
                preferred_element_type=accum_dtype)


def _kernel_whole(x_ref, do_ref, o_ref, *, b_p: int, q: int, stride: int,
                  r: int, s: int, accum_dtype):
    """Legacy update-pass step: whole padded plane resident, row selection
    via the P-block program id (kept for A/B benchmarking)."""
    n_i = pl.program_id(1)
    pb = pl.program_id(2)

    @pl.when(jnp.logical_and(n_i == 0, pb == 0))
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    c = x_ref.shape[-1]
    k_blk = do_ref.shape[-1]
    g = do_ref[0].reshape(b_p * q, k_blk).astype(accum_dtype)
    row0 = pb * b_p * stride
    for rr in range(r):
        for ss in range(s):
            xs = x_ref[0, pl.dslice(row0 + rr, b_p, stride),
                       pl.dslice(ss, q, stride), :]           # (b_p, q, c)
            a = xs.reshape(b_p * q, c).astype(accum_dtype)
            upd = jax.lax.dot_general(
                a, g, (((0,), (0,)), ((), ())),
                preferred_element_type=accum_dtype)           # (c, k_blk)
            o_ref[rr, ss, :, :] += upd


def conv2d_wu(x, do, *, stride: int = 1, padding: int = 0,
              filter_rs: tuple[int, int], b_p: int = 7,
              k_blk: int | None = None, c_blk: int | None = None,
              rb_q: int | None = None, accum_dtype=jnp.float32,
              whole_plane: bool | None = None, interpret: bool = False):
    """dW (R,S,C,K) from x (N,H,W,C) and dO (N,P,Q,K).

    ``b_p``/``rb_q`` are the paper's B_P/B_Q spatial blocking of the update
    pass (``rb_q=None`` = the full row); ``k_blk``/``c_blk`` block the
    output/input features (``c_blk=None`` = unblocked).  P and Q grids are
    ceil-div — tails are masked in-kernel, so no divisibility of the spatial
    dims is required.  ``whole_plane`` selects the legacy resident-plane
    kernel (default: the ``repro.backend`` conv-tiling knob); that path keeps
    the seed's ``P % b_p == 0`` restriction.
    """
    n, h, wdt, c = x.shape
    _, p, q, k = do.shape
    r, s = filter_rs
    b_p = min(b_p, p)
    if k_blk is None:
        k_blk = min(k, 128)
    assert k % k_blk == 0, (k, k_blk)
    if whole_plane is None:
        from repro import backend as be
        whole_plane = be.get_conv_tiling() == "whole"

    if whole_plane:
        return _conv2d_wu_whole(x, do, stride=stride, padding=padding,
                                r=r, s=s, b_p=b_p, k_blk=k_blk,
                                accum_dtype=accum_dtype, interpret=interpret)

    rb_q = q if rb_q in (None, 0) else min(rb_q, q)
    c_blk = c if c_blk in (None, 0) else c_blk
    assert c % c_blk == 0, (c, c_blk)

    xp = pad_input(x, padding=padding, stride=stride, rb_p=b_p, r=r, p=p,
                   rb_q=rb_q, s=s, q=q)
    band_h = (b_p - 1) * stride + r
    band_w = (rb_q - 1) * stride + s
    p_b = math.ceil(p / b_p)
    q_b = math.ceil(q / rb_q)
    k_b = k // k_blk
    c_b = c // c_blk
    # dW tile constant over the inner (n, p_b, q_b) sweep -> one VMEM-resident
    # accumulation pass per (k, c) block pair.
    grid = (k_b, c_b, n, p_b, q_b)

    kern = functools.partial(_kernel_tiled, b_p=b_p, rb_q=rb_q, stride=stride,
                             r=r, s=s, p=p, q=q, accum_dtype=accum_dtype)
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            # Row-band streaming: unblocked indexing (element offsets) —
            # consecutive bands overlap by the (r - stride)-row halo and are
            # not aligned to any fixed block size.  pad_input guarantees the
            # last band stays in bounds.
            pl.BlockSpec((1, band_h, band_w, c_blk),
                         lambda ki, ci, ni, pi, qi:
                             (ni, pi * b_p * stride, qi * rb_q * stride,
                              ci * c_blk),
                         indexing_mode=pl.unblocked),
            pl.BlockSpec((1, b_p, rb_q, k_blk),
                         lambda ki, ci, ni, pi, qi: (ni, pi, qi, ki)),
        ],
        out_specs=pl.BlockSpec((r, s, c_blk, k_blk),
                               lambda ki, ci, ni, pi, qi: (0, 0, ci, ki)),
        out_shape=jax.ShapeDtypeStruct((r, s, c, k), accum_dtype),
        interpret=interpret,
    )(xp, do)
    return out.astype(x.dtype)


def _conv2d_wu_whole(x, do, *, stride, padding, r, s, b_p, k_blk,
                     accum_dtype, interpret):
    """The pre-refactor kernel: whole padded plane per image in VMEM, C and Q
    unblocked, grid (K_b, N, P_b).  Working set scales with H*W*C and
    requires b_p | P."""
    n, h, wdt, c = x.shape
    _, p, q, k = do.shape
    assert p % b_p == 0, (p, b_p)

    xp = pad_input(x, padding=padding, stride=stride, rb_p=b_p, r=r, p=p)
    hp, wp = xp.shape[1], xp.shape[2]
    p_b = p // b_p
    k_b = k // k_blk
    grid = (k_b, n, p_b)   # output tile constant over the (n, p_b) sweep

    kern = functools.partial(_kernel_whole, b_p=b_p, q=q, stride=stride,
                             r=r, s=s, accum_dtype=accum_dtype)
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, hp, wp, c), lambda ki, ni, pi: (ni, 0, 0, 0)),
            pl.BlockSpec((1, b_p, q, k_blk), lambda ki, ni, pi: (ni, pi, 0, ki)),
        ],
        out_specs=pl.BlockSpec((r, s, c, k_blk),
                               lambda ki, ni, pi: (0, 0, 0, ki)),
        out_shape=jax.ShapeDtypeStruct((r, s, c, k), accum_dtype),
        interpret=interpret,
    )(xp, do)
    return out.astype(x.dtype)
