"""Fused blocked matmul Pallas kernel — the paper's small-GEMM + fused-L()
recipe applied to the LM hot path (QKV/MLP projections).

Grid (M_b, N_b, K_b) with a VMEM f32 accumulator tile; the epilogue
(bias / activation / residual) fires on the last K step, while the tile is
hot in VMEM — the §II-G fusion argument, verbatim.  Block shapes are chosen
by ``core.blocking`` to be MXU-aligned (multiples of (8,128)) and to fit the
VMEM working set.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_ACTS = {
    "none": lambda x: x,
    "relu": lambda x: jnp.maximum(x, 0),
    "gelu": jax.nn.gelu,
    "silu": jax.nn.silu,
}


def _kernel(a_ref, b_ref, *refs, act: str, has_bias: bool, has_res: bool,
            n_k: int, out_dtype):
    idx = 0
    bias_ref = res_ref = None
    if has_bias:
        bias_ref = refs[idx]; idx += 1
    if has_res:
        res_ref = refs[idx]; idx += 1
    o_ref = refs[idx]
    acc_ref = refs[idx + 1]

    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot(a_ref[...].astype(jnp.float32),
                                b_ref[...].astype(jnp.float32),
                                preferred_element_type=jnp.float32)

    @pl.when(ki == n_k - 1)
    def _epilogue():
        out = acc_ref[...]
        if has_bias:
            out = out + bias_ref[0].astype(jnp.float32)
        if has_res:
            out = out + res_ref[...].astype(jnp.float32)
        out = _ACTS[act](out)
        o_ref[...] = out.astype(out_dtype)


def matmul_fused(a, b, *, bias=None, act: str = "none", residual=None,
                 bm: int = 128, bn: int = 128, bk: int = 512,
                 interpret: bool = False):
    """act(a @ b + bias [+ residual]).  a: (M,K), b: (K,N) -> (M,N)."""
    m, k = a.shape
    _, n = b.shape
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (a.shape, b.shape, bm, bn, bk)
    n_k = k // bk
    grid = (m // bm, n // bn, n_k)

    in_specs = [
        pl.BlockSpec((bm, bk), lambda mi, ni, ki: (mi, ki)),
        pl.BlockSpec((bk, bn), lambda mi, ni, ki: (ki, ni)),
    ]
    args = [a, b]
    if bias is not None:
        in_specs.append(pl.BlockSpec((1, bn), lambda mi, ni, ki: (0, ni)))
        args.append(bias.reshape(1, n))
    if residual is not None:
        in_specs.append(pl.BlockSpec((bm, bn), lambda mi, ni, ki: (mi, ni)))
        args.append(residual)

    kern = functools.partial(_kernel, act=act, has_bias=bias is not None,
                             has_res=residual is not None, n_k=n_k,
                             out_dtype=a.dtype)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda mi, ni, ki: (mi, ni)),
        out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(*args)
