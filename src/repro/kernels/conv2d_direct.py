"""Direct-convolution forward Pallas kernel (paper §II-B..D,G adapted to TPU).

TPU mapping of the paper's blocked direct convolution:

  * ``VLEN`` feature-map blocking  -> channels live in the lane dimension
    (NHWC / RSCK layouts, C and K innermost).
  * register blocking ``RB_P x RB_Q`` -> an MXU M-tile of ``RB_P`` full output
    rows (M = RB_P*Q), so each grid step is one "microkernel invocation"
    computing an (RB_P*Q, K_blk) output tile.
  * the (r, s, C_b) small-GEMM chain -> statically unrolled (r, s) loop of
    ``jax.lax.dot_general`` calls over VMEM slices, f32 accumulation.
  * layer fusion (§II-G)            -> bias / BN-scale-shift / residual-add /
    ReLU epilogue fused into the same kernel, applied while the tile is in
    VMEM ("hot in cache").
  * two-level prefetch (§II-E)      -> the Mosaic grid pipeliner double-buffers
    the next step's blocks automatically; grid order (N, K_b, P_b) keeps the
    weight block resident across the P sweep (weight-stationary reuse).

The spatial input plane is passed whole per image (it fits VMEM for every
layer of the paper's Table I); strided row/column access inside the kernel
uses strided ``pl.dslice``.  Inputs must be pre-padded (``pad_input``) so no
in-kernel slice ever leaves the array — the bottom padding also covers the
ceil-div grid tail, which is how the paper's "second kernel variant at the
P/Q boundary" (§II-H) disappears on TPU: out-of-range output rows land in
Pallas' masked out-of-bounds stores.
"""
from __future__ import annotations

import dataclasses
import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


@dataclasses.dataclass(frozen=True)
class FuseSpec:
    """Static description of the fused epilogue (paper §II-G L() operators)."""
    bias: bool = False
    bn: bool = False          # folded inference BN: scale * y + shift
    residual: bool = False
    relu: bool = False

    def n_extra_args(self) -> int:
        return int(self.bias) + 2 * int(self.bn) + int(self.residual)


def pad_input(x, *, padding: int, stride: int, rb_p: int, r: int, p: int):
    """Spatially pad x (N,H,W,C) for the kernel: `padding` on all sides plus
    bottom slack so the ceil-div row grid never reads out of bounds."""
    n, h, w, c = x.shape
    p_b = math.ceil(p / rb_p)
    rows_needed = ((p_b * rb_p - 1) * stride + r)        # last row touched + 1
    pad_bottom = max(rows_needed - (h + 2 * padding), 0) + padding
    return jnp.pad(x, ((0, 0), (padding, pad_bottom), (padding, padding), (0, 0)))


def _kernel(x_ref, w_ref, *refs, fuse: FuseSpec, rb_p: int, q: int,
            stride: int, r: int, s: int, accum_dtype, out_dtype):
    """One microkernel invocation: an (rb_p*q, k_blk) output tile."""
    idx = 0
    bias_ref = scale_ref = shift_ref = res_ref = None
    if fuse.bias:
        bias_ref = refs[idx]; idx += 1
    if fuse.bn:
        scale_ref = refs[idx]; shift_ref = refs[idx + 1]; idx += 2
    if fuse.residual:
        res_ref = refs[idx]; idx += 1
    o_ref = refs[idx]

    pb = pl.program_id(2)
    c = x_ref.shape[-1]
    k_blk = w_ref.shape[-1]
    acc = jnp.zeros((rb_p * q, k_blk), dtype=accum_dtype)
    row0 = pb * rb_p * stride
    # The paper's perfectly-chained small-GEMM sequence over (r, s):
    for rr in range(r):
        for ss in range(s):
            xs = x_ref[0, pl.dslice(row0 + rr, rb_p, stride),
                       pl.dslice(ss, q, stride), :]          # (rb_p, q, c)
            a = xs.reshape(rb_p * q, c)
            wb = w_ref[rr, ss, :, :]                         # (c, k_blk)
            acc += jax.lax.dot(a.astype(accum_dtype), wb.astype(accum_dtype),
                               preferred_element_type=accum_dtype)
    # Fused epilogue while the tile is hot in VMEM (§II-G).
    if fuse.bn:
        acc = acc * scale_ref[0, :].astype(accum_dtype)
        acc = acc + shift_ref[0, :].astype(accum_dtype)
    if fuse.bias:
        acc = acc + bias_ref[0, :].astype(accum_dtype)
    if fuse.residual:
        acc = acc + res_ref[0].reshape(rb_p * q, k_blk).astype(accum_dtype)
    if fuse.relu:
        acc = jnp.maximum(acc, 0)
    o_ref[0] = acc.reshape(rb_p, q, k_blk).astype(out_dtype)


def conv2d_direct(x, w, *, stride: int = 1, padding: int = 0,
                  bias=None, scale=None, shift=None, residual=None,
                  relu: bool = False, rb_p: int = 8, k_blk: int | None = None,
                  accum_dtype=jnp.float32, interpret: bool = False):
    """Direct conv fwd.  x: (N,H,W,C), w: (R,S,C,K) -> (N,P,Q,K).

    `rb_p` is the paper's RB_P register block (output rows per microkernel);
    RB_Q is always the full row Q (Q fits the M-tile together with rb_p for
    every shape we target).  `k_blk` is the output-feature block (paper: the
    vectorized K_b loop); defaults to min(K, 128) = one MXU N-tile.
    """
    n, h, wdt, c = x.shape
    r, s, _, k = w.shape
    p = (h + 2 * padding - r) // stride + 1
    q = (wdt + 2 * padding - s) // stride + 1
    rb_p = min(rb_p, p)
    if k_blk is None:
        k_blk = min(k, 128)
    assert k % k_blk == 0, (k, k_blk)

    fuse = FuseSpec(bias=bias is not None, bn=scale is not None,
                    residual=residual is not None, relu=relu)
    if fuse.bn:
        assert shift is not None

    xp = pad_input(x, padding=padding, stride=stride, rb_p=rb_p, r=r, p=p)
    hp, wp = xp.shape[1], xp.shape[2]
    p_b = math.ceil(p / rb_p)
    k_b = k // k_blk
    grid = (n, k_b, p_b)

    in_specs = [
        pl.BlockSpec((1, hp, wp, c), lambda ni, ki, pi: (ni, 0, 0, 0)),
        pl.BlockSpec((r, s, c, k_blk), lambda ni, ki, pi: (0, 0, 0, ki)),
    ]
    args = [xp, w]
    if fuse.bias:
        in_specs.append(pl.BlockSpec((1, k_blk), lambda ni, ki, pi: (0, ki)))
        args.append(bias.reshape(1, k))
    if fuse.bn:
        in_specs.append(pl.BlockSpec((1, k_blk), lambda ni, ki, pi: (0, ki)))
        in_specs.append(pl.BlockSpec((1, k_blk), lambda ni, ki, pi: (0, ki)))
        args.extend([scale.reshape(1, k), shift.reshape(1, k)])
    if fuse.residual:
        in_specs.append(pl.BlockSpec((1, rb_p, q, k_blk),
                                     lambda ni, ki, pi: (ni, pi, 0, ki)))
        args.append(residual)

    out_dtype = x.dtype
    kern = functools.partial(_kernel, fuse=fuse, rb_p=rb_p, q=q,
                             stride=stride, r=r, s=s,
                             accum_dtype=accum_dtype, out_dtype=out_dtype)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, rb_p, q, k_blk),
                               lambda ni, ki, pi: (ni, pi, 0, ki)),
        out_shape=jax.ShapeDtypeStruct((n, p, q, k), out_dtype),
        interpret=interpret,
    )(*args)
