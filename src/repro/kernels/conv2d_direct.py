"""Direct-convolution forward Pallas kernel (paper §II-B..E,G adapted to TPU).

TPU mapping of the paper's blocked direct convolution:

  * ``VLEN`` feature-map blocking  -> channels live in the lane dimension
    (NHWC / RSCK layouts, C and K innermost).
  * register blocking ``RB_P x RB_Q`` -> an MXU M-tile of ``RB_P`` output rows
    by ``RB_Q`` output columns (M = RB_P*RB_Q), so each grid step is one
    "microkernel invocation" computing an (RB_P*RB_Q, K_blk) output tile.
    RB_Q defaults to the full row Q; blocking it is worthwhile for wide
    images whose row band would not fit VMEM.
  * cache blocking (§II-B)          -> the input is *tiled*: each grid step
    streams only the (RB_P-1)*stride + R row band (x (RB_Q-1)*stride + S
    columns) x C_blk channels it actually reads, via unblocked BlockSpec
    index_maps over a (N, K_b, P_b, Q_b, C_b) grid — the VMEM working set is
    independent of H*W (see ``core.blocking.conv_working_set``).
  * C_b accumulation (§II-A alg. 4) -> input channels are blocked; an f32
    VMEM scratch accumulator is zero-initialized on the first C-block visit
    of an output tile and the fused epilogue fires on the last visit — the
    same FLAG_INIT/FLAG_EPILOGUE discipline ``core.streams`` encodes into
    replay schedules, here derived statically from the grid position
    (C_b is always the innermost grid axis, so visits are contiguous).
  * the (r, s) small-GEMM chain     -> statically unrolled (r, s) loop of
    ``jax.lax.dot`` calls over VMEM slices, f32 accumulation.
  * layer fusion (§II-G)            -> bias / BN-scale-shift / residual-add /
    ReLU epilogue fused into the same kernel, applied while the tile is in
    VMEM ("hot in cache").
  * loop order (§II-C)              -> the grid is laid out per ``order``
    (a permutation of "nkpc", C innermost; Q rides with P), trading
    weight-block vs input-band reuse exactly as in the paper.
  * two-level prefetch (§II-E)      -> the Mosaic grid pipeliner
    double-buffers the next step's blocks automatically.

Inputs must be pre-padded (``pad_input``) so no in-kernel slice ever leaves
the array — the bottom/right padding also covers the ceil-div grid tail,
which is how the paper's "second kernel variant at the P/Q boundary" (§II-H)
disappears on TPU: out-of-range output rows land in Pallas' masked
out-of-bounds stores.

The pre-refactor variant that shipped the whole padded input plane per image
into VMEM on every grid step is kept as ``whole_plane=True`` (knob:
``REPRO_CONV_TILING=whole`` / ``repro.backend.set_conv_tiling``) for A/B
benchmarking; it only works for layers whose plane fits the VMEM budget.
"""
from __future__ import annotations

import dataclasses
import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


@dataclasses.dataclass(frozen=True)
class FuseSpec:
    """Static description of the fused epilogue (paper §II-G L() operators)."""
    bias: bool = False
    bn: bool = False          # folded inference BN: scale * y + shift
    residual: bool = False
    relu: bool = False

    def n_extra_args(self) -> int:
        return int(self.bias) + 2 * int(self.bn) + int(self.residual)


def pad_input(x, *, padding: int, stride: int, rb_p: int, r: int, p: int,
              rb_q: int | None = None, s: int | None = None,
              q: int | None = None):
    """Spatially pad x (N,H,W,C) for the kernels: `padding` on all sides plus
    bottom (and, with ``rb_q``, right) slack so the ceil-div grids never read
    out of bounds.

    The bottom slack is exactly ``rows_needed - (h + padding)``: the grid's
    last row band ends at row ``(ceil(p/rb_p)*rb_p - 1)*stride + r`` of the
    padded plane, which for ``stride > 1`` is usually *less* than the
    symmetric ``h + 2*padding`` — padding past it would inflate the plane
    (and every row band) beyond what any grid step can touch.
    """
    n, h, w, c = x.shape
    p_b = math.ceil(p / rb_p)
    rows_needed = (p_b * rb_p - 1) * stride + r          # last row touched + 1
    pad_bottom = max(rows_needed - (h + padding), 0)
    if rb_q is None:        # legacy full-row callers (wu / q8 kernels)
        pad_right = padding
    else:
        q_b = math.ceil(q / rb_q)
        cols_needed = (q_b * rb_q - 1) * stride + s      # last col touched + 1
        pad_right = max(cols_needed - (w + padding), 0)
    return jnp.pad(x, ((0, 0), (padding, pad_bottom), (padding, pad_right),
                       (0, 0)))


def _unpack_fuse_refs(refs, fuse: FuseSpec):
    idx = 0
    bias_ref = scale_ref = shift_ref = res_ref = None
    if fuse.bias:
        bias_ref = refs[idx]; idx += 1
    if fuse.bn:
        scale_ref = refs[idx]; shift_ref = refs[idx + 1]; idx += 2
    if fuse.residual:
        res_ref = refs[idx]; idx += 1
    return bias_ref, scale_ref, shift_ref, res_ref, refs[idx]


def _epilogue(acc, fuse: FuseSpec, bias_ref, scale_ref, shift_ref, res_ref,
              m: int, k_blk: int, accum_dtype):
    """The fused §II-G L() chain, applied while the tile is hot in VMEM."""
    if fuse.bn:
        acc = acc * scale_ref[0, :].astype(accum_dtype)
        acc = acc + shift_ref[0, :].astype(accum_dtype)
    if fuse.bias:
        acc = acc + bias_ref[0, :].astype(accum_dtype)
    if fuse.residual:
        acc = acc + res_ref[0].reshape(m, k_blk).astype(accum_dtype)
    if fuse.relu:
        acc = jnp.maximum(acc, 0)
    return acc


def _kernel_tiled(x_ref, w_ref, *refs, fuse: FuseSpec, rb_p: int,
                  rb_q: int, stride: int, r: int, s: int, c_axis: int,
                  accum_dtype, out_dtype):
    """One microkernel invocation on a streamed row band: accumulate one
    C-block into the scratch tile; init on the first visit, epilogue + store
    on the last (the streams FLAG_INIT/FLAG_EPILOGUE discipline, static)."""
    refs, acc_ref = refs[:-1], refs[-1]
    bias_ref, scale_ref, shift_ref, res_ref, o_ref = \
        _unpack_fuse_refs(refs, fuse)

    ci = pl.program_id(c_axis)
    c_b = pl.num_programs(c_axis)

    @pl.when(ci == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    c_blk = x_ref.shape[-1]
    k_blk = w_ref.shape[-1]
    acc = jnp.zeros((rb_p * rb_q, k_blk), dtype=accum_dtype)
    # The paper's perfectly-chained small-GEMM sequence over (r, s); the
    # band's row 0 is this step's first window row, so offsets are local.
    for rr in range(r):
        for ss in range(s):
            xs = x_ref[0, pl.dslice(rr, rb_p, stride),
                       pl.dslice(ss, rb_q, stride), :]   # (rb_p, rb_q, c_blk)
            a = xs.reshape(rb_p * rb_q, c_blk)
            acc += jax.lax.dot(a.astype(accum_dtype), w_ref[rr, ss, :, :]
                               .astype(accum_dtype),
                               preferred_element_type=accum_dtype)
    acc_ref[...] += acc

    @pl.when(ci == c_b - 1)
    def _finish():
        out = _epilogue(acc_ref[...], fuse, bias_ref, scale_ref, shift_ref,
                        res_ref, rb_p * rb_q, k_blk, accum_dtype)
        o_ref[0] = out.reshape(rb_p, rb_q, k_blk).astype(out_dtype)


def _kernel_whole(x_ref, w_ref, *refs, fuse: FuseSpec, rb_p: int, q: int,
                  stride: int, r: int, s: int, p_axis: int, accum_dtype,
                  out_dtype):
    """Legacy microkernel: whole padded plane resident, row selection via the
    P-block program id (kept for A/B benchmarking against the tiled path)."""
    bias_ref, scale_ref, shift_ref, res_ref, o_ref = \
        _unpack_fuse_refs(refs, fuse)

    pb = pl.program_id(p_axis)
    c = x_ref.shape[-1]
    k_blk = w_ref.shape[-1]
    acc = jnp.zeros((rb_p * q, k_blk), dtype=accum_dtype)
    row0 = pb * rb_p * stride
    for rr in range(r):
        for ss in range(s):
            xs = x_ref[0, pl.dslice(row0 + rr, rb_p, stride),
                       pl.dslice(ss, q, stride), :]          # (rb_p, q, c)
            a = xs.reshape(rb_p * q, c)
            acc += jax.lax.dot(a.astype(accum_dtype),
                               w_ref[rr, ss, :, :].astype(accum_dtype),
                               preferred_element_type=accum_dtype)
    acc = _epilogue(acc, fuse, bias_ref, scale_ref, shift_ref, res_ref,
                    rb_p * q, k_blk, accum_dtype)
    o_ref[0] = acc.reshape(rb_p, q, k_blk).astype(out_dtype)


def _grid_layout(order: str, *, n: int, k_b: int, p_b: int, q_b: int,
                 c_b: int):
    """Grid extents laid out per the §II-C loop order.  ``order`` permutes
    (n, k, p, c) with C innermost (the accumulator tile lives across the
    C sweep); the Q_b axis always rides directly inside P_b."""
    assert sorted(order) == sorted("nkpc"), order
    assert order.endswith("c"), "C-blocks must be innermost (accumulator)"
    axis: dict[str, int] = {}
    dims: list[int] = []
    for ch in order:
        if ch == "p":
            axis["p"] = len(dims); dims.append(p_b)
            axis["q"] = len(dims); dims.append(q_b)
        else:
            axis[ch] = len(dims)
            dims.append({"n": n, "k": k_b, "c": c_b}[ch])
    return tuple(dims), axis


def conv2d_direct(x, w, *, stride: int = 1, padding: int = 0,
                  bias=None, scale=None, shift=None, residual=None,
                  relu: bool = False, rb_p: int = 8, k_blk: int | None = None,
                  c_blk: int | None = None, rb_q: int | None = None,
                  order: str = "nkpc", whole_plane: bool | None = None,
                  accum_dtype=jnp.float32, interpret: bool = False):
    """Direct conv fwd.  x: (N,H,W,C), w: (R,S,C,K) -> (N,P,Q,K).

    `rb_p`/`rb_q` are the paper's RB_P/RB_Q register blocks (output rows /
    columns per microkernel; `rb_q=None` = the full row).  `k_blk` is the
    output-feature block (paper: the vectorized K_b loop); defaults to
    min(K, 128) = one MXU N-tile.  `c_blk` blocks the input features
    (paper C_b; `None` = unblocked): the output tile is then revisited
    across C-block grid steps and accumulated in an f32 VMEM scratch.
    `order` is the §II-C loop order of the grid.  `whole_plane` selects the
    legacy untiled kernel (default: the ``repro.backend`` conv-tiling knob).
    """
    n, h, wdt, c = x.shape
    r, s, _, k = w.shape
    p = (h + 2 * padding - r) // stride + 1
    q = (wdt + 2 * padding - s) // stride + 1
    rb_p = min(rb_p, p)
    rb_q = q if rb_q in (None, 0) else min(rb_q, q)
    if k_blk is None:
        k_blk = min(k, 128)
    c_blk = c if c_blk in (None, 0) else c_blk
    assert k % k_blk == 0, (k, k_blk)
    assert c % c_blk == 0, (c, c_blk)
    if whole_plane is None:
        from repro import backend as be
        whole_plane = be.get_conv_tiling() == "whole"

    fuse = FuseSpec(bias=bias is not None, bn=scale is not None,
                    residual=residual is not None, relu=relu)
    if fuse.bn:
        assert shift is not None

    p_b = math.ceil(p / rb_p)
    q_b = math.ceil(q / rb_q)
    k_b = k // k_blk
    c_b = c // c_blk
    out_dtype = x.dtype

    if whole_plane:
        # the legacy kernel has no C/Q blocking or order freedom — when the
        # "whole" knob overrides a tiled blocking, those axes collapse
        return _conv2d_whole_plane(
            x, w, fuse=fuse, stride=stride, padding=padding, bias=bias,
            scale=scale, shift=shift, residual=residual, rb_p=rb_p,
            k_blk=k_blk, p=p, q=q, r=r, s=s, n=n, k=k, c=c,
            accum_dtype=accum_dtype, out_dtype=out_dtype,
            interpret=interpret)

    xp = pad_input(x, padding=padding, stride=stride, rb_p=rb_p, r=r, p=p,
                   rb_q=rb_q, s=s, q=q)
    band_h = (rb_p - 1) * stride + r
    band_w = (rb_q - 1) * stride + s
    grid, axis = _grid_layout(order, n=n, k_b=k_b, p_b=p_b, q_b=q_b, c_b=c_b)
    an, ak, ap, aq, ac = (axis[d] for d in "nkpqc")

    # Row-band streaming: unblocked indexing (element offsets), because
    # consecutive bands overlap by the (r - stride)-row halo and so are not
    # aligned to any fixed block size.  pad_input guarantees the last band
    # stays in bounds.
    in_specs = [
        pl.BlockSpec((1, band_h, band_w, c_blk),
                     lambda *i: (i[an], i[ap] * rb_p * stride,
                                 i[aq] * rb_q * stride, i[ac] * c_blk),
                     indexing_mode=pl.unblocked),
        pl.BlockSpec((r, s, c_blk, k_blk),
                     lambda *i: (0, 0, i[ac], i[ak])),
    ]
    args = [xp, w]
    if fuse.bias:
        in_specs.append(pl.BlockSpec((1, k_blk), lambda *i: (0, i[ak])))
        args.append(bias.reshape(1, k))
    if fuse.bn:
        in_specs.append(pl.BlockSpec((1, k_blk), lambda *i: (0, i[ak])))
        in_specs.append(pl.BlockSpec((1, k_blk), lambda *i: (0, i[ak])))
        args.extend([scale.reshape(1, k), shift.reshape(1, k)])
    if fuse.residual:
        in_specs.append(pl.BlockSpec((1, rb_p, rb_q, k_blk),
                                     lambda *i: (i[an], i[ap], i[aq], i[ak])))
        args.append(residual)

    kern = functools.partial(_kernel_tiled, fuse=fuse, rb_p=rb_p, rb_q=rb_q,
                             stride=stride, r=r, s=s, c_axis=ac,
                             accum_dtype=accum_dtype, out_dtype=out_dtype)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, rb_p, rb_q, k_blk),
                               lambda *i: (i[an], i[ap], i[aq], i[ak])),
        out_shape=jax.ShapeDtypeStruct((n, p, q, k), out_dtype),
        scratch_shapes=[pltpu.VMEM((rb_p * rb_q, k_blk), accum_dtype)],
        interpret=interpret,
    )(*args)


def _conv2d_whole_plane(x, w, *, fuse, stride, padding, bias, scale, shift,
                        residual, rb_p, k_blk, p, q, r, s, n, k, c,
                        accum_dtype, out_dtype, interpret):
    """The pre-refactor kernel: whole padded plane per image in VMEM, C and Q
    unblocked, grid (N, K_b, P_b).  Working set scales with H*W*C."""
    xp = pad_input(x, padding=padding, stride=stride, rb_p=rb_p, r=r, p=p)
    hp, wp = xp.shape[1], xp.shape[2]
    p_b = math.ceil(p / rb_p)
    k_b = k // k_blk
    grid = (n, k_b, p_b)

    in_specs = [
        pl.BlockSpec((1, hp, wp, c), lambda ni, ki, pi: (ni, 0, 0, 0)),
        pl.BlockSpec((r, s, c, k_blk), lambda ni, ki, pi: (0, 0, 0, ki)),
    ]
    args = [xp, w]
    if fuse.bias:
        in_specs.append(pl.BlockSpec((1, k_blk), lambda ni, ki, pi: (0, ki)))
        args.append(bias.reshape(1, k))
    if fuse.bn:
        in_specs.append(pl.BlockSpec((1, k_blk), lambda ni, ki, pi: (0, ki)))
        in_specs.append(pl.BlockSpec((1, k_blk), lambda ni, ki, pi: (0, ki)))
        args.extend([scale.reshape(1, k), shift.reshape(1, k)])
    if fuse.residual:
        in_specs.append(pl.BlockSpec((1, rb_p, q, k_blk),
                                     lambda ni, ki, pi: (ni, pi, 0, ki)))
        args.append(residual)

    kern = functools.partial(_kernel_whole, fuse=fuse, rb_p=rb_p, q=q,
                             stride=stride, r=r, s=s, p_axis=2,
                             accum_dtype=accum_dtype, out_dtype=out_dtype)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, rb_p, q, k_blk),
                               lambda ni, ki, pi: (ni, pi, 0, ki)),
        out_shape=jax.ShapeDtypeStruct((n, p, q, k), out_dtype),
        interpret=interpret,
    )(*args)
