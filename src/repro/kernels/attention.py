"""Blocked (flash-style) causal attention Pallas kernel.

Not a paper contribution per se, but the paper's blocking discipline applied
to the LM hot path: the KV sweep is the in-grid accumulation loop, the
(BQ, Dh) output tile + running (m, l) softmax statistics live in VMEM
scratch, and fully-masked KV blocks are skipped with ``pl.when`` (the
schedule-level analog of the §II-H boundary variants).  GQA is handled by
mapping each query-head grid step onto its KV head via index_map arithmetic.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            scale: float, causal: bool, bq: int, bk: int, n_kb: int,
            out_dtype):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    def _body():
        q = q_ref[0].astype(jnp.float32) * scale            # (bq, dh)
        k = k_ref[0].astype(jnp.float32)                    # (bk, dh)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (bq, bk)
        if causal:
            qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kpos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(qpos >= kpos, s, _NEG_INF)
        m_prev = m_ref[...]                                 # (bq, 1)
        m_cur = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1, keepdims=True)
        m_ref[...] = m_cur
        v = v_ref[0].astype(jnp.float32)                    # (bk, dh)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot(
            p, v, preferred_element_type=jnp.float32)

    if causal:
        # Skip fully-masked blocks (strictly above the diagonal) — the
        # schedule-level analog of the §II-H boundary variants.
        pl.when(ki * bk <= qi * bq + bq - 1)(_body)
    else:
        _body()

    @pl.when(ki == n_kb - 1)
    def _finish():
        o_ref[0] = (acc_ref[...] / l_ref[...]).astype(out_dtype)


def flash_attention(q, k, v, *, causal: bool = True, scale: float | None = None,
                    bq: int = 128, bk: int = 128, interpret: bool = False):
    """q: (B,Hq,L,Dh), k/v: (B,Hkv,L,Dh) -> (B,Hq,L,Dh).  GQA via head map."""
    b, hq, l, dh = q.shape
    hkv = k.shape[1]
    assert hq % hkv == 0
    rep = hq // hkv
    if scale is None:
        scale = dh ** -0.5
    bq = min(bq, l)
    bk = min(bk, l)
    assert l % bq == 0 and l % bk == 0
    n_kb = l // bk
    grid = (b * hq, l // bq, n_kb)

    qr = q.reshape(b * hq, l, dh)
    kr = k.reshape(b * hkv, l, dh)
    vr = v.reshape(b * hkv, l, dh)

    kern = functools.partial(_kernel, scale=scale, causal=causal, bq=bq,
                             bk=bk, n_kb=n_kb, out_dtype=q.dtype)
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, dh), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, bk, dh), lambda bh, qi, ki: (bh // rep, ki, 0)),
            pl.BlockSpec((1, bk, dh), lambda bh, qi, ki: (bh // rep, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, dh), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hq, l, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, dh), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(b, hq, l, dh)
