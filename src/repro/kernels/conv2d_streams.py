"""Replay engine for kernel streams (paper §II-H, Algorithm 5) as a single
scalar-prefetch-driven Pallas kernel.

The grid is the flat schedule; BlockSpec index_maps read the scalar-prefetched
offset streams (i_off / w_off / o_off of Fig. 1), and the per-step flag word
selects zero-init / epilogue / fused-L() — so boundary variants and fusion
cost zero branches in the steady state, exactly the paper's claim.  Unlike
``conv2d_direct`` this variant blocks the input-feature dimension C_b too, so
one output tile is *revisited* across C-block steps and the fused epilogue
really must fire only on the last visit (the Algorithm-4 ``c_b == C_b-1``
condition, moved into the schedule at dryrun time).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import backend as be
from repro.core.blocking import conv_blocking
from repro.core.streams import (FLAG_EPILOGUE, FLAG_INIT, FLAG_RELU,
                                ConvSchedule, build_conv_schedule)
from repro.kernels.conv2d_direct import pad_input


def _kernel(flags_ref, n_s, kb_s, pb_s, cb_s,   # scalar-prefetched streams
            x_ref, w_ref, bias_ref, o_ref, *, rb_p: int, q: int,
            stride: int, r: int, s: int, accum_dtype):
    i = pl.program_id(0)
    flag = flags_ref[i]

    @pl.when((flag & FLAG_INIT) != 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    c_blk = x_ref.shape[-1]
    k_blk = w_ref.shape[-1]
    acc = jnp.zeros((rb_p * q, k_blk), dtype=accum_dtype)
    for rr in range(r):
        for ss in range(s):
            pb = pb_s[i]
            xs = x_ref[0, pl.dslice(pb * rb_p * stride + rr, rb_p, stride),
                       pl.dslice(ss, q, stride), :]
            a = xs.reshape(rb_p * q, c_blk)
            acc += jax.lax.dot(a.astype(accum_dtype),
                               w_ref[rr, ss].astype(accum_dtype),
                               preferred_element_type=accum_dtype)
    o_ref[0] += acc.reshape(rb_p, q, k_blk)

    @pl.when((flag & FLAG_EPILOGUE) != 0)
    def _epilogue():
        out = o_ref[0] + bias_ref[0].astype(accum_dtype)
        out = jnp.where((flag & FLAG_RELU) != 0, jnp.maximum(out, 0), out)
        o_ref[0] = out


def conv2d_streams(x, w, *, schedule: ConvSchedule, stride: int = 1,
                   padding: int = 0, bias=None, rb_p: int = 8,
                   k_blk: int | None = None, c_blk: int | None = None,
                   accum_dtype=jnp.float32, interpret: bool = False):
    """Replay `schedule` over x (N,H,W,C), w (R,S,C,K) -> (N,P,Q,K) f32.

    Output stays f32 (the accumulator tile lives in the output block across
    C-block revisits — same as the paper's int16 kernels keeping 32-bit
    outputs); callers cast.
    """
    n, h, wdt, c = x.shape
    r, s, _, k = w.shape
    p = (h + 2 * padding - r) // stride + 1
    q = (wdt + 2 * padding - s) // stride + 1
    rb_p = min(rb_p, p)
    k_blk = k_blk or min(k, 128)
    c_blk = c_blk or min(c, 128)
    assert k % k_blk == 0 and c % c_blk == 0
    n_g, k_b, p_b, c_b = schedule.grid
    assert (n_g, k_b, p_b, c_b) == (n, k // k_blk, math.ceil(p / rb_p),
                                    c // c_blk), "schedule/layer mismatch"
    if bias is None:
        bias = jnp.zeros((k,), x.dtype)

    xp = pad_input(x, padding=padding, stride=stride, rb_p=rb_p, r=r, p=p)
    hp, wp = xp.shape[1], xp.shape[2]

    kern = functools.partial(_kernel, rb_p=rb_p, q=q, stride=stride, r=r,
                             s=s, accum_dtype=accum_dtype)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=5,
        grid=(len(schedule),),
        in_specs=[
            pl.BlockSpec((1, hp, wp, c_blk),
                         lambda i, fl, ns, ks, ps, cs: (ns[i], 0, 0, cs[i])),
            pl.BlockSpec((r, s, c_blk, k_blk),
                         lambda i, fl, ns, ks, ps, cs: (0, 0, cs[i], ks[i])),
            pl.BlockSpec((1, k_blk),
                         lambda i, fl, ns, ks, ps, cs: (0, ks[i])),
        ],
        out_specs=pl.BlockSpec((1, rb_p, q, k_blk),
                               lambda i, fl, ns, ks, ps, cs: (ns[i], ps[i], 0, ks[i])),
    )
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n, p, q, k), accum_dtype),
        interpret=interpret,
    )(jnp.asarray(schedule.flags), jnp.asarray(schedule.n_ids),
      jnp.asarray(schedule.kb_ids), jnp.asarray(schedule.pb_ids),
      jnp.asarray(schedule.cb_ids), xp, w, bias.reshape(1, k))


def conv2d_streams_auto(x, w, *, stride=1, padding=0, bias=None, relu=False,
                        rb_p=None, k_blk=None, c_blk=None, order=None,
                        blocking=None, autotune=None, interpret=False):
    """Dryrun + replay in one call (the common path).

    Knob precedence: explicitly passed rb_p/k_blk/c_blk/order always win;
    `blocking` (a ``core.blocking.ConvBlocking``) fills whatever the caller
    left unset; the seed defaults (rb_p=8, 128-lane feature blocks, "nkpc")
    fill the rest.  When the caller pins *nothing* and autotuning is on
    (`autotune` kwarg or the ``repro.backend`` knob), the tuned "streams"
    blocking supplies the knobs *and* the dryrun loop order — the schedule
    itself is shape-specialized, not just the tile sizes.
    """
    n, h, wdt, c = x.shape
    r, s, _, k = w.shape
    p = (h + 2 * padding - r) // stride + 1
    untouched = rb_p is None and k_blk is None and c_blk is None and order is None
    if blocking is None and untouched and be.resolve_autotune(autotune) != "off":
        blocking = conv_blocking(
            h=h, w=wdt, c=c, k=k, r=r, s=s, stride=stride, padding=padding,
            dtype_bytes=x.dtype.itemsize, autotune=autotune, kind="streams",
            backend="interpret" if interpret else "pallas", minibatch=n)
    if blocking is not None:    # fills only the knobs the caller left unset
        rb_p = blocking.rb_p if rb_p is None else rb_p
        k_blk = blocking.k_blk if k_blk is None else k_blk
        c_blk = blocking.c_blk if c_blk is None else c_blk
        order = blocking.order if order is None else order
    rb_p = 8 if rb_p is None else rb_p
    order = order or "nkpc"
    rb_p_eff = min(rb_p, p)
    k_blk = k_blk or min(k, 128)
    c_blk = c_blk or min(c, 128)
    sched = build_conv_schedule(
        n=n, k_b=k // k_blk, p_b=math.ceil(p / rb_p_eff), c_b=c // c_blk,
        order=order, relu=relu)
    out = conv2d_streams(x, w, schedule=sched, stride=stride, padding=padding,
                         bias=bias, rb_p=rb_p, k_blk=k_blk, c_blk=c_blk,
                         interpret=interpret)
    return out.astype(x.dtype)
