"""Grouped matmul for MoE expert dispatch — kernel streams (paper §II-H)
applied to a second domain.

The routing step is the *dryrun*: it sorts tokens by expert into
capacity-padded groups whose starts are tile-aligned, and records a
``tile_eid`` stream (which expert's weight block each M-tile must use).  The
*replay* is one Pallas grid walking the tiles, with the expert-id stream
scalar-prefetched and consumed by the weight BlockSpec index_map — the exact
i_off/w_off/o_off structure of Fig. 1, with w_off = f(expert).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(eid_ref, x_ref, w_ref, o_ref, acc_ref, *, n_k: int, out_dtype):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot(x_ref[...].astype(jnp.float32),
                                w_ref[0].astype(jnp.float32),
                                preferred_element_type=jnp.float32)

    @pl.when(ki == n_k - 1)
    def _emit():
        o_ref[...] = acc_ref[...].astype(out_dtype)


def moe_gmm(tokens, weights, tile_eid, *, bm: int = 128, bn: int = 128,
            bk: int = 512, interpret: bool = False):
    """tokens: (T, D) grouped by expert with tile-aligned group starts;
    weights: (E, D, F); tile_eid: (T//bm,) int32 expert id per M-tile.
    Returns (T, F)."""
    t, d = tokens.shape
    e, _, f = weights.shape
    bm, bn, bk = min(bm, t), min(bn, f), min(bk, d)
    assert t % bm == 0 and f % bn == 0 and d % bk == 0
    assert tile_eid.shape == (t // bm,)
    n_k = d // bk
    grid = (t // bm, f // bn, n_k)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda mi, ni, ki, eid: (mi, ki)),
            pl.BlockSpec((1, bk, bn), lambda mi, ni, ki, eid: (eid[mi], ki, ni)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda mi, ni, ki, eid: (mi, ni)),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
    )
    kern = functools.partial(_kernel, n_k=n_k, out_dtype=tokens.dtype)
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((t, f), tokens.dtype),
        interpret=interpret,
    )(tile_eid, tokens, weights)


def route_dryrun(expert_of_token, num_experts: int, capacity: int, bm: int):
    """Dryrun/routing: build the gather indices + tile_eid stream.

    expert_of_token: (T,) int32.  Returns (gather_idx (E*cap,), tile_eid
    (E*cap//bm,), keep_mask (E*cap,)) — gather_idx[i] = source token for
    grouped row i (capacity-padded groups, group g occupies rows
    [g*cap, (g+1)*cap)).  Pure jnp: runs on device inside jit, the "dryrun
    once per routing step" of §II-H.
    """
    t = expert_of_token.shape[0]
    assert capacity % bm == 0
    # position of each token within its expert group
    onehot = jax.nn.one_hot(expert_of_token, num_experts, dtype=jnp.int32)
    pos_in_group = (jnp.cumsum(onehot, axis=0) - 1) * onehot  # (T, E)
    pos = pos_in_group.sum(axis=1)
    ok = pos < capacity
    dest = expert_of_token * capacity + pos                   # (T,)
    dest = jnp.where(ok, dest, t * 0 + num_experts * capacity)  # drop overflow
    gather_idx = jnp.zeros((num_experts * capacity + 1,), jnp.int32)
    gather_idx = gather_idx.at[dest].set(jnp.arange(t, dtype=jnp.int32) + 1)
    gather_idx = gather_idx[:-1]
    keep = gather_idx > 0
    gather_idx = jnp.maximum(gather_idx - 1, 0)
    tile_eid = jnp.repeat(jnp.arange(num_experts, dtype=jnp.int32),
                          capacity // bm)
    return gather_idx, tile_eid, keep
