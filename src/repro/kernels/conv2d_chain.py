"""Depth-first chain replay — DESIGN.md §16 (paper §II-G/§II-H, one level up).

Executes a single-consumer conv->conv chain band by band: layer l+1's output
band is computed from layer l's output band while that band is still live in
VMEM scratch, so the intermediate activation never materializes in HBM.  The
interleaved step order, per-step output-row ranges, and the FLAG_HANDOFF
discipline come from ``core.streams.build_chain_schedule`` — this module is
the replay half; the band math lives in the dryrun.

Bit-exactness contract (the conformance wall in ``tests/test_chain_fusion.py``
asserts ``assert_array_equal`` against the unfused path): every band step
calls the *same* per-layer kernel the unfused path would, with the blocking
computed from the *full* layer shape.  ``conv2d_direct``'s per-output-element
f32 reduction order depends only on ``c_blk`` (C-block visits, then r, s,
dot-inner-c) — not on the band split — so pinning the full-shape blocking
makes the band-by-band result bit-identical, on the Pallas path and on the
XLA/reference fallback alike.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.blocking import conv_blocking
from repro.core.streams import FLAG_HANDOFF, build_chain_schedule
from repro.kernels import ref
from repro.kernels.conv2d_direct import conv2d_direct


def _lane_ok(c: int, k: int) -> bool:
    # mirrors core.conv.lane_ok (not imported: core.conv imports this package)
    return c % 8 == 0 and k % 8 == 0


def _band_conv(xb, L, blk, impl, residual):
    """One band micro-conv: same dispatch rule as ``core.conv.conv2d_fwd``,
    with the full-shape blocking passed explicitly.  ``xb`` arrives fully
    zero-padded (H plane edges + W), so the conv itself runs padding=0."""
    w = L["w"]
    c, k = w.shape[2], w.shape[3]
    kw = dict(stride=L["stride"], padding=0, bias=L.get("bias"),
              scale=L.get("scale"), shift=L.get("shift"),
              residual=residual, relu=L.get("relu", False))
    if impl == "xla" or not _lane_ok(c, k):
        return ref.conv2d_fused(xb, w, **kw)
    return conv2d_direct(xb, w, rb_p=blk.rb_p, k_blk=blk.k_blk,
                         c_blk=blk.c_blk, rb_q=blk.rb_q, order=blk.order,
                         interpret=(impl == "interpret"), **kw)


def conv2d_chain(x, layers, *, rb: int, impl: str, autotune=None):
    """Run a fused conv chain depth-first.  x: (N,H,W,C) chain input;
    ``layers``: per-conv dicts with ``w`` (R,S,C,K) and the fused-epilogue
    params (stride, padding, bias, scale, shift, residual, relu), producers
    first.  ``rb`` is the final-layer output rows per band
    (``core.blocking.chain_blocking`` picks it); returns the final layer's
    (N,P,Q,K) output, bit-identical to the unfused layer-by-layer path.
    """
    n, h, wd, _ = x.shape
    rs = [(L["w"].shape[0], L["stride"], L["padding"]) for L in layers]
    sched = build_chain_schedule(rs=rs, h_in=h, rb=rb)

    # full-shape per-layer blocking — the bit-exactness anchor (esp. c_blk)
    blks, h_ins, w_cur = [], [], wd
    h_cur = h
    for L in layers:
        r, s, c, k = L["w"].shape
        stride, pad = L["stride"], L["padding"]
        blks.append(conv_blocking(h=h_cur, w=w_cur, c=c, k=k, r=r, s=s,
                                  stride=stride, padding=pad,
                                  dtype_bytes=x.dtype.itemsize, backend=impl,
                                  autotune=autotune, kind="fwd", minibatch=n))
        h_ins.append(h_cur)
        h_cur = (h_cur + 2 * pad - r) // stride + 1
        w_cur = (w_cur + 2 * pad - s) // stride + 1

    live = {}           # layer -> (o0, o1, band) awaiting hand-off
    out_bands = []
    for i in range(len(sched)):
        l = int(sched.layer_ids[i])
        o0, o1 = int(sched.o0[i]), int(sched.o1[i])
        r, stride, pad = rs[l]
        # input rows for out rows [o0, o1), in padded coords then clipped
        a, b = o0 * stride, (o1 - 1) * stride + r
        i0, i1 = max(a - pad, 0), min(b - pad, h_ins[l])
        pt, pb = i0 + pad - a, b - pad - i1
        if l == 0:
            src = x[:, i0:i1]
        else:
            po0, _po1, prev = live[l - 1]
            src = prev[:, i0 - po0:i1 - po0]
        xb = jnp.pad(src, ((0, 0), (pt, pb), (pad, pad), (0, 0)))
        resid = layers[l].get("residual")
        yb = _band_conv(xb, layers[l], blks[l], impl,
                        None if resid is None else resid[:, o0:o1])
        if sched.flags[i] & FLAG_HANDOFF:
            live[l] = (o0, o1, yb)      # stays in VMEM; next step consumes it
        else:
            out_bands.append(yb)        # final layer: the only HBM write-back
    return jnp.concatenate(out_bands, axis=1)
