"""Max-pooling Pallas kernel with the §II-G fusion story: pooling is one of
the bandwidth-bound L() operators the paper fuses after convolutions.  The
kernel reads the conv output tile (still organized in the blocked layout)
and reduces the window in VREGs — one pass over the data.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, o_ref, *, window: int, stride: int, rb_p: int, q_out: int):
    pb = pl.program_id(2)
    c = x_ref.shape[-1]
    row0 = pb * rb_p * stride
    out = jnp.full((rb_p * q_out, c), -jnp.inf, dtype=jnp.float32)
    for wr in range(window):
        for wc in range(window):
            xs = x_ref[0, pl.dslice(row0 + wr, rb_p, stride),
                       pl.dslice(wc, q_out, stride), :]
            out = jnp.maximum(out, xs.reshape(rb_p * q_out, c)
                              .astype(jnp.float32))
    o_ref[0] = out.reshape(rb_p, q_out, c).astype(o_ref.dtype)


def maxpool2d(x, *, window: int = 3, stride: int = 2, padding: int = 1,
              rb_p: int = 8, interpret: bool = False):
    """x: (N,H,W,C) -> (N,P,Q,C) max pooling (paper's ResNet stem pool)."""
    n, h, w, c = x.shape
    p = (h + 2 * padding - window) // stride + 1
    q = (w + 2 * padding - window) // stride + 1
    rb_p = min(rb_p, p)
    pad_rows = max(((math.ceil(p / rb_p) * rb_p - 1) * stride + window)
                   - (h + 2 * padding), 0) + padding
    xp = jnp.pad(x, ((0, 0), (padding, pad_rows), (padding, padding),
                     (0, 0)), constant_values=-jnp.inf)
    hp, wp = xp.shape[1], xp.shape[2]
    grid = (n, 1, math.ceil(p / rb_p))

    kern = functools.partial(_kernel, window=window, stride=stride,
                             rb_p=rb_p, q_out=q)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[pl.BlockSpec((1, hp, wp, c),
                               lambda ni, ki, pi: (ni, 0, 0, 0))],
        out_specs=pl.BlockSpec((1, rb_p, q, c),
                               lambda ni, ki, pi: (ni, pi, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, p, q, c), x.dtype),
        interpret=interpret,
    )(xp)
