"""Roofline-gated perf regression CI (DESIGN.md §12).

The committed bench artifacts (BENCH_conv_fwd.json, BENCH_bwd_wu.json,
BENCH_train_scaling.json, BENCH_q8_infer.json, BENCH_resilience.json,
BENCH_serve_fleet.json) are point-in-time snapshots of the roofline /
goodput / serving-SLO models;
this package turns them into a *gate* in the ReFrame mold — perf numbers
expressed as pass/fail sanity checks against committed references:

  extract     per-bench extractors pull named ``(metric_id, value)`` series
              out of the bench JSONs (stable slash-separated metric IDs)
  policy      per-metric tolerance rules: relative-drop thresholds, hard
              floors ("2-dev fp32 scaling >= 0.8"), directional invariants
              ("tiled never slower than whole-plane")
  compare     baseline-vs-fresh comparison engine -> machine-readable
              Verdict + human diff table
  store       the committed baseline file (BENCH_BASELINES.json, keyed by
              generation context) and the per-PR trajectory append log
              (BENCH_TRAJECTORY.json)

Entry points: ``python -m benchmarks.run --check`` (fail the build on
regression) and ``--update-baselines`` (regenerate + stamp provenance +
append one trajectory record).
"""
from repro.perfci.check import MissingBaseline, run_check, run_update
from repro.perfci.compare import MetricResult, Verdict, compare
from repro.perfci.extract import (SCHEMA_VERSION, context_key, extract_all,
                                  extract_bwd_wu, extract_conv_fwd,
                                  extract_resilience, extract_serve_fleet,
                                  extract_train_scaling)
from repro.perfci.policy import (DEFAULT_CONTEXT, DEFAULT_POLICIES,
                                 Tolerance, policies_for_context, policy_for)
from repro.perfci.store import (BASELINE_PATH, TRAJECTORY_PATH,
                                append_trajectory, baseline_metrics,
                                load_baselines, provenance,
                                trajectory_record, update_baselines)

__all__ = [
    "SCHEMA_VERSION", "context_key", "extract_all", "extract_conv_fwd",
    "extract_bwd_wu", "extract_train_scaling", "extract_resilience",
    "extract_serve_fleet",
    "Tolerance", "DEFAULT_POLICIES", "DEFAULT_CONTEXT", "policy_for",
    "policies_for_context",
    "MetricResult", "Verdict", "compare",
    "BASELINE_PATH", "TRAJECTORY_PATH", "load_baselines", "baseline_metrics",
    "update_baselines", "append_trajectory", "trajectory_record",
    "provenance",
    "MissingBaseline", "run_check", "run_update",
]
