"""Gate driver — the logic behind ``benchmarks.run --check`` and
``--update-baselines`` (kept here so tests can drive it without the bench
harness, and the harness stays a thin CLI).
"""
from __future__ import annotations

from repro.perfci.compare import Verdict, compare
from repro.perfci.extract import extract_all
from repro.perfci.policy import policies_for_context
from repro.perfci.store import (append_trajectory, baseline_metrics,
                                load_baselines, trajectory_record,
                                update_baselines)


class MissingBaseline(Exception):
    """No committed baseline for the current generation context."""


def run_check(fresh_root, *, baseline_path=None, verbose: bool = False,
              out=print) -> Verdict:
    """Compare the bench artifacts under ``fresh_root`` against the
    committed baseline for their context; prints the human diff table and
    returns the Verdict (caller decides the exit code)."""
    context, fresh = extract_all(fresh_root)
    doc = load_baselines(baseline_path)
    base = baseline_metrics(doc, context)
    if base is None:
        have = sorted(doc.get("contexts", {}))
        raise MissingBaseline(
            f"perfci: no baseline for context '{context}' (have: {have}) — "
            f"run `python -m benchmarks.run --dry --update-baselines` under "
            f"the same REPRO_VMEM_BUDGET and commit the result")
    verdict = compare(base, fresh, policies_for_context(context))
    out(f"perfci: context={context} baseline="
        f"{doc['contexts'][context]['provenance'].get('git_sha', '?')} "
        f"({len(base)} metrics)")
    out(verdict.diff_table(verbose=verbose))
    return verdict


def run_update(fresh_root, *, baseline_path=None, trajectory_path=None,
               command: str = "", out=print) -> dict:
    """Re-pin the baseline for the current context from the artifacts under
    ``fresh_root``, stamp provenance, and append exactly one trajectory
    record (with improved/regressed counts vs the previous baseline when
    one existed)."""
    context, fresh = extract_all(fresh_root)
    prev = baseline_metrics(load_baselines(baseline_path,
                                           strict=False), context)
    verdict_json = compare(prev, fresh,
                           policies_for_context(context)).to_json() \
        if prev is not None else None
    update_baselines(fresh, context, path=baseline_path, command=command)
    rec = trajectory_record(context, fresh, verdict_json=verdict_json,
                            command=command)
    append_trajectory(rec, path=trajectory_path)
    out(f"perfci: baseline[{context}] <- {len(fresh)} metrics; trajectory "
        f"record appended ({rec['provenance']['git_sha']})")
    if verdict_json is not None and not verdict_json["ok"]:
        out(f"perfci: note — new baseline is WORSE than the previous one on "
            f"{len(verdict_json['failures'])} metrics (intentional perf "
            f"change? the trajectory records it)")
    return rec
