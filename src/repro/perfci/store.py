"""Committed baseline store + per-PR trajectory log.

``BENCH_BASELINES.json`` (repo root) is the gate's reference: the extracted
metric series per generation context, stamped with provenance —

  {"schema_version": 1,
   "contexts": {"vmem=16777216": {"provenance": {...}, "metrics": {...}},
                "vmem=1048576":  {...}}}

Contexts exist because the analytic blocking (hence every modeled number)
depends on ``REPRO_VMEM_BUDGET``: the CI perf-gate runs the 1 MiB pressure
context while a developer laptop runs the 16 MiB default, and each must be
compared against a baseline generated under the *same* budget (the ReFrame
per-system reference idiom).  ``--update-baselines`` refreshes only the
context it runs under and preserves the others.

``BENCH_TRAJECTORY.json`` is the append-only per-PR history the ROADMAP
kept asking for: exactly one record per ``--update-baselines`` run, holding
the headline aggregates (mean/min efficiencies, worst margins, scaling
cells) plus provenance, so "did PR N make us faster" is one file read.
"""
from __future__ import annotations

import json
import pathlib
import subprocess
import time

from repro.perfci.extract import SCHEMA_VERSION

_ROOT = pathlib.Path(__file__).resolve().parents[3]
BASELINE_PATH = _ROOT / "BENCH_BASELINES.json"
TRAJECTORY_PATH = _ROOT / "BENCH_TRAJECTORY.json"


def _git(*args: str) -> str:
    try:
        out = subprocess.run(["git", *args], cwd=_ROOT, capture_output=True,
                             text=True, timeout=10)
        return out.stdout.strip() if out.returncode == 0 else "unknown"
    except Exception:  # noqa: BLE001 — no git binary / not a checkout
        return "unknown"


def provenance(*, command: str = "") -> dict:
    return {
        "generated_utc": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
        "git_sha": _git("rev-parse", "--short", "HEAD"),
        "git_branch": _git("rev-parse", "--abbrev-ref", "HEAD"),
        "command": command or "python -m benchmarks.run --update-baselines",
    }


def load_baselines(path=None, *, strict: bool = True) -> dict:
    path = pathlib.Path(path or BASELINE_PATH)
    if not path.exists():
        return {"schema_version": SCHEMA_VERSION, "contexts": {}}
    doc = json.loads(path.read_text())
    if doc.get("schema_version") != SCHEMA_VERSION:
        if not strict:
            # schema bump: every old context's metric IDs are stale by
            # definition — the refresh path starts from an empty store
            return {"schema_version": SCHEMA_VERSION, "contexts": {}}
        raise ValueError(
            f"perfci: baseline schema v{doc.get('schema_version')} != "
            f"v{SCHEMA_VERSION} — regenerate with --update-baselines")
    return doc


def baseline_metrics(doc: dict, context: str) -> dict[str, float] | None:
    ctx = doc.get("contexts", {}).get(context)
    return None if ctx is None else ctx["metrics"]


def update_baselines(metrics: dict[str, float], context: str, *, path=None,
                     command: str = "") -> dict:
    """Write ``metrics`` as the new reference for ``context`` (other
    contexts preserved); returns the written document."""
    path = pathlib.Path(path or BASELINE_PATH)
    doc = load_baselines(path, strict=False)
    doc["schema_version"] = SCHEMA_VERSION
    doc.setdefault("contexts", {})[context] = {
        "provenance": provenance(command=command),
        "n_metrics": len(metrics),
        "metrics": {k: metrics[k] for k in sorted(metrics)},
    }
    path.write_text(json.dumps(doc, indent=1, sort_keys=True) + "\n")
    return doc


# -- trajectory ---------------------------------------------------------------

def _agg(metrics: dict[str, float], suffix: str) -> list[float]:
    return [v for k, v in metrics.items() if k.endswith(suffix)]


def trajectory_record(context: str, metrics: dict[str, float], *,
                      verdict_json: dict | None = None,
                      command: str = "") -> dict:
    """Headline aggregates of one baseline refresh — the per-PR data point."""
    fwd_eff = [v for k, v in metrics.items()
               if k.startswith("conv_fwd/") and
               k.endswith("roofline_efficiency")]
    wu_eff = [v for k, v in metrics.items()
              if "/wu_tiled/" in k and k.endswith("roofline_efficiency")]
    margins = _agg(metrics, "_margin")
    rec = {
        "schema_version": SCHEMA_VERSION,
        "context": context,
        "provenance": provenance(command=command),
        "n_metrics": len(metrics),
        "summary": {
            "conv_fwd_eff_mean": round(sum(fwd_eff) / len(fwd_eff), 4)
            if fwd_eff else None,
            "conv_fwd_eff_min": round(min(fwd_eff), 4) if fwd_eff else None,
            "wu_eff_mean": round(sum(wu_eff) / len(wu_eff), 4)
            if wu_eff else None,
            "margin_min": round(min(margins), 4) if margins else None,
            "scaling_d2_fp32": metrics.get(
                "train_scaling/d2/fp32/scaling_efficiency"),
            "scaling_d4_fp32": metrics.get(
                "train_scaling/d4/fp32/scaling_efficiency"),
            "scaling_d4_int8": metrics.get(
                "train_scaling/d4/int8/scaling_efficiency"),
            "q8_min_bw_speedup": metrics.get(
                "q8_infer/resnet50/min_bw_speedup"),
            "resilience_goodput": metrics.get(
                "resilience/reference/goodput_ratio"),
            "serve_fleet_goodput": metrics.get(
                "serve_fleet/reference/goodput"),
            "serve_fleet_p99_ms": metrics.get(
                "serve_fleet/reference/p99_ms"),
        },
    }
    if verdict_json is not None:
        rec["vs_previous"] = {k: verdict_json["counts"].get(k, 0)
                              for k in ("improved", "regressed", "new",
                                        "missing")}
    return rec


def append_trajectory(record: dict, *, path=None) -> dict:
    path = pathlib.Path(path or TRAJECTORY_PATH)
    doc = json.loads(path.read_text()) if path.exists() else \
        {"schema_version": SCHEMA_VERSION, "records": []}
    doc["records"].append(record)
    path.write_text(json.dumps(doc, indent=1, sort_keys=True) + "\n")
    return doc
