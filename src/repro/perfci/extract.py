"""Per-bench extractors: bench JSON -> flat ``{metric_id: value}`` series.

Metric IDs are slash-separated and *stable* — they are the join key between
a fresh bench run and the committed baseline, so renaming one (or renaming
the bench JSON fields they read, see ``launch.roofline.KERNEL_ROOFLINE_KEYS``
/ ``tune.measure.CONV_TRAFFIC_KEYS``) is a baseline-schema change and must
bump ``SCHEMA_VERSION``.

  conv_fwd/{table}/{layer}/tiled/{roofline_efficiency|cost_us|hbm_bytes}
  conv_fwd/{table}/{layer}/tiled/fits_vmem            (0.0 | 1.0)
  conv_fwd/{table}/{layer}/{cost|hbm}_margin          (whole-plane / tiled)
  bwd_wu/{table}/{layer}/wu_tiled/{roofline_efficiency|cost_us|hbm_bytes}
  bwd_wu/{table}/{layer}/wu_tiled/fits_vmem
  bwd_wu/{table}/{layer}/wu_{cost|hbm}_margin         (legacy / tiled)
  bwd_wu/{table}/{layer}/bwd_phase/{roofline_efficiency|cost_us}
  bwd_wu/{table}/{layer}/bwd_hbm_margin               (dilate / phase)
  train_scaling/d{devices}/{reduction}/{scaling_efficiency|
                                        no_overlap_efficiency|images_per_s}
  q8_infer/{table}/{layer}/q8/{roofline_efficiency|cost_us|hbm_bytes}
  q8_infer/{table}/{layer}/q8/fits_vmem
  q8_infer/{table}/{layer}/speedup                    (f32 / q8 cost)
  q8_infer/{table}/min_bw_speedup                     (only when the table
                                                       has bandwidth-bound
                                                       layers)
  resilience/{schedule}/{goodput_ratio|recovery_overhead_steps|lost_steps|
                         restarts|evictions|fold_mass_conserved}
  resilience/fold/{old}to{new}/mass_conserved         (elastic residual
                                                       fold, exact)
  serve_fleet/{schedule}/{goodput|slo_handled_rate|shed_rate|degrade_rate|
                          p50_ms|p99_ms|failed|evictions|respawns|
                          reseeded_entries|hedges|retries}
  chain_fusion/{table}/{chain}/{fused|traffic_margin|hbm_bytes|
                                intermediate_bytes|cost_us|speedup|
                                roofline_efficiency}
  chain_fusion/{table}/{n_chains|n_fused|min_traffic_margin|
                        fused_intermediate_bytes}

Margins are ratios >= 1.0 by construction of the paper's claims ("tiled
never slower than whole-plane", "zero-free duality never moves more
bytes") — the directional invariants ``policy.DEFAULT_POLICIES`` floors at
1.0 so the gate fails the moment a change flips one.  The q8 speedups are
the same idea one level up: int8 must never model slower than f32
(floor 1.0 per layer), and the ISSUE acceptance bar — >= 1.6x on every
bandwidth-bound ResNet-50 layer — is a hard floor on
``q8_infer/resnet50/min_bw_speedup``.
"""
from __future__ import annotations

import json
import pathlib

# v2: + the q8_infer bench (BENCH_q8_infer.json, int8 serving speedups)
# v3: + the resilience bench (BENCH_resilience.json, goodput under faults)
# v4: + the serve_fleet bench (BENCH_serve_fleet.json, serving SLO metrics
#     under replica chaos)
# v5: + the chain_fusion bench (BENCH_chain_fusion.json, depth-first fused
#     conv chains vs unfused)
SCHEMA_VERSION = 5

# bench-name -> committed artifact filename (repo root)
BENCH_FILES = {
    "conv_fwd": "BENCH_conv_fwd.json",
    "bwd_wu": "BENCH_bwd_wu.json",
    "train_scaling": "BENCH_train_scaling.json",
    "q8_infer": "BENCH_q8_infer.json",
    "resilience": "BENCH_resilience.json",
    "serve_fleet": "BENCH_serve_fleet.json",
    "chain_fusion": "BENCH_chain_fusion.json",
}

_EPS = 1e-12


def _ratio(num: float, den: float) -> float:
    return num / max(den, _EPS)


def extract_conv_fwd(report: dict) -> dict[str, float]:
    out: dict[str, float] = {}
    for tname, recs in report["tables"].items():
        for rec in recs:
            t, wp = rec["tiled"], rec["whole_plane"]
            base = f"conv_fwd/{tname}/{rec['layer']}"
            out[f"{base}/tiled/roofline_efficiency"] = t["roofline_efficiency"]
            out[f"{base}/tiled/cost_us"] = t["cost_us"]
            out[f"{base}/tiled/hbm_bytes"] = float(t["hbm_bytes"])
            out[f"{base}/tiled/fits_vmem"] = float(t["fits_vmem"])
            out[f"{base}/cost_margin"] = _ratio(wp["cost_us"], t["cost_us"])
            out[f"{base}/hbm_margin"] = _ratio(wp["hbm_bytes"],
                                               t["hbm_bytes"])
    return out


def extract_bwd_wu(report: dict) -> dict[str, float]:
    out: dict[str, float] = {}
    for tname, recs in report["tables"].items():
        for rec in recs:
            wt, wl = rec["wu"]["tiled"], rec["wu"]["whole_plane"]
            ph, di = rec["bwd_data"]["phase"], rec["bwd_data"]["dilate"]
            base = f"bwd_wu/{tname}/{rec['layer']}"
            out[f"{base}/wu_tiled/roofline_efficiency"] = \
                wt["roofline_efficiency"]
            out[f"{base}/wu_tiled/cost_us"] = wt["cost_us"]
            out[f"{base}/wu_tiled/hbm_bytes"] = float(wt["hbm_bytes"])
            out[f"{base}/wu_tiled/fits_vmem"] = float(wt["fits_vmem"])
            out[f"{base}/wu_cost_margin"] = _ratio(wl["cost_us"],
                                                   wt["cost_us"])
            out[f"{base}/wu_hbm_margin"] = _ratio(wl["hbm_bytes"],
                                                  wt["hbm_bytes"])
            out[f"{base}/bwd_phase/roofline_efficiency"] = \
                ph["roofline_efficiency"]
            out[f"{base}/bwd_phase/cost_us"] = ph["cost_us"]
            out[f"{base}/bwd_hbm_margin"] = _ratio(di["hbm_bytes"],
                                                   ph["hbm_bytes"])
    return out


def extract_train_scaling(report: dict) -> dict[str, float]:
    out: dict[str, float] = {}
    for r in report["rows"]:
        base = f"train_scaling/d{r['devices']}/{r['reduction']}"
        out[f"{base}/scaling_efficiency"] = r["scaling_efficiency"]
        out[f"{base}/no_overlap_efficiency"] = r["no_overlap_efficiency"]
        out[f"{base}/images_per_s"] = r["images_per_s"]
    return out


def extract_q8_infer(report: dict) -> dict[str, float]:
    out: dict[str, float] = {}
    for tname, recs in report["tables"].items():
        for rec in recs:
            if rec.get("path") != "direct":
                continue        # im2col stem: the q8 kernel never runs
            q = rec["q8"]
            base = f"q8_infer/{tname}/{rec['layer']}"
            out[f"{base}/q8/roofline_efficiency"] = q["roofline_efficiency"]
            out[f"{base}/q8/cost_us"] = q["cost_us"]
            out[f"{base}/q8/hbm_bytes"] = float(q["hbm_bytes"])
            out[f"{base}/q8/fits_vmem"] = float(q["fits_vmem"])
            out[f"{base}/speedup"] = rec["speedup"]
    for tname, s in report["summary"].items():
        if s["min_bw_speedup"] is not None:
            out[f"q8_infer/{tname}/min_bw_speedup"] = s["min_bw_speedup"]
    return out


def extract_resilience(report: dict) -> dict[str, float]:
    out: dict[str, float] = {}
    for r in report["schedules"]:
        base = f"resilience/{r['name']}"
        out[f"{base}/goodput_ratio"] = r["goodput_ratio"]
        out[f"{base}/recovery_overhead_steps"] = \
            float(r["recovery_overhead_steps"])
        out[f"{base}/lost_steps"] = float(r["lost_steps"])
        out[f"{base}/restarts"] = float(r["restarts"])
        out[f"{base}/evictions"] = float(r["evictions"])
        out[f"{base}/fold_mass_conserved"] = r["fold_mass_conserved"]
    for f in report["fold"]:
        out[f"resilience/fold/{f['from']}to{f['to']}/mass_conserved"] = \
            f["mass_conserved"]
    return out


def extract_serve_fleet(report: dict) -> dict[str, float]:
    out: dict[str, float] = {}
    for r in report["schedules"]:
        base = f"serve_fleet/{r['name']}"
        out[f"{base}/goodput"] = r["goodput"]
        out[f"{base}/slo_handled_rate"] = r["slo_handled_rate"]
        out[f"{base}/shed_rate"] = r["shed_rate"]
        out[f"{base}/degrade_rate"] = r["degrade_rate"]
        out[f"{base}/p50_ms"] = r["p50_ms"]
        out[f"{base}/p99_ms"] = r["p99_ms"]
        for k in ("failed", "evictions", "respawns", "reseeded_entries",
                  "hedges", "retries"):
            out[f"{base}/{k}"] = float(r[k])
    return out


def extract_chain_fusion(report: dict) -> dict[str, float]:
    out: dict[str, float] = {}
    for tname, table in report["tables"].items():
        for rec in table["chains"]:
            base = f"chain_fusion/{tname}/{rec['chain']}"
            out[f"{base}/fused"] = float(rec["fused"])
            out[f"{base}/traffic_margin"] = rec["traffic_margin"]
            out[f"{base}/hbm_bytes"] = float(rec["hbm_bytes"])
            out[f"{base}/intermediate_bytes"] = \
                float(rec["intermediate_bytes"])
            out[f"{base}/cost_us"] = rec["cost_us"]
            out[f"{base}/speedup"] = rec["speedup"]
            out[f"{base}/roofline_efficiency"] = rec["roofline_efficiency"]
        s = table["summary"]
        out[f"chain_fusion/{tname}/n_chains"] = float(s["n_chains"])
        out[f"chain_fusion/{tname}/n_fused"] = float(s["n_fused"])
        out[f"chain_fusion/{tname}/min_traffic_margin"] = \
            s["min_traffic_margin"]
        out[f"chain_fusion/{tname}/fused_intermediate_bytes"] = \
            float(s["fused_intermediate_bytes"])
    return out


_EXTRACTORS = {
    "conv_fwd": extract_conv_fwd,
    "bwd_wu": extract_bwd_wu,
    "train_scaling": extract_train_scaling,
    "q8_infer": extract_q8_infer,
    "resilience": extract_resilience,
    "serve_fleet": extract_serve_fleet,
    "chain_fusion": extract_chain_fusion,
}


def load_reports(root) -> dict[str, dict]:
    """Read the gated bench JSONs under ``root`` -> {bench_name: report}."""
    root = pathlib.Path(root)
    reports = {}
    for bench, fname in BENCH_FILES.items():
        path = root / fname
        if not path.exists():
            raise FileNotFoundError(
                f"perfci: missing bench artifact {path} — run the emitting "
                f"bench (benchmarks.run --dry regenerates all of them)")
        reports[bench] = json.loads(path.read_text())
    return reports


def context_key(reports: dict[str, dict]) -> str:
    """The generation-context signature baselines are keyed by.

    The bench model's only environment degree of freedom is the VMEM budget
    (``REPRO_VMEM_BUDGET`` changes every analytic blocking, hence every
    modeled number); backend / autotune knobs never reach the model-based
    benches.  The per-report ``vmem_budget`` stamps must agree — comparing
    a 16 MiB baseline against a 1 MiB fresh run would gate noise, not
    regressions (the ReFrame analog: references are keyed by system).
    """
    # (train_scaling, resilience, and serve_fleet carry no vmem stamp: the
    # scaling model and the fault-schedule replays are budget-independent
    # by construction)
    budgets = {reports[b]["vmem_budget"]
               for b in ("conv_fwd", "bwd_wu", "q8_infer", "chain_fusion")
               if b in reports}
    if len(budgets) > 1:
        raise ValueError(f"perfci: bench artifacts disagree on vmem_budget "
                         f"{sorted(budgets)} — regenerate them in one run")
    if not budgets:
        from repro.core.blocking import VMEM_BUDGET
        budgets = {VMEM_BUDGET}
    return f"vmem={budgets.pop()}"


def extract_all(root) -> tuple[str, dict[str, float]]:
    """-> (context_key, merged metric series) for the artifacts under root."""
    reports = load_reports(root)
    metrics: dict[str, float] = {}
    for bench, report in reports.items():
        metrics.update(_EXTRACTORS[bench](report))
    return context_key(reports), metrics
