"""Per-metric tolerance policies — what "regressed" means, metric by metric.

A ``Tolerance`` is matched to a metric ID by ``fnmatch`` pattern; the first
match in the policy list wins, so specific rules (the ISSUE-level hard
floors) precede the family defaults.  Three rule kinds compose:

  * relative drift: a drop (against ``direction``) of more than ``rel_tol``
    vs the baseline fails; movement the *good* way is reported as improved,
    never failed.
  * hard floor / ceiling: absolute bounds that fail regardless of what the
    baseline said — "2-dev fp32 scaling >= 0.8" keeps failing even if a bad
    baseline were committed, and efficiency > 1.0 means the cost model
    itself broke.
  * directional invariants: margins (whole-plane/tiled, dilate/phase cost
    ratios) floored at 1.0 — the paper-level "tiled never slower" claims.

Margins are floored only in the *default-budget* context
(``policies_for_context``): under a 1 MiB pressure budget a late ResNet
layer's whole plane fits VMEM outright, so the legacy schedule legitimately
models cheaper than a band forced tiny by the same budget — there the
margin is drift-gated against its own baseline instead of floored (the
ReFrame per-system-reference idiom).
"""
from __future__ import annotations

import dataclasses
import fnmatch


@dataclasses.dataclass(frozen=True)
class Tolerance:
    pattern: str
    direction: str            # "higher" | "lower" | "both" (drift any way)
    rel_tol: float            # allowed relative drift against direction
    floor: float | None = None
    ceiling: float | None = None
    note: str = ""

    def matches(self, metric_id: str) -> bool:
        return fnmatch.fnmatchcase(metric_id, self.pattern)


# the 16 MiB default of core/blocking.VMEM_BUDGET — the context in which the
# "tiled never slower" directional invariants are claims, not coincidences
DEFAULT_CONTEXT = f"vmem={16 * 1024 * 1024}"

_MARGIN_FLOOR = Tolerance("*_margin", "higher", 0.05, floor=1.0,
                          note="directional invariant: ratio legacy/tiled "
                               ">= 1")
_MARGIN_DRIFT = Tolerance("*_margin", "higher", 0.05,
                          note="pressure context: margin drift-gated only")


# first match wins — keep hard acceptance bars above the family defaults
DEFAULT_POLICIES: tuple[Tolerance, ...] = (
    # single-device efficiency is 1.0 by definition; any drift is a bug in
    # the scaling model, not a perf change
    Tolerance("train_scaling/d1/*/scaling_efficiency", "higher", 0.0,
              floor=1.0, ceiling=1.0, note="identity anchor"),
    # the multi-node acceptance bar carried since PR 5
    Tolerance("train_scaling/d2/fp32/scaling_efficiency", "higher", 0.02,
              floor=0.8, note="ISSUE hard floor: 2-dev fp32 >= 0.8"),
    Tolerance("train_scaling/*/scaling_efficiency", "higher", 0.02),
    Tolerance("train_scaling/*/no_overlap_efficiency", "higher", 0.02),
    Tolerance("train_scaling/*/images_per_s", "higher", 0.02),
    # the PR-8 self-healing bars: a fault-free replay is the goodput
    # identity; the reference schedule (straggler + host death + corrupt
    # checkpoint) must keep >= 90% of fault-free throughput; and the
    # elastic residual fold must never lose gradient mass
    Tolerance("resilience/fault_free/goodput_ratio", "higher", 0.0,
              floor=1.0, ceiling=1.0, note="identity anchor"),
    Tolerance("resilience/reference/goodput_ratio", "higher", 0.02,
              floor=0.9, note="ISSUE hard floor: goodput >= 0.9 under the "
                              "reference fault schedule"),
    Tolerance("resilience/*/goodput_ratio", "higher", 0.02),
    Tolerance("resilience/*/*mass_conserved", "higher", 0.0, floor=1.0,
              ceiling=1.0, note="ISSUE hard floor: zero lost gradient mass "
                                "on elastic fold"),
    Tolerance("resilience/*/recovery_overhead_steps", "lower", 0.0),
    Tolerance("resilience/*/lost_steps", "lower", 0.0),
    # restart/eviction counts are schedule facts: any change is a behavior
    # change in the recovery policy, not noise
    Tolerance("resilience/*", "both", 0.0, note="deterministic replay: "
                                                "exact match"),
    # the PR-9 serving-fleet bars: fault-free goodput is the identity
    # anchor; the reference chaos schedule (straggler + replica death +
    # flaky accelerator + burst) must keep >= 90% of requests in deadline
    # with zero operator intervention; and *every* admitted request must
    # either finish in deadline or ride the int8 degrade path
    Tolerance("serve_fleet/fault_free/goodput", "higher", 0.0,
              floor=1.0, ceiling=1.0, note="identity anchor"),
    Tolerance("serve_fleet/reference/goodput", "higher", 0.02,
              floor=0.9, note="ISSUE hard floor: goodput >= 0.9 under the "
                              "reference chaos schedule"),
    Tolerance("serve_fleet/*/goodput", "higher", 0.02),
    Tolerance("serve_fleet/*/slo_handled_rate", "higher", 0.0, floor=1.0,
              ceiling=1.0, note="ISSUE hard floor: every admitted request "
                                "in deadline or degraded to int8"),
    Tolerance("serve_fleet/*/failed", "lower", 0.0, ceiling=0.0,
              note="retries must never exhaust under the canned schedules"),
    Tolerance("serve_fleet/reference/p99_ms", "lower", 0.02, ceiling=5000.0,
              note="tail bar: recovery keeps p99 under the 5s line"),
    Tolerance("serve_fleet/*/p50_ms", "lower", 0.02),
    Tolerance("serve_fleet/*/p99_ms", "lower", 0.02),
    Tolerance("serve_fleet/*/shed_rate", "lower", 0.0),
    # eviction/respawn/hedge/retry counts are schedule facts: any change is
    # a behavior change in the fleet policy, not noise
    Tolerance("serve_fleet/*", "both", 0.0, note="deterministic replay: "
                                                 "exact match"),
    # the PR-7 acceptance bar: int8 serving >= 1.6x on every
    # bandwidth-bound ResNet-50 layer (BENCH_q8_infer.json summary)
    Tolerance("q8_infer/resnet50/min_bw_speedup", "higher", 0.02, floor=1.6,
              note="ISSUE hard floor: int8 >= 1.6x where f32 is "
                   "bandwidth-bound"),
    Tolerance("q8_infer/*/min_bw_speedup", "higher", 0.02),
    # int8 must never model slower than f32 under the same schedule model —
    # a directional invariant like the margins, but valid in *every* VMEM
    # context (pressure shrinks f32 bands 4x harder than int8 bands)
    Tolerance("q8_infer/*/speedup", "higher", 0.02, floor=1.0,
              note="directional invariant: int8 never slower than f32"),
    # the PR-10 depth-first chain-fusion bars (BENCH_chain_fusion.json).
    # traffic_margin (unfused/fused HBM bytes) is floored at 1.0 in *every*
    # VMEM context — unlike the whole-plane margins this is not a claim
    # about geometry but about the decision rule: an unprofitable chain
    # falls back and is priced at exactly the unfused sum, so the ratio can
    # never dip below 1 unless the fallback rule itself breaks.  These
    # precede _MARGIN_FLOOR so policies_for_context's pressure swap never
    # reaches them.
    Tolerance("chain_fusion/*margin", "higher", 0.02, floor=1.0,
              note="ISSUE invariant: fused HBM <= unfused on every chain, "
                   "every context (fallback prices unfused exactly)"),
    Tolerance("chain_fusion/*/fused_intermediate_bytes", "lower", 0.0,
              ceiling=0.0, note="ISSUE invariant: fused chains move zero "
                                "intermediate HBM bytes"),
    Tolerance("chain_fusion/*/n_fused", "higher", 0.0, floor=1.0,
              note="at least one chain must fuse in every context"),
    Tolerance("chain_fusion/*/n_chains", "both", 0.0,
              note="chain detection is a structure fact: exact match"),
    # fuse decisions and per-chain intermediate bytes are decision facts: a
    # fused chain un-fusing (or starting to spill intermediates) is a
    # behavior change, not noise
    Tolerance("chain_fusion/*/fused", "higher", 0.0),
    Tolerance("chain_fusion/*/intermediate_bytes", "lower", 0.0),
    # fused-vs-unfused modeled speedup may sit below 1.0 under pressure
    # (band launch overhead) — drift-gated, the fuse *decision* is by bytes
    Tolerance("chain_fusion/*/speedup", "higher", 0.02),
    # directional invariants: tiled/phase must never lose to the legacy plan
    _MARGIN_FLOOR,
    # every gated kernel must stay schedulable under the context's budget
    Tolerance("*/fits_vmem", "higher", 0.0, floor=1.0,
              note="kernel must fit the VMEM budget"),
    # efficiency is ideal/cost: (0, 1] by construction (cost >= ideal)
    Tolerance("*/roofline_efficiency", "higher", 0.02, floor=1e-9,
              ceiling=1.0),
    Tolerance("*/cost_us", "lower", 0.02),
    Tolerance("*/hbm_bytes", "lower", 0.02),
    # unknown metrics: hold them steady until a policy is written
    Tolerance("*", "both", 0.05, note="catch-all drift guard"),
)


def policies_for_context(context: str) -> tuple[Tolerance, ...]:
    """The policy list for one generation context: identical to
    ``DEFAULT_POLICIES`` except margins lose their 1.0 floor away from the
    default VMEM budget (see module docstring)."""
    if context == DEFAULT_CONTEXT:
        return DEFAULT_POLICIES
    return tuple(_MARGIN_DRIFT if pol is _MARGIN_FLOOR else pol
                 for pol in DEFAULT_POLICIES)


def policy_for(metric_id: str,
               policies: tuple[Tolerance, ...] = DEFAULT_POLICIES
               ) -> Tolerance:
    for pol in policies:
        if pol.matches(metric_id):
            return pol
    # unreachable with DEFAULT_POLICIES (catch-all); explicit for custom lists
    return Tolerance("*", "both", 0.05, note="implicit catch-all")
