"""The comparison engine: baseline vs fresh metric series -> Verdict.

Every metric in the union of the two series gets a ``MetricResult`` with a
status:

  ok          within tolerance of the baseline
  improved    moved the good way by more than the tolerance (never fails;
              surfaced so ``--update-baselines`` is run to ratchet)
  regressed   moved the bad way past ``rel_tol``
  floor       below a hard floor (fails even if the baseline was too)
  ceiling     above a hard ceiling (cost-model invariant broken)
  missing     in the baseline but absent from the fresh run — a silently
              dropped layer/bench is a gate failure, not a skip
  new         fresh metric with no baseline — passes, listed so the next
              ``--update-baselines`` pins it

``Verdict`` renders both ways: ``to_json()`` for machines, ``diff_table()``
for the human reading the CI log.
"""
from __future__ import annotations

import dataclasses

from repro.perfci.policy import DEFAULT_POLICIES, Tolerance, policy_for

_EPS = 1e-12
FAIL_STATUSES = ("regressed", "floor", "ceiling", "missing")


@dataclasses.dataclass
class MetricResult:
    metric: str
    baseline: float | None
    current: float | None
    status: str
    policy: Tolerance | None = None
    detail: str = ""

    @property
    def failed(self) -> bool:
        return self.status in FAIL_STATUSES

    @property
    def rel_delta(self) -> float | None:
        if self.baseline is None or self.current is None:
            return None
        return (self.current - self.baseline) / max(abs(self.baseline), _EPS)


def _classify(metric: str, base: float, cur: float, pol: Tolerance
              ) -> MetricResult:
    if pol.floor is not None and cur < pol.floor - _EPS:
        return MetricResult(metric, base, cur, "floor", pol,
                            f"value {cur:.6g} < floor {pol.floor:.6g}")
    if pol.ceiling is not None and cur > pol.ceiling + 1e-9:
        return MetricResult(metric, base, cur, "ceiling", pol,
                            f"value {cur:.6g} > ceiling {pol.ceiling:.6g}")
    delta = (cur - base) / max(abs(base), _EPS)
    if pol.direction == "higher":
        bad, good = delta < -pol.rel_tol - _EPS, delta > pol.rel_tol + _EPS
    elif pol.direction == "lower":
        bad, good = delta > pol.rel_tol + _EPS, delta < -pol.rel_tol - _EPS
    else:                                           # "both": any drift is bad
        bad, good = abs(delta) > pol.rel_tol + _EPS, False
    if bad:
        return MetricResult(metric, base, cur, "regressed", pol,
                            f"drift {delta:+.2%} exceeds "
                            f"{pol.rel_tol:.0%} ({pol.direction} is better)")
    if good:
        return MetricResult(metric, base, cur, "improved", pol,
                            f"drift {delta:+.2%}")
    return MetricResult(metric, base, cur, "ok", pol)


def compare(baseline: dict[str, float], current: dict[str, float],
            policies: tuple[Tolerance, ...] = DEFAULT_POLICIES) -> "Verdict":
    results = []
    for metric in sorted(set(baseline) | set(current)):
        base, cur = baseline.get(metric), current.get(metric)
        if cur is None:
            results.append(MetricResult(
                metric, base, None, "missing", policy_for(metric, policies),
                "metric present in baseline but absent from fresh run"))
        elif base is None:
            results.append(MetricResult(
                metric, None, cur, "new", policy_for(metric, policies),
                "no baseline yet — pin with --update-baselines"))
        else:
            results.append(_classify(metric, base, cur,
                                     policy_for(metric, policies)))
    return Verdict(results)


@dataclasses.dataclass
class Verdict:
    results: list[MetricResult]

    @property
    def ok(self) -> bool:
        return not any(r.failed for r in self.results)

    @property
    def counts(self) -> dict[str, int]:
        c: dict[str, int] = {}
        for r in self.results:
            c[r.status] = c.get(r.status, 0) + 1
        return c

    @property
    def failures(self) -> list[MetricResult]:
        return [r for r in self.results if r.failed]

    def to_json(self) -> dict:
        return {
            "ok": self.ok,
            "n_metrics": len(self.results),
            "counts": self.counts,
            "failures": [{
                "metric": r.metric, "status": r.status,
                "baseline": r.baseline, "current": r.current,
                "detail": r.detail,
                "policy": r.policy.pattern if r.policy else None,
            } for r in self.failures],
        }

    def diff_table(self, *, verbose: bool = False, max_rows: int = 40) -> str:
        """Human diff: failures + improvements (everything when verbose)."""
        rows = [r for r in self.results
                if verbose or r.failed or r.status in ("improved", "new")]
        lines = [f"{'METRIC':60s} {'BASE':>12s} {'NEW':>12s} "
                 f"{'DRIFT':>8s}  STATUS"]
        for r in rows[:max_rows]:
            base = "-" if r.baseline is None else f"{r.baseline:.6g}"
            cur = "-" if r.current is None else f"{r.current:.6g}"
            drift = "-" if r.rel_delta is None else f"{r.rel_delta:+.1%}"
            status = r.status + (f"  [{r.detail}]" if r.detail else "")
            lines.append(f"{r.metric:60s} {base:>12s} {cur:>12s} "
                         f"{drift:>8s}  {status}")
        if len(rows) > max_rows:
            lines.append(f"... {len(rows) - max_rows} more rows elided")
        c = self.counts
        summary = ", ".join(f"{c[k]} {k}" for k in
                            ("ok", "improved", "new", "regressed", "floor",
                             "ceiling", "missing") if c.get(k))
        lines.append(f"perf-gate: {'OK' if self.ok else 'FAIL'} "
                     f"({len(self.results)} metrics: {summary})")
        return "\n".join(lines)
