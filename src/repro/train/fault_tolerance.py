"""Fault tolerance: heartbeats, straggler detection, restart-on-failure,
elastic re-scale.

At 1000+ nodes the failure model is: (a) a host dies mid-step (restart from
checkpoint), (b) a host slows down (straggler — detect and either rebalance
or evict), (c) capacity changes (elastic — re-shard the checkpoint onto the
new mesh).  All three policies are implemented host-side here and unit
tested; the device-side state they manipulate is exactly the checkpoint
tree, so none of this touches the compiled step.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.train import checkpoint as ckpt_lib


@dataclasses.dataclass
class Heartbeat:
    """Per-host step-duration tracker with straggler detection."""
    window: int = 20
    threshold: float = 1.5          # x median = straggler
    timeout_s: float = 300.0        # no heartbeat at all = dead

    def __post_init__(self):
        self._durations: dict[str, list[float]] = {}
        self._last_seen: dict[str, float] = {}

    def record(self, host: str, duration_s: float, now: float | None = None):
        self._durations.setdefault(host, []).append(duration_s)
        self._durations[host] = self._durations[host][-self.window:]
        self._last_seen[host] = time.time() if now is None else now

    def stragglers(self) -> list[str]:
        meds = {h: float(np.median(d)) for h, d in self._durations.items()
                if d}
        if len(meds) < 2:
            return []
        global_med = float(np.median(list(meds.values())))
        return [h for h, m in meds.items()
                if m > self.threshold * global_med]

    def dead(self, now: float | None = None) -> list[str]:
        now = time.time() if now is None else now
        return [h for h, t in self._last_seen.items()
                if now - t > self.timeout_s]


@dataclasses.dataclass
class RebalancePlan:
    """Straggler mitigation: shrink the straggler's micro-batch share and
    grow the fast hosts' (the §II-F work-division argument, at host scale)."""
    shares: dict

    @staticmethod
    def from_heartbeat(hb: Heartbeat, hosts: list[str]) -> "RebalancePlan":
        meds = {h: float(np.median(hb._durations.get(h, [1.0]) or [1.0]))
                for h in hosts}
        speed = {h: 1.0 / m for h, m in meds.items()}
        total = sum(speed.values())
        return RebalancePlan({h: s / total for h, s in speed.items()})


class ResilientLoop:
    """Wraps a train loop: periodic (async) checkpoints, restore-on-failure,
    bounded retries.  ``failure_hook`` lets tests inject faults."""

    def __init__(self, *, step_fn, state, data, ckpt_dir,
                 ckpt_every: int = 50, max_retries: int = 3,
                 failure_hook=None, restore_fn=None):
        self.step_fn = step_fn
        self.state = state
        self.data = data
        self.ckpt_dir = ckpt_dir
        self.ckpt_every = ckpt_every
        self.max_retries = max_retries
        self.failure_hook = failure_hook
        self.restore_fn = restore_fn or self._default_restore
        self.checkpointer = ckpt_lib.AsyncCheckpointer(ckpt_dir)
        self.heartbeat = Heartbeat()
        self.restarts = 0
        self.metrics_log: list[dict] = []

    def _default_restore(self, state_template):
        step = ckpt_lib.latest_step(self.ckpt_dir)
        if step is None:
            return state_template, 0
        state = ckpt_lib.restore(self.ckpt_dir, step, state_template)
        return state, step

    def run(self, n_steps: int, start_step: int = 0):
        step = start_step
        retries = 0
        while step < n_steps:
            try:
                t0 = time.time()
                if self.failure_hook is not None:
                    self.failure_hook(step)
                batch = self.data.batch_at(step)
                self.state, metrics = self.step_fn(self.state, batch)
                self.heartbeat.record("host0", time.time() - t0)
                self.metrics_log.append(
                    {"step": step,
                     **{k: float(v) for k, v in metrics.items()}})
                step += 1
                retries = 0
                if step % self.ckpt_every == 0:
                    self.checkpointer.save(step, self.state)
            except Exception:  # noqa: BLE001
                retries += 1
                self.restarts += 1
                if retries > self.max_retries:
                    raise
                self.checkpointer.wait()
                self.state, step = self.restore_fn(self.state)
        self.checkpointer.wait()
        return self.state


def elastic_reshard(ckpt_dir, step, state_template, new_shardings):
    """Re-scale: restore a checkpoint onto a different mesh (data-parallel
    width or model-parallel degree changed).  Leaves are stored unsharded,
    so this is just restore-with-new-shardings; the data pipeline cursor
    (global step) is layout-independent by construction."""
    return ckpt_lib.restore(ckpt_dir, step, state_template,
                            shardings=new_shardings)


def elastic_reshard_cnn(ckpt_dir, step, state_template, new_mesh, *,
                        axis: str = "data"):
    """Elastic re-scale for the data-parallel CNN train state
    (``train/distributed.py``): params and step restore replicated as
    usual, but the int8 error-feedback residual carries one accumulator
    per *old* shard — it cannot simply re-place onto a narrower mesh.
    Restore unsharded (the template has the old width), sum-fold the
    residual groups onto the new width (no un-applied gradient mass is
    dropped), then place per ``cnn_state_shardings``."""
    from repro.train.distributed import reshard_cnn_state
    state = ckpt_lib.restore(ckpt_dir, step, state_template)
    return reshard_cnn_state(state, new_mesh, axis=axis)
