"""Fault tolerance: heartbeats, straggler detection, restart-on-failure,
elastic re-scale — the self-healing loop (DESIGN.md §14).

At 1000+ nodes the failure model is: (a) a host dies mid-step (restart from
checkpoint), (b) a host slows down (straggler — detect and evict), (c) a
checkpoint is corrupt or half-written (walk back to the newest verifiable
one), (d) capacity changes (elastic — fold the state onto the new mesh).
``ResilientLoop`` drives all four without operator intervention:

  * per-host heartbeat recording each step (durations come from the real
    wall clock, or from an injected ``heartbeat_source`` — the chaos
    harness in ``train/chaos.py`` simulates a multi-host fleet this way);
  * dead-host / straggler detection on a policy cadence (``policy_every``)
    *and* on every step failure (a dead host fails the collective — the
    fix is eviction, not retry);
  * eviction -> elastic re-scale: the victims leave ``alive``, an optional
    ``elastic_fn(state, alive)`` folds the state onto the narrower mesh
    (the DP CNN path sum-folds the int8 error-feedback residual so no
    gradient mass is lost — ``train.distributed.reshard_cnn_state``), and
    the folded state is synchronously checkpointed before training resumes;
  * checkpoint I/O runs under bounded retries with exponential backoff, and
    restore walks back past corrupt/partial checkpoints
    (``checkpoint.restore_latest``);
  * every recovery action lands in a structured event log (``events``) —
    restarts, evictions, lost steps, skipped checkpoints, recovery
    wall-time — summarized by ``resilience_summary()``.

The simulated-time seam: ``clock`` is any object with ``time()``/``sleep``;
``Heartbeat`` takes a ``clock`` *callable*.  Production uses the wall clock,
the chaos harness and the resilience bench inject ``chaos.SimClock`` so
detection timing (and therefore goodput) is deterministic.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.train import checkpoint as ckpt_lib


class _WallClock:
    sleep = staticmethod(time.sleep)
    time = staticmethod(time.time)


@dataclasses.dataclass
class Heartbeat:
    """Per-host step-duration tracker with dead-host/straggler detection.

    ``clock`` is the time source ``record``/``dead`` fall back to when no
    explicit ``now`` is passed — wall clock by default, a simulated clock
    under the chaos harness (mixing wall-clock ``_last_seen`` stamps with
    injected ``now`` comparisons was the PR-5 inconsistency)."""
    window: int = 20
    threshold: float = 1.5          # x median = straggler
    timeout_s: float = 300.0        # no heartbeat at all = dead
    clock: object = time.time

    def __post_init__(self):
        self._durations: dict[str, list[float]] = {}
        self._last_seen: dict[str, float] = {}

    def record(self, host: str, duration_s: float, now: float | None = None):
        self._durations.setdefault(host, []).append(duration_s)
        self._durations[host] = self._durations[host][-self.window:]
        self._last_seen[host] = self.clock() if now is None else now

    def ping(self, host: str, now: float | None = None):
        """Liveness only — refresh ``last_seen`` without a duration sample.
        Heartbeats are out-of-band from the training collective: a host
        stuck in a hung all-reduce still answers pings, so a collective
        failure must not make the whole fleet look dead at once."""
        self._last_seen[host] = self.clock() if now is None else now

    def medians(self) -> dict[str, float]:
        """Per-host median step duration over the window — the public read
        API (``RebalancePlan`` and the straggler policy consume this)."""
        return {h: float(np.median(d))
                for h, d in self._durations.items() if d}

    def stragglers(self) -> list[str]:
        meds = self.medians()
        if len(meds) < 2:
            return []
        global_med = float(np.median(list(meds.values())))
        return [h for h, m in meds.items()
                if m > self.threshold * global_med]

    def dead(self, now: float | None = None) -> list[str]:
        now = self.clock() if now is None else now
        return [h for h, t in self._last_seen.items()
                if now - t > self.timeout_s]

    def forget(self, host: str) -> None:
        """Drop a host's history (evicted — it must not keep tripping the
        dead/straggler detectors)."""
        self._durations.pop(host, None)
        self._last_seen.pop(host, None)


@dataclasses.dataclass
class RebalancePlan:
    """Straggler mitigation: shrink the straggler's micro-batch share and
    grow the fast hosts' (the §II-F work-division argument, at host scale)."""
    shares: dict

    @staticmethod
    def from_heartbeat(hb: Heartbeat, hosts: list[str]) -> "RebalancePlan":
        meds = hb.medians()
        speed = {h: 1.0 / meds.get(h, 1.0) for h in hosts}
        total = sum(speed.values())
        return RebalancePlan({h: s / total for h, s in speed.items()})


class ResilientLoop:
    """Wraps a train loop with self-healing recovery (module docstring has
    the policy map).  Legacy single-host use is the degenerate case: one
    host, wall clock, no elastic hook — behaviour identical to the PR-5
    loop plus walk-back restore and checkpoint-I/O retries.

    ``elastic_fn(state, alive) -> (state, step_fn)`` re-builds the training
    state and step for the narrower fleet after an eviction; with ``None``
    an eviction only drops the host from ``alive`` (membership change, the
    LM trainer's simulated-host case).  ``chaos`` is a
    ``train.chaos.ChaosEngine``: it supplies the clock, failure hook and
    per-host heartbeat source, and gets bound back to this loop so injected
    collective failures stop once the dead host is evicted.
    """

    def __init__(self, *, step_fn, state, data, ckpt_dir,
                 ckpt_every: int = 50, max_retries: int = 3,
                 failure_hook=None, restore_fn=None,
                 hosts=("host0",), clock=None, policy_every: int = 10,
                 elastic_fn=None, heartbeat_source=None, heartbeat=None,
                 liveness_source=None, min_hosts: int = 1,
                 io_retries: int = 3, io_backoff_s: float = 0.05,
                 keep: int = 3, chaos=None):
        if chaos is not None:
            clock = chaos.clock if clock is None else clock
            hosts = chaos.hosts if tuple(hosts) == ("host0",) else hosts
            failure_hook = failure_hook or chaos.failure_hook
            heartbeat_source = heartbeat_source or chaos.heartbeat_source
            liveness_source = liveness_source or chaos.liveness
        self.step_fn = step_fn
        self.state = state
        self.data = data
        self.ckpt_dir = ckpt_dir
        self.ckpt_every = ckpt_every
        self.max_retries = max_retries
        self.failure_hook = failure_hook
        self.restore_fn = restore_fn or self._default_restore
        self.clock = clock or _WallClock()
        self.checkpointer = ckpt_lib.AsyncCheckpointer(ckpt_dir, keep=keep)
        self.heartbeat = heartbeat if heartbeat is not None else \
            Heartbeat(clock=self.clock.time)
        self.alive: list[str] = list(hosts)
        self.policy_every = policy_every
        self.elastic_fn = elastic_fn
        self.heartbeat_source = heartbeat_source
        self.liveness_source = liveness_source
        self.min_hosts = min_hosts
        self.io_retries = io_retries
        self.io_backoff_s = io_backoff_s
        self.restarts = 0
        self.evictions = 0
        self.lost_steps = 0
        self.steps_run = 0
        self.io_retries_used = 0
        self.metrics_log: list[dict] = []
        self.events: list[dict] = []
        if chaos is not None:
            chaos.bind(self)

    # -- bookkeeping ----------------------------------------------------------

    def event(self, kind: str, **fields) -> None:
        self.events.append({"kind": kind, "t": self.clock.time(), **fields})

    def resilience_summary(self) -> dict:
        recovery = sum(e.get("recovery_s", 0.0) for e in self.events)
        return {"restarts": self.restarts, "evictions": self.evictions,
                "lost_steps": self.lost_steps, "steps_run": self.steps_run,
                "io_retries": self.io_retries_used,
                "recovery_s": round(recovery, 6),
                "n_hosts": len(self.alive), "n_events": len(self.events)}

    # -- checkpoint I/O (bounded retries, exponential backoff) ----------------

    def _io_retry(self, fn, *, what: str, step: int, fatal: bool = False):
        delay = self.io_backoff_s
        for attempt in range(self.io_retries + 1):
            try:
                return fn()
            except Exception as e:  # noqa: BLE001
                self.io_retries_used += 1
                self.event("io_retry", step=step, what=what,
                           attempt=attempt + 1, error=repr(e))
                if attempt == self.io_retries:
                    if fatal:
                        raise
                    self.event("io_giveup", step=step, what=what)
                    return None
                self.clock.sleep(delay)
                delay *= 2

    def _save(self, step: int, *, sync: bool = False) -> None:
        if sync:
            self._io_retry(
                lambda: ckpt_lib.save(self.ckpt_dir, step, self.state,
                                      keep=self.checkpointer.keep),
                what="sync_save", step=step)
        else:
            self._io_retry(lambda: self.checkpointer.save(step, self.state),
                           what="async_save", step=step)

    def _drain_async_save(self, step: int) -> None:
        """Join any in-flight background save; a failure there is logged
        (and the next save's retry loop will surface it), never allowed to
        mask the recovery we're in the middle of."""
        try:
            self.checkpointer.wait()
        except Exception as e:  # noqa: BLE001
            self.event("async_save_error", step=step, error=repr(e))

    def _default_restore(self, state_template):
        skips = []
        state, step = ckpt_lib.restore_latest(
            self.ckpt_dir, state_template,
            on_skip=lambda s, e: skips.append((s, repr(e))))
        for s, err in skips:
            self.event("ckpt_skipped", step=s, error=err)
        return state, step

    # -- heartbeats + eviction policy -----------------------------------------

    def _ping_liveness(self, step: int) -> None:
        """Out-of-band liveness: after a step failure the collective tells
        us nothing, but responsive hosts still answer pings — only the
        truly dead host's ``last_seen`` goes stale.  Without this, a hung
        collective would age out the *whole* fleet together and eviction
        could never satisfy ``min_hosts``."""
        if self.liveness_source is None:
            return
        now = self.clock.time()
        for host in self.liveness_source(step):
            if host in self.alive:
                self.heartbeat.ping(host, now=now)

    def _record_heartbeats(self, step: int, dt: float) -> None:
        if self.heartbeat_source is not None:
            durations = self.heartbeat_source(step, dt)
        else:
            durations = {h: dt for h in self.alive}
        now = self.clock.time()
        for host, d in durations.items():
            if d is not None and host in self.alive:
                self.heartbeat.record(host, float(d), now=now)

    def _maybe_evict(self, step: int) -> bool:
        """Dead-host/straggler sweep: evict, fold, checkpoint, resume.
        Returns True iff an eviction happened (state/step_fn may be new)."""
        now = self.clock.time()
        dead = [h for h in self.heartbeat.dead(now) if h in self.alive]
        stragglers = [h for h in self.heartbeat.stragglers()
                      if h in self.alive and h not in dead]
        victims = dead + stragglers
        if not victims:
            return False
        if len(self.alive) - len(victims) < self.min_hosts:
            self.event("eviction_skipped", step=step, hosts=victims,
                       reason=f"would leave < {self.min_hosts} hosts")
            return False
        t0 = now
        self._drain_async_save(step)
        for h in victims:
            self.alive.remove(h)
            self.heartbeat.forget(h)
        self.evictions += len(victims)
        if self.elastic_fn is not None:
            self.state, self.step_fn = self.elastic_fn(self.state,
                                                       list(self.alive))
        # durable point AFTER the fold: restores from here on see the
        # re-scaled state, and walk-back skips the pre-fold shapes
        self._save(step, sync=True)
        self.event("eviction", step=step, hosts=victims, dead=dead,
                   stragglers=stragglers, n_alive=len(self.alive),
                   recovery_s=self.clock.time() - t0)
        return True

    # -- the loop -------------------------------------------------------------

    def run(self, n_steps: int, start_step: int = 0):
        step = start_step
        retries = 0
        while step < n_steps:
            try:
                t0 = self.clock.time()
                if self.failure_hook is not None:
                    self.failure_hook(step)
                batch = self.data.batch_at(step)
                self.state, metrics = self.step_fn(self.state, batch)
                self._record_heartbeats(step, self.clock.time() - t0)
                self.steps_run += 1
                self.metrics_log.append(
                    {"step": step,
                     **{k: float(v) for k, v in metrics.items()}})
                step += 1
                retries = 0
                if step % self.ckpt_every == 0:
                    self._save(step)
                if self.policy_every and step % self.policy_every == 0:
                    self._maybe_evict(step)
            except Exception as e:  # noqa: BLE001
                retries += 1
                self.restarts += 1
                self.event("step_failure", step=step, error=repr(e),
                           retry=retries)
                self._ping_liveness(step)
                if self._maybe_evict(step):
                    # a dead host fails the collective on every retry;
                    # eviction (not restore) is the recovery — the state is
                    # still the last good one, so resume at the same step
                    retries = 0
                    continue
                if retries > self.max_retries:
                    raise
                self._drain_async_save(step)
                prev = step
                t_r = self.clock.time()
                self.state, step = self._io_retry(
                    lambda: self.restore_fn(self.state),
                    what="restore", step=step, fatal=True)
                self.lost_steps += max(0, prev - step)
                self.event("restart", step=prev, restored_step=step,
                           lost_steps=max(0, prev - step),
                           recovery_s=self.clock.time() - t_r)
        # drain, don't raise: a failed background save after the last step
        # is an event, not a training failure
        self._drain_async_save(step)
        return self.state


def elastic_reshard(ckpt_dir, step, state_template, new_shardings):
    """Re-scale: restore a checkpoint onto a different mesh (data-parallel
    width or model-parallel degree changed).  Leaves are stored unsharded,
    so this is just restore-with-new-shardings; the data pipeline cursor
    (global step) is layout-independent by construction."""
    return ckpt_lib.restore(ckpt_dir, step, state_template,
                            shardings=new_shardings)


def elastic_reshard_cnn(ckpt_dir, step, state_template, new_mesh, *,
                        axis: str = "data"):
    """Elastic re-scale for the data-parallel CNN train state
    (``train/distributed.py``): params and step restore replicated as
    usual, but the int8 error-feedback residual carries one accumulator
    per *old* shard — it cannot simply re-place onto a narrower mesh.
    Restore unsharded (the template has the old width), sum-fold the
    residual groups onto the new width (no un-applied gradient mass is
    dropped), then place per ``cnn_state_shardings``."""
    from repro.train.distributed import reshard_cnn_state
    state = ckpt_lib.restore(ckpt_dir, step, state_template)
    return reshard_cnn_state(state, new_mesh, axis=axis)
