"""Data-parallel CNN training over GxM (DESIGN.md §11).

The paper's closing claim is that the JIT-optimized conv kernels integrate
into "a lightweight multi-node graph execution model" with high efficiency
at scale.  PR 2 sharded the *inference* half of that claim; this module is
the training half: the PR-4 pipeline (tiled fwd → phase-duality dI →
band-streamed wu) runs per-shard under ``shard_map`` over the data axis of
a ``launch.mesh`` mesh, and the only cross-shard communication is the
gradient reduction between the update pass and the optimizer — exactly
where ``graph/etg.extend_nl`` marks the bwd reduction point of a fan-out
tensor.

Reduction wire format (``REPRO_GRAD_COMPRESS`` / ``grad_compress=``):

  "off"   exact f32 ``lax.pmean`` — bit-reproducible layer math per shard
  "int8"  ``optim.compress.compressed_psum`` per leaf — error-feedback int8
          quantization at 1/4 the bytes; each shard's quantization error
          lives in the train state (``state["residual"]``, one accumulator
          per shard, leading ``(n_shards,)`` axis sharded over the data
          axis) and is re-applied to the next step's gradient.

Microbatch gradient accumulation (``accum_steps``) mirrors the LM step's
§II-J pipelining: the reduction of microbatch i overlaps the compute of
i+1 under the XLA latency-hiding scheduler.

Checkpointing reuses ``train/checkpoint.py`` unchanged — leaves are
gathered on save, and ``cnn_state_shardings`` gives restore the target
placement; ``train.fault_tolerance.elastic_reshard_cnn`` re-shards a saved
state onto a narrower mesh (the residual is sum-folded so no error mass is
lost — ``optim.compress.fold_residual``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.graph.executor import apply_bn_updates
from repro.launch.mesh import data_axis_size, shard_map_fn
from repro.optim.compress import compressed_psum_tree, fold_residual


# -- train state --------------------------------------------------------------

def init_cnn_train_state_dp(params, mesh, *, grad_compress: str | None = None,
                            axis: str = "data"):
    """Sharded DP train state: replicated params + step counter, plus (int8
    reduction only) the per-shard error-feedback residual, stacked on a
    leading ``(n_shards,)`` axis and sharded over ``axis``."""
    from repro import backend as be
    compress = be.resolve_grad_compress(grad_compress)
    n = data_axis_size(mesh)
    state = {"params": params, "step": jnp.zeros((), jnp.int32)}
    if compress == "int8":
        state["residual"] = jax.tree.map(
            lambda p: jnp.zeros((n, *p.shape), jnp.float32), params)
    return jax.device_put(state, cnn_state_shardings(mesh, state, axis=axis))


def cnn_state_specs(state, *, axis: str = "data"):
    """Per-leaf PartitionSpec tree for a DP CNN train state."""
    P = jax.sharding.PartitionSpec
    specs = {"params": jax.tree.map(lambda _: P(), state["params"]),
             "step": P()}
    if "residual" in state:
        specs["residual"] = jax.tree.map(lambda _: P(axis),
                                         state["residual"])
    return specs


def cnn_state_shardings(mesh, state, *, axis: str = "data"):
    """NamedSharding tree matching ``state`` — the ``shardings=`` argument
    of ``checkpoint.restore`` (mesh-elastic restore path)."""
    P = jax.sharding.PartitionSpec
    return jax.tree.map(
        lambda spec: jax.sharding.NamedSharding(mesh, spec),
        cnn_state_specs(state, axis=axis),
        is_leaf=lambda x: isinstance(x, P))


def reshard_cnn_state(state, mesh, *, axis: str = "data"):
    """Place a (restored, unsharded) DP train state onto ``mesh``, folding
    the error-feedback residual to the new data-axis width first."""
    state = dict(state)
    if "residual" in state:
        state["residual"] = fold_residual(state["residual"],
                                          data_axis_size(mesh))
    return jax.device_put(state, cnn_state_shardings(mesh, state, axis=axis))


# -- the step -----------------------------------------------------------------

def make_cnn_train_step_dp(gxm, mesh, *, lr: float = 0.1,
                           bn_momentum: float = 0.9, accum_steps: int = 1,
                           grad_compress: str | None = None,
                           autotune: str | None = None, axis: str = "data"):
    """Data-parallel sibling of ``train.step.make_cnn_train_step``.

    Per shard: the full PR-4 training pipeline on the local slice of the
    batch (BN uses local batch statistics — classic DP).  Cross-shard: one
    gradient reduction *after* the wu pass produced local dW and *before*
    the optimizer consumes it, plus a pmean of the BN batch statistics for
    the running-stat update and of the scalar loss.  With the replicated
    params spec and exact f32 reduction, an ``n``-shard step whose shards
    see identical local batches is bit-identical to the single-device step
    (pinned in tests/test_train_dp.py).

    ``accum_steps`` splits the *local* batch into microbatches whose
    gradients (and BN statistics) are averaged — semantics pinned by the
    accum_steps=k ≡ accum_steps=1 identity test.  Returns
    ``step(state, batch) -> (state, {"loss"})``; build ``state`` with
    ``init_cnn_train_state_dp`` and shard ``batch`` over ``axis`` (the step
    is jit'd over ``shard_map``, so an unsharded host batch also works —
    jit re-shards it to the in_spec).
    """
    from repro import backend as be
    compress = be.resolve_grad_compress(grad_compress)
    P = jax.sharding.PartitionSpec

    def local_loss(params, mb):
        return gxm.loss(params, mb, collect_stats=True)

    def local_grads(params, batch):
        grad_fn = jax.value_and_grad(local_loss, has_aux=True)
        if accum_steps == 1:
            (loss, stats), grads = grad_fn(params, batch)
            return loss, stats, grads

        lead = jax.tree.leaves(batch)[0].shape[0]
        assert lead % accum_steps == 0, \
            f"per-shard batch {lead} not divisible by accum_steps " \
            f"{accum_steps}: trailing examples would be silently dropped"

        def mb_at(i):
            def sl(x):
                m = x.shape[0] // accum_steps
                return jax.lax.dynamic_slice_in_dim(x, i * m, m, 0)
            return jax.tree.map(sl, batch)

        out_sds = jax.eval_shape(local_loss, params, mb_at(0))
        zeros = lambda t: jax.tree.map(         # noqa: E731
            lambda s: jnp.zeros(s.shape, s.dtype), t)

        def micro(i, carry):
            loss_acc, stats_acc, g_acc = carry
            (l, st), g = grad_fn(params, mb_at(i))
            return (loss_acc + l,
                    jax.tree.map(jnp.add, stats_acc, st),
                    jax.tree.map(jnp.add, g_acc, g))

        init = (jnp.zeros(out_sds[0].shape, out_sds[0].dtype),
                zeros(out_sds[1]),
                jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                             params))
        loss, stats, grads = jax.lax.fori_loop(0, accum_steps, micro, init)
        div = lambda t: jax.tree.map(           # noqa: E731
            lambda x: x / accum_steps, t)
        return div(loss), div(stats), div(grads)

    def dp_step(state, batch):
        params = state["params"]
        loss, stats, grads = local_grads(params, batch)
        # the GxM reduction point: local dW exists (wu pass done), the
        # optimizer has not run — §II-J's compute/communication seam
        if compress == "int8":
            residual = jax.tree.map(lambda r: r[0], state["residual"])
            grads, residual = compressed_psum_tree(grads, axis, residual)
            new_residual = jax.tree.map(lambda r: r[None], residual)
        else:
            grads = jax.lax.pmean(grads, axis)
        loss = jax.lax.pmean(loss, axis)
        stats = jax.lax.pmean(stats, axis)
        new_params = jax.tree.map(lambda p, g: p - lr * g, params, grads)
        apply_bn_updates(new_params, stats, bn_momentum)
        new_state = {"params": new_params, "step": state["step"] + 1}
        if compress == "int8":
            new_state["residual"] = new_residual
        return new_state, {"loss": loss}

    state_spec = {"params": P(), "step": P()}
    if compress == "int8":
        state_spec["residual"] = P(axis)
    sharded = shard_map_fn()(dp_step, mesh=mesh,
                             in_specs=(state_spec, P(axis)),
                             out_specs=(state_spec, P()),
                             check_rep=False)
    jitted = jax.jit(sharded)

    def step(state, batch):
        if autotune is None:
            return jitted(state, batch)
        with be.use_autotune(autotune):
            return jitted(state, batch)
    return step


def shard_cnn_batch(batch, mesh, *, axis: str = "data"):
    """Place a host batch with the leading dim sharded over ``axis`` (the
    step's in_spec) so jit never gathers it through one device."""
    P = jax.sharding.PartitionSpec
    sh = jax.sharding.NamedSharding(mesh, P(axis))
    return jax.tree.map(lambda x: jax.device_put(x, sh), batch)


# -- warmup: tune once per host, broadcast the entries ------------------------

def warmup_cnn_train_dp(gxm, mesh, *, global_batch: int,
                        image_hw=(224, 224), mode: str = "tune",
                        backend=None, cache=None, bwd_mode=None):
    """Per-host training warmup for the DP step: tune the fwd/bwd/wu
    blocking entries once at the *local* (per-shard) batch the shard_map
    body lowers to, and export them as a broadcast payload.

    In a multi-process launch only host 0 runs this; every other host
    installs the payload with ``install_warmup_entries`` instead of
    re-searching an identical space (single-controller runs are just the
    degenerate one-host case).  Returns ``(report, payload)``."""
    from repro.train.step import warmup_cnn_train
    from repro.tune.cache import default_cache
    cache = default_cache() if cache is None else cache
    report = warmup_cnn_train(gxm, image_hw=image_hw, minibatch=global_batch,
                              mode=mode, backend=backend, cache=cache,
                              bwd_mode=bwd_mode, mesh=mesh)
    payload = cache.export_entries([e["key"] for e in report if e["cached"]])
    return report, payload


def install_warmup_entries(payload, cache=None, *, persist: bool = True):
    """Receive a broadcast payload (non-zero hosts).  Returns entry count."""
    from repro.tune.cache import default_cache
    cache = default_cache() if cache is None else cache
    return cache.merge_entries(payload, persist=persist)
