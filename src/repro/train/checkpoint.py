"""Checkpointing: atomic, integrity-checked, async-capable, mesh-elastic.

Layout:  <dir>/step_<N>/manifest.json + one .npy per leaf.
  * atomic:   written into ``.tmp-...`` then ``os.replace``d — a crash never
    leaves a half checkpoint that restore would pick up;
  * integrity: per-leaf CRC32 recorded in the manifest and verified on load;
  * async:    ``save_async`` snapshots to host memory synchronously (cheap)
    and writes on a worker thread — the train loop keeps stepping;
  * elastic:  leaves are stored unsharded (gathered); ``restore`` takes a
    target sharding tree, so a checkpoint written on mesh A restores onto
    mesh B (different data/model parallelism) — the re-scale path.  State
    whose *shape* depends on the mesh width (the DP CNN step's per-shard
    int8 residual) goes through ``fault_tolerance.elastic_reshard_cnn``,
    which folds before placing;
  * durable:  ``valid_steps`` scans the directory and reports only the
    checkpoints that verify end to end (manifest parses, every leaf file
    present, CRC32 matches), and ``restore_latest`` walks *back* from the
    newest step until one restores cleanly — a corrupt or partial newest
    checkpoint degrades to the newest verifiable one instead of bricking
    recovery (DESIGN.md §14).  Stale ``.tmp-*`` directories (a crash mid
    ``save``) are invisible to every reader by construction.
"""
from __future__ import annotations

import json
import os
import pathlib
import re
import shutil
import threading
import zlib

import jax
import numpy as np

_SEP = "/"


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        out[key] = leaf
    return out, treedef


def save(ckpt_dir, step: int, tree, *, keep: int = 3) -> str:
    """Synchronous checkpoint write.  Returns the checkpoint path."""
    ckpt_dir = pathlib.Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    flat, _ = _flatten(tree)
    tmp = ckpt_dir / f".tmp-step_{step}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()
    manifest = {"step": step, "leaves": {}}
    for i, (key, leaf) in enumerate(sorted(flat.items())):
        arr = np.asarray(jax.device_get(leaf))
        fname = f"leaf_{i:05d}.npy"
        np.save(tmp / fname, arr)
        manifest["leaves"][key] = {
            "file": fname, "shape": list(arr.shape), "dtype": str(arr.dtype),
            "crc32": zlib.crc32(arr.tobytes()),
        }
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    final = ckpt_dir / f"step_{step}"
    if final.exists():
        shutil.rmtree(final)
    os.replace(tmp, final)
    _gc(ckpt_dir, keep)
    return str(final)


class AsyncCheckpointer:
    """Snapshot-to-host synchronously, write on a worker thread."""

    def __init__(self, ckpt_dir, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: threading.Thread | None = None
        self.last_error: Exception | None = None

    def save(self, step: int, tree):
        snapshot = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        self.wait()

        def work():
            try:
                save(self.ckpt_dir, step, snapshot, keep=self.keep)
            except Exception as e:  # noqa: BLE001
                self.last_error = e
        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self.last_error is not None:
            # hand the error over exactly once: a failed background save
            # must not poison every later save/wait with a stale exception
            err, self.last_error = self.last_error, None
            raise err


def all_steps(ckpt_dir) -> list[int]:
    """Every ``step_<N>`` directory under ``ckpt_dir``, ascending —
    *without* any integrity claim (see ``valid_steps``).  ``.tmp-*``
    write-in-progress directories are never listed."""
    ckpt_dir = pathlib.Path(ckpt_dir)
    if not ckpt_dir.exists():
        return []
    return sorted(int(m.group(1)) for p in ckpt_dir.iterdir()
                  if (m := re.fullmatch(r"step_(\d+)", p.name)))


def latest_step(ckpt_dir) -> int | None:
    steps = all_steps(ckpt_dir)
    return steps[-1] if steps else None


def verify_checkpoint(ckpt_dir, step: int, *, deep: bool = True) -> bool:
    """True iff the checkpoint at ``step`` restores cleanly: the manifest
    parses, every leaf file exists and (``deep``) loads with its recorded
    shape/dtype and matching CRC32.  Never raises."""
    path = pathlib.Path(ckpt_dir) / f"step_{step}"
    try:
        manifest = json.loads((path / "manifest.json").read_text())
        for key, meta in manifest["leaves"].items():
            f = path / meta["file"]
            if not f.exists():
                return False
            if deep:
                arr = np.load(f)
                if (list(arr.shape) != list(meta["shape"])
                        or str(arr.dtype) != meta["dtype"]
                        or zlib.crc32(arr.tobytes()) != meta["crc32"]):
                    return False
        return True
    except Exception:  # noqa: BLE001 — any parse/IO failure = not valid
        return False


def valid_steps(ckpt_dir, *, deep: bool = True) -> list[int]:
    """The steps whose checkpoints verify end to end, ascending.  This is
    the scan ``restore_latest`` walk-back is built on: a torn write (partial
    leaf set), flipped bytes, or a mangled manifest all disqualify a step
    without raising."""
    return [s for s in all_steps(ckpt_dir)
            if verify_checkpoint(ckpt_dir, s, deep=deep)]


def restore(ckpt_dir, step: int, target_tree, *, shardings=None,
            verify: bool = True, match_shapes: bool = False):
    """Restore into the structure of ``target_tree`` (shapes/dtypes may be
    eval_shape'd).  ``shardings``: optional matching tree of NamedShardings —
    this is what makes restore mesh-elastic.  ``match_shapes``: reject a
    checkpoint whose stored leaf shapes disagree with the template's (the
    walk-back path uses this to skip pre-elastic-re-scale checkpoints whose
    residual still carries the old mesh width)."""
    path = pathlib.Path(ckpt_dir) / f"step_{step}"
    manifest = json.loads((path / "manifest.json").read_text())
    flat_t, treedef = _flatten(target_tree)
    flat_s, _ = _flatten(shardings) if shardings is not None else ({}, None)
    out = {}
    for key in flat_t:
        meta = manifest["leaves"][key]
        if match_shapes and hasattr(flat_t[key], "shape") \
                and list(meta["shape"]) != list(flat_t[key].shape):
            raise ValueError(
                f"checkpoint leaf {key} has shape {meta['shape']} but the "
                f"template expects {list(flat_t[key].shape)} (stale "
                f"pre-re-scale checkpoint?)")
        arr = np.load(path / meta["file"])
        if verify and zlib.crc32(arr.tobytes()) != meta["crc32"]:
            raise IOError(f"checkpoint corruption in leaf {key}")
        if key in flat_s:
            arr = jax.device_put(arr, flat_s[key])
        out[key] = arr
    return jax.tree_util.tree_unflatten(treedef,
                                        [out[k] for k in flat_t])


def restore_latest(ckpt_dir, target_tree, *, shardings=None,
                   verify: bool = True, match_shapes: bool = True,
                   on_skip=None):
    """Walk-back restore: try the newest checkpoint first and degrade to the
    newest one that restores cleanly (CRC verified, every leaf present,
    shapes agreeing with the template).  Returns ``(tree, step)``;
    ``(target_tree, 0)`` when nothing under ``ckpt_dir`` is restorable.
    ``on_skip(step, exc)`` observes each rejected checkpoint — the resilient
    loop logs these as resilience events."""
    for step in reversed(all_steps(ckpt_dir)):
        try:
            tree = restore(ckpt_dir, step, target_tree, shardings=shardings,
                           verify=verify, match_shapes=match_shapes)
            return tree, step
        except Exception as e:  # noqa: BLE001 — walk back past any bad step
            if on_skip is not None:
                on_skip(step, e)
    return target_tree, 0


def _gc(ckpt_dir, keep: int):
    ckpt_dir = pathlib.Path(ckpt_dir)
    steps = sorted([int(m.group(1)) for p in ckpt_dir.iterdir()
                    if (m := re.fullmatch(r"step_(\d+)", p.name))])
    for s in steps[:-keep]:
        shutil.rmtree(ckpt_dir / f"step_{s}", ignore_errors=True)
