"""Train / prefill / decode step builders.

``make_train_step`` (the LM step) is pjit-ready and mesh-agnostic: it
closes over the model config and optimizer; the caller jits it with
shardings derived from the logical-axis spec trees (``nn.partitioning``).
Gradient all-reduce across the data axes is implicit in the sharded
autodiff; overlap comes from the XLA latency-hiding scheduler (see
launch/dryrun.py flags) plus optional microbatch gradient accumulation
(``accum_steps``) which pipelines the dW reduction of microbatch i with the
compute of i+1 — the paper's §II-J trade-off at cluster scale.

``make_cnn_train_step`` / ``warmup_cnn_train`` are the GxM (CNN) siblings:
the step routes every conv through ``core.conv.conv2d_train``'s custom VJP
— tiled forward kernel, phase-duality backward-data, band-streamed update
pass (DESIGN.md §4/§10) — and the warmup pre-tunes the "fwd", "bwd"
(dual-conv) and "wu" blocking-cache signatures of the whole training graph
so the first step never tunes inline.  The CNN step here is *device-local*
by construction; its data-parallel sibling —
``train.distributed.make_cnn_train_step_dp``, explicit ``shard_map`` over
the mesh's data axis with the gradient psum placed between the update pass
and the optimizer — is what multi-device runs use (DESIGN.md §11).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.nn import transformer as T
from repro.optim.adamw import clip_by_global_norm


def loss_for_batch(params, cfg, batch, *, impl=None):
    if "embeds" in batch:
        return T.lm_loss_embeds(params, cfg, batch["embeds"],
                                batch["labels"], impl=impl)
    return T.lm_loss(params, cfg, batch["tokens"], batch["labels"],
                     impl=impl)


def make_train_step(cfg, opt, *, lr: float = 3e-4, clip: float = 1.0,
                    accum_steps: int = 1, impl=None):
    def train_step(state, batch):
        params = state["params"]
        if accum_steps == 1:
            loss, grads = jax.value_and_grad(loss_for_batch)(
                params, cfg, batch, impl=impl)
        else:
            def micro(i, carry):
                acc, loss_acc = carry
                mb = jax.tree.map(
                    lambda x: jax.lax.dynamic_slice_in_dim(
                        x, i * (x.shape[0] // accum_steps),
                        x.shape[0] // accum_steps, 0), batch)
                l, g = jax.value_and_grad(loss_for_batch)(
                    params, cfg, mb, impl=impl)
                return (jax.tree.map(jnp.add, acc, g), loss_acc + l)
            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            grads, loss = jax.lax.fori_loop(
                0, accum_steps, micro, (zeros, jnp.zeros((), jnp.float32)))
            grads = jax.tree.map(lambda g: g / accum_steps, grads)
            loss = loss / accum_steps
        grads, gnorm = clip_by_global_norm(grads, clip)
        new_params, new_opt = opt.update(grads, state["opt"], params, lr)
        new_state = {"params": new_params, "opt": new_opt,
                     "step": state["step"] + 1}
        return new_state, {"loss": loss, "grad_norm": gnorm}
    return train_step


def make_cnn_train_step(gxm, *, lr: float = 0.1, bn_momentum: float = 0.9,
                        autotune: str | None = None):
    """Jitted SGD step over a GxM CNN (``graph.executor.GxM``).

    Every conv runs ``conv2d_train``: the forward is the tiled direct
    kernel, dI comes from the §II-I duality (phase-decomposed for strided
    layers under the default ``REPRO_BWD_DUALITY=phase`` plan) and dW from
    the band-streamed §II-J update pass.  ``autotune`` (None = the global
    knob) scopes the blocking-mode around tracing, so a "cache" step
    consults what ``warmup_cnn_train`` persisted — never tunes inline.
    """
    from repro import backend as be

    jitted = jax.jit(functools.partial(gxm.sgd_train_step,
                                       bn_momentum=bn_momentum))

    def step(params, batch):
        if autotune is None:
            return jitted(params, batch, lr)
        with be.use_autotune(autotune):
            return jitted(params, batch, lr)
    return step


def warmup_cnn_train(gxm, *, image_hw=(224, 224), minibatch: int = 1,
                     mode: str = "tune", backend=None, cache=None,
                     bwd_mode: str | None = None, mesh=None) -> list[dict]:
    """Pre-tune every blocking-cache entry one training step of ``gxm``
    needs: the "fwd" signature of each distinct conv, the "bwd" signatures
    of its backward-data dual conv(s), and its "wu" update-pass signature —
    the training analog of serving's ``CnnInferenceEngine.warmup`` (which
    only covers forward).  With ``mesh``, ``minibatch`` is the *global*
    batch and the entries are keyed at the per-shard batch the data-parallel
    step's shard_map body lowers to; tuning runs once per host —
    ``train.distributed.warmup_cnn_train_dp`` wraps this with the
    export/broadcast half.  Returns the ``tune.warmup_convs`` report."""
    from repro import tune
    from repro.graph.serving import conv_shapes, distinct_conv_signatures

    if mesh is not None:
        from repro.launch.mesh import data_axis_size
        shards = data_axis_size(mesh)
        assert minibatch % shards == 0, (minibatch, shards)
        minibatch //= shards
    sigs = distinct_conv_signatures(conv_shapes(gxm.etg, image_hw))
    return tune.warmup_convs(sigs, minibatches=(minibatch,),
                             kinds=("fwd", "bwd", "wu"), mode=mode,
                             backend=backend or gxm.impl, cache=cache,
                             bwd_mode=bwd_mode)


def make_prefill_step(cfg, *, cache_len: int, impl=None):
    def prefill(params, batch):
        kw = dict(impl=impl, return_cache=True, cache_len=cache_len)
        if "embeds" in batch:
            logits, _, cache = T.forward(params, cfg, embeds=batch["embeds"],
                                         **kw)
        else:
            logits, _, cache = T.forward(params, cfg, tokens=batch["tokens"],
                                         **kw)
        return logits[:, -1:, :], cache
    return prefill


def make_decode_step(cfg, *, impl=None):
    def serve_step(params, tokens, cache, idx):
        return T.decode_step(params, cfg, tokens, cache, idx)
    return serve_step


def init_train_state(cfg, opt, key):
    params, specs = T.init_lm(key, cfg)
    return {"params": params, "opt": opt.init(params),
            "step": jnp.zeros((), jnp.int32)}, specs


def train_state_specs(param_specs, opt_state):
    """Logical-axis spec tree for the full train state: optimizer slots
    inherit their parameter's axes (factored accumulators drop the reduced
    dim).  ``opt_state`` may be real or eval_shape'd — only its structure is
    read."""
    is_spec = lambda x: isinstance(x, tuple) and all(
        a is None or isinstance(a, str) for a in x)

    def leaf(spec, slot):
        out = {"m": spec}
        if "v" in slot:
            out["v"] = spec
        if "vr" in slot:
            out["vr"] = spec[:-1]
            out["vc"] = spec[:-2] + spec[-1:]
        return out

    mu = jax.tree.map(leaf, param_specs, opt_state["mu"], is_leaf=is_spec)
    return {"params": param_specs, "opt": {"mu": mu, "count": ()},
            "step": ()}
