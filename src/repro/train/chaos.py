"""Deterministic, seeded fault injection for the resilient training loop
(DESIGN.md §14).

The chaos harness plays the 1000-host failure model against a *simulated*
multi-host clock, so every detection/eviction/recovery decision — and
therefore the resilience bench's goodput numbers — is a pure function of
the schedule, never of wall-clock noise:

  StepFault           the step raises once (preemption, OOM, flaky NIC)
  HostDeath           a host stops heart-beating; while it is still in the
                      loop's ``alive`` set, every step fails with a
                      collective timeout (a dead peer hangs the all-reduce)
  SlowHost            a host's step durations multiply by ``factor`` —
                      the straggler the §II-F work-division argument evicts
  CorruptCheckpoint   flip a byte in a leaf of the newest checkpoint
                      (silent storage corruption — CRC catches it on load)
  TornCheckpoint      mid-write crash artifacts: a partial ``step_<N>``
                      directory newer than the newest valid checkpoint (a
                      non-atomic writer's wreckage) plus a stale ``.tmp-*``
                      dir (what the atomic writer leaves behind)
  FlakySaves          the next N ``save`` calls raise (transient storage
                      outage — the loop's bounded-retry/backoff path)

``ChaosEngine`` binds to a ``ResilientLoop`` (pass ``chaos=engine``): it
supplies the simulated clock, the failure hook and the per-host heartbeat
source, wraps the checkpointer for save-fault injection, and reads the
loop's ``alive`` set back so an injected collective failure stops the
moment the dead host is evicted.  ``ChaosSchedule.generate(seed, ...)``
draws a reproducible schedule — the ``REPRO_CHAOS`` knob feeds it from
``launch/train.py``.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
import shutil

import numpy as np

from repro.core.simtime import SimClock, seeded_rng  # noqa: F401 — SimClock
# is re-exported here for compatibility: it grew up in this module (PR 8)
# and moved to core/simtime.py when the serving fleet (serve/) needed the
# same simulated-time substrate (DESIGN.md §15).
from repro.train import checkpoint as ckpt_lib
from repro.train.fault_tolerance import Heartbeat


class ChaosError(RuntimeError):
    """An injected failure (step fault / collective timeout)."""


# -- fault vocabulary ---------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class StepFault:
    step: int
    message: str = "injected step fault"
    cost_s: float = 0.5             # simulated time burned by the failure


@dataclasses.dataclass(frozen=True)
class HostDeath:
    step: int
    host: str


@dataclasses.dataclass(frozen=True)
class SlowHost:
    step: int
    host: str
    factor: float = 3.0
    until: int | None = None        # recovers at `until` (None = forever)


@dataclasses.dataclass(frozen=True)
class CorruptCheckpoint:
    step: int                       # fires once a checkpoint exists


@dataclasses.dataclass(frozen=True)
class TornCheckpoint:
    step: int                       # fires once a checkpoint exists to tear


@dataclasses.dataclass(frozen=True)
class FlakySaves:
    step: int
    times: int = 1


_KINDS = ("step_fault", "death", "slow", "corrupt", "torn", "flaky_save")


@dataclasses.dataclass(frozen=True)
class ChaosSchedule:
    events: tuple
    seed: int | None = None

    @staticmethod
    def generate(seed: int, *, n_steps: int, hosts, kinds=_KINDS,
                 intensity: float = 1.0) -> "ChaosSchedule":
        """A reproducible random schedule: ~2% of steps fault at unit
        intensity.  Host 0 is never killed (something must survive), and at
        most ``len(hosts) - 1`` deaths are drawn so the fleet never empties.
        Same seed -> identical schedule, bit for bit."""
        hosts = list(hosts)
        rng = seeded_rng(0xC4A05, seed)
        n = max(1, round(n_steps * 0.02 * intensity))
        mortal = hosts[1:]
        events = []
        for _ in range(n):
            kind = kinds[int(rng.integers(len(kinds)))]
            step = int(rng.integers(1, max(2, n_steps)))
            if kind == "death" and mortal:
                events.append(HostDeath(step, mortal.pop(
                    int(rng.integers(len(mortal))))))
            elif kind == "slow" and len(hosts) > 1:
                events.append(SlowHost(
                    step, hosts[int(rng.integers(1, len(hosts)))],
                    factor=float(2.0 + 2.0 * rng.random()),
                    until=step + int(rng.integers(5, 30))))
            elif kind == "corrupt":
                events.append(CorruptCheckpoint(step))
            elif kind == "torn":
                events.append(TornCheckpoint(step))
            elif kind == "flaky_save":
                events.append(FlakySaves(step, times=int(rng.integers(1, 3))))
            else:
                events.append(StepFault(step))
        return ChaosSchedule(tuple(sorted(events, key=lambda e: e.step)),
                             seed=seed)


# -- checkpoint attack helpers (also used directly by tests) ------------------

def corrupt_latest(ckpt_dir) -> int | None:
    """Flip a byte in one leaf of the newest checkpoint; returns the step
    attacked (None when no checkpoint exists yet)."""
    step = ckpt_lib.latest_step(ckpt_dir)
    if step is None:
        return None
    path = pathlib.Path(ckpt_dir) / f"step_{step}"
    manifest = json.loads((path / "manifest.json").read_text())
    fname = sorted(m["file"] for m in manifest["leaves"].values())[0]
    f = path / fname
    raw = bytearray(f.read_bytes())
    raw[-1] ^= 0xFF
    f.write_bytes(bytes(raw))
    return step


def torn_checkpoint(ckpt_dir) -> int | None:
    """Leave mid-write crash wreckage: copy the newest checkpoint to a
    *newer* step number, truncate one leaf and drop another (the partial
    write a non-atomic writer strands), plus a stale ``.tmp-*`` directory
    (the atomic writer's).  Walk-back restore must skip both."""
    latest = ckpt_lib.latest_step(ckpt_dir)
    if latest is None:
        return None
    src = pathlib.Path(ckpt_dir) / f"step_{latest}"
    step = latest + 1
    dst = pathlib.Path(ckpt_dir) / f"step_{step}"
    if dst.exists():
        shutil.rmtree(dst)
    shutil.copytree(src, dst)
    leaves = sorted(p for p in dst.iterdir() if p.suffix == ".npy")
    raw = leaves[0].read_bytes()
    leaves[0].write_bytes(raw[:max(1, len(raw) // 2)])
    if len(leaves) > 1:
        leaves[-1].unlink()
    tmp = pathlib.Path(ckpt_dir) / f".tmp-step_{step + 1}"
    if tmp.exists():
        shutil.rmtree(tmp)
    shutil.copytree(src, tmp)
    return step


class _FlakyCheckpointer:
    """Checkpointer proxy: ``save`` raises while the engine says the
    storage is out; everything else delegates."""

    def __init__(self, inner, engine: "ChaosEngine"):
        self._inner = inner
        self._engine = engine

    def save(self, step, tree):
        if self._engine.take_save_fault():
            raise IOError("chaos: injected transient checkpoint-save failure")
        return self._inner.save(step, tree)

    def __getattr__(self, name):
        return getattr(self._inner, name)


# -- the engine ---------------------------------------------------------------

class ChaosEngine:
    """Replays a ``ChaosSchedule`` against a ``ResilientLoop``.

    The engine owns the ``SimClock`` and advances it: each successful step
    costs ``step_s`` x the slowest alive host's factor; each collective
    failure costs ``collective_timeout_s``; each injected step fault costs
    its ``cost_s``.  Goodput under a schedule is then
    ``t(fault_free) / t(schedule)`` — fully deterministic.
    """

    def __init__(self, schedule: ChaosSchedule, *, hosts, ckpt_dir,
                 step_s: float = 1.0, collective_timeout_s: float = 2.0,
                 clock: SimClock | None = None):
        self.schedule = schedule
        self.hosts = list(hosts)
        self.ckpt_dir = ckpt_dir
        self.step_s = step_s
        self.collective_timeout_s = collective_timeout_s
        self.clock = clock or SimClock()
        self.dead: set[str] = set()
        self.slow: dict[str, SlowHost] = {}
        self.injected: list[dict] = []
        self._fired: set[int] = set()
        self._flaky_saves = 0
        self._loop = None

    def bind(self, loop) -> None:
        self._loop = loop
        loop.checkpointer = _FlakyCheckpointer(loop.checkpointer, self)

    def make_heartbeat(self, *, window: int = 8,
                       threshold: float = 1.5) -> Heartbeat:
        """A Heartbeat scaled to simulated time: the dead timeout is a few
        collective timeouts, so a dead host is detected after a handful of
        failed attempts instead of 300 wall seconds."""
        return Heartbeat(window=window, threshold=threshold,
                         timeout_s=2.5 * max(self.collective_timeout_s,
                                             self.step_s),
                         clock=self.clock.time)

    # -- loop-facing hooks ----------------------------------------------------

    def _alive(self) -> set[str]:
        return set(self._loop.alive) if self._loop is not None \
            else set(self.hosts)

    def take_save_fault(self) -> bool:
        if self._flaky_saves > 0:
            self._flaky_saves -= 1
            self._log("save_fault")
            return True
        return False

    def _drain_saves(self) -> None:
        """Join the loop's in-flight async save before attacking the
        checkpoint directory — the attack must hit a *durable* checkpoint,
        not race a background writer (replay determinism)."""
        if self._loop is None:
            return
        try:
            self._loop.checkpointer.wait()
        except Exception:  # noqa: BLE001 — the loop's retry path owns it
            pass

    def _log(self, kind: str, **fields) -> None:
        self.injected.append({"kind": kind, "t": self.clock.time(), **fields})

    def _apply_due(self, step: int) -> None:
        for i, ev in enumerate(self.schedule.events):
            if i in self._fired or ev.step > step:
                continue
            if isinstance(ev, HostDeath):
                self.dead.add(ev.host)
            elif isinstance(ev, SlowHost):
                self.slow[ev.host] = ev
            elif isinstance(ev, CorruptCheckpoint):
                self._drain_saves()
                attacked = corrupt_latest(self.ckpt_dir)
                if attacked is None:
                    continue            # no checkpoint yet — stay armed
                self._fired.add(i)
                self._log("CorruptCheckpoint", step=step, attacked=attacked)
                continue
            elif isinstance(ev, TornCheckpoint):
                self._drain_saves()
                attacked = torn_checkpoint(self.ckpt_dir)
                if attacked is None:
                    continue
                self._fired.add(i)
                self._log("TornCheckpoint", step=step, attacked=attacked)
                continue
            elif isinstance(ev, FlakySaves):
                self._flaky_saves += ev.times
            elif isinstance(ev, StepFault):
                self._fired.add(i)
                self._log("step_fault", step=step)
                self.clock.advance(ev.cost_s)
                raise ChaosError(f"{ev.message} @ step {step}")
            self._fired.add(i)
            self._log(type(ev).__name__, step=step,
                      host=getattr(ev, "host", None))

    def failure_hook(self, step: int) -> None:
        """Install as the loop's ``failure_hook`` (runs before every step).
        Applies due schedule events, then fails the collective while any
        dead host is still considered alive by the loop."""
        self._apply_due(step)
        dead_alive = self.dead & self._alive()
        if dead_alive:
            self.clock.advance(self.collective_timeout_s)
            self._log("collective_timeout", step=step,
                      hosts=sorted(dead_alive))
            raise ChaosError(
                f"collective timeout: no heartbeat from {sorted(dead_alive)}")

    def liveness(self, step: int) -> list[str]:
        """Hosts that answer an out-of-band liveness ping right now —
        everyone except the dead.  Never advances the clock (pings are
        cheap and concurrent with the hung collective)."""
        return sorted(self._alive() - self.dead)

    def heartbeat_source(self, step: int, dt: float) -> dict:
        """Simulated per-host step durations; advances the clock by the
        slowest alive host (synchronous data parallelism).  Dead hosts are
        absent — their ``last_seen`` goes stale until the timeout fires."""
        alive = self._alive() - self.dead
        durations = {}
        for h in sorted(alive):
            ev = self.slow.get(h)
            factor = ev.factor if ev is not None and \
                (ev.until is None or step < ev.until) else 1.0
            durations[h] = self.step_s * factor
        self.clock.advance(max(durations.values()) if durations
                           else self.step_s)
        return durations
