"""Kernel implementation dispatch.

"pallas"    — real Mosaic lowering (TPU targets; what the dry-run *describes*)
"interpret" — Pallas interpret mode (CPU correctness validation; tests)
"xla"       — pure-jnp/lax reference path (CPU dry-run lowering at 512 devices
              and the numerics oracle)

The per-shape JIT specialization story of the paper (§II-D) is carried by
jax.jit itself: every (layer shape × blocking) pair traces and compiles its
own specialized kernel, on demand, cached — libxsmm's runtime code
generation, one level up.

The *blocking* each specialization uses is governed by the autotune knob
(``REPRO_AUTOTUNE`` / ``set_autotune`` / ``use_autotune``):

  "off"    analytic heuristic only (seed behavior; default)
  "cache"  consult the persistent per-shape tuner cache, analytic on miss
  "tune"   on a miss, search the blocking space, persist the winner

See ``repro.tune`` and DESIGN.md §6.
"""
from __future__ import annotations

import os
from contextlib import contextmanager

_VALID = ("pallas", "interpret", "xla")
_VALID_AUTOTUNE = ("off", "cache", "tune")
_backend = os.environ.get("REPRO_BACKEND", "xla")
_autotune = os.environ.get("REPRO_AUTOTUNE", "off")
if _autotune not in _VALID_AUTOTUNE:
    import sys
    print(f"repro.backend: ignoring invalid REPRO_AUTOTUNE={_autotune!r} "
          f"(valid: {', '.join(_VALID_AUTOTUNE)}); autotuning is off",
          file=sys.stderr)
    _autotune = "off"


def get_backend() -> str:
    return _backend


def set_backend(name: str) -> None:
    global _backend
    assert name in _VALID, name
    _backend = name


@contextmanager
def use_backend(name: str):
    global _backend
    prev = _backend
    set_backend(name)
    try:
        yield
    finally:
        _backend = prev


def resolve(impl: str | None) -> str:
    impl = impl or _backend
    assert impl in _VALID, impl
    return impl


def get_autotune() -> str:
    return _autotune


def set_autotune(mode: str) -> None:
    global _autotune
    assert mode in _VALID_AUTOTUNE, mode
    _autotune = mode


@contextmanager
def use_autotune(mode: str):
    global _autotune
    prev = _autotune
    set_autotune(mode)
    try:
        yield
    finally:
        _autotune = prev


def resolve_autotune(mode: str | None) -> str:
    mode = mode or _autotune
    assert mode in _VALID_AUTOTUNE, mode
    return mode
