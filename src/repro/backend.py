"""Kernel implementation dispatch.

"pallas"    — real Mosaic lowering (TPU targets; what the dry-run *describes*)
"interpret" — Pallas interpret mode (CPU correctness validation; tests)
"xla"       — pure-jnp/lax reference path (CPU dry-run lowering at 512 devices
              and the numerics oracle)

The per-shape JIT specialization story of the paper (§II-D) is carried by
jax.jit itself: every (layer shape × blocking) pair traces and compiles its
own specialized kernel, on demand, cached — libxsmm's runtime code
generation, one level up.

The *blocking* each specialization uses is governed by the autotune knob
(``REPRO_AUTOTUNE`` / ``set_autotune`` / ``use_autotune``):

  "off"    analytic heuristic only (seed behavior; default)
  "cache"  consult the persistent per-shape tuner cache, analytic on miss
  "tune"   on a miss, search the blocking space, persist the winner

See ``repro.tune`` and DESIGN.md §6.

The conv *input strategy* has its own knob (``REPRO_CONV_TILING``
/ ``set_conv_tiling``): "tiled" (default) streams row bands with a VMEM
working set independent of the image size, "whole" is the legacy
whole-plane kernel kept for A/B comparison.  It governs both the forward
kernel (DESIGN.md §9) and the weight-update kernel (DESIGN.md §10).

The strided backward-data plan (``REPRO_BWD_DUALITY`` / ``set_bwd_duality``)
selects how the generic §II-I duality case runs: "phase" (default)
decomposes into stride² forward sub-convs over the *undilated* dO — no
intermediate tensor, no multiply-by-zero work; "dilate" is the legacy
materialize-the-dilated-dO plan kept for A/B.  See DESIGN.md §10.

The data-parallel gradient reduction (``REPRO_GRAD_COMPRESS``
/ ``set_grad_compress``) selects the wire format of the cross-shard psum in
the DP CNN train step: "off" (default) reduces f32 gradients exactly;
"int8" routes every leaf through ``optim.compress.compressed_psum`` —
error-feedback int8 quantization, 1/4 the all-reduce bytes, residual
carried in the train state.  See DESIGN.md §11.

Depth-first chain fusion (``REPRO_CHAIN_FUSION`` / ``set_chain_fusion``)
gates the cross-layer band-fusion path (DESIGN.md §16): "off" (default)
runs every conv task layer-by-layer; "on" lets the GxM inference executor
run detected single-consumer conv->conv chains band-by-band through
``kernels.conv2d_chain`` — the intermediate activation never materializes
in HBM — falling back per-chain to unfused whenever the combined band
working set exceeds ``REPRO_VMEM_BUDGET`` (or fusion is unprofitable).

Quantized inference (``REPRO_QUANTIZE`` / ``set_quantize``) is the per-model
opt-in for the §II-K int8 serving path: "off" (default) runs f32 convs;
"int8" makes ``GxM``/``CnnInferenceEngine`` built without an explicit
``quantized=`` flag mark every conv task "q8" — int8 weights + per-tensor
calibrated activation scales through ``kernels.conv2d_q8``, int32
accumulation, f32 dequant epilogue.  See DESIGN.md §13.
"""
from __future__ import annotations

import os
from contextlib import contextmanager

_VALID = ("pallas", "interpret", "xla")
_VALID_AUTOTUNE = ("off", "cache", "tune")
_VALID_CONV_TILING = ("tiled", "whole")
_VALID_BWD_DUALITY = ("phase", "dilate")
_VALID_GRAD_COMPRESS = ("off", "int8")
_VALID_QUANTIZE = ("off", "int8")
_VALID_CHAIN_FUSION = ("off", "on")
_backend = os.environ.get("REPRO_BACKEND", "xla")
_autotune = os.environ.get("REPRO_AUTOTUNE", "off")
_conv_tiling = os.environ.get("REPRO_CONV_TILING", "tiled")
_bwd_duality = os.environ.get("REPRO_BWD_DUALITY", "phase")
_grad_compress = os.environ.get("REPRO_GRAD_COMPRESS", "off")
_quantize = os.environ.get("REPRO_QUANTIZE", "off")
_chain_fusion = os.environ.get("REPRO_CHAIN_FUSION", "off")
if _chain_fusion not in _VALID_CHAIN_FUSION:
    import sys
    print(f"repro.backend: ignoring invalid REPRO_CHAIN_FUSION="
          f"{_chain_fusion!r} (valid: {', '.join(_VALID_CHAIN_FUSION)}); "
          f"using off", file=sys.stderr)
    _chain_fusion = "off"
if _quantize not in _VALID_QUANTIZE:
    import sys
    print(f"repro.backend: ignoring invalid REPRO_QUANTIZE="
          f"{_quantize!r} (valid: {', '.join(_VALID_QUANTIZE)}); "
          f"using off", file=sys.stderr)
    _quantize = "off"
if _grad_compress not in _VALID_GRAD_COMPRESS:
    import sys
    print(f"repro.backend: ignoring invalid REPRO_GRAD_COMPRESS="
          f"{_grad_compress!r} (valid: {', '.join(_VALID_GRAD_COMPRESS)}); "
          f"using off", file=sys.stderr)
    _grad_compress = "off"
if _bwd_duality not in _VALID_BWD_DUALITY:
    import sys
    print(f"repro.backend: ignoring invalid REPRO_BWD_DUALITY="
          f"{_bwd_duality!r} (valid: {', '.join(_VALID_BWD_DUALITY)}); "
          f"using phase", file=sys.stderr)
    _bwd_duality = "phase"
if _autotune not in _VALID_AUTOTUNE:
    import sys
    print(f"repro.backend: ignoring invalid REPRO_AUTOTUNE={_autotune!r} "
          f"(valid: {', '.join(_VALID_AUTOTUNE)}); autotuning is off",
          file=sys.stderr)
    _autotune = "off"
if _conv_tiling not in _VALID_CONV_TILING:
    import sys
    print(f"repro.backend: ignoring invalid REPRO_CONV_TILING="
          f"{_conv_tiling!r} (valid: {', '.join(_VALID_CONV_TILING)}); "
          f"using tiled", file=sys.stderr)
    _conv_tiling = "tiled"


def get_backend() -> str:
    return _backend


def set_backend(name: str) -> None:
    global _backend
    assert name in _VALID, name
    _backend = name


@contextmanager
def use_backend(name: str):
    global _backend
    prev = _backend
    set_backend(name)
    try:
        yield
    finally:
        _backend = prev


def resolve(impl: str | None) -> str:
    impl = impl or _backend
    assert impl in _VALID, impl
    return impl


def get_autotune() -> str:
    return _autotune


def set_autotune(mode: str) -> None:
    global _autotune
    assert mode in _VALID_AUTOTUNE, mode
    _autotune = mode


@contextmanager
def use_autotune(mode: str):
    global _autotune
    prev = _autotune
    set_autotune(mode)
    try:
        yield
    finally:
        _autotune = prev


def resolve_autotune(mode: str | None) -> str:
    mode = mode or _autotune
    assert mode in _VALID_AUTOTUNE, mode
    return mode


def get_conv_tiling() -> str:
    """Forward direct-conv input strategy: "tiled" streams only the row band
    each grid step needs (VMEM working set independent of H*W — the default);
    "whole" is the legacy whole-plane kernel, kept for A/B benchmarking."""
    return _conv_tiling


def set_conv_tiling(mode: str) -> None:
    global _conv_tiling
    assert mode in _VALID_CONV_TILING, mode
    _conv_tiling = mode


@contextmanager
def use_conv_tiling(mode: str):
    global _conv_tiling
    prev = _conv_tiling
    set_conv_tiling(mode)
    try:
        yield
    finally:
        _conv_tiling = prev


def get_bwd_duality() -> str:
    """Generic strided backward-data plan: "phase" runs stride² forward
    sub-convs over the undilated dO (zero-free — the default); "dilate" is
    the legacy materialized-dilation plan, kept for A/B benchmarking."""
    return _bwd_duality


def set_bwd_duality(mode: str) -> None:
    global _bwd_duality
    assert mode in _VALID_BWD_DUALITY, mode
    _bwd_duality = mode


@contextmanager
def use_bwd_duality(mode: str):
    global _bwd_duality
    prev = _bwd_duality
    set_bwd_duality(mode)
    try:
        yield
    finally:
        _bwd_duality = prev


def get_grad_compress() -> str:
    """Data-parallel gradient-reduction wire format: "off" = exact f32 psum;
    "int8" = error-feedback compressed psum (1/4 the bytes, residual carried
    in the train state).  See ``train/distributed.py`` / DESIGN.md §11."""
    return _grad_compress


def set_grad_compress(mode: str) -> None:
    global _grad_compress
    assert mode in _VALID_GRAD_COMPRESS, mode
    _grad_compress = mode


@contextmanager
def use_grad_compress(mode: str):
    global _grad_compress
    prev = _grad_compress
    set_grad_compress(mode)
    try:
        yield
    finally:
        _grad_compress = prev


def resolve_grad_compress(mode: str | None) -> str:
    mode = mode or _grad_compress
    assert mode in _VALID_GRAD_COMPRESS, mode
    return mode


def get_quantize() -> str:
    """Quantized-inference opt-in: "off" = f32 convs (default); "int8" =
    the §II-K serving path — conv tasks marked "q8", int8 weights and
    calibrated activations through ``kernels.conv2d_q8``.  DESIGN.md §13."""
    return _quantize


def set_quantize(mode: str) -> None:
    global _quantize
    assert mode in _VALID_QUANTIZE, mode
    _quantize = mode


@contextmanager
def use_quantize(mode: str):
    global _quantize
    prev = _quantize
    set_quantize(mode)
    try:
        yield
    finally:
        _quantize = prev


def resolve_quantize(mode: str | None) -> str:
    mode = mode or _quantize
    assert mode in _VALID_QUANTIZE, mode
    return mode


def get_chain_fusion() -> str:
    """Depth-first chain-fusion opt-in: "off" = layer-by-layer conv tasks
    (default); "on" = run single-consumer conv->conv chains band-by-band
    (``kernels.conv2d_chain``), intermediates never touching HBM, with a
    per-chain VMEM/profitability fallback.  DESIGN.md §16."""
    return _chain_fusion


def set_chain_fusion(mode: str) -> None:
    global _chain_fusion
    assert mode in _VALID_CHAIN_FUSION, mode
    _chain_fusion = mode


@contextmanager
def use_chain_fusion(mode: str):
    global _chain_fusion
    prev = _chain_fusion
    set_chain_fusion(mode)
    try:
        yield
    finally:
        _chain_fusion = prev


def resolve_chain_fusion(mode: str | None) -> str:
    mode = mode or _chain_fusion
    assert mode in _VALID_CHAIN_FUSION, mode
    return mode
