"""Kernel implementation dispatch.

"pallas"    — real Mosaic lowering (TPU targets; what the dry-run *describes*)
"interpret" — Pallas interpret mode (CPU correctness validation; tests)
"xla"       — pure-jnp/lax reference path (CPU dry-run lowering at 512 devices
              and the numerics oracle)

The per-shape JIT specialization story of the paper (§II-D) is carried by
jax.jit itself: every (layer shape × blocking) pair traces and compiles its
own specialized kernel, on demand, cached — libxsmm's runtime code
generation, one level up.
"""
from __future__ import annotations

import os
from contextlib import contextmanager

_VALID = ("pallas", "interpret", "xla")
_backend = os.environ.get("REPRO_BACKEND", "xla")


def get_backend() -> str:
    return _backend


def set_backend(name: str) -> None:
    global _backend
    assert name in _VALID, name
    _backend = name


@contextmanager
def use_backend(name: str):
    global _backend
    prev = _backend
    set_backend(name)
    try:
        yield
    finally:
        _backend = prev


def resolve(impl: str | None) -> str:
    impl = impl or _backend
    assert impl in _VALID, impl
    return impl
