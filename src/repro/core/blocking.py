"""Blocking selection — the paper's §II-B/C/D RB_P/RB_Q/cache-block choice,
re-derived for the TPU memory hierarchy (HBM -> VMEM -> VREG, MXU 128x128).

The paper picks register blocks to (a) hide FMA latency with independent
accumulation chains and (b) keep the working set in L1/L2.  On TPU the
analogous constraints are:
  (a) the implicit-GEMM M-tile (rb_p * Q) should be >= 128 rows so the MXU
      runs full-height passes (the "FMA latency" of the systolic array is the
      pipeline fill, amortized by tall tiles);
  (b) the per-grid-step working set (streamed input row band for the tiled
      fwd/bwd/wu kernels — or resident plane for the legacy whole-plane
      variants and streams — + weight/dO block + output/accumulator tile)
      must fit the VMEM budget;
  (c) minor dims should be multiples of 128 lanes / 8 sublanes (K, C blocks).

Two selection paths (DESIGN.md §3, §6):

  * ``conv_blocking_analytic`` / ``matmul_blocking_analytic`` — the closed-
    form heuristic above; always available, and the seed candidate + cost
    model prior for the tuner.
  * ``conv_blocking`` / ``matmul_blocking`` — the public entry points.  When
    autotuning is enabled (``repro.backend`` knob / ``REPRO_AUTOTUNE`` /
    explicit ``autotune=`` kwarg) they consult ``repro.tune``'s persistent
    per-shape cache first — "cache": cached winner or analytic fallback;
    "tune": search-and-persist on a miss — and fall back to the analytic
    answer otherwise, so callers never see a behavioral cliff.
"""
from __future__ import annotations

import dataclasses
import math
import os

# bytes/core we allow a kernel to claim; REPRO_VMEM_BUDGET forces a smaller
# budget (CI exercises the tiled kernel under pressure with it)
VMEM_BUDGET = int(os.environ.get("REPRO_VMEM_BUDGET", 16 * 1024 * 1024))
LANE = 128
SUBLANE = 8
MXU = 128


@dataclasses.dataclass(frozen=True)
class ConvBlocking:
    rb_p: int          # output rows per microkernel (paper RB_P)
    k_blk: int         # output-feature block (paper's K_b vector block)
    c_blk: int         # input-feature block (C_b accumulation passes)
    order: str         # grid/dryrun loop order (paper §II-C)
    vmem_bytes: int    # modeled working set
    rb_q: int = 0      # output cols per microkernel (paper RB_Q; 0 = full Q)


def divisors(x: int):
    return [d for d in range(1, x + 1) if x % d == 0]


def aligned_block(dim: int) -> int:
    """Largest sublane-aligned divisor of `dim` within one MXU lane tile —
    the feature-block choice that every kernel's `dim % blk == 0` assert
    accepts (non-power-of-two dims like Inception's 192 included)."""
    # downward over sublane multiples: <= 16 iterations, this runs per dispatch
    for d in range(min(dim, LANE) - min(dim, LANE) % SUBLANE, 0, -SUBLANE):
        if dim % d == 0:
            return d
    return min(dim, LANE)


def conv_working_set(*, h: int, w: int, c: int, k_blk: int, r: int, s: int,
                     q: int, rb_p: int, padding: int, dtype_bytes: int = 4,
                     stride: int = 1, c_blk: int | None = None,
                     rb_q: int | None = None,
                     whole_plane: bool = False,
                     kind: str = "fwd") -> int:
    """Modeled per-grid-step VMEM bytes for a conv blocking candidate.

    Tiled (default): the input contribution is one streamed row band —
    ``((rb_p-1)*stride + r) x ((rb_q-1)*stride + s) x c_blk`` — so the
    working set is independent of H*W.  ``whole_plane=True`` models the
    legacy kernels (fwd whole-plane variant, legacy wu, q8, streams) that
    keep the full padded plane resident; there it scales with H*W*c_blk.

    ``kind`` picks the residency model: "fwd"/"bwd" (the forward kernel —
    the bwd-data dual *is* a forward launch) hold a weight block and an
    output tile + f32 accumulator next to the input; "wu" (the update pass)
    holds a dO pixel tile and the revisited (r, s, C_blk, K_blk) f32
    weight-gradient accumulator tile instead; "q8" (the quantized forward,
    §II-K — pass ``dtype_bytes=1``) streams int8 bands/weights but keeps an
    f32 output tile + int32 accumulator, so the input side shrinks 4x while
    the output side does not.
    """
    c_blk = c if not c_blk else c_blk
    rb_q = q if not rb_q else rb_q
    if whole_plane:
        hp, wp = h + 2 * padding + r, w + 2 * padding   # padded upper bound
        x_bytes = hp * wp * c_blk * dtype_bytes
    else:
        band_h = (rb_p - 1) * stride + r
        band_w = (rb_q - 1) * stride + s
        x_bytes = band_h * band_w * c_blk * dtype_bytes
    if kind == "wu":
        do_tile = rb_p * rb_q * k_blk * dtype_bytes
        dw_acc = r * s * c_blk * k_blk * 4           # f32 revisited tile
        return x_bytes + do_tile + dw_acc
    wblk = r * s * c_blk * k_blk * dtype_bytes
    out_bytes = 4 if kind == "q8" else dtype_bytes   # q8 stores f32 (§II-K)
    out = rb_p * rb_q * k_blk * out_bytes
    acc = rb_p * rb_q * k_blk * 4
    return x_bytes + wblk + out + acc


def conv_blocking_analytic(*, h: int, w: int, c: int, k: int, r: int, s: int,
                           stride: int, padding: int, dtype_bytes: int = 4,
                           vmem_budget: int = VMEM_BUDGET,
                           require_divisor: bool = False,
                           whole_plane: bool | None = None,
                           kind: str = "fwd") -> ConvBlocking:
    """Closed-form heuristic (no cache consulted).

    ``whole_plane`` (default: ``require_divisor``) selects the resident-
    plane VMEM model: the *legacy* wu kernel (which also needs rb_p | P)
    keeps the full-C padded plane in VMEM, the streams kernel a C_blk slice
    of it.  The forward path — and, with ``kind="wu"`` and
    ``require_divisor=False``, the tiled update pass — is band-streamed: the
    working set is the row band, so the budget constrains the *band* — C
    stays unblocked (single accumulation pass) and RB_Q the full row unless
    the band itself would not fit, which is exactly the large-image regime
    the tiling exists for.  ``kind`` selects the per-step residency model of
    ``conv_working_set`` ("bwd" — the dual forward launch — models as
    "fwd").
    """
    p = (h + 2 * padding - r) // stride + 1
    q = (w + 2 * padding - s) // stride + 1
    k_blk = aligned_block(k)
    whole = require_divisor if whole_plane is None else whole_plane
    ws_kind = kind if kind in ("wu", "q8") else "fwd"

    # c_blk is the reported blocking knob; c_model is what sits in VMEM
    # (the legacy wu kernel has no C blocking — its plane is resident at
    # full C)
    rb_q = q
    if require_divisor:
        c_blk, c_model = aligned_block(c), c
    elif whole:
        c_blk = c_model = aligned_block(c)
    else:
        c_blk = c_model = c

    def ws(rb_p: int, c_m: int, rb_q: int) -> int:
        return conv_working_set(h=h, w=w, c=c, k_blk=k_blk, r=r, s=s, q=q,
                                rb_p=rb_p, padding=padding,
                                dtype_bytes=dtype_bytes, stride=stride,
                                c_blk=c_m, rb_q=rb_q, whole_plane=whole,
                                kind=ws_kind)

    if not whole:
        # prefer a single accumulation pass (c_blk = c); fall back to the
        # lane-aligned block when even a one-row band would blow the budget
        if ws(1, c_model, rb_q) > vmem_budget:
            c_blk = c_model = aligned_block(c)
        while ws(1, c_model, rb_q) > vmem_budget and rb_q > 1:
            rb_q = math.ceil(rb_q / 2)          # wide image: block the row

    cands = divisors(p) if require_divisor else list(range(1, p + 1))
    # smallest rb_p with a full-height MXU M-tile, then grow while VMEM
    # allows.  The band-streamed update pass keeps growing to the budget:
    # its row band is refetched once per P-block on every (K_b, C_b) pass,
    # so a taller block strictly cuts refetch traffic (and deepens the
    # pixel-block contraction) — there is no output-tile reuse to trade off.
    # The q8 forward also grows: its int8 band is 4x smaller, so the same
    # budget admits ~4x the rows — fewer grid steps and proportionally less
    # halo refetch per output row (the §II-K blocking dividend).
    grow_to_budget = kind in ("wu", "q8") and not whole
    best = cands[0]
    for rb in cands:
        if ws(rb, c_model, rb_q) > vmem_budget:
            break
        best = rb
        if rb * rb_q >= MXU and not grow_to_budget:
            break
    # §II-C: for 1x1 convs pull the C loop in (order "npkc" keeps the output
    # tile resident across C-blocks -> more output register reuse).
    order = "npkc" if (r == 1 and s == 1) else "nkpc"
    return ConvBlocking(rb_p=best, k_blk=k_blk, c_blk=c_blk, order=order,
                        vmem_bytes=ws(best, c_model, rb_q), rb_q=rb_q)


def conv_blocking(*, h: int, w: int, c: int, k: int, r: int, s: int,
                  stride: int, padding: int, dtype_bytes: int = 4,
                  vmem_budget: int = VMEM_BUDGET,
                  require_divisor: bool = False,
                  backend: str | None = None,
                  autotune: str | None = None,
                  kind: str | None = None,
                  minibatch: int = 1) -> ConvBlocking:
    """Public blocking choice: tuned winner when available, else analytic.

    `backend`/`autotune`/`kind`/`minibatch` extend the seed signature; left
    at defaults they resolve through ``repro.backend`` (autotune defaults
    "off", preserving the seed's pure-analytic behavior and every existing
    call site).  `minibatch` is part of the tuning key: the winning blocking
    depends on how much batch-reuse amortizes weight traffic.  Kinds:
    "fwd" (tiled forward), "bwd" (the backward-data dual — same kernel,
    separate cache namespace), "wu" (band-streamed update pass; with
    ``require_divisor=True`` the legacy resident-plane variant), "streams",
    "q8" (int8 tiled forward — call with ``dtype_bytes=1``).
    """
    mode = _resolve_autotune(autotune)
    kind = kind or ("wu" if require_divisor else "fwd")
    if mode != "off" and vmem_budget == VMEM_BUDGET:
        blk = _tuned_conv(mode, h=h, w=w, c=c, k=k, r=r, s=s, stride=stride,
                          padding=padding, dtype_bytes=dtype_bytes, kind=kind,
                          backend=_resolve_backend(backend),
                          minibatch=minibatch)
        if blk is not None:
            if not require_divisor or _out_p(h, r, stride, padding) % blk.rb_p == 0:
                return blk
    return conv_blocking_analytic(h=h, w=w, c=c, k=k, r=r, s=s,
                                  stride=stride, padding=padding,
                                  dtype_bytes=dtype_bytes,
                                  vmem_budget=vmem_budget,
                                  require_divisor=require_divisor,
                                  whole_plane=(True if kind == "streams"
                                               else None),
                                  kind=kind)


# -- depth-first chain residency (DESIGN.md §16) -----------------------------


@dataclasses.dataclass(frozen=True)
class ChainBlocking:
    """Band split for a depth-first conv->conv chain.

    ``rb`` is the number of *final-layer* output rows per interleaved band
    step; upstream band heights follow from the halo recurrence.  ``fits``
    is False when even a one-row band blows the budget — the per-chain
    fallback rule (execute unfused) keys off it.
    """
    rb: int            # final-layer output rows per band step
    n_bands: int
    vmem_bytes: int    # peak per-step working set at this rb
    fits: bool


def chain_working_set(layers, *, rows_out: int, dtype_bytes: int = 4,
                      blockings=None) -> int:
    """Peak per-band-step VMEM bytes of a depth-first chain.

    ``layers`` is a list of dicts with each conv's input-plane shape
    (h, w, c) and kernel geometry (k, r, s, stride, padding), producers
    first.  ``rows_out`` is the final layer's output rows per band; each
    upstream band height follows the exact halo recurrence
    (``fusion.chain_band_rows``).  Bands are handed off eagerly — while
    layer l computes, only its input band (= layer l-1's output band),
    weight block, and output band + accumulator are live — so the chain
    peak is the max over layers of the PR-3/4 per-step residency model
    (``conv_working_set``) evaluated at that layer's band height.
    """
    from repro.core.fusion import chain_band_rows
    rs = [(L["r"], L["stride"], L["padding"]) for L in layers]
    rows = chain_band_rows(rs, rows_out)
    peak = 0
    for l, L in enumerate(layers):
        p = (L["h"] + 2 * L["padding"] - L["r"]) // L["stride"] + 1
        q = (L["w"] + 2 * L["padding"] - L["s"]) // L["stride"] + 1
        blk = (blockings[l] if blockings is not None else
               conv_blocking_analytic(h=L["h"], w=L["w"], c=L["c"], k=L["k"],
                                      r=L["r"], s=L["s"], stride=L["stride"],
                                      padding=L["padding"],
                                      dtype_bytes=dtype_bytes))
        ws = conv_working_set(h=L["h"], w=L["w"], c=L["c"], k_blk=blk.k_blk,
                              r=L["r"], s=L["s"], q=q,
                              rb_p=min(rows[l + 1], p),
                              padding=L["padding"], dtype_bytes=dtype_bytes,
                              stride=L["stride"], c_blk=blk.c_blk,
                              rb_q=blk.rb_q)
        peak = max(peak, ws)
    return peak


def chain_blocking(layers, *, vmem_budget: int | None = None,
                   dtype_bytes: int = 4, blockings=None) -> ChainBlocking:
    """Largest final-layer band height whose chain working set fits VMEM.

    The working set is monotone in ``rows_out`` (every term grows with the
    band), so binary search finds the largest fitting band; ``rb = P_final``
    degenerates to a single band (zero halo refetch).  When even one row
    does not fit, returns ``fits=False`` — the executor then runs the chain
    unfused (DESIGN.md §16 fallback rule).
    """
    vmem_budget = VMEM_BUDGET if vmem_budget is None else vmem_budget
    last = layers[-1]
    p_final = (last["h"] + 2 * last["padding"] - last["r"]) // last["stride"] + 1
    if blockings is None:
        blockings = [conv_blocking_analytic(
            h=L["h"], w=L["w"], c=L["c"], k=L["k"], r=L["r"], s=L["s"],
            stride=L["stride"], padding=L["padding"], dtype_bytes=dtype_bytes)
            for L in layers]

    def ws(rb):
        return chain_working_set(layers, rows_out=rb, dtype_bytes=dtype_bytes,
                                 blockings=blockings)

    best = 0
    lo, hi = 1, p_final
    while lo <= hi:
        mid = (lo + hi) // 2
        if ws(mid) <= vmem_budget:
            best, lo = mid, mid + 1
        else:
            hi = mid - 1
    if best == 0:
        return ChainBlocking(rb=1, n_bands=p_final, vmem_bytes=ws(1),
                             fits=False)
    return ChainBlocking(rb=best, n_bands=math.ceil(p_final / best),
                         vmem_bytes=ws(best), fits=True)


@dataclasses.dataclass(frozen=True)
class MatmulBlocking:
    bm: int
    bn: int
    bk: int
    vmem_bytes: int


def matmul_blocking_analytic(m: int, n: int, k: int, *, dtype_bytes: int = 2,
                             vmem_budget: int = VMEM_BUDGET) -> MatmulBlocking:
    bm = min(m, MXU)
    bn = min(n, MXU)
    # largest bk (multiple of LANE, divisor of k) whose blocks fit VMEM
    bk = min(k, 512)
    while k % bk:
        bk //= 2
    def ws(bk_):
        return (bm * bk_ + bk_ * bn) * dtype_bytes + 2 * bm * bn * 4
    while bk > LANE and ws(bk) > vmem_budget:
        bk //= 2
    return MatmulBlocking(bm=bm, bn=bn, bk=max(bk, 1), vmem_bytes=ws(bk))


def matmul_blocking(m: int, n: int, k: int, *, dtype_bytes: int = 2,
                    vmem_budget: int = VMEM_BUDGET,
                    backend: str | None = None,
                    autotune: str | None = None) -> MatmulBlocking:
    """Public matmul tiling: tuned winner when available, else analytic."""
    mode = _resolve_autotune(autotune)
    if mode != "off" and vmem_budget == VMEM_BUDGET:
        blk = _tuned_matmul(mode, m, n, k, dtype_bytes=dtype_bytes,
                            backend=_resolve_backend(backend))
        if blk is not None:
            return blk
    return matmul_blocking_analytic(m, n, k, dtype_bytes=dtype_bytes,
                                    vmem_budget=vmem_budget)


# -- tuner bridge (lazy imports: tune statically imports this module) --------

def _out_p(h: int, r: int, stride: int, padding: int) -> int:
    return (h + 2 * padding - r) // stride + 1


def _resolve_autotune(mode: str | None) -> str:
    if mode is not None:
        return mode
    from repro import backend as be
    return be.get_autotune()


def _resolve_backend(backend: str | None) -> str:
    if backend is not None:
        return backend
    from repro import backend as be
    return be.get_backend()


def _tuned_conv(mode: str, **kw) -> ConvBlocking | None:
    from repro import tune
    if mode == "tune":
        return tune.autotune_conv(**kw)
    return tune.lookup_conv(**kw)


def _tuned_matmul(mode: str, m, n, k, *, dtype_bytes, backend):
    from repro import tune
    if mode == "tune":
        return tune.autotune_matmul(m, n, k, dtype_bytes=dtype_bytes,
                                    backend=backend)
    return tune.lookup_matmul(m, n, k, dtype_bytes=dtype_bytes,
                              backend=backend)
