"""Blocking heuristics — the paper's §II-B/C/D RB_P/RB_Q/cache-block choice,
re-derived for the TPU memory hierarchy (HBM -> VMEM -> VREG, MXU 128x128).

The paper picks register blocks to (a) hide FMA latency with independent
accumulation chains and (b) keep the working set in L1/L2.  On TPU the
analogous constraints are:
  (a) the implicit-GEMM M-tile (rb_p * Q) should be >= 128 rows so the MXU
      runs full-height passes (the "FMA latency" of the systolic array is the
      pipeline fill, amortized by tall tiles);
  (b) the per-grid-step working set (input plane slice + weight block +
      output tile + accumulator) must fit the VMEM budget;
  (c) minor dims should be multiples of 128 lanes / 8 sublanes (K, C blocks).
"""
from __future__ import annotations

import dataclasses
import math

VMEM_BUDGET = 16 * 1024 * 1024   # bytes/core we allow a kernel to claim
LANE = 128
SUBLANE = 8
MXU = 128


@dataclasses.dataclass(frozen=True)
class ConvBlocking:
    rb_p: int          # output rows per microkernel (paper RB_P)
    k_blk: int         # output-feature block (paper's K_b vector block)
    c_blk: int         # input-feature block (streams variant only)
    order: str         # dryrun loop order (paper §II-C)
    vmem_bytes: int    # modeled working set


def divisors(x: int):
    return [d for d in range(1, x + 1) if x % d == 0]


def conv_blocking(*, h: int, w: int, c: int, k: int, r: int, s: int,
                  stride: int, padding: int, dtype_bytes: int = 4,
                  vmem_budget: int = VMEM_BUDGET,
                  require_divisor: bool = False) -> ConvBlocking:
    p = (h + 2 * padding - r) // stride + 1
    q = (w + 2 * padding - s) // stride + 1
    hp, wp = h + 2 * padding + r, w + 2 * padding            # padded plane (upper bound)
    k_blk = min(k, LANE)
    c_blk = min(c, LANE)

    def ws(rb_p: int) -> int:
        plane = hp * wp * c * dtype_bytes
        wblk = r * s * c * k_blk * dtype_bytes
        out = rb_p * q * k_blk * dtype_bytes
        acc = rb_p * q * k_blk * 4
        return plane + wblk + out + acc

    cands = divisors(p) if require_divisor else list(range(1, p + 1))
    # smallest rb_p with a full-height MXU M-tile, then grow while VMEM allows
    best = cands[0]
    for rb in cands:
        if ws(rb) > vmem_budget:
            break
        best = rb
        if rb * q >= MXU:
            break
    # §II-C: for 1x1 convs pull the C loop in (order "npkc" keeps the output
    # tile resident across C-blocks -> more output register reuse).
    order = "npkc" if (r == 1 and s == 1) else "nkpc"
    return ConvBlocking(rb_p=best, k_blk=k_blk, c_blk=c_blk, order=order,
                        vmem_bytes=ws(best))


@dataclasses.dataclass(frozen=True)
class MatmulBlocking:
    bm: int
    bn: int
    bk: int
    vmem_bytes: int


def matmul_blocking(m: int, n: int, k: int, *, dtype_bytes: int = 2,
                    vmem_budget: int = VMEM_BUDGET) -> MatmulBlocking:
    bm = min(m, MXU)
    bn = min(n, MXU)
    # largest bk (multiple of LANE, divisor of k) whose blocks fit VMEM
    bk = min(k, 512)
    while k % bk:
        bk //= 2
    def ws(bk_):
        return (bm * bk_ + bk_ * bn) * dtype_bytes + 2 * bm * bn * 4
    while bk > LANE and ws(bk) > vmem_budget:
        bk //= 2
    return MatmulBlocking(bm=bm, bn=bn, bk=max(bk, 1), vmem_bytes=ws(bk))
