"""DirectConv2D — the paper's contribution as a composable, differentiable
JAX module.

Forward: implementation-selected direct convolution with fused epilogue.
Backward: custom VJP that implements the paper's training pipeline —
  dI via duality (§II-I): weight transform + the same forward kernel;
  dW via the update-pass kernel (§II-J).

Implementation selection ("xla" / "interpret" / "pallas") is per-call or via
``repro.backend``; blocking comes from ``core.blocking`` unless overridden —
the per-shape JIT specialization of §II-D.  With the autotune knob enabled
("cache"/"tune", see ``repro.tune`` and DESIGN.md §6) the blocking is the
empirically tuned per-shape winner instead of the analytic heuristic.
"""
from __future__ import annotations

import functools
from functools import partial

import jax
import jax.numpy as jnp

from repro import backend as be
from repro.core import duality
from repro.core.blocking import conv_blocking
from repro.kernels import ref
from repro.kernels.conv2d_direct import conv2d_direct
from repro.kernels.conv2d_wu import conv2d_wu


def lane_ok(c: int, k: int) -> bool:
    """True when (C, K) block cleanly for the Pallas kernels; small-C layers
    (e.g. ResNet conv1, C=3) take the XLA/im2col path — see DESIGN.md §2.
    Public so warmup/serving can report which signatures the tuned path
    covers (``graph/serving.py``)."""
    return c % 8 == 0 and k % 8 == 0


def conv2d_fwd(x, w, *, stride=1, padding=1, bias=None, scale=None,
               shift=None, residual=None, relu=False, impl=None,
               autotune=None, kind="fwd"):
    """Fused forward conv; dispatches on the selected implementation.

    `autotune` (None -> ``repro.backend`` knob) selects how the blocking is
    chosen: "off" analytic, "cache" tuned-if-cached, "tune" search+persist.
    `kind` is the tuner-cache namespace ("fwd", or "bwd" when this forward
    launch is a backward-data dual conv — same kernel, separately tuned key).
    """
    impl = be.resolve(impl)
    n, h, wdt, c = x.shape
    r, s, _, k = w.shape
    if impl == "xla" or not lane_ok(c, k):
        return ref.conv2d_fused(x, w, stride=stride, padding=padding,
                                bias=bias, scale=scale, shift=shift,
                                residual=residual, relu=relu)
    blk = conv_blocking(h=h, w=wdt, c=c, k=k, r=r, s=s, stride=stride,
                        padding=padding, dtype_bytes=x.dtype.itemsize,
                        backend=impl, autotune=autotune, kind=kind,
                        minibatch=n)
    return conv2d_direct(x, w, stride=stride, padding=padding, bias=bias,
                         scale=scale, shift=shift, residual=residual,
                         relu=relu, rb_p=blk.rb_p, k_blk=blk.k_blk,
                         c_blk=blk.c_blk, rb_q=blk.rb_q, order=blk.order,
                         interpret=(impl == "interpret"))


def conv2d_chain_fwd(x, layers, *, rb, impl=None, autotune=None):
    """Depth-first fused conv chain (DESIGN.md §16): run single-consumer
    conv->conv ``layers`` band-by-band so no intermediate activation
    materializes in HBM.  Per-band dispatch follows the same rule as
    ``conv2d_fwd`` (XLA/non-lane-aligned layers take the reference path),
    with each layer's blocking pinned to its full shape — which makes the
    result bit-identical to the unfused layer-by-layer execution."""
    from repro.kernels.conv2d_chain import conv2d_chain
    return conv2d_chain(x, layers, rb=rb, impl=be.resolve(impl),
                        autotune=autotune)


def conv2d_q8_fwd(x, w_q, *, x_scale, w_scale, stride=1, padding=1,
                  bias=None, scale=None, shift=None, residual=None,
                  relu=False, impl=None, autotune=None):
    """Fused quantized forward conv (§II-K): quantize the f32 activation
    against its calibrated per-tensor scale, run the int8 tiled kernel with
    a per-K-channel dequant + f32 epilogue, return f32.

    XLA / non-lane-aligned fallback: fold the premultiplied dequant scale
    into the reference epilogue's BN-scale slot — ``(acc*deq)*bn + ...`` ==
    ``acc*(deq*bn) + ...``, algebraically identical to the kernel path, so
    the fallback differs only by f32 rounding, not by quantization scheme.
    """
    from repro.core.quantize import quantize_act
    impl = be.resolve(impl)
    n, h, wdt, c = x.shape
    r, s, _, k = w_q.shape
    x_q = quantize_act(x, x_scale)
    if impl == "xla" or not lane_ok(c, k):
        deq = (jnp.reshape(x_scale, ()).astype(jnp.float32)
               * w_scale.astype(jnp.float32))
        combined = deq if scale is None else deq * scale
        combined_shift = shift if scale is not None else \
            jnp.zeros((k,), jnp.float32)
        # int8 operands as f32: ref.conv2d_fused casts its output to the
        # input dtype, so feeding int8 directly would truncate the result
        return ref.conv2d_fused(x_q.astype(jnp.float32),
                                w_q.astype(jnp.float32), stride=stride,
                                padding=padding, bias=bias, scale=combined,
                                shift=combined_shift, residual=residual,
                                relu=relu)
    blk = conv_blocking(h=h, w=wdt, c=c, k=k, r=r, s=s, stride=stride,
                        padding=padding, dtype_bytes=1, backend=impl,
                        autotune=autotune, kind="q8", minibatch=n)
    from repro.kernels.conv2d_q8 import conv2d_q8
    return conv2d_q8(x_q, w_q, x_scale=x_scale, w_scale=w_scale,
                     stride=stride, padding=padding, bias=bias, scale=scale,
                     shift=shift, residual=residual, relu=relu,
                     rb_p=blk.rb_p, k_blk=blk.k_blk, c_blk=blk.c_blk,
                     rb_q=blk.rb_q, order=blk.order,
                     interpret=(impl == "interpret"))


def conv2d_bwd_data_via_fwd(do, w, *, stride, padding, input_hw, impl=None,
                            autotune=None, mode=None):
    """dI using the §II-I duality: transform weights, run the fwd kernel.

    The generic (stride > 1, R,S > 1) case follows ``mode`` / the
    ``REPRO_BWD_DUALITY`` knob: "phase" (default) launches stride² forward
    sub-convs over the *undilated* dO — no dilated intermediate is ever
    allocated; "dilate" is the legacy materialized plan kept for A/B.
    Every forward launch tunes/looks up its blocking under kind "bwd".
    """
    r, s = w.shape[0], w.shape[1]
    scenario, _ = duality.bwd_data_plan(r=r, s=s, stride=stride,
                                        padding=padding, input_hw=input_hw,
                                        mode=mode)
    if scenario == "phase":
        return duality.phase_bwd_data(
            do, w, stride=stride, padding=padding, input_hw=input_hw,
            conv_fn=lambda a, b, st, pd: conv2d_fwd(
                a, b, stride=st, padding=pd, impl=impl, autotune=autotune,
                kind="bwd"))
    do2, wt, kw, post = duality.prepare_bwd_data(
        do, w, stride=stride, padding=padding, input_hw=input_hw, mode=mode)
    y = conv2d_fwd(do2, wt, stride=kw["stride"], padding=kw["padding"],
                   impl=impl, autotune=autotune, kind="bwd")
    return post(y)


def conv2d_bwd_weights(x, do, *, stride, padding, filter_rs, impl=None,
                       autotune=None, whole_plane=None):
    """dW via the update-pass kernel (§II-J).

    The default tiled kernel streams row bands and blocks C/Q with ceil-div
    tails (no divisibility constraints); ``whole_plane`` (default: the
    ``repro.backend`` conv-tiling knob) selects the legacy resident-plane
    kernel, which still needs ``rb_p | P`` (``require_divisor``)."""
    impl = be.resolve(impl)
    n, h, wdt, c = x.shape
    _, p, q, k = do.shape
    if impl == "xla" or not lane_ok(c, k):
        return ref.conv2d_bwd_weights(x, do, stride=stride, padding=padding,
                                      filter_rs=filter_rs)
    if whole_plane is None:
        whole_plane = be.get_conv_tiling() == "whole"
    blk = conv_blocking(h=h, w=wdt, c=c, k=k, r=filter_rs[0], s=filter_rs[1],
                        stride=stride, padding=padding,
                        dtype_bytes=x.dtype.itemsize,
                        require_divisor=whole_plane,
                        backend=impl, autotune=autotune, kind="wu",
                        minibatch=n)
    if whole_plane:
        return conv2d_wu(x, do, stride=stride, padding=padding,
                         filter_rs=filter_rs, b_p=blk.rb_p, k_blk=blk.k_blk,
                         whole_plane=True, interpret=(impl == "interpret"))
    return conv2d_wu(x, do, stride=stride, padding=padding,
                     filter_rs=filter_rs, b_p=blk.rb_p, k_blk=blk.k_blk,
                     c_blk=blk.c_blk, rb_q=blk.rb_q, whole_plane=False,
                     interpret=(impl == "interpret"))


@partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4))
def conv2d_train(x, w, stride: int, padding: int, impl: str | None):
    """Differentiable direct conv whose VJP is the paper's bwd pipeline."""
    return conv2d_fwd(x, w, stride=stride, padding=padding, impl=impl)


def _fwd(x, w, stride, padding, impl):
    return conv2d_train(x, w, stride, padding, impl), (x, w)


def _bwd(stride, padding, impl, resid, do):
    x, w = resid
    r, s, _, _ = w.shape
    di = conv2d_bwd_data_via_fwd(do, w, stride=stride, padding=padding,
                                 input_hw=(x.shape[1], x.shape[2]), impl=impl)
    dw = conv2d_bwd_weights(x, do, stride=stride, padding=padding,
                            filter_rs=(r, s), impl=impl)
    return di.astype(x.dtype), dw.astype(w.dtype)


conv2d_train.defvjp(_fwd, _bwd)
