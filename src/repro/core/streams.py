"""Kernel streams — the paper's §II-H dryrun/replay framework, TPU-native.

The paper records, per thread, the exact sequence of microkernel invocations
(variant + input/weight/output sub-tensor offsets + fused-operator calls),
run-length-encodes it into segments, and replays it branch-free.

On TPU the replay engine is a single ``pallas_call`` whose grid walks a flat
schedule; the offset streams are *scalar-prefetched* arrays consumed by the
BlockSpec index_maps (``PrefetchScalarGridSpec``), and the per-step flags
(zero-init / epilogue / fused-L()) are read from SMEM inside the kernel.  The
paper's "prefetch arguments = next invocation's offsets" property (§II-E,
Fig. 1) is what the Mosaic pipeliner derives automatically from the same
streams: block (i+1) is fetched while block (i) computes.

The *dryrun* phase below performs the Algorithm-4 loop nest on the host,
records the streams, and RLE-encodes them into segments (Fig. 2); the
*replay* phase is ``kernels/conv2d_streams.py``.
"""
from __future__ import annotations

import dataclasses

import numpy as np

# Per-step flag bits (the "kernel variant / APPLY" column of Fig. 2).
FLAG_INIT = 1       # first visit of this output tile: zero the accumulator
FLAG_EPILOGUE = 2   # last visit: apply the fused L() and write back
FLAG_RELU = 4       # L() includes ReLU
FLAG_HANDOFF = 8    # depth-first hand-off: this step's output band feeds the
                    # next chain layer directly from VMEM (no HBM write-back)


@dataclasses.dataclass(frozen=True)
class ConvSchedule:
    """Flat replay schedule: one entry per microkernel invocation."""
    n_ids: np.ndarray       # image index stream
    kb_ids: np.ndarray      # output-feature block offset stream (w/o offsets)
    pb_ids: np.ndarray      # output row-block offset stream (o offsets)
    cb_ids: np.ndarray      # input-feature block offset stream (i offsets)
    flags: np.ndarray       # per-step variant/fusion flags
    segments: tuple         # RLE segments: (kind, start, length)
    grid: tuple             # (n, k_b, p_b, c_b) loop bounds

    def __len__(self):
        return len(self.n_ids)


def build_conv_schedule(*, n: int, k_b: int, p_b: int, c_b: int,
                        order: str = "nkpc", relu: bool = False) -> ConvSchedule:
    """Dryrun: walk the §II-A loop nest in `order` and record the streams.

    `order` is a permutation of "nkpc" (minibatch, K-blocks, row-blocks,
    C-blocks), c innermost or not — the §II-C loop-order choice.  C-block
    steps for one output tile must be contiguous (the accumulator lives in
    the output VMEM tile), so "c" must be the innermost dimension; other
    orders trade weight-block vs input-plane reuse exactly as in the paper.
    """
    assert sorted(order) == sorted("nkpc"), order
    assert order.endswith("c"), "C-blocks must be innermost (accumulator tile)"
    bounds = {"n": n, "k": k_b, "p": p_b, "c": c_b}
    dims = [bounds[d] for d in order]
    idx = np.stack(np.meshgrid(*[np.arange(d) for d in dims], indexing="ij"),
                   axis=-1).reshape(-1, 4)
    cols = {d: idx[:, i] for i, d in enumerate(order)}
    cb = cols["c"]
    flags = np.zeros(len(idx), dtype=np.int32)
    flags[cb == 0] |= FLAG_INIT
    flags[cb == c_b - 1] |= FLAG_EPILOGUE
    if relu:
        flags[cb == c_b - 1] |= FLAG_RELU

    segments = rle_segments(flags)
    return ConvSchedule(
        n_ids=cols["n"].astype(np.int32), kb_ids=cols["k"].astype(np.int32),
        pb_ids=cols["p"].astype(np.int32), cb_ids=cb.astype(np.int32),
        flags=flags, segments=tuple(segments), grid=(n, k_b, p_b, c_b))


@dataclasses.dataclass(frozen=True)
class ChainSchedule:
    """Interleaved depth-first replay schedule for a conv->conv chain
    (DESIGN.md §16): producer band step -> consumer band step, repeated per
    final-layer output band.  Every step is a complete band micro-conv
    (INIT|EPILOGUE); non-final steps carry FLAG_HANDOFF — their output band
    stays in VMEM as the next step's input and never reaches HBM.

    ``o0``/``o1`` are the step's *output-row* range at its layer (real,
    clipped coordinates) — the replay engine computes exactly these rows,
    so the band math lives here, not in the kernel.
    """
    layer_ids: np.ndarray   # chain-layer index per step
    band_ids: np.ndarray    # final-layer band index per step
    o0: np.ndarray          # first output row of this step's band
    o1: np.ndarray          # one-past-last output row
    flags: np.ndarray
    segments: tuple         # RLE segments: (flags, start, length)
    grid: tuple             # (n_layers, n_bands)

    def __len__(self):
        return len(self.layer_ids)


def build_chain_schedule(*, rs, h_in: int, rb: int) -> ChainSchedule:
    """Dryrun for a depth-first chain: emit one interleaved schedule.

    ``rs`` is the per-layer (r, stride, padding) list, producers first;
    ``h_in`` the chain input height; ``rb`` the final-layer output rows per
    band.  Per band, needed output rows are back-propagated through the
    exact halo recurrence — out rows [o0, o1) of layer l+1 need real rows
    [o0*s - pad, (o1-1)*s + r - pad) of its input, clipped at the plane
    edges — then steps are emitted producer-first.  Consecutive bands of
    non-final layers overlap by the (r-1)*stride halo; those rows are
    recomputed, which is the price ``chain_traffic`` charges instead of an
    intermediate HBM round-trip.
    """
    rs = [tuple(t) for t in rs]
    n_layers = len(rs)
    p = []                          # per-layer output rows
    h = h_in
    for r, stride, pad in rs:
        h = (h + 2 * pad - r) // stride + 1
        p.append(h)
    n_bands = -(-p[-1] // rb)

    layer_ids, band_ids, o0s, o1s, flags = [], [], [], [], []
    for b in range(n_bands):
        o = [None] * n_layers
        o[-1] = (b * rb, min((b + 1) * rb, p[-1]))
        for l in range(n_layers - 2, -1, -1):
            lo, hi = o[l + 1]
            r, stride, pad = rs[l + 1]
            o[l] = (max(lo * stride - pad, 0),
                    min((hi - 1) * stride + r - pad, p[l]))
        for l in range(n_layers):
            assert o[l][1] > o[l][0], (b, l, o)
            layer_ids.append(l)
            band_ids.append(b)
            o0s.append(o[l][0])
            o1s.append(o[l][1])
            f = FLAG_INIT | FLAG_EPILOGUE
            if l < n_layers - 1:
                f |= FLAG_HANDOFF
            flags.append(f)

    flags = np.asarray(flags, dtype=np.int32)
    return ChainSchedule(
        layer_ids=np.asarray(layer_ids, dtype=np.int32),
        band_ids=np.asarray(band_ids, dtype=np.int32),
        o0=np.asarray(o0s, dtype=np.int32),
        o1=np.asarray(o1s, dtype=np.int32),
        flags=flags, segments=tuple(rle_segments(flags)),
        grid=(n_layers, n_bands))


def rle_segments(flags: np.ndarray):
    """Run-length encode the flag stream into (flag_value, start, length)
    segments — the paper's CONV-STREAK / APPLY compression (Fig. 2)."""
    segs = []
    start = 0
    for i in range(1, len(flags) + 1):
        if i == len(flags) or flags[i] != flags[start]:
            segs.append((int(flags[start]), start, i - start))
            start = i
    return segs


def decode_segments(segs, total: int) -> np.ndarray:
    """Inverse of rle_segments (used by tests + the executor)."""
    out = np.zeros(total, dtype=np.int32)
    for val, start, length in segs:
        out[start:start + length] = val
    return out


def prefetch_streams(sched: ConvSchedule):
    """The §II-E property: prefetch offsets at step i are the argument
    offsets of step i+1 (the last step prefetches itself — a no-op)."""
    def nxt(a):
        return np.concatenate([a[1:], a[-1:]])
    return (nxt(sched.n_ids), nxt(sched.kb_ids),
            nxt(sched.pb_ids), nxt(sched.cb_ids))
