"""Reduced precision (paper §II-K, TPU serving edition).

The paper's int16->int32 4VNNIW kernels halve the input bytes of the hot
loop while keeping a 32-bit accumulator.  Two analogs live here:

* LM serving (``quantize_int8``/``dequantize``): store weights int8 with
  per-output-channel scales, dequantize on the fly (XLA fuses the dequant
  into the consuming matmul), keep bf16/f32 math.  Decode is
  weight-bandwidth-bound, so the memory roofline term drops ~2x — same
  shape of win, new bottleneck (exactly the §III-B discussion: the output
  bytes don't shrink, so the speedup is < 2).

* CNN serving (``calibrate_network``/``quantize_gxm_params``): the *real*
  §II-K kernel path — per-conv activation scales calibrated from warmup
  batches, int8 weights with per-K-channel scales, executed by
  ``kernels.conv2d_q8`` (int8×int8→int32 accumulate, f32 dequant
  epilogue).  All scales carry the same ``+ 1e-12`` guard so an all-zero
  tensor quantizes to zeros instead of dividing by zero.  DESIGN.md §13.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _is_leaf_dict(x):
    return isinstance(x, dict) and set(x) == {"q", "s"}


def quantize_int8(params, *, min_size: int = 1024):
    """Per-output-channel symmetric int8 for matrices; small tensors stay
    as-is.  Returns a tree where quantized leaves become {"q","s"} dicts."""
    def leaf(p):
        if p.ndim < 2 or p.size < min_size:
            return p
        scale = jnp.max(jnp.abs(p.astype(jnp.float32)),
                        axis=tuple(range(p.ndim - 1))) / 127.0 + 1e-12
        q = jnp.clip(jnp.round(p.astype(jnp.float32) / scale),
                     -127, 127).astype(jnp.int8)
        return {"q": q, "s": scale.astype(jnp.float32)}
    return jax.tree.map(leaf, params)


def dequantize(qparams, dtype=jnp.bfloat16):
    def leaf(x):
        if _is_leaf_dict(x):
            return (x["q"].astype(jnp.float32) * x["s"]).astype(dtype)
        return x
    return jax.tree.map(leaf, qparams, is_leaf=_is_leaf_dict)


def quantized_specs(param_specs, params_or_shapes, *, min_size: int = 1024):
    """Mirror the logical-axis spec tree onto the quantized structure."""
    is_spec = lambda x: isinstance(x, tuple) and all(
        a is None or isinstance(a, str) for a in x)

    def leaf(spec, p):
        if p.ndim < 2 or p.size < min_size:
            return spec
        return {"q": spec, "s": spec[-1:]}
    return jax.tree.map(leaf, param_specs, params_or_shapes, is_leaf=is_spec)


def quantize_act(x, scale):
    """Symmetric int8 activation quantization against a calibrated scale:
    round-to-nearest, clip to ±127 (values beyond the calibration range
    saturate instead of wrapping)."""
    return jnp.clip(jnp.round(x.astype(jnp.float32) / scale),
                    -127, 127).astype(jnp.int8)


def calibrate_network(gxm, params, batches) -> dict:
    """Per-conv activation scales from warmup batches.

    Runs the *f32* inference forward eagerly with a tap on every conv
    input, aggregates the absolute max per conv task across ``batches``,
    and returns ``{task_name: scale}`` with ``scale = absmax/127 + 1e-12``
    (f32 scalars).  Deterministic: same params + same batches -> bit-equal
    scales (pure max-reduction, no randomness).
    """
    absmax: dict = {}

    def tap(name, v):
        m = jnp.max(jnp.abs(v.astype(jnp.float32)))
        prev = absmax.get(name)
        absmax[name] = m if prev is None else jnp.maximum(prev, m)

    for b in batches:
        gxm.forward(params, jnp.asarray(b), train=False, tap=tap)
    return {name: (m / 127.0 + 1e-12).astype(jnp.float32)
            for name, m in absmax.items()}


def quantize_gxm_params(etg, params, act_scales) -> dict:
    """Quantize the conv weights of a GxM params tree for the q8 path.

    For every conv task the ETG marked ``kernel_kind == "q8"``: replace
    ``w`` with int8 ``w_q`` + per-K-channel ``w_scale`` and attach the
    calibrated per-tensor activation ``x_scale``.  BN/bias leaves stay f32
    (they fold into the f32 epilogue after dequantization).  Tasks without
    a calibrated scale (never tapped) stay f32.
    """
    out = {name: dict(p) for name, p in params.items()}
    for t in etg.tasks:
        if t.op != "conv" or t.attrs.get("kernel_kind") != "q8":
            continue
        if t.name not in act_scales:
            continue
        p = out[t.name]
        w = p.pop("w").astype(jnp.float32)
        w_scale = jnp.max(jnp.abs(w), axis=(0, 1, 2)) / 127.0 + 1e-12
        p["w_q"] = jnp.clip(jnp.round(w / w_scale), -127, 127) \
            .astype(jnp.int8)
        p["w_scale"] = w_scale.astype(jnp.float32)
        p["x_scale"] = jnp.asarray(act_scales[t.name], jnp.float32)
    return out


def quantization_error(params, dtype=jnp.bfloat16):
    """Max relative reconstruction error per leaf (test utility)."""
    deq = dequantize(quantize_int8(params), dtype)
    def err(a, b):
        a = a.astype(jnp.float32); b = b.astype(jnp.float32)
        return float(jnp.max(jnp.abs(a - b))
                     / (jnp.max(jnp.abs(a)) + 1e-9))
    return jax.tree.map(err, params, deq)
