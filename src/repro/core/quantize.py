"""Reduced precision (paper §II-K, TPU serving edition).

The paper's int16->int32 4VNNIW kernels halve the input bytes of the hot
loop while keeping a 32-bit accumulator.  The serving-side analog: store
weights int8 with per-output-channel scales, dequantize on the fly (XLA
fuses the dequant into the consuming matmul), keep bf16/f32 math.  Decode
is weight-bandwidth-bound, so the memory roofline term drops ~2x — same
shape of win, new bottleneck (exactly the §III-B discussion: the output
bytes don't shrink, so the speedup is < 2).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _is_leaf_dict(x):
    return isinstance(x, dict) and set(x) == {"q", "s"}


def quantize_int8(params, *, min_size: int = 1024):
    """Per-output-channel symmetric int8 for matrices; small tensors stay
    as-is.  Returns a tree where quantized leaves become {"q","s"} dicts."""
    def leaf(p):
        if p.ndim < 2 or p.size < min_size:
            return p
        scale = jnp.max(jnp.abs(p.astype(jnp.float32)),
                        axis=tuple(range(p.ndim - 1))) / 127.0 + 1e-12
        q = jnp.clip(jnp.round(p.astype(jnp.float32) / scale),
                     -127, 127).astype(jnp.int8)
        return {"q": q, "s": scale.astype(jnp.float32)}
    return jax.tree.map(leaf, params)


def dequantize(qparams, dtype=jnp.bfloat16):
    def leaf(x):
        if _is_leaf_dict(x):
            return (x["q"].astype(jnp.float32) * x["s"]).astype(dtype)
        return x
    return jax.tree.map(leaf, qparams, is_leaf=_is_leaf_dict)


def quantized_specs(param_specs, params_or_shapes, *, min_size: int = 1024):
    """Mirror the logical-axis spec tree onto the quantized structure."""
    is_spec = lambda x: isinstance(x, tuple) and all(
        a is None or isinstance(a, str) for a in x)

    def leaf(spec, p):
        if p.ndim < 2 or p.size < min_size:
            return spec
        return {"q": spec, "s": spec[-1:]}
    return jax.tree.map(leaf, param_specs, params_or_shapes, is_leaf=is_spec)


def quantization_error(params, dtype=jnp.bfloat16):
    """Max relative reconstruction error per leaf (test utility)."""
    deq = dequantize(quantize_int8(params), dtype)
    def err(a, b):
        a = a.astype(jnp.float32); b = b.astype(jnp.float32)
        return float(jnp.max(jnp.abs(a - b))
                     / (jnp.max(jnp.abs(a)) + 1e-9))
    return jax.tree.map(err, params, deq)
