from repro.core.conv import conv2d_fwd, conv2d_train  # noqa: F401
from repro.core.blocking import conv_blocking, matmul_blocking  # noqa: F401
from repro.core.streams import build_conv_schedule  # noqa: F401
from repro.core.fusion import fuse_network  # noqa: F401
