"""Weight-gradient parallelization strategy (paper §II-J), lifted from
threads-sharing-an-LLC to chips-sharing-ICI.

The paper's two extremes, per layer, for T workers:
  "shared":  partition (C, K) feature maps across workers; every worker
             re-reads T/T_c x the input and T/T_k x the dO tensor, but dW is
             written once.
  "copies":  partition the minibatch; activations are read once, but T full
             dW copies must be reduced (2T x dW traffic).
Hybrids pick a minibatch-parallelism degree in between.  The dryrun phase
costs both and picks the cheaper — we do exactly that, with ICI bandwidth as
the reduction cost, and surface the choice to the mesh layer:
  "copies"  -> dW lives data-parallel, one all-reduce (the default DP grad
               sync; overlappable).
  "shared"  -> dW is reduce-scattered over the data axis (ZeRO-2 flavor) so
               each chip owns a shard — less dW traffic, more activation
               traffic when the shard must be re-gathered.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class WuCost:
    strategy: str          # "shared" | "copies" | hybrid degree
    act_bytes: float       # activation + grad-output read traffic
    dw_bytes: float        # weight-gradient write/reduce traffic
    total: float


def choose_wu_strategy(*, n: int, c: int, k: int, h: int, w: int,
                       p: int, q: int, r: int, s: int,
                       n_workers: int, dtype_bytes: int = 4,
                       feature_par: tuple[int, int] | None = None) -> WuCost:
    """Cost the two §II-J extremes for this layer and pick the cheaper."""
    dw = r * s * c * k * dtype_bytes
    act = n * c * h * w * dtype_bytes
    dout = n * k * p * q * dtype_bytes
    t = n_workers
    if feature_par is None:
        # split workers over (C, K) as evenly as possible
        tc = max(int(t ** 0.5), 1)
        tk = max(t // tc, 1)
    else:
        tc, tk = feature_par
    shared = WuCost("shared",
                    act_bytes=act * (t / tc) + dout * (t / tk),
                    dw_bytes=float(dw),
                    total=act * (t / tc) + dout * (t / tk) + dw)
    copies = WuCost("copies",
                    act_bytes=float(act + dout),
                    dw_bytes=2.0 * t * dw,
                    total=act + dout + 2.0 * t * dw)
    return shared if shared.total < copies.total else copies


def hybrid_copies(*, n: int, dw_bytes: int, act_bytes: int,
                  n_workers: int) -> int:
    """Pick the minibatch-parallel degree m (number of dW copies) minimizing
    modeled traffic — the paper's hybrid between the two extremes."""
    best_m, best_cost = 1, float("inf")
    m = 1
    while m <= min(n, n_workers):
        cost = act_bytes * (n_workers / m) / n_workers + 2.0 * m * dw_bytes
        if cost < best_cost:
            best_m, best_cost = m, cost
        m *= 2
    return best_m
