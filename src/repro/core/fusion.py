"""Layer-fusion pattern matching (paper §II-G/§II-H locality, GxM graph pass).

Two levels of fusion live here:

  * ``fuse_network`` — the §II-G rule: collapse bandwidth-bound L()
    operators (BatchNorm-apply, bias, eltwise-add, ReLU) into the producing
    convolution's fused epilogue whenever the intermediate tensor has a
    single consumer — "apply L() while the sub-tensor is hot in cache".
  * ``detect_chains`` — one level up (DESIGN.md §16): group single-consumer
    conv->conv edges of the *fused* graph into depth-first ``Chain``s, so the
    executor can compute layer l+1's output band from layer l's band while
    it is still resident in VMEM and the intermediate activation never
    round-trips HBM.  The per-layer halo algebra ((r-1)·stride growth, the
    exact ``rows_in = (rows_out-1)·stride + r`` recurrence) lives here too.

Both passes build a users index once (``users_index``) instead of rescanning
the whole node list per node — the same O(n²) bug class fixed for
``graph.etg.extend_nl`` in PR 5.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass
class Node:
    name: str
    op: str                 # conv / bn / relu / add / pool / fc / ...
    inputs: list
    attrs: dict
    fused: list = dataclasses.field(default_factory=list)  # fused L() ops


def users_index(nodes) -> dict[str, list[Node]]:
    """tensor name -> consumer nodes, built in one O(edges) scan.  A node
    listing the same tensor twice (e.g. self-residual) appears twice —
    callers that need fan-*out* semantics de-duplicate, callers that need
    "is this edge exclusive" semantics must not."""
    users: dict[str, list[Node]] = {}
    for n in nodes:
        for i in n.inputs:
            users.setdefault(i, []).append(n)
    return users


def consumers(nodes, name, index: dict | None = None):
    """Consumers of tensor `name` (de-duplicated).  Pass a prebuilt
    ``users_index`` when calling in a loop — the fallback scan is O(n) per
    call and exists only for one-off queries."""
    if index is None:
        return [n for n in nodes if name in n.inputs]
    seen, out = set(), []
    for n in index.get(name, ()):
        if id(n) not in seen:
            seen.add(id(n))
            out.append(n)
    return out


FUSABLE = ("bn", "bias", "relu", "add")


def fuse_network(nodes: list[Node]) -> list[Node]:
    """Greedy single-consumer chain fusion into conv epilogues.

    conv -> bn -> relu                  => conv{bn,relu}
    conv -> bn -> add(skip) -> relu     => conv{bn,residual,relu}
    conv -> bias -> relu                => conv{bias,relu}

    Pure (operates on copies) and idempotent: re-running on an already-fused
    list is a no-op, because every fusable L() node has been folded away and
    the remaining edges are conv->conv / multi-consumer.
    """
    nodes = [dataclasses.replace(n, fused=list(n.fused),
                                 inputs=list(n.inputs), attrs=dict(n.attrs))
             for n in nodes]
    users = users_index(nodes)
    dead: set[str] = set()

    for n in nodes:
        if n.op != "conv":
            continue
        cur = n
        while True:
            outs = [c for c in users.get(cur.name, ())
                    if c.name not in dead]
            if len(outs) != 1:
                break
            nxt = outs[0]
            if nxt.op not in FUSABLE:
                break
            if nxt.op == "add":
                if any(f[0] == "add" for f in n.fused):
                    break  # one residual input per epilogue
                other = [i for i in nxt.inputs if i != cur.name]
                if len(other) != 1:
                    break
                n.fused.append(("add", {"residual": other[0]}))
                n.inputs.append(other[0])   # dependency for topo ordering
                users.setdefault(other[0], []).append(n)
            else:
                n.fused.append((nxt.op, dict(nxt.attrs)))
            dead.add(nxt.name)
            # the fused conv now produces the fused chain's output name
            n.attrs["output_name"] = nxt.name
            cur = nxt

    out = []
    owner_of = {n.attrs["output_name"]: n.name for n in nodes
                if "output_name" in n.attrs and n.name not in dead}
    for n in nodes:
        if n.name in dead:
            continue
        # rewire inputs that pointed at fused-away nodes
        n.inputs = [owner_of.get(i, i) for i in n.inputs]
        out.append(n)
    return out


# -- depth-first conv->conv chains (DESIGN.md §16) ---------------------------


@dataclasses.dataclass(frozen=True)
class Chain:
    """A maximal single-consumer conv->conv chain of the fused graph.

    ``names`` orders producers before consumers; ``rs`` carries each layer's
    (r, stride, padding) for the halo algebra; ``halo_growth`` is the
    per-layer band-halo growth (r-1)·stride the ROADMAP quotes — the extra
    input rows (in that layer's input units) a consumer band drags in beyond
    its stride-scaled footprint.
    """
    names: tuple
    rs: tuple               # per-layer (r, stride, padding)
    halo_growth: tuple      # per-layer (r - 1) * stride

    def __len__(self):
        return len(self.names)


def chain_band_rows(rs, rows_out: int) -> list[int]:
    """The exact halo recurrence: rows of every layer's *input* band needed
    to produce ``rows_out`` rows of the final layer's output.

    Returns ``rows`` of length L+1 with ``rows[l]`` = input rows of layer l
    (l = 0..L-1, un-clipped — plane edges clip in the executor) and
    ``rows[L] = rows_out``; each step applies
    ``rows_in = (rows_out - 1)·stride + r``.
    """
    rows = [rows_out]
    for r, stride, _pad in reversed(tuple(rs)):
        rows.append((rows[-1] - 1) * stride + r)
    return list(reversed(rows))


def detect_chains(nodes: list[Node], *, min_len: int = 2) -> list[Chain]:
    """Group fusable conv->conv edges of a *fused* node list into maximal
    depth-first chains.

    An edge producer->consumer is chain-fusable iff the consumer is a conv
    whose *data* input (``inputs[0]``) is the producer's output and the
    producer's output has exactly one use in the whole graph (a residual
    reference counts as a use: fusing across it would need the intermediate
    in HBM anyway).  Chains never overlap; detection is pure metadata — the
    node list is not rewritten, so the pass is trivially idempotent and
    topology-preserving.
    """
    users = users_index(nodes)
    in_chain: set[str] = set()
    chains: list[Chain] = []

    def next_link(cur: Node) -> Node | None:
        uses = users.get(cur.name, ())
        if len(uses) != 1:
            return None
        nxt = uses[0]
        if nxt.op != "conv" or nxt.name in in_chain:
            return None
        if not nxt.inputs or nxt.inputs[0] != cur.name:
            return None         # feeds the residual slot, not the data slot
        return nxt

    for n in nodes:
        if n.op != "conv" or n.name in in_chain:
            continue
        members = [n]
        cur = n
        while True:
            nxt = next_link(cur)
            if nxt is None:
                break
            members.append(nxt)
            cur = nxt
        if len(members) < min_len:
            continue
        for m in members:
            in_chain.add(m.name)
        rs = tuple((m.attrs["r"], m.attrs["stride"], m.attrs["padding"])
                   for m in members)
        chains.append(Chain(
            names=tuple(m.name for m in members),
            rs=rs,
            halo_growth=tuple((r - 1) * s for r, s, _ in rs)))
    return chains


def fusion_stats(nl_before: list[Node], nl_after: list[Node]) -> dict:
    return {
        "nodes_before": len(nl_before),
        "nodes_after": len(nl_after),
        "ops_fused": len(nl_before) - len(nl_after),
    }
