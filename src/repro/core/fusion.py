"""Layer-fusion pattern matching (paper §II-G + GxM graph optimization).

Walks the network list and collapses bandwidth-bound L() operators
(BatchNorm-apply, bias, eltwise-add, ReLU) into the producing convolution's
fused epilogue whenever the intermediate tensor has a single consumer — the
"apply L() while the sub-tensor is hot in cache" rule.  This is the pass the
paper says vendor libraries lacked; here it is a first-class graph pass.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass
class Node:
    name: str
    op: str                 # conv / bn / relu / add / pool / fc / ...
    inputs: list
    attrs: dict
    fused: list = dataclasses.field(default_factory=list)  # fused L() ops


def consumers(nodes, name):
    return [n for n in nodes if name in n.inputs]


FUSABLE = ("bn", "bias", "relu", "add")


def fuse_network(nodes: list[Node]) -> list[Node]:
    """Greedy single-consumer chain fusion into conv epilogues.

    conv -> bn -> relu                  => conv{bn,relu}
    conv -> bn -> add(skip) -> relu     => conv{bn,residual,relu}
    conv -> bias -> relu                => conv{bias,relu}
    """
    nodes = [dataclasses.replace(n, fused=list(n.fused)) for n in nodes]
    by_name = {n.name: n for n in nodes}
    dead: set[str] = set()

    for n in nodes:
        if n.op != "conv":
            continue
        cur = n
        while True:
            outs = [c for c in nodes if cur.name in c.inputs
                    and c.name not in dead]
            if len(outs) != 1:
                break
            nxt = outs[0]
            if nxt.op not in FUSABLE:
                break
            if nxt.op == "add":
                if any(f[0] == "add" for f in n.fused):
                    break  # one residual input per epilogue
                other = [i for i in nxt.inputs if i != cur.name]
                if len(other) != 1:
                    break
                n.fused.append(("add", {"residual": other[0]}))
                n.inputs.append(other[0])   # dependency for topo ordering
            else:
                n.fused.append((nxt.op, dict(nxt.attrs)))
            dead.add(nxt.name)
            # the fused conv now produces the fused chain's output name
            n.attrs["output_name"] = nxt.name
            cur = nxt

    out = []
    for n in nodes:
        if n.name in dead:
            continue
        # rewire inputs that pointed at fused-away nodes
        new_inputs = []
        for i in n.inputs:
            owner = next((m for m in nodes if m.attrs.get("output_name") == i
                          and m.name not in dead), None)
            new_inputs.append(owner.name if owner is not None else i)
        n.inputs = new_inputs
        out.append(n)
    return out


def fusion_stats(nl_before: list[Node], nl_after: list[Node]) -> dict:
    return {
        "nodes_before": len(nl_before),
        "nodes_after": len(nl_after),
        "ops_fused": len(nl_before) - len(nl_after),
    }
