"""Backward-by-duality (paper §II-I): rewrite the data-gradient convolution
as a *forward* convolution over a transformed weight tensor, so one
high-performance forward kernel serves both passes ("duality ... to reduce
number of code generators").

Scenario 1 (stride == 1):       W'[r',s',k,c] = W[R-1-r', S-1-s', c, k]
                                dI = conv(dO, W', pad = R-1-pad)
Scenario 2 (R == S == 1):       dI[:, ::stride, ::stride] = conv(dO, W^T)
Generic (stride>1 and R,S>1):   dilate dO by stride, then scenario 1 —
                                the small-GEMM fallback of Algorithm 7,
                                expressed as one more forward conv.
"""
from __future__ import annotations

import jax.numpy as jnp


def transform_weights(w):
    """W (R,S,C,K) -> W' (R,S,K,C): KC-transpose + RS-flip."""
    return jnp.flip(w, axis=(0, 1)).transpose(0, 1, 3, 2)


def dilate(x, stride: int):
    """Insert stride-1 zeros between spatial elements of x (N,P,Q,K)."""
    if stride == 1:
        return x
    n, p, q, k = x.shape
    out = jnp.zeros((n, (p - 1) * stride + 1, (q - 1) * stride + 1, k),
                    dtype=x.dtype)
    return out.at[:, ::stride, ::stride, :].set(x)


def bwd_data_plan(*, r: int, s: int, stride: int, padding: int,
                  input_hw: tuple[int, int]):
    """Return (scenario, fwd-conv parameters) implementing dI = dual-fwd.

    The returned plan is consumed by ``core.conv.conv2d_bwd_data_via_fwd``
    which runs the *forward* kernel.  scenario ∈ {"stride1", "1x1", "generic"}.
    """
    if stride == 1:
        return ("stride1", dict(stride=1, padding=r - 1 - padding))
    if r == 1 and s == 1:
        return ("1x1", dict(stride=1, padding=0))
    return ("generic", dict(stride=1, padding=r - 1 - padding))


def prepare_bwd_data(do, w, *, stride: int, padding: int,
                     input_hw: tuple[int, int]):
    """Transform (dO, W) so a plain forward conv yields dI.

    Returns (do', w', fwd_kwargs, post) where post(y) -> dI.
    """
    r, s, c, k = w.shape
    h, wdt = input_hw
    scenario, kw = bwd_data_plan(r=r, s=s, stride=stride, padding=padding,
                                 input_hw=input_hw)
    wt = transform_weights(w)

    def fit(y):
        """Pad-with-zeros/crop y to the exact (h, wdt) input plane — rows
        beyond the receptive field carry zero gradient."""
        pad_h = max(h - y.shape[1], 0)
        pad_w = max(wdt - y.shape[2], 0)
        y = jnp.pad(y, ((0, 0), (0, pad_h), (0, pad_w), (0, 0)))
        return y[:, :h, :wdt, :]

    if scenario == "stride1":
        return do, wt, kw, fit
    if scenario == "1x1":
        p, q = do.shape[1], do.shape[2]

        def post(y):
            n = y.shape[0]
            out = jnp.zeros((n, h, wdt, c), dtype=y.dtype)
            return out.at[:, :(p - 1) * stride + 1:stride,
                          :(q - 1) * stride + 1:stride, :].set(y)
        return do, wt, kw, post
    # Generic: dilate dO, then it is the stride-1 dual.  When the forward
    # conv floored ((h + 2p - r) % stride != 0) the dual needs *asymmetric*
    # padding — pre-pad explicitly and run the kernel pad-free.
    p, q = do.shape[1], do.shape[2]
    dod = dilate(do, stride)
    top = r - 1 - padding
    left = s - 1 - padding
    assert top >= 0 and left >= 0, "padding > filter-1 unsupported"
    bottom = max(h + padding - (p - 1) * stride - 1, 0)
    right = max(wdt + padding - (q - 1) * stride - 1, 0)
    dod = jnp.pad(dod, ((0, 0), (top, bottom), (left, right), (0, 0)))
    return dod, wt, dict(stride=1, padding=0), fit
