"""Backward-by-duality (paper §II-I): rewrite the data-gradient convolution
as a *forward* convolution over a transformed weight tensor, so one
high-performance forward kernel serves both passes ("duality ... to reduce
number of code generators").

Scenario "stride1" (stride == 1):  W'[r',s',k,c] = W[R-1-r', S-1-s', c, k]
                                   dI = conv(dO, W', pad = R-1-pad)
Scenario "1x1"   (R == S == 1):    dI[:, ::stride, ::stride] = conv(dO, W^T)
Generic (stride>1 and R,S>1) — two interchangeable plans:

  "phase"  (default)  stride² *phase sub-convolutions* over the undilated
           dO: input row y belongs to phase (y+pad) mod stride, and only the
           filter taps r ≡ (y+pad) (mod stride) ever touch it, so dI's
           stride×stride subgrids are each an ordinary stride-1 forward conv
           of dO with a flipped/KC-transposed sub-filter — the Algorithm-7
           small-GEMM fallback expressed with *no* dilated tensor and no
           multiply-by-zero FLOPs (cuDNN's implicit fractionally-strided
           conv; the zero-memory-overhead discipline of Zhang et al. 2018).
  "dilate" (A/B baseline, knob ``REPRO_BWD_DUALITY=dilate``) dilate dO by
           stride, then scenario "stride1" — one more forward conv, but over
           a plane that is ~stride² zeros.

The phase plan is a pure function of the conv geometry (``phase_plan``), so
``dual_conv_signatures`` can enumerate the exact forward-conv shapes the
backward pass will launch — that is what lets training warmup pre-tune the
"bwd" blocking cache entries (``tune.warmup_convs``).
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
from jax import lax

VALID_MODES = ("phase", "dilate")


def resolve_mode(mode: str | None) -> str:
    """Generic-scenario plan: explicit ``mode`` wins, else the
    ``REPRO_BWD_DUALITY`` / ``repro.backend`` knob."""
    if mode is None:
        from repro import backend as be
        mode = be.get_bwd_duality()
    assert mode in VALID_MODES, mode
    return mode


def transform_weights(w):
    """W (R,S,C,K) -> W' (R,S,K,C): KC-transpose + RS-flip."""
    return jnp.flip(w, axis=(0, 1)).transpose(0, 1, 3, 2)


def dilate(x, stride: int):
    """Insert stride-1 zeros between spatial elements of x (N,P,Q,K).

    One scatter-free ``lax.pad`` with interior padding — a single fused HBM
    write, not the zeros-buffer + ``.at[].set`` pair (two HBM-sized buffers)
    the seed used.
    """
    if stride == 1:
        return x
    zero = jnp.zeros((), x.dtype)
    return lax.pad(x, zero, ((0, 0, 0), (0, 0, stride - 1),
                             (0, 0, stride - 1), (0, 0, 0)))


# -- the phase decomposition --------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PhaseAxis:
    """One spatial axis of one phase sub-convolution.

    ``res`` is the dI residue class this phase fills (y ≡ res mod stride);
    ``phi = (res + pad) mod stride`` selects the filter taps (r ≡ phi);
    ``taps`` how many such taps exist (0 -> this phase carries zero
    gradient); ``lo``/``hi`` the explicit dO padding of the stride-1 dual
    conv; ``off`` the first dual-output row belonging to the phase; ``count``
    how many dI rows the phase owns.
    """
    res: int
    phi: int
    taps: int
    lo: int
    hi: int
    off: int
    count: int


def _phase_axis(res: int, *, f: int, stride: int, padding: int, in_dim: int,
                out_dim: int) -> PhaseAxis:
    phi = (res + padding) % stride
    taps = len(range(phi, f, stride))
    count = max(-(-(in_dim - res) // stride), 0)
    off = (res + padding - phi) // stride
    lo = taps - 1
    hi = max(off + count - out_dim, 0)
    return PhaseAxis(res=res, phi=phi, taps=taps, lo=lo, hi=hi, off=off,
                     count=count)


def phase_plan(*, r: int, s: int, stride: int, padding: int,
               input_hw: tuple[int, int],
               out_hw: tuple[int, int]) -> list[tuple[PhaseAxis, PhaseAxis]]:
    """The stride² phase sub-convolutions of the generic backward-data plan,
    as (row-axis, col-axis) pairs — one per dI subgrid, in row-major residue
    order.  Phases with zero filter taps (possible when stride > R) are
    included with ``taps == 0`` so callers can emit zeros for them."""
    h, w = input_hw
    p, q = out_hw
    plans = []
    for ry in range(stride):
        ax_y = _phase_axis(ry, f=r, stride=stride, padding=padding,
                           in_dim=h, out_dim=p)
        for rx in range(stride):
            ax_x = _phase_axis(rx, f=s, stride=stride, padding=padding,
                               in_dim=w, out_dim=q)
            plans.append((ax_y, ax_x))
    return plans


def phase_bwd_data(do, w, *, stride: int, padding: int,
                   input_hw: tuple[int, int], conv_fn):
    """dI via the stride² phase sub-convolutions (no dilated dO anywhere).

    ``conv_fn(x, w, stride, padding)`` runs a forward conv — the caller
    injects ``core.conv.conv2d_fwd`` so every sub-conv goes through the same
    tuned tiled kernel (blocking kind "bwd") as the rest of the stack.
    """
    r, s, c, k = w.shape
    n, p, q, _ = do.shape
    h, wdt = input_hw
    st = stride
    ph, pw = -(-h // st), -(-wdt // st)        # interleave grid (ceil-div)
    rows = []
    for ax_y, ax_x in phase_plan(r=r, s=s, stride=st, padding=padding,
                                 input_hw=(h, wdt), out_hw=(p, q)):
        if ax_y.taps == 0 or ax_x.taps == 0:
            yp = jnp.zeros((n, ph, pw, c), do.dtype)
        else:
            sub = transform_weights(
                w[ax_y.phi::st, ax_x.phi::st])          # (taps_y, taps_x, k, c)
            dop = jnp.pad(do, ((0, 0), (ax_y.lo, ax_y.hi),
                               (ax_x.lo, ax_x.hi), (0, 0)))
            y = conv_fn(dop, sub, 1, 0)
            yp = y[:, ax_y.off:ax_y.off + ax_y.count,
                   ax_x.off:ax_x.off + ax_x.count, :]
            yp = jnp.pad(yp, ((0, 0), (0, ph - ax_y.count),
                              (0, pw - ax_x.count), (0, 0)))
        rows.append(yp)
    # interleave the stride×stride subgrids back into the (h, w) plane:
    # a reshape/transpose XLA fuses, not a scatter chain
    a = jnp.stack(rows).reshape(st, st, n, ph, pw, c)
    a = a.transpose(2, 3, 0, 4, 1, 5)          # (n, ph, st_y, pw, st_x, c)
    return a.reshape(n, ph * st, pw * st, c)[:, :h, :wdt, :]


def dual_conv_signatures(*, r: int, s: int, c: int, k: int, stride: int,
                         padding: int, input_hw: tuple[int, int],
                         mode: str | None = None,
                         unique: bool = True) -> list[dict]:
    """The exact forward-conv signatures the backward-data pass launches for
    this layer — h/w are the (pre-padded) dO plane each sub-conv sees, C/K
    are swapped by the duality transform.  Keyed the same way
    ``core.conv.conv2d_fwd`` keys its blocking lookups (tuner kind "bwd"),
    so warming these signatures means the first training step never tunes
    inline (``tune.warmup_convs``).  ``unique=False`` keeps duplicate phase
    signatures (phases with identical geometry are still *separate*
    launches — what the cost model must count)."""
    h, wdt = input_hw
    p = (h + 2 * padding - r) // stride + 1
    q = (wdt + 2 * padding - s) // stride + 1
    if stride == 1:
        return [dict(h=p, w=q, c=k, k=c, r=r, s=s, stride=1,
                     padding=r - 1 - padding)]
    if r == 1 and s == 1:
        return [dict(h=p, w=q, c=k, k=c, r=1, s=1, stride=1, padding=0)]
    if resolve_mode(mode) == "dilate":
        pd = (p - 1) * stride + 1
        qd = (q - 1) * stride + 1
        top = r - 1 - padding
        left = s - 1 - padding
        bottom = max(h + padding - (p - 1) * stride - 1, 0)
        right = max(wdt + padding - (q - 1) * stride - 1, 0)
        return [dict(h=pd + top + bottom, w=qd + left + right, c=k, k=c,
                     r=r, s=s, stride=1, padding=0)]
    sigs, seen = [], set()
    for ax_y, ax_x in phase_plan(r=r, s=s, stride=stride, padding=padding,
                                 input_hw=(h, wdt), out_hw=(p, q)):
        if ax_y.taps == 0 or ax_x.taps == 0:
            continue
        sig = dict(h=p + ax_y.lo + ax_y.hi, w=q + ax_x.lo + ax_x.hi,
                   c=k, k=c, r=ax_y.taps, s=ax_x.taps, stride=1, padding=0)
        key = tuple(sorted(sig.items()))
        if not unique or key not in seen:
            seen.add(key)
            sigs.append(sig)
    return sigs


# -- plan selection -----------------------------------------------------------

def bwd_data_plan(*, r: int, s: int, stride: int, padding: int,
                  input_hw: tuple[int, int], mode: str | None = None):
    """Return (scenario, fwd-conv parameters) implementing dI = dual-fwd.

    The returned plan is consumed by ``core.conv.conv2d_bwd_data_via_fwd``
    which runs the *forward* kernel.  scenario ∈ {"stride1", "1x1", "phase",
    "dilate"}; the generic (stride > 1, R,S > 1) case picks "phase" or
    "dilate" per ``mode`` / the ``REPRO_BWD_DUALITY`` knob.
    """
    if stride == 1:
        return ("stride1", dict(stride=1, padding=r - 1 - padding))
    if r == 1 and s == 1:
        return ("1x1", dict(stride=1, padding=0))
    if resolve_mode(mode) == "dilate":
        return ("dilate", dict(stride=1, padding=0))
    return ("phase", dict(stride=1, padding=0,
                          n_phases=stride * stride))


def prepare_bwd_data(do, w, *, stride: int, padding: int,
                     input_hw: tuple[int, int], mode: str | None = None):
    """Transform (dO, W) so a *single* plain forward conv yields dI.

    Returns (do', w', fwd_kwargs, post) where post(y) -> dI.  Only the
    single-conv scenarios land here; the "phase" plan is multi-conv and is
    executed by ``phase_bwd_data`` (``core.conv`` dispatches on
    ``bwd_data_plan``'s scenario).
    """
    r, s, c, k = w.shape
    h, wdt = input_hw
    scenario, kw = bwd_data_plan(r=r, s=s, stride=stride, padding=padding,
                                 input_hw=input_hw, mode=mode)
    assert scenario != "phase", "phase plan is multi-conv: use phase_bwd_data"
    wt = transform_weights(w)

    def fit(y):
        """Pad-with-zeros/crop y to the exact (h, wdt) input plane — rows
        beyond the receptive field carry zero gradient."""
        pad_h = max(h - y.shape[1], 0)
        pad_w = max(wdt - y.shape[2], 0)
        y = jnp.pad(y, ((0, 0), (0, pad_h), (0, pad_w), (0, 0)))
        return y[:, :h, :wdt, :]

    if scenario == "stride1":
        return do, wt, kw, fit
    if scenario == "1x1":
        p, q = do.shape[1], do.shape[2]

        def post(y):
            n = y.shape[0]
            out = jnp.zeros((n, h, wdt, c), dtype=y.dtype)
            return out.at[:, :(p - 1) * stride + 1:stride,
                          :(q - 1) * stride + 1:stride, :].set(y)
        return do, wt, kw, post
    # Dilate (A/B baseline): dilate dO, then it is the stride-1 dual.  When
    # the forward conv floored ((h + 2p - r) % stride != 0) the dual needs
    # *asymmetric* padding — pre-pad explicitly and run the kernel pad-free.
    p, q = do.shape[1], do.shape[2]
    dod = dilate(do, stride)
    top = r - 1 - padding
    left = s - 1 - padding
    assert top >= 0 and left >= 0, "padding > filter-1 unsupported"
    bottom = max(h + padding - (p - 1) * stride - 1, 0)
    right = max(wdt + padding - (q - 1) * stride - 1, 0)
    dod = jnp.pad(dod, ((0, 0), (top, bottom), (left, right), (0, 0)))
    return dod, wt, dict(stride=1, padding=0), fit
