"""Simulated time + seeded randomness — the determinism substrate shared by
the training chaos harness (``train/chaos.py``, DESIGN.md §14) and the
serving-fleet chaos harness (``serve/chaos.py``, DESIGN.md §15).

Every resilience number this repo reports (detection latency, recovery
overhead, goodput, tail latency) is a pure function of a seeded schedule
replayed against a ``SimClock``: ``sleep`` *advances* instead of blocking,
so backoff and timeout policies cost modeled seconds, bit-reproducibly.
``seeded_rng`` is the one way schedules draw randomness — a
``SeedSequence`` over integer components, so "same seed -> same schedule"
holds across platforms and numpy versions.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class SimClock:
    """Simulated time: ``sleep`` advances instead of blocking, so backoff
    and detection timeouts cost *modeled* seconds, deterministically."""
    t: float = 0.0

    def time(self) -> float:
        return self.t

    def sleep(self, s: float) -> None:
        self.t += float(s)

    def advance(self, s: float) -> None:
        self.t += float(s)

    def advance_to(self, t: float) -> None:
        """Jump forward to absolute time ``t`` (no-op if already past it) —
        the event-loop form of ``advance`` used by the fleet router's
        discrete-event simulation."""
        self.t = max(self.t, float(t))


def seeded_rng(*components: int) -> np.random.Generator:
    """A ``default_rng`` over ``SeedSequence(components)`` — the shared
    schedule-RNG helper: every chaos schedule derives from one of these so
    generation is reproducible bit for bit."""
    return np.random.default_rng(
        np.random.SeedSequence([int(c) for c in components]))
