"""phi3.5-moe-42b-a6.6b — MoE 16 experts top-2, GQA (kv=8).
[hf:microsoft/Phi-3.5-MoE-instruct; hf]"""
from repro.nn.config import ModelCfg, MoECfg

CONFIG = ModelCfg(
    name="phi3.5-moe-42b-a6.6b", family="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_head=128,
    d_ff=6400, vocab=32064,
    moe=MoECfg(n_experts=16, top_k=2),
    tie_embeddings=False, fsdp=True,
    block_pattern=(("attn", "moe"),),
    rope_theta=1e4,
)
