"""internlm2-1.8b — dense, GQA (kv=8).  [arXiv:2403.17297; hf]"""
from repro.nn.config import ModelCfg

CONFIG = ModelCfg(
    name="internlm2-1.8b", family="dense",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=8, d_head=128,
    d_ff=8192, vocab=92544,
    tie_embeddings=False,
    block_pattern=(("attn", "dense"),),
    rope_theta=1e6,
)
