"""smollm-360m — llama-arch small, GQA (kv=5).  [hf:HuggingFaceTB/SmolLM; hf]"""
from repro.nn.config import ModelCfg

CONFIG = ModelCfg(
    name="smollm-360m", family="dense",
    n_layers=32, d_model=960, n_heads=15, n_kv_heads=5, d_head=64,
    d_ff=2560, vocab=49152,
    tie_embeddings=True,
    block_pattern=(("attn", "dense"),),
    rope_theta=1e4,
)
