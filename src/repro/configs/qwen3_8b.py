"""qwen3-8b — dense, GQA (kv=8), qk-norm.  [hf:Qwen/Qwen3-8B; hf]"""
from repro.nn.config import ModelCfg

CONFIG = ModelCfg(
    name="qwen3-8b", family="dense",
    n_layers=36, d_model=4096, n_heads=32, n_kv_heads=8, d_head=128,
    d_ff=12288, vocab=151936,
    qk_norm=True, tie_embeddings=False, fsdp=True,
    block_pattern=(("attn", "dense"),),
    rope_theta=1e6,
)
