"""jamba-1.5-large-398b — hybrid Mamba+attention 1:7 interleave, MoE 16
experts top-2 every other layer.  [arXiv:2403.19887; hf]

Pattern (one repeat = 8 layers): attention at position 4, Mamba elsewhere;
MoE MLP on odd positions (every other layer), dense on even.
"""
from repro.nn.config import ModelCfg, MoECfg


def _pattern():
    out = []
    for pos in range(8):
        mixer = "attn" if pos == 4 else "mamba"
        mlp = "moe" if pos % 2 == 1 else "dense"
        out.append((mixer, mlp))
    return tuple(out)


CONFIG = ModelCfg(
    name="jamba-1.5-large-398b", family="hybrid",
    n_layers=72, d_model=8192, n_heads=64, n_kv_heads=8, d_head=128,
    d_ff=24576, vocab=65536,
    moe=MoECfg(n_experts=16, top_k=2),
    tie_embeddings=False, fsdp=True, factored_opt=True,
    block_pattern=_pattern(),
    rope_theta=1e6,
    d_conv=4, d_state=16, expand=2,
    scan_chunk=64,
    sub_quadratic=True,
    accum_steps=8,     # 398B @ 1M-token batch on 256 chips: microbatch to fit
)
