"""internvl2-2b — InternViT (stub frontend: precomputed patch embeddings)
+ InternLM2-1.8b backbone.  [arXiv:2404.16821; hf]"""
from repro.nn.config import ModelCfg

CONFIG = ModelCfg(
    name="internvl2-2b", family="vlm",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=8, d_head=128,
    d_ff=8192, vocab=92553,
    tie_embeddings=False, frontend="vision",
    block_pattern=(("attn", "dense"),),
    rope_theta=1e6,
)
