"""dbrx-132b — MoE 16 experts top-4 (fine-grained), GQA (kv=8).
[hf:databricks/dbrx-base; unverified]"""
from repro.nn.config import ModelCfg, MoECfg

CONFIG = ModelCfg(
    name="dbrx-132b", family="moe",
    n_layers=40, d_model=6144, n_heads=48, n_kv_heads=8, d_head=128,
    d_ff=10752, vocab=100352,
    moe=MoECfg(n_experts=16, top_k=4),
    tie_embeddings=False, fsdp=True, factored_opt=True,
    block_pattern=(("attn", "moe"),),
    rope_theta=5e5,
    accum_steps=4,
)
