"""musicgen-large — decoder-only over EnCodec tokens (audio frontend is a
stub: the backbone consumes codec token ids / frame embeddings).
[arXiv:2306.05284; hf]"""
from repro.nn.config import ModelCfg

CONFIG = ModelCfg(
    name="musicgen-large", family="audio",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=32, d_head=64,
    d_ff=8192, vocab=2048,
    tie_embeddings=False, frontend="audio",
    block_pattern=(("attn", "dense"),),
    rope_theta=1e4,
)
