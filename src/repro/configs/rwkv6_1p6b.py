"""rwkv6-1.6b (Finch) — attention-free, data-dependent decay.
[arXiv:2404.05892; unverified]"""
from repro.nn.config import ModelCfg

CONFIG = ModelCfg(
    name="rwkv6-1.6b", family="ssm",
    n_layers=24, d_model=2048, n_heads=32, n_kv_heads=32, d_head=64,
    d_ff=7168, vocab=65536,
    tie_embeddings=False,
    block_pattern=(("rwkv", "rwkv_cm"),),
    sub_quadratic=True,
)
