"""Assigned input-shape sets and dry-run input specs.

Each LM arch pairs with 4 shapes; ``train_*`` lowers train_step,
``prefill_*`` lowers the prefill forward, ``decode_*``/``long_*`` lower
serve_step (one token against a seq_len cache).  ``long_500k`` requires
sub-quadratic sequence mixing — skipped (with a reason) for pure
full-attention archs, run for ssm/hybrid (see DESIGN.md §5).

Also home to the conv regression shapes (``STEM_CONV``/``STEM_CONV_HALF``)
shared by the kernel tests and benchmarks (DESIGN.md §9).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.nn.config import ModelCfg


@dataclasses.dataclass(frozen=True)
class Shape:
    name: str
    kind: str            # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": Shape("train_4k", "train", 4096, 256),
    "prefill_32k": Shape("prefill_32k", "prefill", 32768, 32),
    "decode_32k": Shape("decode_32k", "decode", 32768, 128),
    "long_500k": Shape("long_500k", "decode", 524288, 1),
}

# Conv regression shapes (tests/test_kernels_conv.py + benchmarks): the
# ResNet conv1 stem — a 224x224 input, 7x7 stride-2, 112x112 output — whose
# padded input plane exceeds any forced-small VMEM budget on the legacy
# whole-plane kernel and therefore only runs blocked.  C is lane-padded 3->8
# (the real c=3 stem takes the im2col path, DESIGN.md §2); the half-res
# variant pins that the tiled working set is independent of H*W.
STEM_CONV = dict(name="resnet_conv1_stem", n=1, h=224, w=224, c=8, k=64,
                 r=7, s=7, stride=2, padding=3)
STEM_CONV_HALF = dict(name="resnet_conv1_stem_halfres", n=1, h=112, w=112,
                      c=8, k=64, r=7, s=7, stride=2, padding=3)


def applicable(cfg: ModelCfg, shape: Shape) -> tuple[bool, str]:
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, ("pure full-attention arch: no sub-quadratic path in "
                       "its published form (DESIGN.md §5)")
    return True, ""


def cells_for(cfg: ModelCfg):
    """All (shape, applicable, reason) cells for an arch."""
    return [(s, *applicable(cfg, s)) for s in SHAPES.values()]


def input_specs(cfg: ModelCfg, shape: Shape, *, for_cache: bool = True):
    """ShapeDtypeStruct stand-ins for every model input of this cell —
    weak-type-correct, shardable, no device allocation."""
    b, l = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    dt = jnp.dtype(cfg.dtype)
    if shape.kind == "train":
        if cfg.frontend == "vision":
            # VLM: stub frontend delivers precomputed patch embeddings
            return {
                "embeds": jax.ShapeDtypeStruct((b, l, cfg.d_model), dt),
                "labels": jax.ShapeDtypeStruct((b, l), i32),
            }
        return {
            "tokens": jax.ShapeDtypeStruct((b, l), i32),
            "labels": jax.ShapeDtypeStruct((b, l), i32),
        }
    if shape.kind == "prefill":
        if cfg.frontend == "vision":
            return {"embeds": jax.ShapeDtypeStruct((b, l, cfg.d_model), dt)}
        return {"tokens": jax.ShapeDtypeStruct((b, l), i32)}
    # decode: one token + cache of seq_len
    from repro.nn.transformer import init_cache
    cache = jax.eval_shape(lambda: init_cache(cfg, b, l))
    return {
        "tokens": jax.ShapeDtypeStruct((b, 1), i32),
        "cache": cache,
        "idx": jax.ShapeDtypeStruct((), i32),
    }
