from repro.configs.registry import ARCHS, get_config, smoke_config, list_archs  # noqa: F401
from repro.configs.shapes import SHAPES, cells_for, input_specs  # noqa: F401
