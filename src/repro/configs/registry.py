"""Architecture registry: ``--arch <id>`` -> ModelCfg, + reduced smoke
configs for CPU tests."""
from __future__ import annotations

import dataclasses

from repro.configs import (dbrx_132b, internlm2_1p8b, internvl2_2b,
                           jamba_1p5_large, musicgen_large, phi35_moe,
                           qwen2_1p5b, qwen3_8b, rwkv6_1p6b, smollm_360m)
from repro.nn.config import ModelCfg, MoECfg

ARCHS: dict[str, ModelCfg] = {
    c.name: c for c in [
        qwen2_1p5b.CONFIG, qwen3_8b.CONFIG, internlm2_1p8b.CONFIG,
        smollm_360m.CONFIG, phi35_moe.CONFIG, dbrx_132b.CONFIG,
        musicgen_large.CONFIG, rwkv6_1p6b.CONFIG, internvl2_2b.CONFIG,
        jamba_1p5_large.CONFIG,
    ]
}


def list_archs():
    return sorted(ARCHS)


def get_config(name: str) -> ModelCfg:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; available: {list_archs()}")
    return ARCHS[name]


def smoke_config(cfg: ModelCfg) -> ModelCfg:
    """Reduced same-family config: tiny widths/depth, same structure/flags.
    Exercised by per-arch CPU smoke tests (one fwd + one train step)."""
    moe = MoECfg(n_experts=4, top_k=min(cfg.moe.top_k, 2)) if cfg.moe else None
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        n_layers=len(cfg.block_pattern),
        d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
        d_ff=128, vocab=256,
        moe=moe,
        d_state=8, d_conv=4, expand=2,
        scan_chunk=8,
        dtype="float32", remat=False,
    )
