"""Shape-specialized blocking autotuner (paper §II-D, made empirical).

The seed port hardcoded one analytic heuristic in ``core.blocking``.  This
package searches the real parameter space of the Pallas kernels — RB_P,
K_blk, C_blk, loop order — per (shape, dtype, stride/padding, backend,
device) and remembers winners in a persistent versioned cache, so every later
process gets the tuned blocking for free: libxsmm's dispatch cache, one level
up.

Layering (no cycles): ``core.blocking`` lazily calls ``lookup_conv`` /
``autotune_conv`` here; this package statically imports the *analytic*
helpers from ``core.blocking`` as the search seed.

  mode "off"    analytic heuristic only (default; seed behavior)
  mode "cache"  consult the cache, fall back to the heuristic on a miss
  mode "tune"   on a miss, search + persist the winner, then use it

Select with ``REPRO_AUTOTUNE``, ``repro.backend.set_autotune()``, or the
``autotune=`` kwarg threaded through ``core.conv`` / ``kernels.ops``.
See DESIGN.md §6 for the cache key format and the re-tune workflow.
"""
from __future__ import annotations

import dataclasses

from repro.core.blocking import VMEM_BUDGET, ConvBlocking, MatmulBlocking
from repro.tune.cache import (CACHE_VERSION, TuneCache,  # noqa: F401
                              conv_key, default_cache, device_kind,
                              matmul_key)
from repro.tune.measure import (can_measure, conv_cost_us,  # noqa: F401
                                matmul_cost_us, rank_conv)
from repro.tune.space import (conv_candidates,  # noqa: F401
                              matmul_candidates, out_dim)

_CONV_FIELDS = ("rb_p", "k_blk", "c_blk", "order", "vmem_bytes", "rb_q")


def _to_conv(entry: dict, *, c: int, k: int) -> ConvBlocking | None:
    blk = entry.get("blocking", {})
    if not all(f in blk for f in _CONV_FIELDS):
        return None
    if k % blk["k_blk"] or c % blk["c_blk"]:    # key drift safety net
        return None
    if blk["rb_q"] < 0:
        return None
    if blk["vmem_bytes"] > VMEM_BUDGET:
        # the cache key has no budget coordinate: an entry tuned under the
        # default 16 MiB must not serve a REPRO_VMEM_BUDGET-forced process
        return None
    return ConvBlocking(**{f: blk[f] for f in _CONV_FIELDS})


def lookup_conv(*, h, w, c, k, r, s, stride, padding, dtype_bytes=4,
                kind="fwd", backend="xla", minibatch=1,
                cache: TuneCache | None = None) -> ConvBlocking | None:
    """Cache-only consult; None on a miss (caller falls back to analytic)."""
    cache = default_cache() if cache is None else cache
    key = conv_key(kind=kind, h=h, w=w, c=c, k=k, r=r, s=s, stride=stride,
                   padding=padding, dtype_bytes=dtype_bytes, backend=backend,
                   minibatch=minibatch)
    entry = cache.lookup(key)
    return _to_conv(entry, c=c, k=k) if entry else None


def autotune_conv(*, h, w, c, k, r, s, stride, padding, dtype_bytes=4,
                  kind="fwd", backend="xla", minibatch=1,
                  cache: TuneCache | None = None,
                  persist: bool = True) -> ConvBlocking:
    """Cache hit, else search the space, persist the winner, return it."""
    cache = default_cache() if cache is None else cache
    hit = lookup_conv(h=h, w=w, c=c, k=k, r=r, s=s, stride=stride,
                      padding=padding, dtype_bytes=dtype_bytes, kind=kind,
                      backend=backend, minibatch=minibatch, cache=cache)
    if hit is not None:
        return hit
    shape = dict(h=h, w=w, c=c, k=k, r=r, s=s, stride=stride,
                 padding=padding, dtype_bytes=dtype_bytes)
    cands = conv_candidates(h=h, w=w, c=c, k=k, r=r, s=s, stride=stride,
                            padding=padding, dtype_bytes=dtype_bytes,
                            kind=kind)
    ranked = rank_conv(shape, cands, kind=kind, backend=backend,
                       minibatch=minibatch)
    score, best = ranked[0]
    if k % best.k_blk == 0 and c % best.c_blk == 0:
        # only persist entries the lookup validator will accept — a
        # non-dividing winner (possible for lane-unalignable dims that the
        # kernels reject anyway) would otherwise miss forever
        key = conv_key(kind=kind, h=h, w=w, c=c, k=k, r=r, s=s,
                       stride=stride, padding=padding,
                       dtype_bytes=dtype_bytes, backend=backend,
                       minibatch=minibatch)
        cache.store(key, dataclasses.asdict(best),
                    source="measured" if can_measure(backend) else "model",
                    score_us=score, persist=persist)
    return best


def warmup_convs(shapes, *, minibatches=(1,), kinds=("fwd",), mode="tune",
                 backend=None, cache: TuneCache | None = None,
                 dtype_bytes=4, bwd_mode=None) -> list[dict]:
    """Pre-populate the blocking cache for conv ``shapes`` — the serving /
    training warmup entry (DESIGN.md §8, §10).

    ``shapes``: dicts with h/w/c/k/r/s/stride/padding (e.g. from
    ``graph.serving.conv_shapes``).  One entry is tuned per shape × ``kinds``
    × ``minibatches`` — minibatch is part of the cache key, so serving warms
    exactly the per-device batch of every bucket it will run.  Kinds beyond
    "fwd" cover the training pass: "wu" keys the update-pass blocking on the
    layer shape itself; "bwd" expands each layer into the *dual* forward-conv
    signature(s) its backward-data plan launches
    (``duality.dual_conv_signatures`` — stride² sub-convs under the default
    phase plan, selected by ``bwd_mode`` / the ``REPRO_BWD_DUALITY`` knob) so
    the first training step never tunes inline; "q8" keys the int8 serving
    path (pass ``dtype_bytes=1``).  ``mode`` follows the knob
    semantics: "tune" searches+persists on a miss, "cache" only reports what
    is already there.  All new entries are persisted in one atomic write at
    the end.  Returns one report dict per key:
    ``{"key", "kind", "cached", "source"}``.
    """
    from repro import backend as be
    from repro.core import duality
    backend = be.resolve(backend)
    cache = default_cache() if cache is None else cache
    report = []
    for sh in shapes:
        base = {f: sh[f] for f in ("h", "w", "c", "k", "r", "s",
                                   "stride", "padding")}
        db = sh.get("dtype_bytes", dtype_bytes)
        for kind in kinds:
            if kind == "bwd":
                targets = duality.dual_conv_signatures(
                    r=base["r"], s=base["s"], c=base["c"], k=base["k"],
                    stride=base["stride"], padding=base["padding"],
                    input_hw=(base["h"], base["w"]), mode=bwd_mode)
            else:
                targets = [base]
            for tgt in targets:
                for mb in minibatches:
                    if mode == "tune":
                        autotune_conv(**tgt, dtype_bytes=db, kind=kind,
                                      backend=backend, minibatch=mb,
                                      cache=cache, persist=False)
                    key = conv_key(kind=kind, **tgt, dtype_bytes=db,
                                   backend=backend, minibatch=mb)
                    entry = cache.lookup(key)
                    report.append({"key": key, "kind": kind,
                                   "cached": entry is not None,
                                   "source": entry["source"] if entry
                                   else None})
    if mode == "tune" and any(e["cached"] for e in report):
        try:
            cache.save()
        except OSError as e:        # unwritable path: warm in-memory only
            import sys
            print(f"repro.tune: warmup cache not persisted "
                  f"({cache.path}: {e})", file=sys.stderr)
    return report


def lookup_matmul(m, n, k, *, dtype_bytes=2, backend="xla",
                  cache: TuneCache | None = None) -> MatmulBlocking | None:
    cache = default_cache() if cache is None else cache
    entry = cache.lookup(matmul_key(m=m, n=n, k=k, dtype_bytes=dtype_bytes,
                                    backend=backend))
    if not entry:
        return None
    blk = entry.get("blocking", {})
    if not all(f in blk for f in ("bm", "bn", "bk", "vmem_bytes")):
        return None
    if m % blk["bm"] or n % blk["bn"] or k % blk["bk"]:
        return None
    return MatmulBlocking(bm=blk["bm"], bn=blk["bn"], bk=blk["bk"],
                          vmem_bytes=blk["vmem_bytes"])


def autotune_matmul(m, n, k, *, dtype_bytes=2, backend="xla",
                    cache: TuneCache | None = None,
                    persist: bool = True) -> MatmulBlocking:
    cache = default_cache() if cache is None else cache
    hit = lookup_matmul(m, n, k, dtype_bytes=dtype_bytes, backend=backend,
                        cache=cache)
    if hit is not None:
        return hit
    cands = matmul_candidates(m, n, k, dtype_bytes=dtype_bytes)
    scored = sorted(((matmul_cost_us(m, n, k, b, dtype_bytes=dtype_bytes), b)
                     for b in cands), key=lambda t: t[0])
    score, best = scored[0]
    if m % best.bm == 0 and n % best.bn == 0 and k % best.bk == 0:
        cache.store(matmul_key(m=m, n=n, k=k, dtype_bytes=dtype_bytes,
                               backend=backend),
                    dataclasses.asdict(best), source="model", score_us=score,
                    persist=persist)
    return best
