"""Blocking search space (paper §II-D: the per-shape specialization axis).

For a conv layer the tunable coordinates are exactly the knobs the Pallas
kernels expose:

  rb_p   output rows per microkernel (paper RB_P; MXU M-tile = rb_p*rb_q)
  rb_q   output cols per microkernel (paper RB_Q; fwd/bwd/wu, 0/q = full row)
  k_blk  output-feature block (paper K_b; MXU N-tile, must divide K)
  c_blk  input-feature block (paper C_b accumulation; must divide C)
  order  grid/dryrun loop order over (N, K_b, P_b, C_b) (paper §II-C)

``conv_candidates`` enumerates the feasible cross product — VMEM-budget
filtered, lane-aligned, divisibility-respecting — with the analytic heuristic
first, so it is both the cost-model prior and the seed the search can never
do worse than.  Kinds:

  "fwd"     conv2d_direct tiled forward: all five coordinates free (C-block
            accumulation + RB_Q column blocking + grid loop order)
  "bwd"     the backward-data dual conv — the same tiled forward kernel run
            on the transformed (dO, W') problem, so the same five coordinates
            are free; a separate kind so dual-shape winners get their own
            cache namespace (shapes come from ``duality.dual_conv_signatures``)
  "wu"      conv2d_wu band-streamed update pass: rb_p ceil-div (tails are
            masked in-kernel, no divisor constraint), c_blk / rb_q free; the
            grid order is fixed (K_b, C_b, N, P_b, Q_b), so order is not a
            coordinate
  "streams" conv2d_streams: rb_p/k_blk/c_blk/order free; whole-plane
  "q8"      conv2d_q8 tiled int8 forward: the same five coordinates as
            "fwd" but priced at 1 byte/element input-side (pass
            ``dtype_bytes=1``) — the 4x-smaller band admits taller rb_p
            under the same budget, so its candidate pool is genuinely
            different from the f32 space (own cache namespace)
"""
from __future__ import annotations

from repro.core.blocking import (LANE, SUBLANE, VMEM_BUDGET, ConvBlocking,
                                 MatmulBlocking, conv_blocking_analytic,
                                 conv_working_set, divisors,
                                 matmul_blocking_analytic)

ORDERS = ("nkpc", "npkc", "knpc", "pknc")
MAX_CANDIDATES = 128


def out_dim(h: int, r: int, stride: int, padding: int) -> int:
    return (h + 2 * padding - r) // stride + 1


def _feature_blocks(dim: int) -> list[int]:
    """Divisors of `dim` that are sublane-aligned and at most one MXU tile."""
    blocks = [d for d in divisors(dim) if d % SUBLANE == 0 and d <= LANE]
    return blocks or [dim]          # tiny dims: single un-aligned block


def _rb_candidates(p: int, *, require_divisor: bool) -> list[int]:
    if require_divisor:
        cands = divisors(p)
    else:
        # divisors (exact grids) + powers of two (ceil-div grids) + full P
        cands = set(divisors(p))
        rb = 1
        while rb < p:
            cands.add(rb)
            rb *= 2
        cands.add(p)
        cands = sorted(cands)
    if len(cands) > 12:             # spread-sample large spatial dims
        step = len(cands) / 12
        cands = sorted({cands[int(i * step)] for i in range(12)} | {cands[-1]})
    return cands


def _rb_q_candidates(q: int) -> list[int]:
    """RB_Q column blocks: the full row plus a few power-of-two column
    blocks for wide images (the ceil-div Q grid masks the tail)."""
    return sorted({q} | {b for b in (8, 16, 32, 64, 128) if b < q})


def conv_candidates(*, h: int, w: int, c: int, k: int, r: int, s: int,
                    stride: int, padding: int, dtype_bytes: int = 4,
                    kind: str = "fwd",
                    vmem_budget: int = VMEM_BUDGET) -> list[ConvBlocking]:
    """Feasible blockings, analytic seed first, deduplicated, budget-capped."""
    assert kind in ("fwd", "bwd", "wu", "streams", "q8"), kind
    p = out_dim(h, r, stride, padding)
    q = out_dim(w, s, stride, padding)
    whole = kind == "streams"       # only streams keeps the plane resident
    seed = conv_blocking_analytic(
        h=h, w=w, c=c, k=k, r=r, s=s, stride=stride, padding=padding,
        dtype_bytes=dtype_bytes, vmem_budget=vmem_budget,
        whole_plane=(True if whole else None), kind=kind)

    k_blocks = _feature_blocks(k)
    if kind == "wu":
        # band-streamed update pass: c_blk / rb_q free, grid order fixed
        c_blocks = sorted({c} | set(_feature_blocks(c)), reverse=True)
        orders = (seed.order,)
        rb_qs = _rb_q_candidates(max(q, 1))
    elif kind == "streams":
        c_blocks = _feature_blocks(c)
        orders = ORDERS
        rb_qs = [q]
    else:
        # fwd/bwd/q8: full-C single-pass first, then lane-aligned C_b blocks
        c_blocks = sorted({c} | set(_feature_blocks(c)), reverse=True)
        orders = ORDERS
        rb_qs = _rb_q_candidates(max(q, 1))
    rbs = _rb_candidates(max(p, 1), require_divisor=False)
    ws_kind = kind if kind in ("wu", "q8") else "fwd"

    pool: list[ConvBlocking] = []
    seen = {(seed.rb_p, seed.k_blk, seed.c_blk, seed.order,
             seed.rb_q or q)}
    for rb in rbs:
        for kb in k_blocks:
            for cb in c_blocks:
                for rq in rb_qs:
                    ws = conv_working_set(
                        h=h, w=w, c=c, k_blk=kb, r=r, s=s, q=q, rb_p=rb,
                        padding=padding, dtype_bytes=dtype_bytes,
                        stride=stride, c_blk=cb, rb_q=rq,
                        whole_plane=whole, kind=ws_kind)
                    if ws > vmem_budget:
                        continue
                    for order in orders:
                        key = (rb, kb, cb, order, rq)
                        if key in seen:
                            continue
                        seen.add(key)
                        pool.append(ConvBlocking(rb_p=rb, k_blk=kb, c_blk=cb,
                                                 order=order, vmem_bytes=ws,
                                                 rb_q=rq))
    if len(pool) > MAX_CANDIDATES - 1:
        # spread-sample the (rb_p-major) pool instead of truncating its
        # prefix: a prefix cut would exhaust the budget inside the first
        # rb_p value's c_blk x rb_q x order cross product and never explore
        # the register-block axis at all
        step = len(pool) / (MAX_CANDIDATES - 1)
        pool = [pool[int(i * step)] for i in range(MAX_CANDIDATES - 1)]
    return [seed] + pool


def matmul_candidates(m: int, n: int, k: int, *, dtype_bytes: int = 2,
                      vmem_budget: int = VMEM_BUDGET) -> list[MatmulBlocking]:
    """Tile candidates for the fused matmul kernel (bm/bn/bk must divide)."""
    seed = matmul_blocking_analytic(m, n, k, dtype_bytes=dtype_bytes,
                                    vmem_budget=vmem_budget)

    def largest_divisor(dim: int, cap: int) -> int:
        return max(d for d in divisors(dim) if d <= cap)

    bms = [d for d in (64, 128, 256) if m % d == 0] or [largest_divisor(m, 256)]
    bns = [d for d in (64, 128, 256) if n % d == 0] or [largest_divisor(n, 256)]
    bks = ([d for d in (128, 256, 512, 1024) if k % d == 0]
           or [largest_divisor(k, 1024)])

    def ws(bm, bn, bk):
        return (bm * bk + bk * bn) * dtype_bytes + 2 * bm * bn * 4

    # the analytic seed joins the pool only if it tiles the problem exactly —
    # callers (ops.matmul) fall back to the reference path otherwise, so a
    # persisted non-dividing winner would be a permanently rejected entry
    out, seen = [], set()
    if m % seed.bm == 0 and n % seed.bn == 0 and k % seed.bk == 0:
        out.append(seed)
        seen.add((seed.bm, seed.bn, seed.bk))
    for bm in bms:
        for bn in bns:
            for bk in bks:
                if (bm, bn, bk) in seen or ws(bm, bn, bk) > vmem_budget:
                    continue
                seen.add((bm, bn, bk))
                out.append(MatmulBlocking(bm=bm, bn=bn, bk=bk,
                                          vmem_bytes=ws(bm, bn, bk)))
    return out[:MAX_CANDIDATES] or [seed]


