"""Blocking search space (paper §II-D: the per-shape specialization axis).

For a conv layer the tunable coordinates are exactly the knobs the Pallas
kernels expose:

  rb_p   output rows per microkernel (paper RB_P; MXU M-tile = rb_p*Q)
  k_blk  output-feature block (paper K_b; MXU N-tile, must divide K)
  c_blk  input-feature block (streams kernel only; must divide C)
  order  dryrun loop order over (N, K_b, P_b, C_b) (paper §II-C)

``conv_candidates`` enumerates the feasible cross product — VMEM-budget
filtered, lane-aligned, divisibility-respecting — with the analytic heuristic
first, so it is both the cost-model prior and the seed the search can never
do worse than.  Kinds:

  "fwd"     conv2d_direct forward: C unblocked, grid order fixed (N,K_b,P_b)
  "wu"      conv2d_wu update pass: rb_p must divide P
  "streams" conv2d_streams: all four coordinates free
"""
from __future__ import annotations

import math

from repro.core.blocking import (LANE, SUBLANE, VMEM_BUDGET, ConvBlocking,
                                 MatmulBlocking, conv_blocking_analytic,
                                 conv_working_set, divisors,
                                 matmul_blocking_analytic)

ORDERS = ("nkpc", "npkc", "knpc", "pknc")
MAX_CANDIDATES = 128


def out_dim(h: int, r: int, stride: int, padding: int) -> int:
    return (h + 2 * padding - r) // stride + 1


def _feature_blocks(dim: int) -> list[int]:
    """Divisors of `dim` that are sublane-aligned and at most one MXU tile."""
    blocks = [d for d in divisors(dim) if d % SUBLANE == 0 and d <= LANE]
    return blocks or [dim]          # tiny dims: single un-aligned block


def _rb_candidates(p: int, *, require_divisor: bool) -> list[int]:
    if require_divisor:
        cands = divisors(p)
    else:
        # divisors (exact grids) + powers of two (ceil-div grids) + full P
        cands = set(divisors(p))
        rb = 1
        while rb < p:
            cands.add(rb)
            rb *= 2
        cands.add(p)
        cands = sorted(cands)
    if len(cands) > 12:             # spread-sample large spatial dims
        step = len(cands) / 12
        cands = sorted({cands[int(i * step)] for i in range(12)} | {cands[-1]})
    return cands


def conv_candidates(*, h: int, w: int, c: int, k: int, r: int, s: int,
                    stride: int, padding: int, dtype_bytes: int = 4,
                    kind: str = "fwd",
                    vmem_budget: int = VMEM_BUDGET) -> list[ConvBlocking]:
    """Feasible blockings, analytic seed first, deduplicated, budget-capped."""
    assert kind in ("fwd", "wu", "streams"), kind
    p = out_dim(h, r, stride, padding)
    q = out_dim(w, s, stride, padding)
    seed = conv_blocking_analytic(
        h=h, w=w, c=c, k=k, r=r, s=s, stride=stride, padding=padding,
        dtype_bytes=dtype_bytes, vmem_budget=vmem_budget,
        require_divisor=(kind == "wu"))

    k_blocks = _feature_blocks(k)
    c_blocks = _feature_blocks(c) if kind == "streams" else [c]
    orders = ORDERS if kind == "streams" else (seed.order,)
    rbs = _rb_candidates(max(p, 1), require_divisor=(kind == "wu"))

    out: list[ConvBlocking] = [seed]
    seen = {(seed.rb_p, seed.k_blk, seed.c_blk, seed.order)}
    for rb in rbs:
        for kb in k_blocks:
            for cb in c_blocks:
                ws = conv_working_set(
                    h=h, w=w, c=cb if kind == "streams" else c, k_blk=kb,
                    r=r, s=s, q=q, rb_p=rb, padding=padding,
                    dtype_bytes=dtype_bytes)
                if ws > vmem_budget:
                    continue
                for order in orders:
                    key = (rb, kb, cb, order)
                    if key in seen:
                        continue
                    seen.add(key)
                    out.append(ConvBlocking(rb_p=rb, k_blk=kb, c_blk=cb,
                                            order=order, vmem_bytes=ws))
    return out[:MAX_CANDIDATES]


def matmul_candidates(m: int, n: int, k: int, *, dtype_bytes: int = 2,
                      vmem_budget: int = VMEM_BUDGET) -> list[MatmulBlocking]:
    """Tile candidates for the fused matmul kernel (bm/bn/bk must divide)."""
    seed = matmul_blocking_analytic(m, n, k, dtype_bytes=dtype_bytes,
                                    vmem_budget=vmem_budget)

    def largest_divisor(dim: int, cap: int) -> int:
        return max(d for d in divisors(dim) if d <= cap)

    bms = [d for d in (64, 128, 256) if m % d == 0] or [largest_divisor(m, 256)]
    bns = [d for d in (64, 128, 256) if n % d == 0] or [largest_divisor(n, 256)]
    bks = ([d for d in (128, 256, 512, 1024) if k % d == 0]
           or [largest_divisor(k, 1024)])

    def ws(bm, bn, bk):
        return (bm * bk + bk * bn) * dtype_bytes + 2 * bm * bn * 4

    # the analytic seed joins the pool only if it tiles the problem exactly —
    # callers (ops.matmul) fall back to the reference path otherwise, so a
    # persisted non-dividing winner would be a permanently rejected entry
    out, seen = [], set()
    if m % seed.bm == 0 and n % seed.bn == 0 and k % seed.bk == 0:
        out.append(seed)
        seen.add((seed.bm, seed.bn, seed.bk))
    for bm in bms:
        for bn in bns:
            for bk in bks:
                if (bm, bn, bk) in seen or ws(bm, bn, bk) > vmem_budget:
                    continue
                seen.add((bm, bn, bk))
                out.append(MatmulBlocking(bm=bm, bn=bn, bk=bk,
                                          vmem_bytes=ws(bm, bn, bk)))
    return out[:MAX_CANDIDATES] or [seed]


def grid_shape(*, n: int, p: int, c: int, k: int,
               blk: ConvBlocking, kind: str) -> tuple[int, ...]:
    """Loop extents (N, K_b, P_b, C_b) a blocking induces."""
    c_b = c // blk.c_blk if kind == "streams" else 1
    return (n, max(k // blk.k_blk, 1), math.ceil(p / blk.rb_p), max(c_b, 1))
