"""Persistent shape-specialized blocking cache — the libxsmm dispatch cache
one level up (paper §II-D: "JIT the right microkernel for the layer at hand",
here: *remember* the right blocking for the layer at hand).

Entries are keyed by everything that changes the winner:

  kind | shape params | dtype bytes | stride/padding | backend | device_kind

and stored in a single versioned JSON file (default
``~/.cache/repro_tune/blockings-v1.json``, override with ``REPRO_TUNE_CACHE``).
Writes are atomic (tempfile + ``os.replace``) so concurrent benchmark runs
never observe a torn file.  A version mismatch on load discards the file —
bump ``CACHE_VERSION`` whenever the candidate space, the cost model, or the
entry format changes incompatibly (see DESIGN.md §6).
"""
from __future__ import annotations

import json
import os
import sys
import tempfile
import threading
import time

CACHE_VERSION = 4     # v4: the "q8" int8-forward kind + its 1-byte-input
                      #     working-set model (grow-to-budget rb_p)
                      # v3: tiled-wu space (c_blk/rb_q free, ceil-div rb_p)
                      #     + the "bwd" dual-conv kind
                      # v2: ConvBlocking grew rb_q (RB_Q column blocking)
_ENV_VAR = "REPRO_TUNE_CACHE"


def default_cache_path() -> str:
    env = os.environ.get(_ENV_VAR)
    if env:
        return env
    base = os.environ.get("XDG_CACHE_HOME",
                          os.path.join(os.path.expanduser("~"), ".cache"))
    return os.path.join(base, "repro_tune", f"blockings-v{CACHE_VERSION}.json")


def device_kind() -> str:
    """Cache-key component: the accelerator the blocking was tuned for."""
    try:
        import jax
        return jax.devices()[0].device_kind.replace("|", "_")
    except Exception:  # noqa: BLE001 — no backend at all
        return "unknown"


def conv_key(*, kind: str, h: int, w: int, c: int, k: int, r: int, s: int,
             stride: int, padding: int, dtype_bytes: int, backend: str,
             minibatch: int = 1, device: str | None = None) -> str:
    device = device or device_kind()
    # minibatch is part of the key: the memory/refetch terms of the cost
    # model (and real wall clock) scale with N, so winners differ by batch
    return (f"conv|{kind}|n{minibatch}h{h}w{w}c{c}k{k}r{r}s{s}"
            f"|st{stride}pd{padding}|b{dtype_bytes}|{backend}|{device}")


def matmul_key(*, m: int, n: int, k: int, dtype_bytes: int, backend: str,
               device: str | None = None) -> str:
    device = device or device_kind()
    return f"matmul|m{m}n{n}k{k}|b{dtype_bytes}|{backend}|{device}"


class TuneCache:
    """In-memory dict over a versioned JSON file.  Thread-safe; lazily loaded."""

    def __init__(self, path: str | None = None):
        self.path = path or default_cache_path()
        self._entries: dict[str, dict] | None = None
        self._lock = threading.Lock()
        self._warned_readonly = False

    # -- persistence ---------------------------------------------------------
    def _load_locked(self) -> dict[str, dict]:
        if self._entries is not None:
            return self._entries
        self._entries = {}
        try:
            with open(self.path, encoding="utf-8") as f:
                blob = json.load(f)
            if blob.get("version") == CACHE_VERSION:
                self._entries = dict(blob.get("entries", {}))
        except (OSError, ValueError):
            pass                      # cold cache / stale version / torn file
        return self._entries

    def save(self) -> None:
        with self._lock:
            entries = self._load_locked()
            # merge what other processes persisted since our lazy load —
            # our own entries win on key conflict, nobody's work is dropped
            try:
                with open(self.path, encoding="utf-8") as f:
                    blob = json.load(f)
                if blob.get("version") == CACHE_VERSION:
                    merged = dict(blob.get("entries", {}))
                    merged.update(entries)
                    self._entries = entries = merged
            except (OSError, ValueError):
                pass
            blob = {"version": CACHE_VERSION, "entries": entries}
            d = os.path.dirname(self.path) or "."
            os.makedirs(d, exist_ok=True)
            fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
            try:
                with os.fdopen(fd, "w", encoding="utf-8") as f:
                    json.dump(blob, f, indent=1, sort_keys=True)
                os.replace(tmp, self.path)
            except BaseException:
                try:
                    os.unlink(tmp)
                except OSError:
                    pass
                raise

    # -- access --------------------------------------------------------------
    def lookup(self, key: str) -> dict | None:
        with self._lock:
            e = self._load_locked().get(key)
        return dict(e) if e is not None else None

    def store(self, key: str, blocking: dict, *, source: str,
              score_us: float, persist: bool = True) -> None:
        entry = {"blocking": dict(blocking), "source": source,
                 "score_us": float(score_us), "version": CACHE_VERSION,
                 "tuned_at": time.time()}
        with self._lock:
            self._load_locked()[key] = entry
        if persist:
            try:
                self.save()
            except OSError as e:     # unwritable path: keep tuning in-memory
                if not self._warned_readonly:
                    self._warned_readonly = True
                    print(f"repro.tune: cache not persisted "
                          f"({self.path}: {e}); continuing in-memory",
                          file=sys.stderr)

    def export_entries(self, keys=None) -> dict[str, dict]:
        """Snapshot entries (all, or just ``keys``) as a JSON-serializable
        payload — the "broadcast" half of tune-once-per-host warmup: host 0
        tunes, exports, and every other host ``merge_entries`` the payload
        instead of re-searching the same space (DESIGN.md §11)."""
        with self._lock:
            entries = self._load_locked()
            if keys is None:
                return {k: dict(v) for k, v in entries.items()}
            return {k: dict(entries[k]) for k in keys if k in entries}

    def merge_entries(self, payload: dict[str, dict], *,
                      persist: bool = True) -> int:
        """Install a broadcast payload verbatim (tuned_at/score preserved).
        Returns the number of entries installed."""
        with self._lock:
            self._load_locked().update(
                {k: dict(v) for k, v in payload.items()})
        if persist:
            try:
                self.save()
            except OSError as e:
                if not self._warned_readonly:
                    self._warned_readonly = True
                    print(f"repro.tune: cache not persisted "
                          f"({self.path}: {e}); continuing in-memory",
                          file=sys.stderr)
        return len(payload)

    def clear(self) -> None:
        with self._lock:
            self._entries = {}
        try:
            os.unlink(self.path)
        except OSError:
            pass

    def __len__(self) -> int:
        with self._lock:
            return len(self._load_locked())


_default: TuneCache | None = None
_default_lock = threading.Lock()


def default_cache() -> TuneCache:
    """Process-wide cache singleton (re-created if REPRO_TUNE_CACHE moved)."""
    global _default
    with _default_lock:
        if _default is None or _default.path != default_cache_path():
            _default = TuneCache()
        return _default
