"""Candidate scoring: wall-clock on real hardware, cost model everywhere else.

On a TPU ("pallas" backend with a TPU device attached) each candidate blocking
compiles and times the actual kernel — the paper's empirical specialization.
Under "interpret"/"xla" on CPU, wall time measures the interpreter (or a
different algorithm entirely), so candidates are ranked by an analytic cost
model instead:

  t_model = max(t_compute, t_memory) + n_steps * STEP_OVERHEAD

  t_compute  FLOPs / (peak * MXU tile utilization): the M-tile (rb_p*Q rows),
             N-tile (k_blk lanes) and contraction tile (c_blk) each pay a
             ceil-to-128 occupancy factor — the paper's "register block must
             fill the FMA pipeline", re-derived for a 128x128 systolic array.
  t_memory   HBM traffic from loop-order-aware block refetch counts: a block
             whose index depends on loop set S is fetched once per iteration
             of the loops at positions up to S's innermost member (§II-C cache
             blocking, computed exactly instead of assumed).  The tiled
             forward kernel's input block is the streamed *row band* (its
             index varies with P, so it refetches per row-block); the legacy
             whole-plane variant ships the full padded plane on every grid
             step (the "bytes accessed" upper-bound convention of
             ``launch.roofline``).  A C_b-blocked output tile pays the
             multi-pass term: each extra accumulation visit is modeled as a
             read-back + rewrite.
  n_steps    grid size: each step pays a fixed pipeline-fill overhead.

The model is deliberately the same family as ``benchmarks.resnet50_layers.
modeled_v5e_efficiency`` but blocking-resolved, so tuned-vs-heuristic deltas
are meaningful even offline.
"""
from __future__ import annotations

import math

from repro.core.blocking import LANE, ConvBlocking, MatmulBlocking
from repro.launch.roofline import (HBM_BW, PEAK_FLOPS, STEP_OVERHEAD_S,
                                   kernel_roofline)
from repro.tune.space import out_dim

STEP_OVERHEAD_US = STEP_OVERHEAD_S * 1e6

# Stable traffic-dict keys (``conv_traffic`` / ``_wu_traffic``).  The bench
# JSONs derive their persisted fields from these and the perf-gate extractors
# (repro.perfci.extract) join on the derived names — renaming one is a
# baseline-schema change and must bump perfci's SCHEMA_VERSION.
CONV_TRAFFIC_KEYS = ("flops", "util", "x_bytes", "w_bytes", "o_bytes",
                     "hbm_bytes", "n_steps", "extents")

# Stable keys of the ``chain_traffic`` decision dict (DESIGN.md §16).
CHAIN_TRAFFIC_KEYS = ("fused", "fits_vmem", "rb", "n_bands", "vmem_bytes",
                      "flops", "x_bytes", "w_bytes", "o_bytes", "hbm_bytes",
                      "intermediate_bytes", "unfused_hbm_bytes",
                      "unfused_intermediate_bytes", "n_steps", "n_layers")


def _tile_util(extent: int) -> float:
    """Occupancy of a 128-wide MXU dimension holding `extent` elements."""
    if extent <= 0:
        return 1.0
    return extent / (LANE * math.ceil(extent / LANE))


def _refetches(dep_positions: list[int], extents: tuple[int, ...]) -> int:
    """Times a block is (re)fetched over a nested loop: once per iteration of
    every loop at or outside the innermost dependency *that actually varies*."""
    live = [p for p in dep_positions if extents[p] > 1]
    if not live:
        return 1
    inner = max(live)
    n = 1
    for p in range(inner + 1):
        n *= extents[p]
    return n


def conv_traffic(shape: dict, blk: ConvBlocking, *, minibatch: int = 1,
                 kind: str = "fwd", whole_plane: bool = False) -> dict:
    """Schedule-resolved FLOPs / HBM traffic / occupancy for one conv layer
    under blocking `blk` — the inputs of ``launch.roofline.kernel_roofline``.

    Traffic terms (all in bytes, summed over the whole launch):
      * input  — the tiled fwd/bwd kernel streams one row band per step
        (deps: N, P, C_b); ``whole_plane`` ships the padded plane on *every*
        grid step; streams keeps the plane resident per (N, C_b).
      * weight — one (r, s, C_blk, K_blk) block, resident across the P sweep
        when the loop order allows (§II-C).
      * output — one f32 tile per (N, K_b, P_b) visit; when C is blocked
        (tiled fwd with c_blk < C, or streams) every extra accumulation pass
        re-reads and rewrites the tile: the multi-pass output term.

    ``kind="q8"`` is the tiled forward with int8 byte accounting: pass a
    shape dict with ``dtype_bytes=1`` and the input-band and weight-block
    terms shrink 4x while the output term stays f32 (the §II-K asymmetry —
    which is exactly why the modeled speedup lands near the paper's 1.6x on
    bandwidth-bound layers instead of 4x).

    ``kind="wu"`` models the update pass instead: the tiled kernel streams
    an input row band *and* a dO pixel tile on every step of its
    ``(K_b, C_b, N, P_b, Q_b)`` grid and writes each (r, s, C_blk, K_blk)
    f32 dW tile exactly once (the accumulation revisits stay in VMEM); the
    legacy ``whole_plane`` variant keeps the entire padded plane resident
    across the P sweep (its block index is constant over P_b, so Pallas
    re-fetches per (k, n)) — but that residency is exactly why it cannot
    schedule once the plane approaches the VMEM budget, the §II-J
    regression the tiling removes.
    """
    h, w, c, k = shape["h"], shape["w"], shape["c"], shape["k"]
    r, s = shape["r"], shape["s"]
    stride, padding = shape["stride"], shape["padding"]
    dtype_bytes = shape.get("dtype_bytes", 4)
    p = out_dim(h, r, stride, padding)
    q = out_dim(w, s, stride, padding)
    n = minibatch
    hp, wp = h + 2 * padding + r, w + 2 * padding

    if kind == "wu":
        return _wu_traffic(h=h, w=w, c=c, k=k, r=r, s=s, stride=stride,
                           p=p, q=q, hp=hp, wp=wp, n=n, blk=blk,
                           dtype_bytes=dtype_bytes, whole_plane=whole_plane)

    tiled_fwd = kind in ("fwd", "bwd", "q8") and not whole_plane
    if whole_plane:
        c_blk, rb_q = c, q
    elif kind == "streams":
        c_blk, rb_q = blk.c_blk, q
    else:
        c_blk, rb_q = blk.c_blk, (blk.rb_q or q)
    rb_p = min(blk.rb_p, p)
    rb_q = min(rb_q, q)
    p_b = math.ceil(p / rb_p)
    q_b = math.ceil(q / rb_q) if tiled_fwd else 1
    k_b = max(k // blk.k_blk, 1)
    c_b = max(c // c_blk, 1)
    extents = (n, k_b, p_b * q_b, c_b)

    # the legacy whole-plane fwd has a fixed grid order
    order = "nkpc" if whole_plane else blk.order
    pos = {dim: i for i, dim in enumerate(order)}
    by_dim = {"n": extents[0], "k": extents[1], "p": extents[2],
              "c": extents[3]}
    ordered = tuple(by_dim[d] for d in order)
    n_steps = extents[0] * extents[1] * extents[2] * extents[3]

    # compute: every grid step runs the full (r,s) small-GEMM chain
    flops = 2.0 * n * p * q * c * k * r * s
    util = (_tile_util(rb_p * rb_q) * _tile_util(blk.k_blk)
            * _tile_util(c_blk))

    if tiled_fwd:
        band_h = (rb_p - 1) * stride + r
        band_w = (rb_q - 1) * stride + s
        x_bytes = band_h * band_w * c_blk * dtype_bytes
        x_f = _refetches([pos["n"], pos["p"], pos["c"]], ordered)
    else:
        x_bytes = hp * wp * c_blk * dtype_bytes
        if whole_plane:
            # the legacy fwd kernel ships the entire padded plane into VMEM
            # on every grid step — charge it per step (upper bound; VMEM
            # residency across the sweep cannot be assumed once the plane
            # approaches the budget, which is the regime tiling targets)
            x_f = n_steps
        else:
            x_f = _refetches([pos["n"], pos["c"]], ordered)
    w_bytes = r * s * c_blk * blk.k_blk * dtype_bytes
    o_bytes = rb_p * rb_q * blk.k_blk * 4   # f32 tile (q8 output stays f32)
    w_f = _refetches([pos["k"], pos["c"]], ordered)
    o_f = _refetches([pos["n"], pos["k"], pos["p"]], ordered)
    revisit = max(extents[3], 1)
    # multi-pass output traffic: every extra C-block visit of an output tile
    # is a read-back + rewrite (streams accumulates through the out block;
    # the tiled fwd scratch tile is modeled the same way — conservative)
    multipass = (2 * revisit - 1) if (kind == "streams" or tiled_fwd) else 1
    o_traffic = o_bytes * o_f * multipass
    total = x_bytes * x_f + w_bytes * w_f + o_traffic
    return {
        "flops": flops,
        "util": util,
        "x_bytes": x_bytes * x_f,
        "w_bytes": w_bytes * w_f,
        "o_bytes": o_traffic,
        "hbm_bytes": total,
        "n_steps": n_steps,
        "extents": extents,
    }


def _wu_traffic(*, h, w, c, k, r, s, stride, p, q, hp, wp, n, blk,
                dtype_bytes, whole_plane) -> dict:
    """Update-pass traffic: see ``conv_traffic``.  The GEMM per step is
    dW[r,s] += X^T @ dO with M=C_blk, N=K_blk, K=pixel-block, so occupancy
    is (c_blk, k_blk, rb_p*rb_q)-tiled."""
    flops = 2.0 * n * p * q * c * k * r * s
    k_blk = min(blk.k_blk, k)
    if whole_plane:
        rb_p = min(blk.rb_p, p)
        p_b = math.ceil(p / rb_p)
        n_steps = (k // k_blk) * n * p_b                  # (K_b, N, P_b)
        util = _tile_util(c) * _tile_util(k_blk) * _tile_util(rb_p * q)
        # the plane's block index is constant over the P_b sweep: fetched
        # once per (k, n), resident (in VMEM, or nowhere at all) in between
        x_traffic = hp * wp * c * dtype_bytes * (k // k_blk) * n
        do_traffic = rb_p * q * k_blk * dtype_bytes * n_steps
    else:
        rb_p = min(blk.rb_p, p)
        rb_q = min(blk.rb_q or q, q)
        c_blk = blk.c_blk or c
        band_h = (rb_p - 1) * stride + r
        band_w = (rb_q - 1) * stride + s
        p_b = math.ceil(p / rb_p)
        q_b = math.ceil(q / rb_q)
        n_steps = (k // k_blk) * (c // c_blk) * n * p_b * q_b
        util = _tile_util(c_blk) * _tile_util(k_blk) * _tile_util(rb_p * rb_q)
        # band + dO tile are re-streamed on every step ((n, p, q) are the
        # innermost grid axes; each C-block pass re-reads the dO tiles)
        x_traffic = band_h * band_w * c_blk * dtype_bytes * n_steps
        do_traffic = rb_p * rb_q * k_blk * dtype_bytes * n_steps
    # each (r, s, C_blk, K_blk) f32 tile is written exactly once — the
    # (n, p, q) accumulation revisits never leave VMEM
    dw_traffic = r * s * c * k * 4
    total = x_traffic + do_traffic + dw_traffic
    return {
        "flops": flops,
        "util": util,
        "x_bytes": x_traffic,
        "w_bytes": do_traffic,      # the "weight slot" input is dO here
        "o_bytes": dw_traffic,
        "hbm_bytes": total,
        "n_steps": n_steps,
        "extents": (n, k // k_blk, p_b, 1 if whole_plane else c // (blk.c_blk or c)),
    }


def conv_cost_us(shape: dict, blk: ConvBlocking, *, minibatch: int = 1,
                 kind: str = "fwd", whole_plane: bool = False) -> float:
    """Modeled microseconds for one conv of `shape` under blocking `blk`."""
    t = conv_traffic(shape, blk, minibatch=minibatch, kind=kind,
                     whole_plane=whole_plane)
    roof = kernel_roofline(flops=t["flops"], hbm_bytes=t["hbm_bytes"],
                           util=t["util"], n_steps=0)
    return roof["step_time_s"] * 1e6 + t["n_steps"] * STEP_OVERHEAD_US


def chain_traffic(shapes: list, *, minibatch: int = 1,
                  vmem_budget: int | None = None) -> dict:
    """Price a depth-first conv->conv chain against its unfused execution
    and decide whether to fuse it (DESIGN.md §16).

    ``shapes`` is the per-layer conv shape dict list, producers first.  The
    fused price replays the exact interleaved band schedule
    (``core.streams.build_chain_schedule``) and charges, per band step, the
    per-layer ``conv_traffic`` of that band under the *full-shape* blocking:

      * layer-0 input bands come from HBM — overlapping halo rows between
        consecutive bands are charged again (refetched halos, honestly);
      * every hand-off band (FLAG_HANDOFF) is VMEM-resident — its input-read
        and output-write terms are 0 HBM bytes, the depth-first dividend;
      * weight blocks are charged per band step (they cycle out of VMEM
        while the other chain layers run), same granularity as unfused;
      * only the final layer's output bands are written back.

    Decision (the per-chain fallback rule): fuse iff the combined band
    working set fits ``vmem_budget`` (``core.blocking.chain_blocking``) AND
    the fused HBM bytes do not exceed the unfused sum — halo recompute can
    lose on adversarial geometry, and an unprofitable chain simply runs
    layer-by-layer.  On fallback the reported traffic *is* the unfused sum.

    Returns ``CHAIN_TRAFFIC_KEYS`` plus ``parts``/``unfused_parts`` (the
    per-launch ``conv_traffic`` dicts, for ``launch.roofline.chain_roofline``).
    """
    from repro.core.blocking import chain_blocking, conv_blocking_analytic
    from repro.core.streams import FLAG_HANDOFF, build_chain_schedule

    n = minibatch
    dtype_bytes = shapes[0].get("dtype_bytes", 4)
    blks, unfused_parts, dims = [], [], []
    for sh in shapes:
        blk = conv_blocking_analytic(
            h=sh["h"], w=sh["w"], c=sh["c"], k=sh["k"], r=sh["r"], s=sh["s"],
            stride=sh["stride"], padding=sh["padding"],
            dtype_bytes=sh.get("dtype_bytes", 4))
        blks.append(blk)
        unfused_parts.append(conv_traffic(sh, blk, minibatch=n))
        dims.append((out_dim(sh["h"], sh["r"], sh["stride"], sh["padding"]),
                     out_dim(sh["w"], sh["s"], sh["stride"], sh["padding"])))
    unfused_hbm = sum(p["hbm_bytes"] for p in unfused_parts)
    # unfused: every intermediate activation round-trips HBM (write + read)
    unfused_inter = sum(2.0 * dims[l][0] * dims[l][1] * shapes[l]["k"]
                        * shapes[l].get("dtype_bytes", 4) * n
                        for l in range(len(shapes) - 1))

    cb = chain_blocking(shapes, vmem_budget=vmem_budget,
                        dtype_bytes=dtype_bytes, blockings=blks)
    sched = build_chain_schedule(
        rs=[(sh["r"], sh["stride"], sh["padding"]) for sh in shapes],
        h_in=shapes[0]["h"], rb=cb.rb)

    fused = dict.fromkeys(("flops", "x_bytes", "w_bytes", "o_bytes",
                           "hbm_bytes", "n_steps"), 0.0)
    parts = []
    for i in range(len(sched)):
        l = int(sched.layer_ids[i])
        o0, o1 = int(sched.o0[i]), int(sched.o1[i])
        sh = shapes[l]
        band = dict(sh)
        # padded band buffer: exact halo recurrence rows, W pre-padded
        band["h"] = (o1 - o0 - 1) * sh["stride"] + sh["r"]
        band["w"] = sh["w"] + 2 * sh["padding"]
        band["padding"] = 0
        t = conv_traffic(band, blks[l], minibatch=n)
        handoff = bool(sched.flags[i] & FLAG_HANDOFF)
        x_hbm = t["x_bytes"] if l == 0 else 0.0        # hand-off: VMEM read
        o_hbm = 0.0 if handoff else t["o_bytes"]       # hand-off: VMEM write
        part = dict(t)
        part["x_bytes"], part["o_bytes"] = x_hbm, o_hbm
        part["hbm_bytes"] = x_hbm + t["w_bytes"] + o_hbm
        parts.append(part)
        fused["flops"] += t["flops"]
        fused["x_bytes"] += x_hbm
        fused["w_bytes"] += t["w_bytes"]
        fused["o_bytes"] += o_hbm
        fused["hbm_bytes"] += part["hbm_bytes"]
        fused["n_steps"] += t["n_steps"]

    fuse = cb.fits and fused["hbm_bytes"] <= unfused_hbm
    out = {
        "fused": fuse,
        "fits_vmem": cb.fits,
        "rb": cb.rb,
        "n_bands": cb.n_bands,
        "vmem_bytes": cb.vmem_bytes,
        "n_layers": len(shapes),
        "unfused_hbm_bytes": unfused_hbm,
        "unfused_intermediate_bytes": unfused_inter,
        "unfused_parts": unfused_parts,
    }
    if fuse:
        out.update(fused)
        out["intermediate_bytes"] = 0.0     # the depth-first invariant
        out["parts"] = parts
    else:   # fallback: the chain runs layer-by-layer — price it as such
        out["flops"] = sum(p["flops"] for p in unfused_parts)
        out["x_bytes"] = sum(p["x_bytes"] for p in unfused_parts)
        out["w_bytes"] = sum(p["w_bytes"] for p in unfused_parts)
        out["o_bytes"] = sum(p["o_bytes"] for p in unfused_parts)
        out["hbm_bytes"] = unfused_hbm
        out["n_steps"] = sum(p["n_steps"] for p in unfused_parts)
        out["intermediate_bytes"] = unfused_inter
        out["parts"] = unfused_parts
    return out


def bwd_data_traffic(shape: dict, *, minibatch: int = 1,
                     mode: str = "phase") -> dict:
    """Modeled traffic of the whole §II-I backward-data pipeline of `shape`
    under duality plan ``mode`` ("phase" | "dilate").

    Returns the per-launch ``conv_traffic`` dicts of every dual forward conv
    the plan runs (``duality.dual_conv_signatures`` with ``unique=False`` —
    one for the single-conv scenarios, one per non-empty phase for the phase
    plan, duplicates included: identical-geometry phases are still separate
    launches) plus ``extra_hbm_bytes``: the
    non-kernel HBM traffic the plan pays outside the conv launches —
    materializing the dilated dO (write + source read) for "dilate",
    re-interleaving the stride×stride dI subgrids for "phase".  Feed the
    result to ``launch.roofline.composite_roofline``.
    """
    from repro.core import duality
    from repro.core.blocking import conv_blocking_analytic

    h, w, c, k = shape["h"], shape["w"], shape["c"], shape["k"]
    r, s = shape["r"], shape["s"]
    stride, padding = shape["stride"], shape["padding"]
    dtype_bytes = shape.get("dtype_bytes", 4)
    p = out_dim(h, r, stride, padding)
    q = out_dim(w, s, stride, padding)
    sigs = duality.dual_conv_signatures(r=r, s=s, c=c, k=k, stride=stride,
                                        padding=padding, input_hw=(h, w),
                                        mode=mode, unique=False)
    parts = []
    for sg in sigs:
        blk = conv_blocking_analytic(
            h=sg["h"], w=sg["w"], c=sg["c"], k=sg["k"], r=sg["r"], s=sg["s"],
            stride=sg["stride"], padding=sg["padding"],
            dtype_bytes=dtype_bytes, kind="bwd")
        parts.append(conv_traffic(sg, blk, minibatch=minibatch, kind="bwd"))
    extra = 0.0
    generic = stride > 1 and not (r == 1 and s == 1)
    if generic and mode == "dilate":
        # write the (stride²-sparse) dilated+padded plane, read dO to fill it
        sg = sigs[0]
        extra = (sg["h"] * sg["w"] + p * q) * k * dtype_bytes * minibatch
    elif generic and mode == "phase":
        # interleave: read each phase output once, write dI once
        extra = 2.0 * h * w * c * dtype_bytes * minibatch
    return {"parts": parts, "extra_hbm_bytes": extra,
            "n_convs": len(parts), "mode": mode}


def bwd_data_cost_us(shape: dict, *, minibatch: int = 1,
                     mode: str = "phase") -> float:
    """Modeled microseconds for the full backward-data pipeline of `shape`."""
    from repro.launch.roofline import composite_roofline
    t = bwd_data_traffic(shape, minibatch=minibatch, mode=mode)
    roof = composite_roofline(t["parts"],
                              extra_hbm_bytes=t["extra_hbm_bytes"])
    return roof["cost_s"] * 1e6


def matmul_cost_us(m: int, n: int, k: int, blk: MatmulBlocking, *,
                   dtype_bytes: int = 2) -> float:
    flops = 2.0 * m * n * k
    util = (_tile_util(blk.bm) * _tile_util(blk.bn)
            * _tile_util(min(blk.bk, LANE)))
    t_comp = flops / (PEAK_FLOPS * max(util, 1e-3))
    g_m, g_n, g_k = m // blk.bm, n // blk.bn, k // blk.bk
    traffic = (g_n * (m * k) + g_m * (k * n)) * dtype_bytes + m * n * 4
    t_mem = traffic / HBM_BW
    return max(t_comp, t_mem) * 1e6 + g_m * g_n * g_k * STEP_OVERHEAD_US


# -- real-kernel timing (TPU path) -------------------------------------------

def can_measure(backend: str) -> bool:
    """Wall-clock only means something when the real kernel actually runs."""
    if backend != "pallas":
        return False
    try:
        import jax
        return jax.devices()[0].platform == "tpu"
    except Exception:  # noqa: BLE001
        return False


def measure_conv_us(shape: dict, blk: ConvBlocking, *, kind: str = "fwd",
                    minibatch: int = 1, warmup: int = 2,
                    iters: int = 5) -> float:
    """Compile and time the real kernel for one candidate (TPU only)."""
    import time

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.kernels.conv2d_direct import conv2d_direct
    from repro.kernels.conv2d_streams import conv2d_streams_auto
    from repro.kernels.conv2d_wu import conv2d_wu

    rng = np.random.default_rng(0)
    h, w, c, k = shape["h"], shape["w"], shape["c"], shape["k"]
    r, s = shape["r"], shape["s"]
    stride, padding = shape["stride"], shape["padding"]
    x = jnp.asarray(rng.standard_normal((minibatch, h, w, c)), jnp.float32)
    wt = jnp.asarray(rng.standard_normal((r, s, c, k)) * 0.1, jnp.float32)

    if kind == "streams":
        # blocking= pins all four knobs AND skips the autotune consult —
        # re-entering the tuner mid-measurement would recurse on the same
        # not-yet-cached key.
        fn = jax.jit(lambda x, wt: conv2d_streams_auto(
            x, wt, stride=stride, padding=padding, blocking=blk))
    elif kind == "wu":
        p = out_dim(h, r, stride, padding)
        q = out_dim(w, s, stride, padding)
        do = jnp.asarray(rng.standard_normal((minibatch, p, q, k)),
                         jnp.float32)
        fn = jax.jit(lambda x, do: conv2d_wu(
            x, do, stride=stride, padding=padding, filter_rs=(r, s),
            b_p=blk.rb_p, k_blk=blk.k_blk, c_blk=blk.c_blk, rb_q=blk.rb_q,
            whole_plane=False))
        wt = do
    elif kind == "q8":
        from repro.kernels.conv2d_q8 import conv2d_q8, quantize_conv_inputs
        x_q, w_q, sx, sw = quantize_conv_inputs(x, wt)
        fn = jax.jit(lambda x, wt: conv2d_q8(
            x, wt, x_scale=sx, w_scale=sw, stride=stride, padding=padding,
            rb_p=blk.rb_p, k_blk=blk.k_blk, c_blk=blk.c_blk, rb_q=blk.rb_q,
            order=blk.order, whole_plane=False))
        x, wt = x_q, w_q
    else:                       # "fwd" and "bwd" (the dual IS a fwd launch)
        fn = jax.jit(lambda x, wt: conv2d_direct(
            x, wt, stride=stride, padding=padding, rb_p=blk.rb_p,
            k_blk=blk.k_blk, c_blk=blk.c_blk, rb_q=blk.rb_q,
            order=blk.order, whole_plane=False))

    for _ in range(warmup):
        jax.block_until_ready(fn(x, wt))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(x, wt))
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2] * 1e6


def rank_conv(shape: dict, candidates: list[ConvBlocking], *,
              kind: str = "fwd", backend: str = "xla", minibatch: int = 1,
              measure_top: int = 8) -> list[tuple[float, ConvBlocking]]:
    """Score candidates; returns (score_us, blocking) sorted best-first.

    Model scores everywhere; on TPU the model shortlists `measure_top`
    candidates which are then re-ranked by real wall clock.
    """
    scored = sorted(
        ((conv_cost_us(shape, b, minibatch=minibatch, kind=kind), b)
         for b in candidates), key=lambda t: t[0])
    if not can_measure(backend):
        return scored
    timed = []
    for _, b in scored[:measure_top]:
        try:
            timed.append((measure_conv_us(shape, b, kind=kind,
                                          minibatch=minibatch), b))
        except Exception:  # noqa: BLE001 — candidate failed to compile
            continue
    timed.sort(key=lambda t: t[0])
    return timed or scored
