"""Decoder-only LM assembled from the mixer/MLP substrate.

The layer stack is a ``lax.scan`` over *pattern repeats* (pattern entries
unrolled inside the body) with optional remat — HLO size stays flat whether
the model has 24 or 72 layers, which keeps the 512-device dry-run
compilable.  Hybrid archs (Jamba: 1 attention + 7 Mamba per repeat, MoE on
odd positions) are just longer patterns.

Three entry points:
  forward      — teacher-forced full sequence (train / prefill)
  decode_step  — one token with unified cache (KV / conv+ssm / wkv states)
  init_cache   — allocate the decode cache for a given (batch, max_len)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.nn import attention, mamba, mlp, moe, rwkv
from repro.nn.common import rms_norm, softmax_xent
from repro.nn.partitioning import constrain


def _dtype(cfg):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_block(key, cfg, pos: int, dtype):
    """One pattern-position layer: mixer + mlp + 2 norms."""
    mixer, mlp_kind = cfg.block_pattern[pos]
    k1, k2 = jax.random.split(key)
    p, s = {}, {}
    if mixer == "attn":
        p["mixer"], s["mixer"] = attention.init(k1, cfg, dtype)
    elif mixer == "mamba":
        p["mixer"], s["mixer"] = mamba.init(k1, cfg, dtype)
    elif mixer == "rwkv":
        p["mixer"], s["mixer"] = rwkv.init(k1, cfg, dtype)
    else:
        raise ValueError(mixer)
    if mlp_kind == "dense":
        p["mlp"], s["mlp"] = mlp.init(k2, cfg, dtype)
    elif mlp_kind == "moe":
        p["mlp"], s["mlp"] = moe.init(k2, cfg, dtype)
    elif mlp_kind == "rwkv_cm":
        p["mlp"], s["mlp"] = mlp.init_rwkv_cm(k2, cfg, dtype)
    else:
        raise ValueError(mlp_kind)
    p["norm1"] = jnp.ones((cfg.d_model,), dtype); s["norm1"] = ("embed",)
    p["norm2"] = jnp.ones((cfg.d_model,), dtype); s["norm2"] = ("embed",)
    return p, s


def init_lm(key, cfg):
    """Returns (params, specs).  Block params are stacked over pattern
    repeats (leading "layers" axis) for the scan."""
    dtype = _dtype(cfg)
    keys = jax.random.split(key, 3)
    params, specs = {}, {}
    params["embed"] = jax.random.normal(
        keys[0], (cfg.vocab, cfg.d_model), dtype) * 0.02
    specs["embed"] = ("vocab", "embed")
    params["final_norm"] = jnp.ones((cfg.d_model,), dtype)
    specs["final_norm"] = ("embed",)
    if not cfg.tie_embeddings:
        params["head"] = jax.random.normal(
            keys[1], (cfg.d_model, cfg.vocab), dtype) * 0.02
        specs["head"] = ("embed", "vocab")

    reps = cfg.pattern_repeats
    blocks, bspecs = {}, {}
    for pos in range(len(cfg.block_pattern)):
        bkeys = jax.random.split(jax.random.fold_in(keys[2], pos), reps)
        stacked = jax.vmap(lambda k: init_block(k, cfg, pos, dtype)[0])(bkeys)
        _, spec = init_block(bkeys[0], cfg, pos, dtype)
        blocks[str(pos)] = stacked
        bspecs[str(pos)] = jax.tree.map(
            lambda ax: ("layers",) + tuple(ax), spec,
            is_leaf=lambda x: isinstance(x, tuple))
    params["blocks"] = blocks
    specs["blocks"] = bspecs
    return params, specs


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------

def _apply_block(p, cfg, pos: int, x, positions, *, impl=None,
                 collect_state: bool = False):
    mixer, mlp_kind = cfg.block_pattern[pos]
    aux = jnp.zeros((), jnp.float32)
    state = None
    h = rms_norm(x, p["norm1"], eps=cfg.norm_eps)
    if mixer == "attn":
        if collect_state:
            y, (k, v) = attention.apply(p["mixer"], cfg, h, positions,
                                        impl=impl, return_kv=True)
            state = {"k": k, "v": v}
        else:
            y = attention.apply(p["mixer"], cfg, h, positions, impl=impl)
    elif mixer == "mamba":
        if collect_state:
            y, (cs, hs) = mamba.apply(p["mixer"], cfg, h, impl=impl,
                                      return_state=True)
            state = {"conv": cs, "ssm": hs}
        else:
            y = mamba.apply(p["mixer"], cfg, h, impl=impl)
    else:  # rwkv
        if collect_state:
            y, (xp, sw) = rwkv.apply(p["mixer"], cfg, h, return_state=True)
            state = {"x_prev": xp, "s": sw}
        else:
            y = rwkv.apply(p["mixer"], cfg, h)
    x = x + y
    h = rms_norm(x, p["norm2"], eps=cfg.norm_eps)
    if mlp_kind == "dense":
        y = mlp.apply(p["mlp"], cfg, h)
    elif mlp_kind == "moe":
        y, losses = moe.apply(p["mlp"], cfg, h)
        aux = aux + 0.01 * losses["lb_loss"] + 1e-3 * losses["z_loss"]
    else:  # rwkv channel mix
        h_prev = jnp.pad(h, ((0, 0), (1, 0), (0, 0)))[:, :-1]
        if collect_state:
            state = dict(state or {})
            state["cm_x_prev"] = h[:, -1, :]
        y = mlp.apply_rwkv_cm(p["mlp"], cfg, h, h_prev)
    x = x + y
    return x, aux, state


def forward(params, cfg, *, tokens=None, embeds=None, positions=None,
            impl=None, return_cache: bool = False, cache_len: int | None = None):
    """-> logits (B,L,V) [, cache].  ``embeds`` bypasses the token embedding
    (VLM/audio frontend stubs feed precomputed embeddings)."""
    if embeds is None:
        embeds = params["embed"][tokens]
    x = constrain(embeds, ("batch", "seq", "embed_act"))
    b, l, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(l, dtype=jnp.int32), (b, l))

    npos = len(cfg.block_pattern)

    # Inner per-block remat (patterns > 1 block): backward holds one block's
    # intermediates at a time instead of the whole repeat (Jamba: 8 layers).
    inner_ckpt = cfg.remat and npos > 1 and not return_cache

    def body(carry, layer_p):
        x, aux = carry
        states = []
        for pos in range(npos):
            def fn(pp, xx, *, _pos=pos):
                return _apply_block(pp, cfg, _pos, xx, positions, impl=impl,
                                    collect_state=return_cache)
            if inner_ckpt:
                fn = jax.checkpoint(fn)
            x, aux_i, st = fn(layer_p[str(pos)], x)
            aux = aux + aux_i
            states.append(st)
        out = _pack_states(states, cfg, cache_len) if return_cache else None
        return (x, aux), out

    if cfg.remat:
        body = jax.checkpoint(body)
    (x, aux), cache = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                                   params["blocks"])
    x = rms_norm(x, params["final_norm"], eps=cfg.norm_eps)
    head = params.get("head")
    logits = x @ (head if head is not None else params["embed"].T)
    logits = constrain(logits, ("batch", "seq", "vocab"))
    if return_cache:
        return logits, aux, cache
    return logits, aux


def _pack_states(states, cfg, cache_len):
    """Pad per-layer prefill states into decode-cache layout."""
    packed = []
    for pos, st in enumerate(states):
        if st is None:
            packed.append({})
            continue
        d = {}
        for k2, v2 in st.items():
            if k2 in ("k", "v"):
                s_max = cache_len or v2.shape[2]
                pad = s_max - v2.shape[2]
                d[k2] = jnp.pad(v2, ((0, 0), (0, 0), (0, pad), (0, 0)))
            else:
                d[k2] = v2
        packed.append(d)
    return {str(i): p for i, p in enumerate(packed)}


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

def init_cache(cfg, batch: int, max_len: int):
    """Allocate the decode cache (stacked over pattern repeats)."""
    dtype = _dtype(cfg)
    reps = cfg.pattern_repeats
    hd, nkv = cfg.head_dim, cfg.n_kv_heads
    nh = cfg.n_heads
    dh = cfg.d_model // nh
    cache = {}
    for pos, (mixer, mlp_kind) in enumerate(cfg.block_pattern):
        c = {}
        if mixer == "attn":
            c["k"] = jnp.zeros((reps, batch, nkv, max_len, hd), dtype)
            c["v"] = jnp.zeros((reps, batch, nkv, max_len, hd), dtype)
        elif mixer == "mamba":
            c["conv"] = jnp.zeros((reps, batch, cfg.d_conv - 1, cfg.d_inner),
                                  dtype)
            c["ssm"] = jnp.zeros((reps, batch, cfg.d_inner, cfg.d_state),
                                 jnp.float32)
        else:  # rwkv
            c["x_prev"] = jnp.zeros((reps, batch, cfg.d_model), dtype)
            c["s"] = jnp.zeros((reps, batch, nh, dh, dh), jnp.float32)
        if mlp_kind == "rwkv_cm":
            c["cm_x_prev"] = jnp.zeros((reps, batch, cfg.d_model), dtype)
        cache[str(pos)] = c
    return cache


def _decode_block(p, cfg, pos: int, x, cache, idx):
    mixer, mlp_kind = cfg.block_pattern[pos]
    new = {}
    h = rms_norm(x, p["norm1"], eps=cfg.norm_eps)
    if mixer == "attn":
        y, (ck, cv) = attention.decode(p["mixer"], cfg, h,
                                       (cache["k"], cache["v"]), idx)
        new["k"], new["v"] = ck, cv
    elif mixer == "mamba":
        y, (cs, hs) = mamba.decode(p["mixer"], cfg, h,
                                   (cache["conv"], cache["ssm"]))
        new["conv"], new["ssm"] = cs, hs
    else:
        y, (xp, sw) = rwkv.decode(p["mixer"], cfg, h,
                                  (cache["x_prev"], cache["s"]))
        new["x_prev"], new["s"] = xp, sw
    x = x + y
    h = rms_norm(x, p["norm2"], eps=cfg.norm_eps)
    if mlp_kind == "dense":
        y = mlp.apply(p["mlp"], cfg, h)
    elif mlp_kind == "moe":
        y, _ = moe.apply(p["mlp"], cfg, h)
    else:
        h_prev = cache["cm_x_prev"][:, None, :]
        new["cm_x_prev"] = h[:, -1, :]
        y = mlp.apply_rwkv_cm(p["mlp"], cfg, h, h_prev)
    x = x + y
    return x, new


def decode_step(params, cfg, tokens, cache, idx, *, embeds=None):
    """tokens: (B,1) [or embeds (B,1,D)]; idx: scalar position.  Returns
    (logits (B,1,V), new cache)."""
    if embeds is None:
        x = params["embed"][tokens]
    else:
        x = embeds
    npos = len(cfg.block_pattern)

    def body(x, inp):
        layer_p, layer_c = inp
        new_c = {}
        for pos in range(npos):
            x, nc = _decode_block(layer_p[str(pos)], cfg, pos, x,
                                  layer_c[str(pos)], idx)
            new_c[str(pos)] = nc
        return x, new_c

    x, new_cache = jax.lax.scan(body, x, (params["blocks"], cache))
    x = rms_norm(x, params["final_norm"], eps=cfg.norm_eps)
    head = params.get("head")
    logits = x @ (head if head is not None else params["embed"].T)
    return logits, new_cache


def lm_loss(params, cfg, tokens, labels, *, impl=None):
    logits, aux = forward(params, cfg, tokens=tokens, impl=impl)
    return softmax_xent(logits, labels) + aux


def lm_loss_embeds(params, cfg, embeds, labels, *, impl=None):
    logits, aux = forward(params, cfg, embeds=embeds, impl=impl)
    return softmax_xent(logits, labels) + aux
