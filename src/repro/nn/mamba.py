"""Mamba selective-SSM mixer (Jamba's attention-free layer).

The depthwise causal conv1d here is the one convolution on an assigned
architecture's hot path — it runs through the paper-style direct kernel
(``kernels/conv1d_causal.py``).

Selective scan: h_t = a_t ⊙ h_{t-1} + b_t with data-dependent a_t, b_t.
Implemented as a *chunked* scan (``lax.scan`` over chunks carrying h,
``associative_scan`` within a chunk) so the per-token (d_inner, d_state)
state tensor is only materialized for ``scan_chunk`` tokens at a time — the
cache-blocking idea of §II-C applied to a recurrence.  Decode is the O(1)
single-step update (what makes long_500k runnable for ssm/hybrid archs).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.nn.common import dense_init
from repro.nn.partitioning import constrain


def init(key, cfg, dtype):
    d, di, ds, dc = cfg.d_model, cfg.d_inner, cfg.d_state, cfg.d_conv
    dt_rank = max(d // 16, 1)
    ks = jax.random.split(key, 6)
    p, s = {}, {}
    p["in_proj"], s["in_proj"] = dense_init(ks[0], (d, 2 * di), ("embed", "inner"), dtype=dtype)
    p["conv_w"] = jax.random.normal(ks[1], (dc, di), dtype) * (dc ** -0.5)
    s["conv_w"] = (None, "inner")
    p["conv_b"] = jnp.zeros((di,), dtype); s["conv_b"] = ("inner",)
    p["x_proj"], s["x_proj"] = dense_init(ks[2], (di, dt_rank + 2 * ds), ("inner", None), dtype=dtype)
    p["dt_proj"], s["dt_proj"] = dense_init(ks[3], (dt_rank, di), (None, "inner"), dtype=dtype)
    p["dt_bias"] = jnp.zeros((di,), dtype); s["dt_bias"] = ("inner",)
    p["A_log"] = jnp.log(jnp.tile(jnp.arange(1, ds + 1, dtype=jnp.float32),
                                  (di, 1))).astype(dtype)
    s["A_log"] = ("inner", None)
    p["D"] = jnp.ones((di,), dtype); s["D"] = ("inner",)
    p["out_proj"], s["out_proj"] = dense_init(ks[4], (di, d), ("inner", "embed"), dtype=dtype)
    return p, s


def _ssm_inputs(p, cfg, xc):
    """xc: post-conv activations (B,L,di) -> (a, bx, C) for one chunk.
    Only ever called on chunk-sized slices (decode: L=1) so the
    (B, chunk, di, ds) tensors stay small."""
    d = cfg.d_model
    ds = cfg.d_state
    dt_rank = max(d // 16, 1)
    xc = constrain(xc, ("batch", "seq", "inner"))
    proj = xc @ p["x_proj"]                                    # (B,L,r+2s)
    dt_low, bmat, cmat = jnp.split(proj, [dt_rank, dt_rank + ds], axis=-1)
    dt = jax.nn.softplus(dt_low @ p["dt_proj"] + p["dt_bias"])  # (B,L,di)
    a_cont = -jnp.exp(p["A_log"].astype(jnp.float32))           # (di,ds)
    a = jnp.exp(dt[..., None].astype(jnp.float32) * a_cont)     # (B,L,di,ds)
    bx = (dt[..., None] * bmat[:, :, None, :]).astype(jnp.float32) \
        * xc[..., None].astype(jnp.float32)                     # (B,L,di,ds)
    a = constrain(a, ("batch", "seq", "inner", None))
    bx = constrain(bx, ("batch", "seq", "inner", None))
    return a, bx, cmat


def _fused_chunk_scan(p, cfg, xc, h0, chunk: int):
    """Chunked selective scan with the (di, ds) state tensors folded INTO
    the rematerialized chunk body: per-token state is only ever live for
    one chunk (the §II-C cache-blocking idea applied to a recurrence).
    Saves per chunk: the (B, chunk, di) input slice + the (B, di, ds)
    carry — never the (B, L, di, ds) tensors.
    Returns (y (B,L,di) f32, h_T)."""
    b, l, di = xc.shape
    if l % chunk:
        chunk = l
    nc = l // chunk
    xc_c = xc.reshape(b, nc, chunk, di).transpose(1, 0, 2, 3)

    @jax.checkpoint
    def body(h, xc_i):
        a, bx, cmat = _ssm_inputs(p, cfg, xc_i)            # chunk-sized

        def comb(x, y):
            return (x[0] * y[0], y[0] * x[1] + y[1])
        pa, pb = jax.lax.associative_scan(comb, (a, bx), axis=1)
        h_all = pa * h[:, None] + pb                       # (B,chunk,di,ds)
        h_all = constrain(h_all, ("batch", "seq", "inner", None))
        y = jnp.einsum("bcds,bcs->bcd", h_all,
                       cmat.astype(jnp.float32))           # (B,chunk,di)
        return h_all[:, -1], y

    h_t, y_c = jax.lax.scan(body, h0, xc_c)
    y = y_c.transpose(1, 0, 2, 3).reshape(b, l, di)
    return y, h_t


def apply(p, cfg, x, *, impl=None, return_state: bool = False):
    """x: (B,L,D) -> (B,L,D).  Optionally returns (conv_state, ssm_state)."""
    b, l, _ = x.shape
    di = cfg.d_inner
    xz = x @ p["in_proj"]
    xi, z = jnp.split(xz, 2, axis=-1)
    xc = ops.conv1d(xi, p["conv_w"], bias=p["conv_b"], act="silu", impl=impl)
    xc = constrain(xc, ("batch", "seq", "inner"))
    h0 = constrain(jnp.zeros((b, di, cfg.d_state), jnp.float32),
                   ("batch", "inner", None))
    y, h_t = _fused_chunk_scan(p, cfg, xc, h0, cfg.scan_chunk)
    y = y + p["D"].astype(jnp.float32) * xc.astype(jnp.float32)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    out = (y.astype(x.dtype)) @ p["out_proj"]
    if return_state:
        conv_state = xi[:, -(cfg.d_conv - 1):, :]          # (B,dc-1,di)
        return out, (conv_state.astype(x.dtype), h_t)
    return out


def decode(p, cfg, x, state):
    """One-token decode.  x: (B,1,D); state = (conv_state (B,dc-1,di),
    ssm_state (B,di,ds) f32)."""
    conv_state, h = state
    b = x.shape[0]
    xz = x @ p["in_proj"]
    xi, z = jnp.split(xz, 2, axis=-1)                      # (B,1,di)
    window = constrain(jnp.concatenate([conv_state, xi], axis=1),
                       ("batch", None, "inner"))     # (B,dc,di)
    xc = (window.astype(jnp.float32)
          * p["conv_w"].astype(jnp.float32)[None]).sum(axis=1, keepdims=True)
    xc = jax.nn.silu(xc + p["conv_b"].astype(jnp.float32)).astype(x.dtype)
    a, bx, cmat = _ssm_inputs(p, cfg, xc)                  # L=1
    h = a[:, 0] * h + bx[:, 0]                             # (B,di,ds)
    h = constrain(h, ("batch", "inner", None))
    y = jnp.einsum("bds,bs->bd", h, cmat[:, 0].astype(jnp.float32))[:, None]
    y = y + p["D"].astype(jnp.float32) * xc.astype(jnp.float32)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    out = y.astype(x.dtype) @ p["out_proj"]
    return out, (window[:, 1:, :], h)
