"""Logical-axis partitioning (MaxText-style rules) + activation sharding
constraints.

Every parameter leaf carries a tuple of logical axis names (see
``nn/common.py``); a *rules* dict maps logical names to physical mesh axes.
``to_shardings`` sanitizes the result per-leaf: a mesh axis is dropped when
the dim is not divisible by its size, and duplicate mesh axes keep their
first (highest-priority) occurrence — so one rule table serves every arch
and both mesh shapes, with graceful per-tensor fallback to replication.
"""
from __future__ import annotations

from contextlib import contextmanager

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        out = 1
        for a in axis:
            out *= mesh.shape[a]
        return out
    return mesh.shape[axis]


def sanitize_spec(axes, shape, mesh: Mesh):
    """Drop non-dividing / duplicate mesh axes; returns a valid spec tuple."""
    used = set()
    out = []
    for dim, axis in zip(shape, axes):
        if axis is None:
            out.append(None)
            continue
        flat = axis if isinstance(axis, (tuple, list)) else (axis,)
        kept = []
        size = 1
        for a in flat:
            if a in used:
                continue
            s = mesh.shape[a]
            if dim % (size * s) == 0:
                kept.append(a)
                size *= s
        for a in kept:
            used.add(a)
        if not kept:
            out.append(None)
        elif len(kept) == 1:
            out.append(kept[0])
        else:
            out.append(tuple(kept))
    return tuple(out)


def spec_for(logical_axes, shape, rules: dict, mesh: Mesh) -> P:
    axes = [rules.get(a) for a in logical_axes]
    # pad in case logical tuple is shorter than rank (stacked layers etc.)
    axes = list(axes) + [None] * (len(shape) - len(axes))
    return P(*sanitize_spec(axes[:len(shape)], shape, mesh))


def to_shardings(spec_tree, shape_tree, rules: dict, mesh: Mesh):
    """specs (tuples of logical names) x shapes -> NamedSharding tree."""
    is_spec = lambda x: isinstance(x, tuple) and all(
        a is None or isinstance(a, str) for a in x)
    return jax.tree.map(
        lambda s, shp: NamedSharding(mesh, spec_for(s, shp.shape, rules, mesh)),
        spec_tree, shape_tree, is_leaf=is_spec)


# ---------------------------------------------------------------------------
# Activation sharding constraints
# ---------------------------------------------------------------------------
# GSPMD propagation loses shardings through scan/associative_scan bodies
# (observed: Jamba's per-token SSM state replicating to TB/device).  Model
# code calls ``constrain(x, logical_axes)`` at the key activation points;
# it is a no-op unless a mesh context is active (tests and tiny runs are
# unaffected).

_ACT = {"mesh": None, "rules": None}


@contextmanager
def activation_ctx(mesh: Mesh, rules: dict):
    prev = dict(_ACT)
    _ACT["mesh"], _ACT["rules"] = mesh, rules
    try:
        yield
    finally:
        _ACT.update(prev)


def constrain(x, logical_axes):
    mesh, rules = _ACT["mesh"], _ACT["rules"]
    if mesh is None:
        return x
    spec = spec_for(logical_axes, x.shape, rules, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def activation_rules(mesh: Mesh, profile: str = "tp") -> dict:
    """Logical activation axes -> mesh axes (merged with param rules).

    Profiles (the §Perf sharding-strategy lever):
      "tp"  — Megatron-style tensor parallel over "model" (default)
      "ddp" — no tensor parallelism: batch over ALL axes, ZeRO-3 storage
      "ep"  — expert-parallel only: experts on "model", everything else DP
    """
    dall = batch_axes(mesh) + ("model",)
    if profile == "ddp":
        return {"batch": dall, "seq": None, "seq_kv": None,
                "embed_act": None, "heads": None, "kv_heads": None,
                "mlp": None, "inner": None, "expert": None, "vocab": None,
                None: None}
    if profile == "ep":
        return {"batch": dall, "seq": None, "seq_kv": None,
                "embed_act": None, "heads": None, "kv_heads": None,
                "mlp": None, "inner": None, "expert": "model",
                "vocab": None, None: None}
    return {
        "batch": batch_axes(mesh),
        "seq": None,
        "seq_kv": "model",            # decode KV cache: sequence-parallel
        "embed_act": None,            # activations replicated on embed dim
        "heads": "model", "kv_heads": "model",
        "mlp": "model", "inner": "model", "expert": "model",
        "vocab": "model",
        None: None,
    }


# ---------------------------------------------------------------------------
# Rule tables
# ---------------------------------------------------------------------------

def param_rules(*, fsdp: bool, mesh: Mesh, profile: str = "tp") -> dict:
    """Weight sharding: tensor-parallel over "model"; optionally ZeRO-3/FSDP
    over "data" (+"pod" when present) on the embed dim.  Profiles as in
    ``activation_rules``."""
    data_axes = ("pod", "data") if "pod" in mesh.shape else ("data",)
    if profile == "ddp":
        # ZeRO-3 storage over every axis; no tensor parallelism
        return {"embed": data_axes + ("model",), "heads": None,
                "kv_heads": None, "mlp": None, "inner": None,
                "expert": None, "vocab": None, "layers": None, None: None}
    if profile == "ep":
        return {"embed": data_axes if fsdp else None, "heads": None,
                "kv_heads": None, "mlp": None, "inner": None,
                "expert": "model", "vocab": None, "layers": None,
                None: None}
    fs = data_axes if fsdp else None
    return {
        "embed": fs,
        "heads": "model", "kv_heads": "model",
        "mlp": "model", "inner": "model",
        "expert": "model",
        "vocab": "model",
        "layers": None,
        None: None,
    }


def batch_axes(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.shape else ("data",)


def batch_spec(global_batch: int, mesh: Mesh, trailing=(None,)) -> P:
    """Shard the batch dim over (pod, data); fall back to replication when
    the batch is too small (long_500k, batch 1)."""
    axes = batch_axes(mesh)
    size = 1
    kept = []
    for a in axes:
        s = mesh.shape[a]
        if global_batch % (size * s) == 0:
            kept.append(a)
            size *= s
    lead = tuple(kept) if kept else None
    if isinstance(lead, tuple) and len(lead) == 1:
        lead = lead[0]
    return P(lead, *trailing)


def cache_shardings(cache_shapes, mesh: Mesh, global_batch: int):
    """Decode-cache shardings: batch over data axes; KV sequence dim over
    "model" (ring/sequence-parallel decode); ssm/wkv states shard the
    feature dim over "model"."""
    def leaf(shp):
        shape = shp.shape
        rank = len(shape)
        axes = [None] * rank
        # leading dim is always pattern-repeats (scan axis); batch is dim 1
        b_ax = batch_axes(mesh)
        size = 1
        kept = []
        for a in b_ax:
            s = mesh.shape[a]
            if shape[1] % (size * s) == 0:
                kept.append(a)
                size *= s
        if kept:
            axes[1] = tuple(kept) if len(kept) > 1 else kept[0]
        if rank == 5:      # attn KV (reps, B, nkv, S, dh): shard S
            if shape[3] % mesh.shape["model"] == 0:
                axes[3] = "model"
        elif rank >= 3:    # states (reps, B, feat, ...) : shard feat
            if shape[2] % mesh.shape["model"] == 0:
                axes[2] = "model"
        return NamedSharding(mesh, P(*axes))
    return jax.tree.map(leaf, cache_shapes)
