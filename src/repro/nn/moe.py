"""Mixture-of-Experts MLP (top-k router, grouped capacity dispatch).

GShard/Switch formulation with *dispatch groups*: tokens are grouped into
contiguous chunks of ``group_size`` within their sequence, each group gets a
local expert capacity C = S·k·cf/E, and dispatch/combine tensors are
(G, S, E, C) — total memory linear in S, sharded over (data: G, model: E),
with GSPMD inserting the all_to_all pair around the expert compute.

The routing step is the paper's §II-H *dryrun* (it computes the offset
streams); the per-expert SwiGLU is the *replay* — the Pallas streams-GMM
(kernels/moe_gmm.py) is the single-chip version of the same schedule and is
exercised in tests/benchmarks.

Aux losses: load-balancing (Switch) + router z-loss.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.nn.common import dense_init
from repro.nn.partitioning import constrain

GROUP_SIZE = 512


def init(key, cfg, dtype):
    d, dff, e = cfg.d_model, cfg.d_ff, cfg.moe.n_experts
    ks = jax.random.split(key, 4)
    p, s = {}, {}
    p["router"], s["router"] = dense_init(ks[0], (d, e), ("embed", None), dtype=dtype)
    p["w_gate"], s["w_gate"] = dense_init(
        ks[1], (e, d, dff), ("expert", "embed", "mlp"), dtype=dtype)
    p["w_up"], s["w_up"] = dense_init(
        ks[2], (e, d, dff), ("expert", "embed", "mlp"), dtype=dtype)
    p["w_down"], s["w_down"] = dense_init(
        ks[3], (e, dff, d), ("expert", "mlp", "embed"), dtype=dtype)
    return p, s


def apply(p, cfg, x):
    """x: (B,L,D) -> (out (B,L,D), aux losses dict)."""
    b, l, d = x.shape
    e, k = cfg.moe.n_experts, cfg.moe.top_k
    s = min(GROUP_SIZE, l)
    if l % s:
        s = l
    g = (b * l) // s
    xg = x.reshape(g, s, d)

    logits = (xg @ p["router"].astype(x.dtype)).astype(jnp.float32)  # (G,S,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)          # (G,S,k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    cap = max(int(cfg.moe.capacity_factor * s * k / e), 1)

    # --- dryrun: per-group dispatch streams ---------------------------------
    combine = jnp.zeros((g, s, e, cap), dtype=jnp.float32)
    dispatch = jnp.zeros((g, s, e, cap), dtype=jnp.float32)
    counts = jnp.zeros((g, e), dtype=jnp.float32)          # queue fill
    for slot in range(k):
        onehot = jax.nn.one_hot(gate_idx[..., slot], e, dtype=jnp.float32)
        pos_in_slot = jnp.cumsum(onehot, axis=1) - onehot  # (G,S,E)
        pos = ((pos_in_slot + counts[:, None, :]) * onehot).sum(-1)
        pos = pos.astype(jnp.int32)                        # (G,S)
        keep = pos < cap
        posc = jnp.minimum(pos, cap - 1)
        mask = (onehot * keep[..., None])[..., None] \
            * jax.nn.one_hot(posc, cap, dtype=jnp.float32)[..., None, :]
        dispatch = dispatch + mask
        combine = combine + mask * gate_vals[..., slot][..., None, None]
        counts = counts + (onehot * keep[..., None]).sum(axis=1)

    dispatch = dispatch.astype(x.dtype)                    # (G,S,E,C)
    dispatch = constrain(dispatch, ("batch", "seq", "expert", None))
    combine = constrain(combine, ("batch", "seq", "expert", None))
    # --- replay: batched expert SwiGLU --------------------------------------
    xe = jnp.einsum("gsec,gsd->gecd", dispatch, xg)        # (G,E,C,D)
    xe = constrain(xe, ("batch", "expert", None, None))
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xe, p["w_gate"]))
    u = jnp.einsum("gecd,edf->gecf", xe, p["w_up"])
    h = constrain(h, ("batch", "expert", None, "mlp"))
    u = constrain(u, ("batch", "expert", None, "mlp"))
    ye = jnp.einsum("gecf,efd->gecd", h * u, p["w_down"])  # (G,E,C,D)
    ye = constrain(ye, ("batch", "expert", None, None))
    out = jnp.einsum("gsec,gecd->gsd", combine.astype(x.dtype), ye)

    # --- aux losses ---------------------------------------------------------
    me = probs.mean(axis=(0, 1))                           # mean router prob
    ce = jax.nn.one_hot(gate_idx[..., 0], e).mean(axis=(0, 1))
    lb_loss = e * jnp.sum(me * ce)
    z_loss = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    return out.reshape(b, l, d), {"lb_loss": lb_loss, "z_loss": z_loss}
