"""Shared NN primitives: norms, rotary embeddings, init helpers.

Params are plain dicts; every init function returns ``(params, specs)``
where ``specs`` mirrors the param tree with tuples of *logical* axis names
("embed", "heads", "mlp", "vocab", "expert", ...).  The mesh layer maps
logical names to physical mesh axes via per-config rules (MaxText-style),
so sharding strategy changes are config edits.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rms_norm(x, scale, *, eps: float = 1e-5):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * scale.astype(jnp.float32)).astype(dtype)


def init_rms_norm(d: int, dtype=jnp.float32):
    return jnp.ones((d,), dtype), ("embed",)


def dense_init(key, shape, logical_axes, *, scale=None, dtype=jnp.float32):
    fan_in = shape[0]
    scale = scale if scale is not None else fan_in ** -0.5
    return (jax.random.normal(key, shape, dtype) * scale), logical_axes


def rope_freqs(d_head: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, d_head, 2, dtype=jnp.float32)
                            / d_head))


def apply_rope(x, positions, *, theta: float = 1e4):
    """x: (..., L, Dh), positions: (..., L) int32."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                           # (Dh/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., L, Dh/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


def softmax_xent(logits, labels, *, z_loss: float = 0.0):
    """Mean token cross-entropy; labels == -1 are masked out."""
    mask = labels >= 0
    labels = jnp.maximum(labels, 0)
    logz = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    gold = jnp.take_along_axis(logits.astype(jnp.float32),
                               labels[..., None], axis=-1)[..., 0]
    loss = (logz - gold) * mask
    if z_loss:
        loss = loss + z_loss * (logz ** 2) * mask
    return loss.sum() / jnp.maximum(mask.sum(), 1)
