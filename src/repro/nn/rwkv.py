"""RWKV-6 (Finch) time-mix with data-dependent decay + channel mix.

Attention-free: the WKV recurrence carries an (H, dh, dh) state —
S_{t} = diag(w_t) S_{t-1} + k_t ⊗ v_t ;  y_t = (S_{t-1} + diag(u) k_t ⊗ v_t) r_t
Training uses a chunked ``lax.scan`` over time; decode is the O(1) update
(long_500k runs for this arch).  Token shift is a size-1 temporal shift —
*not* a convolution (see DESIGN.md §5 on technique applicability).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.nn.common import dense_init
from repro.nn.partitioning import constrain

_LORA = 64


def init(key, cfg, dtype):
    d = cfg.d_model
    ks = jax.random.split(key, 8)
    p, s = {}, {}
    for i, nm in enumerate(("w_r", "w_k", "w_v", "w_g")):
        p[nm], s[nm] = dense_init(ks[i], (d, d), ("embed", "heads"), dtype=dtype)
    p["w_o"], s["w_o"] = dense_init(ks[4], (d, d), ("heads", "embed"), dtype=dtype)
    # data-dependent decay LoRA (the Finch contribution)
    p["w_dec_a"], s["w_dec_a"] = dense_init(ks[5], (d, _LORA), ("embed", None), dtype=dtype)
    p["w_dec_b"], s["w_dec_b"] = dense_init(ks[6], (_LORA, d), (None, "heads"), dtype=dtype)
    p["dec_bias"] = jnp.full((d,), -6.0, dtype); s["dec_bias"] = ("heads",)
    for nm in ("mu_r", "mu_k", "mu_v", "mu_g", "mu_w"):
        p[nm] = jnp.full((d,), 0.5, dtype); s[nm] = ("embed",)
    nh = cfg.n_heads
    dh = d // nh
    p["u"] = jnp.zeros((nh, dh), dtype); s["u"] = ("heads", None)
    p["ln_x"] = jnp.ones((d,), dtype); s["ln_x"] = ("heads",)
    return p, s


def _mix(x, x_prev, mu):
    return x + mu * (x_prev - x)


def _proj_rkvgw(p, cfg, x, x_prev):
    nh = cfg.n_heads
    b, l, d = x.shape
    dh = d // nh
    r = _mix(x, x_prev, p["mu_r"]) @ p["w_r"]
    k = _mix(x, x_prev, p["mu_k"]) @ p["w_k"]
    v = _mix(x, x_prev, p["mu_v"]) @ p["w_v"]
    g = jax.nn.silu(_mix(x, x_prev, p["mu_g"]) @ p["w_g"])
    xw = _mix(x, x_prev, p["mu_w"])
    dec = jnp.tanh(xw @ p["w_dec_a"]) @ p["w_dec_b"] + p["dec_bias"]
    w = jnp.exp(-jnp.exp(dec.astype(jnp.float32)))         # (B,L,D) in (0,1)
    hshape = (b, l, nh, dh)
    cons = lambda t: constrain(t.reshape(hshape),
                               ("batch", "seq", "heads", None))
    return (cons(r), cons(k), cons(v), g, cons(w))


def _wkv_scan(r, k, v, w, u, s0, *, chunk: int = 64):
    """Chunked WKV recurrence.  r,k,v,w: (B,L,H,dh) (w f32); u: (H,dh);
    s0: (B,H,dh,dh) f32.  Returns (y (B,L,H,dh) f32, s_T).

    The outer scan carries the (dh, dh) state once per *chunk*; the chunk
    body (rematerialized) runs the per-token recurrence — so AD saves
    O(L/chunk) states instead of O(L)."""
    b, l, h, dh = r.shape
    if l % chunk:
        chunk = l
    nc = l // chunk

    def to_chunks(t):
        return t.reshape(b, nc, chunk, h, dh).transpose(1, 0, 2, 3, 4)

    seq = tuple(to_chunks(t.astype(jnp.float32)) for t in (r, k, v)) \
        + (to_chunks(w),)

    @jax.checkpoint
    def chunk_body(s, inp):
        rc, kc, vc, wc = inp                               # (B,chunk,H,dh)

        def step(s, t):
            rt, kt, vt, wt = t                             # (B,H,dh)
            kv = kt[..., :, None] * vt[..., None, :]       # (B,H,dh,dh)
            kv = constrain(kv, ("batch", "heads", None, None))
            y = jnp.einsum("bhij,bhi->bhj",
                           s + u[None, :, :, None] * kv, rt)
            s = wt[..., None] * s + kv
            s = constrain(s, ("batch", "heads", None, None))
            return s, y

        trans = lambda t: t.transpose(1, 0, 2, 3)          # (chunk,B,H,dh)
        s, ys = jax.lax.scan(step, s, (trans(rc), trans(kc),
                                       trans(vc), trans(wc)))
        return s, ys.transpose(1, 0, 2, 3)

    s_t, ys = jax.lax.scan(chunk_body, s0, seq)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, l, h, dh)
    return y, s_t


def apply(p, cfg, x, *, return_state: bool = False):
    b, l, d = x.shape
    nh = cfg.n_heads
    dh = d // nh
    x_prev = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    r, k, v, g, w = _proj_rkvgw(p, cfg, x, x_prev)
    s0 = constrain(jnp.zeros((b, nh, dh, dh), jnp.float32),
                   ("batch", "heads", None, None))
    y, s_t = _wkv_scan(r, k, v, w, p["u"].astype(jnp.float32), s0)
    y = y.reshape(b, l, d)
    # group-norm per head (ln_x), then gate and output-project
    y = y.reshape(b, l, nh, dh)
    y = (y - y.mean(-1, keepdims=True)) \
        * jax.lax.rsqrt(y.var(-1, keepdims=True) + 1e-5)
    y = y.reshape(b, l, d) * p["ln_x"].astype(jnp.float32)
    out = (y.astype(x.dtype) * g) @ p["w_o"]
    if return_state:
        return out, (x[:, -1, :], s_t)
    return out


def decode(p, cfg, x, state):
    """x: (B,1,D); state = (x_prev (B,D), s (B,H,dh,dh) f32)."""
    xp_last, s = state
    b, _, d = x.shape
    nh = cfg.n_heads
    dh = d // nh
    x_prev = xp_last[:, None, :]
    r, k, v, g, w = _proj_rkvgw(p, cfg, x, x_prev)
    rt, kt, vt, wt = (t[:, 0].astype(jnp.float32) for t in (r, k, v, w))
    u = p["u"].astype(jnp.float32)
    kv = kt[..., :, None] * vt[..., None, :]
    y = jnp.einsum("bhij,bhi->bhj", s + u[None, :, :, None] * kv, rt)
    s = wt[..., None] * s + kv
    y = (y - y.mean(-1, keepdims=True)) \
        * jax.lax.rsqrt(y.var(-1, keepdims=True) + 1e-5)
    y = y.reshape(b, 1, d) * p["ln_x"].astype(jnp.float32)
    out = (y.astype(x.dtype) * g) @ p["w_o"]
    return out, (x[:, -1, :], s)
