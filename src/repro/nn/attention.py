"""GQA attention (optional QKV bias, qk-norm) with train, prefill and
decode paths.  Train/prefill use the blocked flash kernel (ops.attention);
decode is the memory-bound KV-cache GEMV, left to XLA.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.nn.common import apply_rope, dense_init, rms_norm
from repro.nn.partitioning import constrain


def init(key, cfg, dtype):
    d, hd = cfg.d_model, cfg.head_dim
    nh, nkv = cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 4)
    p, s = {}, {}
    p["wq"], s["wq"] = dense_init(ks[0], (d, nh * hd), ("embed", "heads"), dtype=dtype)
    p["wk"], s["wk"] = dense_init(ks[1], (d, nkv * hd), ("embed", "kv_heads"), dtype=dtype)
    p["wv"], s["wv"] = dense_init(ks[2], (d, nkv * hd), ("embed", "kv_heads"), dtype=dtype)
    p["wo"], s["wo"] = dense_init(ks[3], (nh * hd, d), ("heads", "embed"), dtype=dtype)
    if cfg.qkv_bias:
        for nm, width in (("bq", nh * hd), ("bk", nkv * hd), ("bv", nkv * hd)):
            p[nm] = jnp.zeros((width,), dtype)
            s[nm] = ("heads" if nm == "bq" else "kv_heads",)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dtype); s["q_norm"] = (None,)
        p["k_norm"] = jnp.ones((hd,), dtype); s["k_norm"] = (None,)
    return p, s


def _project(p, cfg, x):
    b, l, d = x.shape
    hd, nh, nkv = cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, l, nh, hd).transpose(0, 2, 1, 3)
    k = k.reshape(b, l, nkv, hd).transpose(0, 2, 1, 3)
    v = v.reshape(b, l, nkv, hd).transpose(0, 2, 1, 3)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], eps=cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], eps=cfg.norm_eps)
    q = constrain(q, ("batch", "heads", "seq", None))
    k = constrain(k, ("batch", "kv_heads", "seq", None))
    v = constrain(v, ("batch", "kv_heads", "seq", None))
    return q, k, v


def apply(p, cfg, x, positions, *, impl=None, return_kv: bool = False):
    """Full-sequence causal attention.  x: (B,L,D)."""
    b, l, _ = x.shape
    q, k, v = _project(p, cfg, x)
    q = apply_rope(q, positions[:, None, :], theta=cfg.rope_theta)
    k = apply_rope(k, positions[:, None, :], theta=cfg.rope_theta)
    o = ops.attention(q, k, v, causal=True, impl=impl)
    o = constrain(o, ("batch", "heads", "seq", None))
    o = o.transpose(0, 2, 1, 3).reshape(b, l, cfg.n_heads * cfg.head_dim)
    out = o @ p["wo"]
    if return_kv:
        return out, (k, v)
    return out


def decode(p, cfg, x, cache_kv, idx):
    """One-token decode.  x: (B,1,D); cache_kv = (K,V) with K/V
    (B,nkv,S,dh); idx: current position — scalar int32 (lockstep batch) or
    (B,) int32 (continuous batching: per-lane positions).  Returns
    (out (B,1,D), new cache)."""
    b = x.shape[0]
    hd, nh, nkv = cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
    ck, cv = cache_kv
    s = ck.shape[2]
    per_lane = jnp.ndim(idx) == 1
    pos = (idx[:, None].astype(jnp.int32) if per_lane
           else jnp.full((b, 1), idx, dtype=jnp.int32))
    q, k, v = _project(p, cfg, x)
    q = apply_rope(q, pos[:, None, :], theta=cfg.rope_theta)
    k = apply_rope(k, pos[:, None, :], theta=cfg.rope_theta)
    if per_lane:
        upd = jax.vmap(lambda c, kk, ii: jax.lax.dynamic_update_slice(
            c, kk, (0, ii, 0)))
        ck = upd(ck, k.astype(ck.dtype), idx)
        cv = upd(cv, v.astype(cv.dtype), idx)
    else:
        ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype),
                                          (0, 0, idx, 0))
        cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype),
                                          (0, 0, idx, 0))
    ck = constrain(ck, ("batch", "kv_heads", "seq_kv", None))
    cv = constrain(cv, ("batch", "kv_heads", "seq_kv", None))
    rep = nh // nkv
    qg = q.reshape(b, nkv, rep, hd)                       # (B,nkv,rep,dh)
    logits = jnp.einsum("bgrd,bgsd->bgrs", qg.astype(jnp.float32),
                        ck.astype(jnp.float32)) * (hd ** -0.5)
    bound = idx[:, None, None, None] if per_lane else idx
    mask = jnp.arange(s)[None, None, None, :] <= bound
    logits = jnp.where(mask, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bgrs,bgsd->bgrd", probs, cv.astype(jnp.float32))
    o = o.reshape(b, 1, nh * hd).astype(x.dtype)
    return o @ p["wo"], (ck, cv)
