"""Model configuration — one frozen dataclass drives every architecture.

``block_pattern`` is the repeating layer pattern; each entry is
``(mixer, mlp)`` with mixer ∈ {"attn", "mamba", "rwkv"} and mlp ∈ {"dense",
"moe", "rwkv_cm"}.  ``n_layers`` must be a multiple of the pattern length —
the decoder scans over pattern repeats (keeps HLO size flat at any depth).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class MoECfg:
    n_experts: int
    top_k: int
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class ModelCfg:
    name: str
    family: str                   # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int | None = None     # default d_model // n_heads
    qkv_bias: bool = False
    qk_norm: bool = False
    moe: MoECfg | None = None
    block_pattern: tuple = (("attn", "dense"),)
    rope_theta: float = 1e6
    norm_eps: float = 1e-5
    tie_embeddings: bool = True
    frontend: str | None = None   # "audio" | "vision" stub (see DESIGN.md)
    # mamba
    d_conv: int = 4
    d_state: int = 16
    expand: int = 2
    # execution
    dtype: str = "bfloat16"
    scan_chunk: int = 128         # ssm chunked-scan length
    remat: bool = True
    sub_quadratic: bool = False   # True for ssm/hybrid: long_500k is runnable
    fsdp: bool = False            # ZeRO-3 param sharding over the data axes
    factored_opt: bool = False    # Adafactor-style second moment (100B+ archs)
    accum_steps: int = 1          # gradient-accumulation microbatches
    sharding: str = "tp"          # sharding profile: tp | ddp | ep

    @property
    def head_dim(self) -> int:
        return self.d_head or (self.d_model // self.n_heads)

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def pattern_repeats(self) -> int:
        assert self.n_layers % len(self.block_pattern) == 0, \
            (self.n_layers, len(self.block_pattern))
        return self.n_layers // len(self.block_pattern)

    def param_count(self) -> int:
        """Analytic parameter count (embeddings + per-layer)."""
        d, dff, v = self.d_model, self.d_ff, self.vocab
        hd, nh, nkv = self.head_dim, self.n_heads, self.n_kv_heads
        total = v * d * (1 if self.tie_embeddings else 2)
        for mixer, mlp in self.block_pattern:
            reps = self.pattern_repeats
            if mixer == "attn":
                mix = d * (nh * hd) + 2 * d * (nkv * hd) + (nh * hd) * d
            elif mixer == "mamba":
                di, ds = self.d_inner, self.d_state
                mix = d * 2 * di + di * self.d_conv + di * (2 * ds + 2) \
                    + di * d + di * ds
            elif mixer == "rwkv":
                mix = 4 * d * d + d * d  # r,k,v,g(,w lora approx) + out
            else:
                raise ValueError(mixer)
            if mlp == "dense":
                ff = 3 * d * dff
            elif mlp == "moe":
                ff = 3 * d * dff * self.moe.n_experts + d * self.moe.n_experts
            elif mlp == "rwkv_cm":
                ff = 2 * d * dff
            else:
                raise ValueError(mlp)
            total += reps * (mix + ff)
        return total

    def expert_param_count(self) -> int:
        """Parameters living in expert weights (EP-shardable)."""
        if self.moe is None:
            return 0
        moe_layers = sum(1 for _, m in self.block_pattern if m == "moe") \
            * self.pattern_repeats
        return moe_layers * 3 * self.d_model * self.d_ff * self.moe.n_experts

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k of n_experts)."""
        if self.moe is None:
            return self.param_count()
        d, dff = self.d_model, self.d_ff
        full = self.param_count()
        moe_layers = sum(1 for _, m in self.block_pattern if m == "moe") \
            * self.pattern_repeats
        inactive = moe_layers * 3 * d * dff * (self.moe.n_experts
                                               - self.moe.top_k)
        return full - inactive
