"""MLP blocks: SwiGLU (dense LMs) and RWKV channel-mix."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.nn.common import dense_init
from repro.nn.partitioning import constrain


def init(key, cfg, dtype):
    d, dff = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    p, s = {}, {}
    p["w_gate"], s["w_gate"] = dense_init(ks[0], (d, dff), ("embed", "mlp"), dtype=dtype)
    p["w_up"], s["w_up"] = dense_init(ks[1], (d, dff), ("embed", "mlp"), dtype=dtype)
    p["w_down"], s["w_down"] = dense_init(ks[2], (dff, d), ("mlp", "embed"), dtype=dtype)
    return p, s


def apply(p, cfg, x):
    g = jax.nn.silu(x @ p["w_gate"])
    u = x @ p["w_up"]
    g = constrain(g, ("batch", "seq", "mlp"))
    u = constrain(u, ("batch", "seq", "mlp"))
    return (g * u) @ p["w_down"]


def init_rwkv_cm(key, cfg, dtype):
    d, dff = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 2)
    p, s = {}, {}
    p["mu_k"] = jnp.full((d,), 0.5, dtype); s["mu_k"] = ("embed",)
    p["w_k"], s["w_k"] = dense_init(ks[0], (d, dff), ("embed", "mlp"), dtype=dtype)
    p["w_v"], s["w_v"] = dense_init(ks[1], (dff, d), ("mlp", "embed"), dtype=dtype)
    return p, s


def apply_rwkv_cm(p, cfg, x, x_prev):
    """RWKV channel mix.  x_prev is the token-shifted x (B,L,D)."""
    xk = x + p["mu_k"] * (x_prev - x)
    k = jnp.square(jax.nn.relu(xk @ p["w_k"]))
    k = constrain(k, ("batch", "seq", "mlp"))
    return k @ p["w_v"]
