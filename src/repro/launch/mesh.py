"""Production mesh construction.

Kept as functions (never module-level constants) so importing this module
never touches jax device state.  The dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import to materialize the placeholder devices.
"""
from __future__ import annotations

import jax


def shard_map_fn():
    """The ``shard_map`` entry point across jax versions (pre-0.5 keeps it
    in ``jax.experimental``).  Shared by the GxM executor and the
    data-parallel training step."""
    sm = getattr(jax, "shard_map", None)
    if sm is None:
        from jax.experimental.shard_map import shard_map as sm
    return sm


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(*, model: int = 1, data: int | None = None):
    """Tiny mesh over whatever devices exist (tests / local runs).

    ``data`` caps the data-parallel width to a subset of the available
    devices — the elastic re-scale path builds a *smaller* mesh in the same
    process this way (``train.fault_tolerance.elastic_reshard_cnn``)."""
    import numpy as np

    devs = jax.devices()
    n = len(devs)
    model = min(model, n)
    width = n // model if data is None else min(data, n // model)
    assert width >= 1, (n, model, data)
    grid = np.asarray(devs[:width * model], dtype=object).reshape(
        width, model)
    return jax.sharding.Mesh(grid, ("data", "model"))


def data_axis_size(mesh) -> int:
    """Width of the data-parallel axis (1 when the mesh has none)."""
    return int(mesh.shape.get("data", 1))
