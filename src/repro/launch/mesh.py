"""Production mesh construction.

Kept as functions (never module-level constants) so importing this module
never touches jax device state.  The dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import to materialize the placeholder devices.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(*, model: int = 1):
    """Tiny mesh over whatever devices exist (tests / local runs)."""
    n = len(jax.devices())
    model = min(model, n)
    return jax.make_mesh((n // model, model), ("data", "model"))
