"""Aggregate dry-run JSONs into the EXPERIMENTS.md roofline tables.

  python -m repro.launch.report [--dir experiments/dryrun] [--mesh single]
"""
from __future__ import annotations

import argparse
import json
import pathlib


def fmt_time(s):
    if s == 0:
        return "0"
    if s < 1e-3:
        return f"{s*1e6:.0f}us"
    if s < 1:
        return f"{s*1e3:.1f}ms"
    return f"{s:.2f}s"


def load(dirpath):
    recs = []
    for p in sorted(pathlib.Path(dirpath).glob("*.json")):
        recs.append(json.loads(p.read_text()))
    return recs


def mesh_dims(mesh):
    return ((512, 32, 16) if mesh == "multi" else (256, 16, 16))


def roofline_table(recs, mesh="single"):
    """Analytic three-term roofline (primary; see launch/analytic.py for why
    the XLA-CPU artifact numbers can't be used directly) merged with the
    compiled artifact's memory + collective-schedule evidence."""
    from repro.configs import SHAPES, get_config
    from repro.launch import analytic as A
    chips, dp, mp = mesh_dims(mesh)
    rows = []
    for r in recs:
        if r["mesh"] != mesh:
            continue
        if not r["applicable"]:
            rows.append((r["arch"], r["shape"], "SKIP", "", "", "", "", "",
                         r["skip_reason"][:48]))
            continue
        cfg = get_config(r["arch"])
        shape = SHAPES[r["shape"]]
        t = A.analytic_roofline(cfg, shape, chips=chips, model_par=mp,
                                data_par=dp)
        rows.append((
            r["arch"], r["shape"],
            fmt_time(t.compute_s), fmt_time(t.memory_s),
            fmt_time(t.collective_s), t.dominant,
            f"{A.mfu(cfg, shape, t, chips):.3f}",
            r["roofline"]["collective_count"],
            f"{r['memory']['total_per_device_bytes']/2**30:.1f}GiB",
        ))
    hdr = ("arch", "shape", "compute", "memory", "collective", "dominant",
           "MFU@roofline", "n_coll(HLO)", "mem/dev")
    return hdr, rows


def to_markdown(hdr, rows):
    out = ["| " + " | ".join(hdr) + " |",
           "|" + "|".join("---" for _ in hdr) + "|"]
    for row in rows:
        out.append("| " + " | ".join(str(c) for c in row) + " |")
    return "\n".join(out)


def dryrun_table(recs):
    out = []
    for r in recs:
        if not r["applicable"]:
            continue
        m = r["memory"]
        rf = r["roofline"]
        out.append((r["arch"], r["shape"], r["mesh"], r["chips"],
                    f"{m['total_per_device_bytes']/2**30:.2f}",
                    f"{rf['flops_per_device']/1e12:.2f}",
                    f"{rf['collective_wire_bytes']/2**20:.1f}",
                    rf["collective_count"], f"{r['compile_s']:.0f}s"))
    hdr = ("arch", "shape", "mesh", "chips", "GiB/dev", "TFLOP/dev",
           "coll MiB/dev", "n_coll", "compile")
    return hdr, out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--kind", choices=("roofline", "dryrun"),
                    default="roofline")
    args = ap.parse_args()
    recs = load(args.dir)
    if args.kind == "roofline":
        hdr, rows = roofline_table(recs, args.mesh)
    else:
        hdr, rows = dryrun_table(recs)
    print(to_markdown(hdr, rows))


if __name__ == "__main__":
    main()
