"""Bonus dry-run: the paper's own workload (ResNet-50 training through the
GxM executor) lowered on the production meshes — data-parallel over
(pod, data), weights replicated, SGD-momentum update, gradient all-reduce
implicit in the sharded autodiff.  This is Fig. 9's configuration at
256/512 chips instead of 16 nodes.

  python -m repro.launch.dryrun_cnn [--mesh single|multi] [--batch 256]
"""
import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("REPRO_EXTRA_XLA_FLAGS", ""))

import argparse  # noqa: E402
import json      # noqa: E402
import pathlib   # noqa: E402
import time      # noqa: E402

import jax       # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.graph import GxM, resnet50  # noqa: E402
from repro.launch import roofline as rl  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402

RESNET50_FLOPS_PER_IMG = 3 * 4.1e9   # fwd+bwd+wu


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", choices=("single", "multi"), default="single")
    ap.add_argument("--batch", type=int, default=256)
    ap.add_argument("--image", type=int, default=224)
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    mesh = make_production_mesh(multi_pod=args.mesh == "multi")
    chips = 1
    for v in mesh.shape.values():
        chips *= v
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)

    m = GxM(resnet50(num_classes=1000), impl="xla", num_classes=1000)
    params_shapes = jax.eval_shape(
        lambda k: m.init(k), jax.random.PRNGKey(0))
    mom_shapes = params_shapes   # SGD momentum buffers mirror params

    def train_step(params, mom, batch):
        loss, grads = jax.value_and_grad(m.loss)(params, batch)
        new_mom = jax.tree.map(lambda v, g: 0.9 * v + g, mom, grads)
        new_params = jax.tree.map(lambda p, v: p - 0.1 * v, params, new_mom)
        return new_params, new_mom, loss

    rep = NamedSharding(mesh, P())
    param_sh = jax.tree.map(lambda _: rep, params_shapes)
    batch_sh = {"image": NamedSharding(mesh, P(batch_axes, None, None, None)),
                "label": NamedSharding(mesh, P(batch_axes))}
    batch_shapes = {
        "image": jax.ShapeDtypeStruct(
            (args.batch, args.image, args.image, 3), jnp.float32),
        "label": jax.ShapeDtypeStruct((args.batch,), jnp.int32)}

    t0 = time.time()
    lowered = jax.jit(
        train_step,
        in_shardings=(param_sh, param_sh, batch_sh),
        out_shardings=(param_sh, param_sh, None),
        donate_argnums=(0, 1),
    ).lower(params_shapes, mom_shapes, batch_shapes)
    compiled = lowered.compile()
    dt = time.time() - t0
    print(compiled.memory_analysis())
    print({k: v for k, v in rl.cost_analysis_dict(compiled).items()
           if k in ("flops", "bytes accessed")})

    colls = rl.parse_collectives(compiled.as_text(), default_group=chips)
    ma = compiled.memory_analysis()
    n_params = sum(x.size for x in jax.tree.leaves(params_shapes))
    rec = {
        "arch": "resnet50-gxm", "shape": f"train_{args.batch}x{args.image}",
        "mesh": args.mesh, "chips": chips, "applicable": True,
        "compile_s": round(dt, 1),
        "memory": {"total_per_device_bytes":
                   ma.argument_size_in_bytes + ma.output_size_in_bytes
                   + ma.temp_size_in_bytes - ma.alias_size_in_bytes},
        "collectives": {"count": colls.count,
                        "wire_bytes": colls.wire_bytes,
                        "by_kind": colls.by_kind},
        "n_params": n_params,
        "grad_allreduce_model_s":
            2 * (chips - 1) / chips * n_params * 4 / rl.ICI_BW,
        "compute_model_s":
            args.batch * RESNET50_FLOPS_PER_IMG / (chips * rl.PEAK_FLOPS
                                                   * 0.55),
    }
    outdir = pathlib.Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)
    (outdir / f"resnet50-gxm__train__{args.mesh}.json").write_text(
        json.dumps(rec, indent=1))
    print(json.dumps({k: v for k, v in rec.items() if k != "memory"},
                     indent=1))


if __name__ == "__main__":
    main()
