"""Analytic (napkin-math) roofline model per (arch × shape × mesh).

Why this exists: XLA *CPU* ``cost_analysis()`` does not multiply while-loop
bodies by trip count, so scan-over-layers models under-report FLOPs/bytes by
~n_layers (verified: useful_ratio > 1 in the raw sweep).  The dry-run
artifact remains the evidence that the program compiles, fits, and which
collectives appear; the three roofline *terms* are computed here from the
model config and sharding — the same napkin math the §Perf hypothesis loop
uses.  All formulas per device per step.

Conventions: bf16 activations/params (2B), f32 accumulators.  Ring
collective on n participants moves 2(n-1)/n x payload for all-reduce,
(n-1)/n for all-gather / reduce-scatter.
"""
from __future__ import annotations

import dataclasses

from repro.launch.roofline import HBM_BW, ICI_BW, PEAK_FLOPS

BP = 2      # bytes per param / activation element (bf16)


@dataclasses.dataclass
class Terms:
    compute_s: float
    memory_s: float
    collective_s: float
    flops: float
    hbm_bytes: float
    wire_bytes: float
    breakdown: dict

    @property
    def dominant(self):
        d = {"compute": self.compute_s, "memory": self.memory_s,
             "collective": self.collective_s}
        return max(d, key=d.get)

    @property
    def step_time_s(self):
        return max(self.compute_s, self.memory_s, self.collective_s)


def _ring(n, kind="ar"):
    if n <= 1:
        return 0.0
    return (2 * (n - 1) / n) if kind == "ar" else ((n - 1) / n)


def _mixer_flops_per_tok(cfg, mixer, ctx: float):
    d, hd = cfg.d_model, cfg.head_dim
    nh, nkv = cfg.n_heads, cfg.n_kv_heads
    if mixer == "attn":
        proj = 2 * (d * nh * hd + 2 * d * nkv * hd + nh * hd * d)
        attn = 4 * nh * hd * ctx            # QK^T + PV
        return proj + attn
    if mixer == "mamba":
        di, ds = cfg.d_inner, cfg.d_state
        dtr = max(cfg.d_model // 16, 1)
        proj = 2 * (d * 2 * di + di * (dtr + 2 * ds) + dtr * di + di * d)
        scan = 10 * di * ds                 # a,bx,recurrence,y-einsum
        conv = 2 * cfg.d_conv * di
        return proj + scan + conv
    if mixer == "rwkv":
        lora = 2 * (d * 64 + 64 * d)
        proj = 2 * 5 * d * d + lora
        wkv = 6 * d * (d // cfg.n_heads)    # state update + readout
        return proj + wkv
    raise ValueError(mixer)


def _mlp_flops_per_tok(cfg, mlp):
    d, dff = cfg.d_model, cfg.d_ff
    if mlp == "dense":
        return 2 * 3 * d * dff
    if mlp == "moe":
        k, cf = cfg.moe.top_k, cfg.moe.capacity_factor
        return 2 * 3 * d * dff * k * cf + 2 * d * cfg.moe.n_experts
    if mlp == "rwkv_cm":
        return 2 * 2 * d * dff
    raise ValueError(mlp)


def fwd_flops_per_token(cfg, ctx: float) -> float:
    reps = cfg.pattern_repeats
    per_layer = sum(_mixer_flops_per_tok(cfg, mx, ctx)
                    + _mlp_flops_per_tok(cfg, ml)
                    for mx, ml in cfg.block_pattern)
    return reps * per_layer


def analytic_roofline(cfg, shape, *, chips: int, model_par: int,
                      data_par: int, profile: str | None = None,
                      quantized: bool = False) -> Terms:
    profile = profile or cfg.sharding
    d, v = cfg.d_model, cfg.vocab
    p_total = cfg.param_count()
    p_expert = cfg.expert_param_count()

    if profile == "ddp":
        # no tensor parallelism: batch over every axis, ZeRO-3 storage
        data_par = chips
        model_par_dense = 1
        p_model_shard = p_total                    # params used per device
        fsdp_par = chips
    elif profile == "ep":
        # dense parts data-parallel over every axis; experts on "model"
        data_par = chips
        model_par_dense = 1
        p_model_shard = (p_total - p_expert) + p_expert / model_par
        fsdp_par = data_par if cfg.fsdp else 1
    else:
        model_par_dense = model_par
        p_model_shard = p_total / model_par
        fsdp_par = data_par if cfg.fsdp else 1
    p_shard = p_model_shard / fsdp_par             # params held per device

    if shape.kind == "decode":
        t_glob = shape.global_batch
        ctx = shape.seq_len
    else:
        t_glob = shape.global_batch * shape.seq_len
        ctx = shape.seq_len / 2                    # causal average
    t_loc = t_glob / min(data_par, max(shape.global_batch, 1))
    if shape.global_batch < data_par:              # batch unshardable
        t_loc = t_glob

    # ---------------- FLOPs -------------------------------------------------
    fwd_tok = fwd_flops_per_token(cfg, ctx)
    logits_tok = 2 * d * v
    if shape.kind == "train":
        # fwd + bwd(2x fwd) (+1x recompute under remat); logits no remat
        blk_factor = 4 if cfg.remat else 3
        flops_glob = t_glob * (blk_factor * fwd_tok + 3 * logits_tok)
    else:
        flops_glob = t_glob * (fwd_tok + logits_tok)
    flops_dev = flops_glob / chips

    # ---------------- HBM bytes --------------------------------------------
    br = {}
    accum = cfg.accum_steps if shape.kind == "train" else 1
    wbytes = BP / 2 if quantized else BP           # int8 weights at serving
    vocab_par = model_par_dense
    # params: read for fwd (+recompute+bwd) per microbatch, plus optimizer
    if shape.kind == "train":
        br["params"] = (3 * accum) * p_model_shard * BP \
            + 4 * p_shard * 4                      # adam read/write f32
        # activations: ~20 d-wide tensors per layer per token (fwd+bwd)
        br["acts"] = 20 * cfg.n_layers * (t_loc / accum) * d * BP * accum
        br["logits"] = 3 * t_loc * (v / vocab_par) * BP
        br["grads"] = 2 * p_shard * BP
    elif shape.kind == "prefill":
        br["params"] = p_model_shard * wbytes
        br["acts"] = 8 * cfg.n_layers * t_loc * d * BP
        br["logits"] = t_loc * (v / vocab_par) * BP
        # KV cache write
        n_attn = sum(mx == "attn" for mx, _ in cfg.block_pattern) \
            * cfg.pattern_repeats
        br["kv"] = 2 * n_attn * t_loc * cfg.n_kv_heads * cfg.head_dim * BP
    else:  # decode
        br["params"] = p_model_shard * wbytes
        n_attn = sum(mx == "attn" for mx, _ in cfg.block_pattern) \
            * cfg.pattern_repeats
        b_loc = max(shape.global_batch / data_par, 1)
        kv_line = cfg.n_kv_heads * cfg.head_dim * 2 * BP
        seq_par = model_par if profile == "tp" else 1
        br["kv"] = n_attn * b_loc * (shape.seq_len / seq_par) * kv_line
        # recurrent states (ssm/wkv)
        n_ssm = sum(mx in ("mamba", "rwkv") for mx, _ in cfg.block_pattern) \
            * cfg.pattern_repeats
        state = (cfg.d_inner * cfg.d_state if "mamba" in
                 [m for m, _ in cfg.block_pattern] else d * cfg.head_dim)
        br["state"] = 2 * n_ssm * b_loc * (state / seq_par) * 4
        br["acts"] = 8 * cfg.n_layers * b_loc * d * BP
        br["logits"] = b_loc * (v / vocab_par) * BP
    hbm_dev = float(sum(br.values()))

    # ---------------- Collectives ------------------------------------------
    cb = {}
    act_payload = t_loc * d * BP                    # per-device activations
    n_blocks = cfg.n_layers
    passes = (3 if cfg.remat else 2) if shape.kind == "train" else 1
    if shape.kind == "train":
        if profile == "ddp" or cfg.fsdp:
            # ZeRO-3: reduce-scatter grads + all-gather params (fwd + bwd)
            cb["zero_rs_grads"] = _ring(fsdp_par, "ag") * p_model_shard * BP
            cb["zero_ag_params"] = 2 * _ring(fsdp_par, "ag") \
                * p_model_shard * BP * accum
        else:
            cb["dp_grad_ar"] = _ring(data_par) * p_model_shard * BP
    if profile == "tp":
        # TP: 2 all-reduces per block x (fwd + recompute + bwd)
        cb["tp_act_ar"] = passes * 2 * n_blocks * _ring(model_par) \
            * act_payload
    # MoE all-to-all (there and back), per moe layer
    if cfg.moe is not None and profile in ("tp", "ep"):
        n_moe = sum(ml == "moe" for _, ml in cfg.block_pattern) \
            * cfg.pattern_repeats
        a2a = 2 * n_moe * (t_loc * cfg.moe.top_k * cfg.moe.capacity_factor
                           * d * BP)
        cb["moe_a2a"] = a2a * passes * (model_par - 1) / model_par
    wire_dev = float(sum(cb.values()))

    return Terms(
        compute_s=flops_dev / PEAK_FLOPS,
        memory_s=hbm_dev / HBM_BW,
        collective_s=wire_dev / ICI_BW,
        flops=flops_dev, hbm_bytes=hbm_dev, wire_bytes=wire_dev,
        breakdown={"hbm": br, "wire": cb},
    )


def model_flops_global(cfg, shape) -> float:
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n_active * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n_active * shape.global_batch * shape.seq_len
    return 2.0 * n_active * shape.global_batch


def mfu(cfg, shape, terms: Terms, chips: int) -> float:
    """Useful-FLOPs utilization at the roofline step time."""
    t = terms.step_time_s
    if t == 0:
        return 0.0
    return model_flops_global(cfg, shape) / t / (PEAK_FLOPS * chips)
