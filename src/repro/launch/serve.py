"""Batched LM serving driver — the *language-model* path: continuous-
batching prefill + lockstep decode over a shared KV cache.  The CNN/image
path (bucketed batching over the GxM executor) lives in
``launch/serve_cnn.py``.

  python -m repro.launch.serve --arch qwen2-1.5b --smoke --requests 4

Requests are prefilling into a shared KV/state cache (one lane per request)
and decoded in lockstep; finished lanes are refilled from the queue —
a minimal continuous-batching scheduler over the same serve_step that the
dry-run lowers at scale.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, list_archs, smoke_config
from repro.nn import transformer as T


def generate(params, cfg, prompts, *, max_new: int = 16, max_len: int = 64,
             greedy: bool = True, seed: int = 0):
    """prompts: list of 1-D int arrays.  Returns list of generated ids."""
    b = len(prompts)
    plen = max(len(p) for p in prompts)
    toks = np.zeros((b, plen), np.int32)
    for i, p in enumerate(prompts):
        toks[i, plen - len(p):] = p          # left-pad (lockstep decode)
    cache = T.init_cache(cfg, b, max_len)

    # prefill (teacher-forced forward that also fills the cache)
    logits, _, cache = T.forward(params, cfg, tokens=jnp.asarray(toks),
                                 return_cache=True, cache_len=max_len)
    step_fn = jax.jit(
        lambda p, t, c, i: T.decode_step(p, cfg, t, c, i))
    out = [[] for _ in range(b)]
    last = jnp.argmax(logits[:, -1:, :], axis=-1).astype(jnp.int32)
    key = jax.random.PRNGKey(seed)
    for t in range(max_new):
        for i in range(b):
            out[i].append(int(last[i, 0]))
        logits, cache = step_fn(params, last, cache, jnp.int32(plen + t))
        if greedy:
            last = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        else:
            key, k2 = jax.random.split(key)
            last = jax.random.categorical(k2, logits, axis=-1).astype(jnp.int32)
    return out


def serve_continuous(params, cfg, request_queue, *, lanes: int = 4,
                     max_len: int = 64, max_new: int = 16, eos: int = 0,
                     seed: int = 0):
    """Continuous batching: `lanes` concurrent sequences decode in lockstep;
    a lane that finishes (EOS or max_new) is immediately refilled from the
    queue by prefilling *only that lane's* cache slot.  Returns
    {request_id: generated ids}.

    This is the scheduler shape real serving systems use; the per-lane
    refill is a cache-slot overwrite, so the decode step stays one jitted
    program regardless of arrival order.
    """
    queue = list(enumerate(request_queue))
    results: dict[int, list[int]] = {}
    lane_req = [-1] * lanes
    lane_new = [0] * lanes
    cache = T.init_cache(cfg, lanes, max_len)
    pos = np.zeros(lanes, np.int32)     # per-lane decode position
    cur = np.zeros((lanes, 1), np.int32)

    step_fn = jax.jit(lambda p, t, c, i: T.decode_step(p, cfg, t, c, i))

    def refill(lane):
        nonlocal cache
        if not queue:
            lane_req[lane] = -1
            return
        rid, prompt = queue.pop(0)
        lane_req[lane] = rid
        results[rid] = []
        # prefill just this lane (batch-1 forward), write its cache slot
        logits, _, c1 = T.forward(params, cfg,
                                  tokens=jnp.asarray(prompt)[None, :],
                                  return_cache=True, cache_len=max_len)
        cache = jax.tree.map(
            lambda full, one: full.at[:, lane:lane + 1].set(one), cache, c1)
        pos[lane] = len(prompt)
        first = int(jnp.argmax(logits[0, -1]))
        results[rid].append(first)          # first token comes from prefill
        lane_new[lane] = 1
        cur[lane, 0] = first
        if first == eos or max_new <= 1:
            refill(lane)

    for lane in range(lanes):
        refill(lane)

    while any(r >= 0 for r in lane_req):
        logits, cache = step_fn(params, jnp.asarray(cur), cache,
                                jnp.asarray(pos))      # per-lane positions
        nxt = np.asarray(jnp.argmax(logits, axis=-1)).astype(np.int32)
        for lane in range(lanes):
            rid = lane_req[lane]
            if rid < 0:
                continue
            tok = int(nxt[lane, 0])
            results[rid].append(tok)
            lane_new[lane] += 1
            pos[lane] += 1
            cur[lane, 0] = tok
            done = (tok == eos or lane_new[lane] >= max_new
                    or pos[lane] >= max_len - 1)
            if done:
                refill(lane)
    return results


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list_archs(), default="qwen2-1.5b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_config(cfg)
    params, _ = T.init_lm(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, size=rng.integers(3, 10))
               for _ in range(args.requests)]
    t0 = time.time()
    outs = generate(params, cfg, prompts, max_new=args.max_new)
    dt = time.time() - t0
    for i, o in enumerate(outs):
        print(f"req{i}: prompt={list(prompts[i])[:6]}... -> {o[:8]}...")
    total = args.requests * args.max_new
    print(f"decoded {total} tokens in {dt:.2f}s "
          f"({total/dt:.1f} tok/s batched)")


if __name__ == "__main__":
    main()
