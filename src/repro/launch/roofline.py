"""Three-term roofline extraction from compiled dry-run artifacts.

  compute_s    = HLO_FLOPs_per_device / peak_FLOPs
  memory_s     = HLO_bytes_per_device / HBM_bw        (XLA "bytes accessed":
                 an upper bound on HBM traffic — fused ops count once)
  collective_s = Σ_ops per-device payload × ring_factor / link_bw

``cost_analysis()`` values on a partitioned module are already per-device.
Collective payloads are parsed from the compiled HLO: the result shape of
every all-reduce / all-gather / reduce-scatter / all-to-all /
collective-permute is the per-device shard; ring_factor(n) = 2(n-1)/n for
all-reduce, (n-1)/n otherwise.

Hardware model (TPU v5e target): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import re

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link
STEP_OVERHEAD_S = 5e-7       # grid-step pipeline-fill overhead (one source
                             # of truth; repro.tune.measure re-exports in us)

# Stable result-dict keys.  The bench JSONs persist these names and the
# perf-gate extractors (repro.perfci.extract) join on them — renaming one is
# a baseline-schema change and must bump perfci's SCHEMA_VERSION.
KERNEL_ROOFLINE_KEYS = ("compute_s", "memory_s", "step_time_s", "cost_s",
                        "dominant", "efficiency")
COMPOSITE_ROOFLINE_KEYS = ("cost_s", "flops", "hbm_bytes", "n_steps",
                           "launches", "efficiency")
CHAIN_ROOFLINE_KEYS = ("cost_s", "unfused_cost_s", "speedup", "flops",
                       "hbm_bytes", "unfused_hbm_bytes",
                       "intermediate_bytes", "launches", "efficiency",
                       "fused")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLL = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
         "collective-permute")
# "%name = <shape or (tuple)> <collective>(" — shape first on RHS
_LINE = re.compile(
    r"=\s+(\([^)]*\)|\S+)\s+(" + "|".join(_COLL) + r")(?:-start)?\(")
_SHAPE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_V2 = re.compile(r"replica_groups=\{\{([^}]*)\}")


def _shape_bytes(s: str) -> int:
    total = 0
    for m in _SHAPE.finditer(s):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_V2.search(line)
    if m:
        return len(m.group(1).split(","))
    return default


@dataclasses.dataclass
class CollectiveStats:
    per_device_bytes: float       # Σ payload shards
    wire_bytes: float             # Σ payload × ring factor
    by_kind: dict
    count: int


def parse_collectives(hlo_text: str, *, default_group: int) -> CollectiveStats:
    per_dev = 0.0
    wire = 0.0
    by_kind: dict[str, float] = {}
    count = 0
    for line in hlo_text.splitlines():
        m = _LINE.search(line)
        if not m:
            continue
        shape_s, kind = m.group(1), m.group(2)
        b = _shape_bytes(shape_s)
        if b == 0:
            continue
        n = max(_group_size(line, default_group), 2)
        factor = 2 * (n - 1) / n if kind == "all-reduce" else (n - 1) / n
        per_dev += b
        wire += b * factor
        by_kind[kind] = by_kind.get(kind, 0.0) + b
        count += 1
    return CollectiveStats(per_dev, wire, by_kind, count)


@dataclasses.dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    flops_per_device: float
    bytes_per_device: float
    collectives: CollectiveStats
    model_flops_global: float
    useful_ratio: float           # MODEL_FLOPS / (HLO_FLOPs × chips)

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Roofline step time = max of the three overlappable terms."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def roofline_fraction(self) -> float:
        """Useful-FLOPs MFU at the roofline step time."""
        if self.step_time_s == 0:
            return 0.0
        chips_flops = self.model_flops_global / self.step_time_s
        return chips_flops / (PEAK_FLOPS * self._chips)

    _chips: int = 256


def kernel_roofline(*, flops: float, hbm_bytes: float, util: float = 1.0,
                    n_steps: int = 0,
                    step_overhead_s: float = STEP_OVERHEAD_S) -> dict:
    """Roofline terms for one *blocked kernel launch* (the per-layer analog
    of ``analyze``'s whole-module extraction).

    ``hbm_bytes`` is the schedule-resolved traffic from ``repro.tune``'s
    block-refetch model — including the multi-pass output term a C_b-blocked
    kernel pays when an output tile is revisited across accumulation passes
    (each extra visit is modeled as a read-back + rewrite, the conservative
    "bytes accessed" convention used for the HLO extraction above).
    ``efficiency`` is ideal-compute-time / modeled-cost: the Fig. 4 right
    axis ("% of peak") for one layer.
    """
    t_comp = flops / (PEAK_FLOPS * max(util, 1e-3))
    t_mem = hbm_bytes / HBM_BW
    step_time = max(t_comp, t_mem)
    cost = step_time + n_steps * step_overhead_s
    ideal = flops / PEAK_FLOPS
    return {
        "compute_s": t_comp,
        "memory_s": t_mem,
        "step_time_s": step_time,
        "cost_s": cost,
        "dominant": "compute" if t_comp >= t_mem else "memory",
        "efficiency": ideal / cost if cost > 0 else 0.0,
    }


def composite_roofline(parts: list[dict], *, extra_hbm_bytes: float = 0.0,
                       step_overhead_s: float = STEP_OVERHEAD_S) -> dict:
    """Roofline for a *multi-launch* kernel pipeline — e.g. the stride²
    phase sub-convolutions of the §II-I strided dual, or the dilate plan's
    single conv plus its materialization pass.

    Each part is a ``repro.tune.measure.conv_traffic`` dict (flops /
    hbm_bytes / util / n_steps); launches serialize, so the pipeline cost is
    the sum of per-launch ``kernel_roofline`` costs.  ``extra_hbm_bytes``
    charges non-kernel HBM traffic the pipeline pays between launches
    (materializing a dilated dO, re-interleaving phase outputs) at HBM
    bandwidth — traffic a zero-free plan avoids entirely.
    """
    cost = extra_hbm_bytes / HBM_BW
    flops = 0.0
    hbm = extra_hbm_bytes
    steps = 0
    for t in parts:
        roof = kernel_roofline(flops=t["flops"], hbm_bytes=t["hbm_bytes"],
                               util=t.get("util", 1.0),
                               n_steps=t.get("n_steps", 0),
                               step_overhead_s=step_overhead_s)
        cost += roof["cost_s"]
        flops += t["flops"]
        hbm += t["hbm_bytes"]
        steps += t.get("n_steps", 0)
    ideal = flops / PEAK_FLOPS
    return {
        "cost_s": cost,
        "flops": flops,
        "hbm_bytes": hbm,
        "n_steps": steps,
        "launches": len(parts),
        "efficiency": ideal / cost if cost > 0 else 0.0,
    }


def chain_roofline(chain_t: dict, *,
                   step_overhead_s: float = STEP_OVERHEAD_S) -> dict:
    """Roofline for a depth-first fused conv chain (DESIGN.md §16).

    ``chain_t`` is a ``repro.tune.measure.chain_traffic`` dict.  The fused
    cost composites the per-band-step launches of the interleaved schedule
    (hand-off bands already priced at 0 HBM bytes); the unfused cost
    composites the layer-by-layer launches.  When the chain fell back
    (``fused=False``) the two are identical by construction — the fallback
    rule — so ``speedup`` is exactly 1.0 there.
    """
    fused_roof = composite_roofline(chain_t["parts"],
                                    step_overhead_s=step_overhead_s)
    unfused_roof = composite_roofline(chain_t["unfused_parts"],
                                      step_overhead_s=step_overhead_s)
    cost = fused_roof["cost_s"]
    return {
        "cost_s": cost,
        "unfused_cost_s": unfused_roof["cost_s"],
        "speedup": unfused_roof["cost_s"] / cost if cost > 0 else 0.0,
        "flops": fused_roof["flops"],
        "hbm_bytes": chain_t["hbm_bytes"],
        "unfused_hbm_bytes": chain_t["unfused_hbm_bytes"],
        "intermediate_bytes": chain_t["intermediate_bytes"],
        "launches": fused_roof["launches"],
        "efficiency": fused_roof["efficiency"],
        "fused": chain_t["fused"],
    }


def cost_analysis_dict(compiled) -> dict:
    """Normalize ``Compiled.cost_analysis()`` across jax versions: older
    releases return a one-element list of dicts, newer ones a flat dict."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca


def analyze(compiled, *, chips: int, model_flops_global: float) -> Roofline:
    ca = cost_analysis_dict(compiled)
    flops = float(ca.get("flops", 0.0))
    bytes_acc = float(ca.get("bytes accessed", 0.0))
    colls = parse_collectives(compiled.as_text(), default_group=chips)
    r = Roofline(
        compute_s=flops / PEAK_FLOPS,
        memory_s=bytes_acc / HBM_BW,
        collective_s=colls.wire_bytes / ICI_BW,
        flops_per_device=flops,
        bytes_per_device=bytes_acc,
        collectives=colls,
        model_flops_global=model_flops_global,
        useful_ratio=(model_flops_global / (flops * chips)
                      if flops else 0.0),
    )
    r._chips = chips
    return r


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS: 6·N_active·D for training, 2·N_active·D for inference
    forward (D = tokens processed)."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch


def to_dict(r: Roofline) -> dict:
    return {
        "compute_s": r.compute_s,
        "memory_s": r.memory_s,
        "collective_s": r.collective_s,
        "dominant": r.dominant,
        "step_time_s": r.step_time_s,
        "flops_per_device": r.flops_per_device,
        "bytes_per_device": r.bytes_per_device,
        "collective_per_device_bytes": r.collectives.per_device_bytes,
        "collective_wire_bytes": r.collectives.wire_bytes,
        "collective_count": r.collectives.count,
        "collective_by_kind": r.collectives.by_kind,
        "model_flops_global": r.model_flops_global,
        "useful_ratio": r.useful_ratio,
        "roofline_fraction": r.roofline_fraction,
    }
