"""Continuous-batching CNN image-recognition server over the GxM executor —
the serving side of the paper's image-throughput story (DESIGN.md §8).

  PYTHONPATH=src python -m repro.launch.serve_cnn --arch resnet50 --smoke

Requests (single images) land in a queue; the scheduler drains it in
batches: each batch is padded up to the *minimal* bucket of a fixed ladder,
so every step hits one jitted, autotune-warmed, AOT-compiled executor
(``graph/serving.py``), data-parallel sharded across the local devices via
``shard_map`` over ``launch.mesh.make_host_mesh``.  Startup warmup
pre-populates the per-shape blocking cache (``repro.tune``) and compiles
every bucket, so the request path never tunes, traces, or compiles.

``--fleet N`` runs the resilient multi-replica mode instead (DESIGN.md
§15): N replicas sharing the warmed engine pair (f32 + int8 twin) behind
``serve.FleetRouter`` — deadlines, hedging, health eviction + respawn,
load shed, degrade-to-int8 — against the seeded replica-fault schedule
from ``REPRO_SERVE_CHAOS=<seed>`` / ``--fleet-chaos-seed``.

This is the CNN/image sibling of the LM decode server in
``launch/serve.py``.
"""
from __future__ import annotations

import argparse
import collections
import json
import os
import time

import jax
import numpy as np

from repro.graph import GxM, inception_v3, resnet50
from repro.graph.serving import CnnInferenceEngine, pick_bucket
from repro.launch.mesh import make_host_mesh


class ImageServer:
    """Continuous-batching scheduler over a ``CnnInferenceEngine``.

    ``submit`` enqueues one image and returns a request id; ``step`` serves
    one padded bucket off the queue head; ``run`` drains the queue.  Results
    map request id -> (top-1 class, top-1 logit).
    """

    def __init__(self, engine: CnnInferenceEngine, *, clock=None):
        self.engine = engine
        self.clock = clock if clock is not None else time.perf_counter
        self.queue: collections.deque = collections.deque()
        self.results: dict[int, tuple[int, float]] = {}
        self._next_rid = 0
        self._counters = {"batches": 0, "images": 0, "padded_lanes": 0,
                          "by_bucket": collections.Counter(), "serve_s": 0.0}
        self.latencies_s: list[float] = []

    def submit(self, image) -> int:
        rid = self._next_rid
        self._next_rid += 1
        self.queue.append((rid, image, self.clock()))
        return rid

    def step(self) -> int:
        """Serve up to one largest-bucket batch from the queue head; returns
        the number of requests served (0 when the queue is empty)."""
        if not self.queue:
            return 0
        take = min(len(self.queue), max(self.engine.buckets))
        reqs = [self.queue.popleft() for _ in range(take)]
        images = np.stack([img for _, img, _ in reqs])
        bucket = pick_bucket(take, self.engine.buckets)
        st = self._counters
        t0 = self.clock()
        logits = np.asarray(self.engine.infer(images))
        t1 = self.clock()
        st["serve_s"] += t1 - t0
        for (rid, _, t_enq), row in zip(reqs, logits):
            top1 = int(np.argmax(row))
            self.results[rid] = (top1, float(row[top1]))
            self.latencies_s.append(t1 - t_enq)
        st["batches"] += 1
        st["images"] += take
        st["padded_lanes"] += bucket - take
        st["by_bucket"][bucket] += 1
        return take

    def run(self) -> dict[int, tuple[int, float]]:
        while self.queue:
            self.step()
        return dict(self.results)

    def stats(self) -> dict:
        """Counter snapshot plus the enqueue->complete latency summary
        (queue wait included — that is what a client experiences, not just
        the executor's serve time)."""
        st = dict(self._counters)
        st["by_bucket"] = dict(st["by_bucket"])
        lat = np.sort(np.asarray(self.latencies_s, dtype=np.float64))
        st["latency"] = {
            "count": int(lat.size),
            "p50_ms": round(float(np.percentile(lat, 50)) * 1e3, 3)
            if lat.size else 0.0,
            "p99_ms": round(float(np.percentile(lat, 99)) * 1e3, 3)
            if lat.size else 0.0,
            "max_ms": round(float(lat[-1]) * 1e3, 3) if lat.size else 0.0,
        }
        return st


def build_model(arch: str, *, smoke: bool, num_classes: int,
                image: int = 0, impl=None):
    """Topology + default image size per arch (tiny variants for --smoke)."""
    if arch == "resnet50":
        nl = resnet50(num_classes,
                      stages=(1, 1, 1, 1) if smoke else (3, 4, 6, 3))
        image = image or (32 if smoke else 224)
    elif arch == "inception":
        nl = inception_v3(num_classes)
        image = image or (48 if smoke else 224)
    else:
        raise ValueError(f"unknown arch {arch!r}")
    return GxM(nl, impl=impl, num_classes=num_classes), image


def run_fleet(args, engine, q8_engine, image: int) -> dict:
    """The resilient multi-replica mode: N replicas sharing the warmed
    engine pair behind ``serve.FleetRouter``, replaying Poisson arrivals
    against the ``REPRO_SERVE_CHAOS``-seeded fault schedule."""
    from repro.serve import (FleetRouter, Replica, ServeChaosEngine,
                             ServeChaosSchedule, poisson_arrivals)
    names = [f"r{i}" for i in range(args.fleet)]
    make_replica = lambda name: Replica(  # noqa: E731
        name, infer_fn=engine.infer,
        q8_infer_fn=q8_engine.infer if q8_engine is not None else None)
    arrivals = poisson_arrivals(0, n=args.requests, rate_per_s=1.5)
    horizon = max(t for t, _ in arrivals)
    chaos = None
    if args.fleet_chaos_seed is not None:
        schedule = ServeChaosSchedule.generate(
            args.fleet_chaos_seed, horizon_s=horizon, replicas=names)
        chaos = ServeChaosEngine(schedule)
        print(f"chaos: seed {args.fleet_chaos_seed}, "
              f"{len(schedule.events)} events over {horizon:.0f}s")
    rng = np.random.default_rng(0)
    image_fn = lambda _i: rng.standard_normal(  # noqa: E731
        (image, image, 3)).astype(np.float32)
    router = FleetRouter([make_replica(n) for n in names], chaos=chaos,
                         deadline_s=args.deadline,
                         replica_factory=make_replica,
                         burst_image_fn=image_fn)
    report = router.run([(t, image_fn(0)) for t, _ in arrivals])
    report.pop("events")
    summary = {"arch": args.arch, "fleet": args.fleet,
               "chaos_seed": args.fleet_chaos_seed, **report}
    print(json.dumps(summary))
    assert all(r.result is not None for r in router.requests.values()
               if r.status == "done")
    assert report["slo_handled_rate"] == 1.0, \
        "an admitted request busted its deadline without degrading"
    return summary


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=("resnet50", "inception"),
                    default="resnet50")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny topology + image size (CI / local CPU)")
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--image", type=int, default=0,
                    help="input H=W (0: per-arch default)")
    ap.add_argument("--max-batch", type=int, default=16)
    ap.add_argument("--classes", type=int, default=0,
                    help="classifier width (0: 10 smoke / 1000 full)")
    ap.add_argument("--autotune", choices=("off", "cache", "tune"),
                    default="tune", help="blocking-cache warmup mode")
    ap.add_argument("--fleet", type=int, default=0,
                    help="serve from N replicas behind the resilient "
                         "FleetRouter (0: single-engine batching)")
    ap.add_argument("--fleet-chaos-seed", type=int,
                    default=(int(os.environ["REPRO_SERVE_CHAOS"])
                             if os.environ.get("REPRO_SERVE_CHAOS")
                             else None),
                    help="inject a seeded replica-fault schedule "
                         "(serve/chaos.py) into --fleet mode; also "
                         "settable via REPRO_SERVE_CHAOS=<seed>")
    ap.add_argument("--deadline", type=float, default=6.0,
                    help="--fleet per-request deadline (simulated seconds)")
    args = ap.parse_args(argv)

    classes = args.classes or (10 if args.smoke else 1000)
    m, image = build_model(args.arch, smoke=args.smoke, num_classes=classes,
                           image=args.image)
    params = m.init(jax.random.PRNGKey(0))
    mesh = make_host_mesh()
    engine = CnnInferenceEngine(m, params, image_hw=(image, image),
                                mesh=mesh, max_batch=args.max_batch)

    t0 = time.perf_counter()
    report = engine.warmup(autotune=args.autotune)
    warm_s = time.perf_counter() - t0
    print(f"warmup: {report['conv_signatures']} conv signatures "
          f"({report['pallas_path_signatures']} on the tuned kernel path), "
          f"{report['tune_entries']} blocking-cache entries, "
          f"buckets {report['buckets']} compiled in {warm_s:.1f}s")

    if args.fleet:
        mq, _ = build_model(args.arch, smoke=args.smoke,
                            num_classes=classes, image=args.image)
        # quantized=True re-marks mq's ETG: the int8 degrade twin
        q8_engine = CnnInferenceEngine(mq, params, image_hw=(image, image),
                                       mesh=mesh, max_batch=args.max_batch,
                                       quantized=True)
        q8_engine.warmup(autotune="off")
        return run_fleet(args, engine, q8_engine, image)

    # arrivals in random-size bursts so partial buckets (and therefore
    # pad-to-bucket) actually happen — the continuous-batching shape
    server = ImageServer(engine)
    rng = np.random.default_rng(0)
    remaining = args.requests
    while remaining:
        burst = int(rng.integers(1, min(remaining, args.max_batch) + 1))
        for _ in range(burst):
            server.submit(rng.standard_normal((image, image, 3),
                                              dtype=np.float32))
        remaining -= burst
        server.step()
    results = server.run()

    st = server.stats()
    ips = st["images"] / st["serve_s"] if st["serve_s"] else 0.0
    summary = {
        "arch": args.arch, "devices": len(jax.devices()),
        "data_shards": engine.num_shards, "image": image,
        "requests": len(results), "batches": st["batches"],
        "pad_fraction": round(st["padded_lanes"]
                              / max(st["images"] + st["padded_lanes"], 1), 3),
        "by_bucket": st["by_bucket"],
        "latency_p99_ms": st["latency"]["p99_ms"],
        "images_per_s": round(ips, 1),
    }
    print(json.dumps(summary))
    assert len(results) == args.requests
    return summary


if __name__ == "__main__":
    main()
