"""End-to-end trainer.

The same code path drives the CPU examples (tiny configs, host mesh) and
the production lowering (full configs, 16x16 / 2x16x16 mesh): model init ->
sharded train_step -> resilient loop (async checkpoints, restore-on-failure)
-> metrics.

  python -m repro.launch.train --arch qwen2-1.5b --smoke --steps 20
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

from repro.configs import get_config, list_archs, smoke_config
from repro.data import make_pipeline
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.nn import transformer as T
from repro.nn.partitioning import (activation_ctx, activation_rules,
                                   batch_spec, param_rules, to_shardings)
from repro.optim.adamw import AdamW
from repro.train import checkpoint as ckpt_lib
from repro.train.fault_tolerance import ResilientLoop
from repro.train.step import (init_train_state, make_train_step,
                              train_state_specs)


def build(cfg, mesh, *, lr=3e-4, accum_steps=1, seed=0, impl=None):
    opt = AdamW(factored=cfg.factored_opt,
                state_dtype=jnp.bfloat16 if cfg.factored_opt else jnp.float32)
    rules = param_rules(fsdp=cfg.fsdp, mesh=mesh)
    state, param_specs = init_train_state(cfg, opt, jax.random.PRNGKey(seed))
    spec_tree = train_state_specs(param_specs, state["opt"])
    shapes = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
    state_sh = to_shardings(spec_tree, shapes, rules, mesh)
    state = jax.device_put(state, state_sh)
    step = make_train_step(cfg, opt, lr=lr, accum_steps=accum_steps,
                           impl=impl)

    def data_sharding(batch):
        return {k: NamedSharding(
            mesh, batch_spec(v.shape[0], mesh, (None,) * (v.ndim - 1)))
            for k, v in batch.items()}

    jitted = jax.jit(step, donate_argnums=(0,),
                     out_shardings=(state_sh, None))
    act_rules = activation_rules(mesh)

    def run_step(state, batch):
        sh = data_sharding(batch)
        batch = jax.device_put(batch, sh)
        with activation_ctx(mesh, act_rules):
            return jitted(state, batch)

    return state, run_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list_archs(), default="smollm-360m")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--accum-steps", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--data-path", default=None)
    ap.add_argument("--chaos-seed", type=int,
                    default=(int(os.environ["REPRO_CHAOS"])
                             if os.environ.get("REPRO_CHAOS") else None),
                    help="inject a seeded fault schedule (train/chaos.py) "
                         "against a simulated 4-host fleet; also settable "
                         "via REPRO_CHAOS=<seed>")
    ap.add_argument("--chaos-hosts", type=int, default=4)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_config(cfg)
    mesh = (make_production_mesh() if args.production_mesh
            else make_host_mesh(model=args.model_parallel))
    print(f"arch={cfg.name} params={cfg.param_count()/1e6:.1f}M "
          f"mesh={dict(mesh.shape)}")

    state, run_step = build(cfg, mesh, lr=args.lr,
                            accum_steps=args.accum_steps)
    data = make_pipeline(cfg, seq_len=args.seq_len,
                         global_batch=args.global_batch,
                         path=args.data_path)

    # walk-back resume: a corrupt or torn newest checkpoint degrades to the
    # newest verifiable one instead of bricking the run
    state, start = ckpt_lib.restore_latest(args.ckpt_dir, state)
    if start:
        print(f"resuming from checkpoint step {start}")

    chaos = None
    if args.chaos_seed is not None:
        from repro.train.chaos import ChaosEngine, ChaosSchedule
        hosts = [f"host{i}" for i in range(args.chaos_hosts)]
        sched = ChaosSchedule.generate(args.chaos_seed, n_steps=args.steps,
                                       hosts=hosts)
        chaos = ChaosEngine(sched, hosts=hosts, ckpt_dir=args.ckpt_dir)
        print(f"chaos: seed={args.chaos_seed} "
              f"events={[type(e).__name__ for e in sched.events]}")

    loop = ResilientLoop(step_fn=run_step, state=state, data=data,
                         ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
                         policy_every=5, chaos=chaos,
                         heartbeat=(chaos.make_heartbeat()
                                    if chaos is not None else None))
    t0 = time.time()
    loop.run(args.steps, start_step=start)
    dt = time.time() - t0
    toks = (args.steps - start) * args.global_batch * args.seq_len
    for m in loop.metrics_log[:3] + loop.metrics_log[-3:]:
        print(json.dumps(m))
    print(f"tokens/s={toks/dt:.0f}  restarts={loop.restarts}")
    print("resilience " + json.dumps(loop.resilience_summary()))
    return loop


if __name__ == "__main__":
    main()
