"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell and
record memory / cost / collective / roofline evidence.

Usage:
  python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all [--mesh both] [--out experiments/dryrun]

Writes one JSON per cell; --all skips cells whose JSON already exists
(restartable — the driver itself is fault-tolerant).
"""
import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("REPRO_EXTRA_XLA_FLAGS", ""))

import argparse      # noqa: E402
import dataclasses   # noqa: E402
import json          # noqa: E402
import pathlib       # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro import backend as be                       # noqa: E402
from repro.configs import SHAPES, get_config, list_archs, smoke_config  # noqa: E402
from repro.configs.shapes import applicable, input_specs  # noqa: E402
from repro.launch import roofline as rl               # noqa: E402
from repro.launch.mesh import make_production_mesh    # noqa: E402
from repro.nn import transformer as T                 # noqa: E402
from repro.nn.partitioning import (activation_ctx, activation_rules,  # noqa: E402
                                   batch_spec, cache_shardings,
                                   param_rules, to_shardings)
from repro.optim.adamw import AdamW                   # noqa: E402
from repro.train.step import (make_decode_step, make_prefill_step,  # noqa: E402
                              make_train_step, train_state_specs)


def abstract_state(cfg, opt):
    key = jax.random.PRNGKey(0)
    params_shapes = jax.eval_shape(lambda k: T.init_lm(k, cfg)[0], key)
    # spec tree structure is dim-independent: build it from the smoke config
    _, specs = T.init_lm(key, smoke_config(cfg))
    opt_shapes = jax.eval_shape(opt.init, params_shapes)
    state_shapes = {"params": params_shapes, "opt": opt_shapes,
                    "step": jax.ShapeDtypeStruct((), jnp.int32)}
    return state_shapes, train_state_specs(specs, opt_shapes), params_shapes, specs


def batch_shardings(batch_shapes, mesh):
    out = {}
    for k, v in batch_shapes.items():
        trailing = (None,) * (len(v.shape) - 1)
        out[k] = NamedSharding(mesh, batch_spec(v.shape[0], mesh, trailing))
    return out


def lower_cell(arch: str, shape_name: str, multi_pod: bool, *,
               sharding: str | None = None, accum: int | None = None,
               quantize: bool = False, remat: str | None = None,
               moe_cf: float | None = None):
    cfg = get_config(arch)
    overrides = {}
    if sharding:
        overrides["sharding"] = sharding
    if accum:
        overrides["accum_steps"] = accum
    if remat is not None:
        overrides["remat"] = remat == "on"
    if moe_cf is not None and cfg.moe is not None:
        overrides["moe"] = dataclasses.replace(cfg.moe,
                                               capacity_factor=moe_cf)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    shape = SHAPES[shape_name]
    ok, reason = applicable(cfg, shape)
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "multi" if multi_pod else "single",
           "applicable": ok, "skip_reason": reason,
           "sharding": cfg.sharding, "accum_steps": cfg.accum_steps,
           "quantized": quantize}
    if not ok:
        return rec
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = 1
    for v in mesh.shape.values():
        chips *= v
    opt = AdamW(factored=cfg.factored_opt,
                state_dtype=jnp.bfloat16 if cfg.factored_opt else jnp.float32)
    rules = param_rules(fsdp=cfg.fsdp, mesh=mesh, profile=cfg.sharding)
    specs_in = input_specs(cfg, shape)

    t0 = time.time()
    act_rules = activation_rules(mesh, cfg.sharding)
    with be.use_backend("xla"), activation_ctx(mesh, act_rules):
        if shape.kind == "train":
            state_shapes, state_spec, _, _ = abstract_state(cfg, opt)
            state_sh = to_shardings(state_spec, state_shapes, rules, mesh)
            step = make_train_step(cfg, opt, accum_steps=cfg.accum_steps)
            bsh = batch_shardings(specs_in, mesh)
            jitted = jax.jit(step, in_shardings=(state_sh, bsh),
                             out_shardings=(state_sh, None),
                             donate_argnums=(0,))
            lowered = jitted.lower(state_shapes, specs_in)
        elif shape.kind == "prefill":
            _, _, params_shapes, pspecs = abstract_state(cfg, opt)
            param_sh = to_shardings(pspecs, params_shapes, rules, mesh)
            step = make_prefill_step(cfg, cache_len=shape.seq_len)
            bsh = batch_shardings(specs_in, mesh)
            jitted = jax.jit(step, in_shardings=(param_sh, bsh))
            lowered = jitted.lower(params_shapes, specs_in)
        else:  # decode
            _, _, params_shapes, pspecs = abstract_state(cfg, opt)
            if quantize:
                from repro.core.quantize import (dequantize, quantize_int8,
                                                 quantized_specs)
                pspecs = quantized_specs(pspecs, params_shapes)
                params_shapes = jax.eval_shape(quantize_int8, params_shapes)
                base = make_decode_step(cfg)

                def step(qp, tokens, cache, idx):
                    return base(dequantize(qp, jnp.dtype(cfg.dtype)),
                                tokens, cache, idx)
            else:
                step = make_decode_step(cfg)
            param_sh = to_shardings(pspecs, params_shapes, rules, mesh)
            cache_sh = cache_shardings(specs_in["cache"], mesh,
                                       shape.global_batch)
            tok_sh = NamedSharding(
                mesh, batch_spec(shape.global_batch, mesh, (None,)))
            idx_sh = NamedSharding(mesh, P())
            jitted = jax.jit(step,
                             in_shardings=(param_sh, tok_sh, cache_sh, idx_sh),
                             out_shardings=(None, cache_sh),
                             donate_argnums=(2,))
            lowered = jitted.lower(params_shapes, specs_in["tokens"],
                                   specs_in["cache"], specs_in["idx"])
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    ma = compiled.memory_analysis()
    mem = {k: getattr(ma, k) for k in (
        "argument_size_in_bytes", "output_size_in_bytes",
        "temp_size_in_bytes", "alias_size_in_bytes",
        "generated_code_size_in_bytes")}
    mem["total_per_device_bytes"] = (
        mem["argument_size_in_bytes"] + mem["output_size_in_bytes"]
        + mem["temp_size_in_bytes"] - mem["alias_size_in_bytes"])
    roof = rl.analyze(compiled, chips=chips,
                      model_flops_global=rl.model_flops(cfg, shape))
    rec.update({
        "chips": chips,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": mem,
        "params_total": cfg.param_count(),
        "params_active": cfg.active_param_count(),
        "roofline": rl.to_dict(roof),
    })
    print(compiled.memory_analysis())
    return rec, compiled


def run_cell(arch, shape_name, mesh_kind, outdir, save_hlo=False, tag="",
             **kw):
    suffix = f"__{tag}" if tag else ""
    path = outdir / f"{arch}__{shape_name}__{mesh_kind}{suffix}.json"
    out = lower_cell(arch, shape_name, mesh_kind == "multi", **kw)
    rec, compiled = out if isinstance(out, tuple) else (out, None)
    path.write_text(json.dumps(rec, indent=1))
    if save_hlo and compiled is not None:
        (outdir / f"{arch}__{shape_name}__{mesh_kind}{suffix}.hlo.txt"
         ).write_text(compiled.as_text())
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list_archs())
    ap.add_argument("--shape", choices=sorted(SHAPES))
    ap.add_argument("--mesh", choices=("single", "multi", "both"),
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--sharding", choices=("tp", "ddp", "ep"), default=None,
                    help="override the arch's sharding profile (§Perf)")
    ap.add_argument("--accum", type=int, default=None)
    ap.add_argument("--quantize", action="store_true",
                    help="int8 weights for decode cells (§II-K analog)")
    ap.add_argument("--remat", choices=("on", "off"), default=None)
    ap.add_argument("--moe-cf", type=float, default=None)
    ap.add_argument("--tag", default="",
                    help="suffix for the output JSON (hillclimb variants)")
    args = ap.parse_args()

    outdir = pathlib.Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)
    meshes = ("single", "multi") if args.mesh == "both" else (args.mesh,)
    cells = ([(a, s) for a in list_archs() for s in SHAPES]
             if args.all else [(args.arch, args.shape)])

    failures = []
    for arch, shape_name in cells:
        for mesh_kind in meshes:
            suffix = f"__{args.tag}" if args.tag else ""
            path = outdir / f"{arch}__{shape_name}__{mesh_kind}{suffix}.json"
            if path.exists() and not args.force:
                print(f"[skip-cached] {path.name}")
                continue
            t0 = time.time()
            try:
                rec = run_cell(arch, shape_name, mesh_kind, outdir,
                               args.save_hlo, tag=args.tag,
                               sharding=args.sharding, accum=args.accum,
                               quantize=args.quantize, remat=args.remat,
                               moe_cf=args.moe_cf)
                status = ("SKIP(" + rec["skip_reason"][:40] + ")"
                          if not rec["applicable"] else
                          f"ok compile={rec['compile_s']}s "
                          f"dom={rec['roofline']['dominant']} "
                          f"mem={rec['memory']['total_per_device_bytes']/2**30:.2f}GiB")
            except Exception as e:  # noqa: BLE001
                failures.append((arch, shape_name, mesh_kind, repr(e)))
                path.with_suffix(".error.txt").write_text(
                    traceback.format_exc())
                status = f"FAIL {e!r}"
            print(f"[{arch} × {shape_name} × {mesh_kind}] "
                  f"{status} ({time.time()-t0:.0f}s)", flush=True)
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print("  ", f)
        raise SystemExit(1)
    print("\nall requested cells passed")


if __name__ == "__main__":
    main()
