"""Checkpointing + fault tolerance: round-trip, corruption detection,
async, GC, durable walk-back restore, resilient-loop recovery edge cases
(in-flight async-save failure, retry exhaustion, no-checkpoint restart,
data-cursor agreement), heartbeat/clock semantics, data-pipeline cursor."""
import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import SyntheticLMData
from repro.train import checkpoint as C
from repro.train.fault_tolerance import Heartbeat, RebalancePlan, ResilientLoop


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"params": {"w": jax.random.normal(k, (8, 4)),
                       "b": jnp.zeros((4,))},
            "opt": {"mu": {"w": jnp.ones((8, 4)), "b": jnp.ones((4,))},
                    "count": jnp.int32(7)},
            "step": jnp.int32(3)}


def test_save_restore_roundtrip(tmp_path):
    tree = _tree()
    C.save(tmp_path, 10, tree)
    out = C.restore(tmp_path, 10, tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_and_gc(tmp_path):
    tree = _tree()
    for s in (1, 2, 3, 4, 5):
        C.save(tmp_path, s, tree, keep=2)
    assert C.latest_step(tmp_path) == 5
    kept = sorted(p.name for p in pathlib.Path(tmp_path).iterdir())
    assert kept == ["step_4", "step_5"]


def test_corruption_detected(tmp_path):
    tree = _tree()
    path = C.save(tmp_path, 1, tree)
    # flip bytes in one leaf
    manifest = json.loads((pathlib.Path(path) / "manifest.json").read_text())
    fname = next(iter(manifest["leaves"].values()))["file"]
    f = pathlib.Path(path) / fname
    raw = bytearray(f.read_bytes())
    raw[-1] ^= 0xFF
    f.write_bytes(bytes(raw))
    with pytest.raises(IOError, match="corruption"):
        C.restore(tmp_path, 1, tree)


def test_async_checkpointer(tmp_path):
    tree = _tree()
    ac = C.AsyncCheckpointer(tmp_path)
    ac.save(5, tree)
    ac.wait()
    assert C.latest_step(tmp_path) == 5


def test_resilient_loop_recovers(tmp_path):
    """Inject a failure mid-training; the loop must restore the last
    checkpoint and finish with identical final state to a failure-free run
    (bitwise — the data pipeline is step-indexed)."""
    data = SyntheticLMData(vocab=16, seq_len=4, global_batch=2)

    def step_fn(state, batch):
        s = state["x"] + jnp.float32(batch["tokens"].sum())
        return {"x": s}, {"loss": s}

    fail_at = {17}

    def hook(step):
        if step in fail_at:
            fail_at.clear()
            raise RuntimeError("injected node failure")

    loop = ResilientLoop(step_fn=step_fn, state={"x": jnp.float32(0)},
                         data=data, ckpt_dir=tmp_path, ckpt_every=5,
                         failure_hook=hook)
    final = loop.run(25)
    assert loop.restarts == 1

    loop2 = ResilientLoop(step_fn=step_fn, state={"x": jnp.float32(0)},
                          data=data, ckpt_dir=str(tmp_path) + "_b",
                          ckpt_every=5)
    final2 = loop2.run(25)
    np.testing.assert_array_equal(np.asarray(final["x"]),
                                  np.asarray(final2["x"]))


def test_all_steps_ignores_tmp_and_valid_steps_ignores_corrupt(tmp_path):
    tree = _tree()
    for s in (1, 2, 3):
        C.save(tmp_path, s, tree)
    # a crash mid-save leaves a .tmp- dir: invisible to every reader
    (pathlib.Path(tmp_path) / ".tmp-step_4").mkdir()
    assert C.all_steps(tmp_path) == [1, 2, 3]
    assert C.latest_step(tmp_path) == 3
    # corrupt the newest: all_steps still lists it, valid_steps drops it
    from repro.train.chaos import corrupt_latest
    assert corrupt_latest(tmp_path) == 3
    assert C.all_steps(tmp_path) == [1, 2, 3]
    assert C.valid_steps(tmp_path) == [1, 2]
    assert C.verify_checkpoint(tmp_path, 2)
    assert not C.verify_checkpoint(tmp_path, 3)
    assert not C.verify_checkpoint(tmp_path, 99)      # absent: False, no raise


def test_restore_latest_walks_back_past_corrupt_and_torn(tmp_path):
    from repro.train.chaos import corrupt_latest, torn_checkpoint
    t1, t2 = _tree(1), _tree(2)
    C.save(tmp_path, 1, t1)
    C.save(tmp_path, 2, t2)
    torn = torn_checkpoint(tmp_path)       # fake newest step 3, half-written
    assert torn == 3
    corrupt_latest(tmp_path)               # and flip bytes in it for spite
    skipped = []
    out, step = C.restore_latest(tmp_path, t1,
                                 on_skip=lambda s, e: skipped.append(s))
    assert step == 2 and skipped == [3]
    for a, b in zip(jax.tree.leaves(t2), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_restore_latest_empty_dir_resumes_step0(tmp_path):
    template = _tree()
    out, step = C.restore_latest(tmp_path / "never_written", template)
    assert step == 0 and out is template


def test_restore_match_shapes_skips_pre_rescale_checkpoints(tmp_path):
    """A checkpoint saved before an elastic fold carries the old residual
    width; the walk-back must skip it rather than restore a wrong-shaped
    tree (and the shape error must name the leaf)."""
    wide = {"residual": jnp.ones((4, 3)), "step": jnp.int32(1)}
    narrow = {"residual": jnp.full((2, 3), 2.0), "step": jnp.int32(2)}
    C.save(tmp_path, 1, wide)
    C.save(tmp_path, 2, narrow)
    with pytest.raises(ValueError, match="residual"):
        C.restore(tmp_path, 1, narrow, match_shapes=True)
    out, step = C.restore_latest(tmp_path, narrow)
    assert step == 2
    # corrupt the post-fold checkpoint: the only remaining one mismatches
    # the template, so walk-back degrades all the way to (template, 0)
    from repro.train.chaos import corrupt_latest
    corrupt_latest(tmp_path)
    out, step = C.restore_latest(tmp_path, narrow)
    assert step == 0 and out is narrow


def test_async_checkpointer_stale_error_cleared(tmp_path):
    """Regression: a failed background save must raise from wait() exactly
    once — not poison every later save/wait with the same stale exception."""
    target = tmp_path / "ckpt"
    target.write_text("a file where the checkpoint dir should be")
    ac = C.AsyncCheckpointer(target)
    ac.save(1, _tree())
    with pytest.raises(Exception):
        ac.wait()
    ac.wait()                              # error handed over already: clean
    target.unlink()                        # storage repaired
    ac.save(2, _tree())
    ac.wait()
    assert C.latest_step(target) == 2


def test_loop_survives_failure_during_inflight_async_save(tmp_path,
                                                          monkeypatch):
    """A step failure while the background save is (and stays) broken: the
    drain logs the async error, restore falls back to step 0, and the loop
    still completes — storage loss degrades, never deadlocks."""
    real_save = C.save
    broken = {"on": True}

    def flaky_save(*a, **k):
        if broken["on"]:
            raise IOError("storage outage")
        return real_save(*a, **k)
    monkeypatch.setattr(C, "save", flaky_save)
    data = SyntheticLMData(vocab=16, seq_len=4, global_batch=2)
    fail_at = {7}

    def hook(step):
        if step in fail_at:
            fail_at.clear()
            raise RuntimeError("node failure mid-outage")

    loop = ResilientLoop(step_fn=lambda s, b: (s, {"loss": 0.0}), state={},
                         data=data, ckpt_dir=tmp_path, ckpt_every=5,
                         failure_hook=hook, io_backoff_s=0.0)
    loop.run(10)
    kinds = [e["kind"] for e in loop.events]
    assert "async_save_error" in kinds or "io_retry" in kinds
    restart = next(e for e in loop.events if e["kind"] == "restart")
    assert restart["restored_step"] == 0    # nothing durable to walk back to
    assert loop.io_retries_used > 0


def test_loop_max_retries_exhaustion_reraises(tmp_path):
    def hook(step):
        raise RuntimeError("persistent failure")

    loop = ResilientLoop(step_fn=lambda s, b: (s, {"loss": 0.0}), state={},
                         data=SyntheticLMData(vocab=16, seq_len=4,
                                              global_batch=2),
                         ckpt_dir=tmp_path, ckpt_every=5, max_retries=2,
                         failure_hook=hook)
    with pytest.raises(RuntimeError, match="persistent"):
        loop.run(10)
    assert loop.restarts == 3               # initial try + 2 retries


def test_loop_restart_without_checkpoint_resumes_step0(tmp_path):
    seen = []
    fail_at = {3}

    def hook(step):
        if step in fail_at:
            fail_at.clear()
            raise RuntimeError("early failure, nothing saved yet")

    def step_fn(state, batch):
        seen.append(int(batch["tokens"][0, 0]))
        return state, {"loss": 0.0}

    data = SyntheticLMData(vocab=64, seq_len=4, global_batch=2)
    loop = ResilientLoop(step_fn=step_fn, state={}, data=data,
                         ckpt_dir=tmp_path, ckpt_every=100, failure_hook=hook)
    loop.run(5)
    assert loop.lost_steps == 3
    want = [int(data.batch_at(s)["tokens"][0, 0]) for s in
            [0, 1, 2] + [0, 1, 2, 3, 4]]
    assert seen == want                     # full replay from step 0


def test_loop_restore_step_and_data_cursor_agree(tmp_path):
    """After a restore to checkpoint step S the very next batch consumed is
    ``data.batch_at(S)`` — the failed segment replays exactly."""
    steps_seen = []
    fail_at = {7}

    def hook(step):
        if step in fail_at:
            fail_at.clear()
            raise RuntimeError("fail between checkpoints")

    class CursorData:
        def batch_at(self, step):
            return {"step": step}

    def step_fn(state, batch):
        steps_seen.append(batch["step"])
        return {"x": jnp.float32(batch["step"])}, {"loss": 0.0}

    loop = ResilientLoop(step_fn=step_fn, state={"x": jnp.float32(0)},
                         data=CursorData(), ckpt_dir=tmp_path, ckpt_every=5,
                         failure_hook=hook)
    loop.run(10)
    assert steps_seen == [0, 1, 2, 3, 4, 5, 6, 5, 6, 7, 8, 9]
    assert loop.lost_steps == 2


def test_heartbeat_straggler_detection():
    hb = Heartbeat(window=10, threshold=1.5)
    for _ in range(10):
        for h in ("h0", "h1", "h2", "h3"):
            hb.record(h, 1.0 if h != "h2" else 3.0)
    assert hb.stragglers() == ["h2"]
    plan = RebalancePlan.from_heartbeat(hb, ["h0", "h1", "h2", "h3"])
    assert plan.shares["h2"] < plan.shares["h0"]
    assert abs(sum(plan.shares.values()) - 1.0) < 1e-9


def test_heartbeat_medians_clock_and_ping():
    """The public medians() API (RebalancePlan no longer reaches into
    _durations), clock-consistent last-seen stamps, liveness pings, and
    forget() after eviction."""
    t = {"now": 100.0}
    hb = Heartbeat(window=4, timeout_s=10.0, clock=lambda: t["now"])
    hb.record("h0", 1.0)                    # stamped from the injected clock
    hb.record("h1", 2.0, now=100.0)         # explicit now: same meaning
    assert hb.medians() == {"h0": 1.0, "h1": 2.0}
    t["now"] = 109.0
    assert hb.dead() == []
    t["now"] = 111.0
    assert sorted(hb.dead()) == ["h0", "h1"]
    hb.ping("h0")                           # liveness only: no new duration
    assert hb.dead() == ["h1"] and hb.medians()["h0"] == 1.0
    hb.forget("h1")
    assert hb.dead() == [] and "h1" not in hb.medians()
    plan = RebalancePlan.from_heartbeat(hb, ["h0", "h9"])
    assert plan.shares["h9"] > 0            # unseen host: 1.0 fallback median
    assert abs(sum(plan.shares.values()) - 1.0) < 1e-9


def test_data_pipeline_deterministic_and_sharded():
    full = SyntheticLMData(vocab=97, seq_len=8, global_batch=8)
    s0 = SyntheticLMData(vocab=97, seq_len=8, global_batch=8, n_shards=2,
                         shard=0)
    b_full_a = full.batch_at(3)
    b_full_b = full.batch_at(3)
    np.testing.assert_array_equal(b_full_a["tokens"], b_full_b["tokens"])
    assert s0.batch_at(3)["tokens"].shape == (4, 8)
    # labels are next-token shifted
    np.testing.assert_array_equal(b_full_a["tokens"][:, 1:],
                                  b_full_a["labels"][:, :-1])
