"""Checkpointing + fault tolerance: round-trip, corruption detection,
async, GC, resilient loop with injected failures, data-pipeline cursor."""
import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import SyntheticLMData
from repro.train import checkpoint as C
from repro.train.fault_tolerance import Heartbeat, RebalancePlan, ResilientLoop


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"params": {"w": jax.random.normal(k, (8, 4)),
                       "b": jnp.zeros((4,))},
            "opt": {"mu": {"w": jnp.ones((8, 4)), "b": jnp.ones((4,))},
                    "count": jnp.int32(7)},
            "step": jnp.int32(3)}


def test_save_restore_roundtrip(tmp_path):
    tree = _tree()
    C.save(tmp_path, 10, tree)
    out = C.restore(tmp_path, 10, tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_and_gc(tmp_path):
    tree = _tree()
    for s in (1, 2, 3, 4, 5):
        C.save(tmp_path, s, tree, keep=2)
    assert C.latest_step(tmp_path) == 5
    kept = sorted(p.name for p in pathlib.Path(tmp_path).iterdir())
    assert kept == ["step_4", "step_5"]


def test_corruption_detected(tmp_path):
    tree = _tree()
    path = C.save(tmp_path, 1, tree)
    # flip bytes in one leaf
    manifest = json.loads((pathlib.Path(path) / "manifest.json").read_text())
    fname = next(iter(manifest["leaves"].values()))["file"]
    f = pathlib.Path(path) / fname
    raw = bytearray(f.read_bytes())
    raw[-1] ^= 0xFF
    f.write_bytes(bytes(raw))
    with pytest.raises(IOError, match="corruption"):
        C.restore(tmp_path, 1, tree)


def test_async_checkpointer(tmp_path):
    tree = _tree()
    ac = C.AsyncCheckpointer(tmp_path)
    ac.save(5, tree)
    ac.wait()
    assert C.latest_step(tmp_path) == 5


def test_resilient_loop_recovers(tmp_path):
    """Inject a failure mid-training; the loop must restore the last
    checkpoint and finish with identical final state to a failure-free run
    (bitwise — the data pipeline is step-indexed)."""
    data = SyntheticLMData(vocab=16, seq_len=4, global_batch=2)

    def step_fn(state, batch):
        s = state["x"] + jnp.float32(batch["tokens"].sum())
        return {"x": s}, {"loss": s}

    fail_at = {17}

    def hook(step):
        if step in fail_at:
            fail_at.clear()
            raise RuntimeError("injected node failure")

    loop = ResilientLoop(step_fn=step_fn, state={"x": jnp.float32(0)},
                         data=data, ckpt_dir=tmp_path, ckpt_every=5,
                         failure_hook=hook)
    final = loop.run(25)
    assert loop.restarts == 1

    loop2 = ResilientLoop(step_fn=step_fn, state={"x": jnp.float32(0)},
                          data=data, ckpt_dir=str(tmp_path) + "_b",
                          ckpt_every=5)
    final2 = loop2.run(25)
    np.testing.assert_array_equal(np.asarray(final["x"]),
                                  np.asarray(final2["x"]))


def test_heartbeat_straggler_detection():
    hb = Heartbeat(window=10, threshold=1.5)
    for _ in range(10):
        for h in ("h0", "h1", "h2", "h3"):
            hb.record(h, 1.0 if h != "h2" else 3.0)
    assert hb.stragglers() == ["h2"]
    plan = RebalancePlan.from_heartbeat(hb, ["h0", "h1", "h2", "h3"])
    assert plan.shares["h2"] < plan.shares["h0"]
    assert abs(sum(plan.shares.values()) - 1.0) < 1e-9


def test_data_pipeline_deterministic_and_sharded():
    full = SyntheticLMData(vocab=97, seq_len=8, global_batch=8)
    s0 = SyntheticLMData(vocab=97, seq_len=8, global_batch=8, n_shards=2,
                         shard=0)
    b_full_a = full.batch_at(3)
    b_full_b = full.batch_at(3)
    np.testing.assert_array_equal(b_full_a["tokens"], b_full_b["tokens"])
    assert s0.batch_at(3)["tokens"].shape == (4, 8)
    # labels are next-token shifted
    np.testing.assert_array_equal(b_full_a["tokens"][:, 1:],
                                  b_full_a["labels"][:, :-1])
