"""Teacher-forced parity: running the decode path token-by-token must
reproduce the training forward's logits — per mixer family (attention KV
cache, Mamba conv+ssm state, RWKV wkv state + channel-mix shift)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, smoke_config
from repro.nn import transformer as T

FAMILIES = ["qwen2-1.5b", "rwkv6-1.6b", "jamba-1.5-large-398b",
            "phi3.5-moe-42b-a6.6b"]


def _parity_cfg(arch):
    """Reduced config in the *dropless* MoE regime: capacity-based dispatch
    legitimately drops different tokens in grouped (train) vs per-token
    (decode) dispatch, so exact parity is only defined when capacity is
    ample — the standard serving configuration."""
    cfg = smoke_config(get_config(arch))
    if cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=16.0))
    return cfg


@pytest.mark.parametrize("arch", FAMILIES)
def test_decode_matches_forward(arch):
    cfg = _parity_cfg(arch)
    key = jax.random.PRNGKey(0)
    params, _ = T.init_lm(key, cfg)
    b, l = 2, 8
    toks = jax.random.randint(key, (b, l), 0, cfg.vocab)
    ref_logits, _ = T.forward(params, cfg, tokens=toks)

    cache = T.init_cache(cfg, b, l)
    outs = []
    for t in range(l):
        logits, cache = T.decode_step(params, cfg, toks[:, t:t + 1], cache,
                                      jnp.int32(t))
        outs.append(logits)
    dec_logits = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec_logits, np.float32),
                               np.asarray(ref_logits, np.float32),
                               rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("arch", ["qwen2-1.5b", "jamba-1.5-large-398b"])
def test_prefill_then_decode(arch):
    """Prefill fills the cache; continuing with decode_step must match the
    full-sequence forward on the suffix."""
    cfg = _parity_cfg(arch)
    key = jax.random.PRNGKey(1)
    params, _ = T.init_lm(key, cfg)
    b, lp, ls = 2, 6, 3
    toks = jax.random.randint(key, (b, lp + ls), 0, cfg.vocab)
    full_logits, _ = T.forward(params, cfg, tokens=toks)

    _, _, cache = T.forward(params, cfg, tokens=toks[:, :lp],
                            return_cache=True, cache_len=lp + ls)
    outs = []
    for t in range(ls):
        logits, cache = T.decode_step(params, cfg, toks[:, lp + t:lp + t + 1],
                                      cache, jnp.int32(lp + t))
        outs.append(logits)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec, np.float32),
                               np.asarray(full_logits[:, lp:], np.float32),
                               rtol=2e-2, atol=2e-2)
