"""Serving layer: batched generate + continuous batching with lane refill."""
import jax
import numpy as np

from repro.configs import get_config, smoke_config
from repro.launch.serve import generate, serve_continuous
from repro.nn import transformer as T


def _setup(arch="qwen2-1.5b"):
    cfg = smoke_config(get_config(arch))
    params, _ = T.init_lm(jax.random.PRNGKey(0), cfg)
    return cfg, params


def test_generate_batch_matches_single():
    """Lockstep batched decode must equal one-at-a-time decode (greedy)."""
    cfg, params = _setup()
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, cfg.vocab, size=6) for _ in range(3)]
    batched = generate(params, cfg, prompts, max_new=5, max_len=32)
    for i, p in enumerate(prompts):
        single = generate(params, cfg, [p], max_new=5, max_len=32)
        assert batched[i] == single[0], i


def test_continuous_batching_serves_all_requests():
    cfg, params = _setup()
    rng = np.random.default_rng(1)
    reqs = [rng.integers(1, cfg.vocab, size=int(rng.integers(3, 7)))
            for _ in range(6)]
    out = serve_continuous(params, cfg, reqs, lanes=2, max_len=32,
                           max_new=4)
    assert set(out) == set(range(6))          # every request served
    assert all(1 <= len(v) <= 4 for v in out.values())
    assert all(0 <= t < cfg.vocab for v in out.values() for t in v)


def test_continuous_matches_dedicated_lane():
    """A request served through the continuous scheduler must produce the
    same greedy tokens as a dedicated generate() call."""
    cfg, params = _setup()
    rng = np.random.default_rng(2)
    reqs = [rng.integers(1, cfg.vocab, size=5) for _ in range(2)]
    cont = serve_continuous(params, cfg, reqs, lanes=2, max_len=32,
                            max_new=4, eos=-1)
    for i, p in enumerate(reqs):
        ded = generate(params, cfg, [p], max_new=4, max_len=32)
        assert cont[i] == ded[0], (i, cont[i], ded[0])
