"""Per-assigned-architecture smoke tests: reduced config of the same
family, one forward + one train step + one decode step on CPU; output
shapes + no NaNs (deliverable f)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, get_config, smoke_config
from repro.nn import transformer as T

ALL = sorted(ARCHS)


@pytest.mark.parametrize("arch", ALL)
def test_smoke_forward_and_train(arch):
    cfg = smoke_config(get_config(arch))
    key = jax.random.PRNGKey(0)
    params, specs = T.init_lm(key, cfg)
    b, l = 2, 16
    toks = jax.random.randint(key, (b, l), 0, cfg.vocab)
    labels = jax.random.randint(key, (b, l), 0, cfg.vocab)
    if cfg.frontend == "vision":
        embeds = jax.random.normal(key, (b, l, cfg.d_model), jnp.float32)
        logits, aux = T.forward(params, cfg, embeds=embeds)
        loss, grads = jax.value_and_grad(T.lm_loss_embeds)(
            params, cfg, embeds, labels)
    else:
        logits, aux = T.forward(params, cfg, tokens=toks)
        loss, grads = jax.value_and_grad(T.lm_loss)(params, cfg, toks, labels)
    assert logits.shape == (b, l, cfg.vocab)
    assert bool(jnp.isfinite(logits).all())
    assert bool(jnp.isfinite(loss))
    gn = jax.tree.reduce(lambda a, g: a + jnp.sum(g * g), grads, 0.0)
    assert bool(jnp.isfinite(gn)) and float(gn) > 0


@pytest.mark.parametrize("arch", ALL)
def test_smoke_decode(arch):
    cfg = smoke_config(get_config(arch))
    key = jax.random.PRNGKey(0)
    params, _ = T.init_lm(key, cfg)
    b, max_len = 2, 8
    cache = T.init_cache(cfg, b, max_len)
    tok = jax.random.randint(key, (b, 1), 0, cfg.vocab)
    for i in range(3):
        logits, cache = T.decode_step(params, cfg, tok, cache,
                                      jnp.int32(i))
        assert logits.shape == (b, 1, cfg.vocab)
        assert bool(jnp.isfinite(logits).all())
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)


@pytest.mark.parametrize("arch", ALL)
def test_param_specs_cover_params(arch):
    """Every param leaf has a logical-axis spec of matching rank."""
    cfg = smoke_config(get_config(arch))
    params, specs = T.init_lm(jax.random.PRNGKey(0), cfg)
    flat_p = jax.tree_util.tree_leaves_with_path(params)
    is_spec = lambda x: isinstance(x, tuple) and all(
        a is None or isinstance(a, str) for a in x)
    flat_s = jax.tree_util.tree_leaves(specs, is_leaf=is_spec)
    assert len(flat_p) == len(flat_s)
    for (_, p), s in zip(flat_p, flat_s):
        assert len(s) <= p.ndim, (s, p.shape)


def test_published_param_counts():
    """Analytic param counts must land near the published totals."""
    expect = {
        "qwen2-1.5b": 1.5e9, "qwen3-8b": 8.2e9, "internlm2-1.8b": 1.9e9,
        "smollm-360m": 0.36e9, "phi3.5-moe-42b-a6.6b": 42e9,
        "dbrx-132b": 132e9, "rwkv6-1.6b": 1.6e9,
        "jamba-1.5-large-398b": 398e9,
    }
    for name, target in expect.items():
        got = get_config(name).param_count()
        assert abs(got - target) / target < 0.12, (name, got, target)


def test_active_param_counts():
    assert abs(get_config("phi3.5-moe-42b-a6.6b").active_param_count()
               - 6.6e9) / 6.6e9 < 0.1
    assert abs(get_config("dbrx-132b").active_param_count()
               - 36e9) / 36e9 < 0.1
    assert abs(get_config("jamba-1.5-large-398b").active_param_count()
               - 94e9) / 94e9 < 0.1
