"""Distributed behaviour tests.  These run in *subprocesses* with
XLA_FLAGS=--xla_force_host_platform_device_count=8 so the main test
process (and the smoke tests) keep seeing exactly 1 device."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(body: str) -> str:
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys
        sys.path.insert(0, %r)
        import jax, jax.numpy as jnp
        import numpy as np
        assert len(jax.devices()) == 8
    """) % os.path.join(REPO, "src") + textwrap.dedent(body)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=420)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


def test_sharded_train_step_matches_single_device():
    out = run_sub("""
        from repro.configs import get_config, smoke_config
        from repro.launch.train import build
        from repro.launch.mesh import make_host_mesh
        from repro.data import SyntheticLMData
        import jax
        cfg = smoke_config(get_config("qwen2-1.5b"))
        data = SyntheticLMData(vocab=cfg.vocab, seq_len=16, global_batch=8)

        mesh1 = jax.make_mesh((1, 1), ("data", "model"))
        mesh8 = jax.make_mesh((4, 2), ("data", "model"))
        losses = {}
        for name, mesh in (("single", mesh1), ("sharded", mesh8)):
            state, step = build(cfg, mesh, lr=1e-2)
            ls = []
            for i in range(3):
                state, m = step(state, data.batch_at(i))
                ls.append(float(m["loss"]))
            losses[name] = ls
        for a, b in zip(losses["single"], losses["sharded"]):
            assert abs(a - b) < 2e-2, (losses)
        print("MATCH", losses["sharded"])
    """)
    assert "MATCH" in out


def test_production_mesh_axes():
    out = run_sub("""
        # make_mesh with 512 logical devices needs the flag; with 8 devices
        # we verify the function shape logic via a scaled-down equivalent.
        from repro.launch.mesh import make_host_mesh
        m = make_host_mesh(model=2)
        assert dict(m.shape) == {"data": 4, "model": 2}
        print("MESH-OK")
    """)
    assert "MESH-OK" in out


def test_compressed_psum_error_feedback():
    out = run_sub("""
        from functools import partial
        from jax.sharding import PartitionSpec as P
        from repro.optim.compress import compressed_psum
        import jax, jax.numpy as jnp, numpy as np
        shard_map = getattr(jax, "shard_map", None)
        if shard_map is None:  # pre-0.5 jax keeps it in experimental
            from jax.experimental.shard_map import shard_map
        mesh = jax.make_mesh((8,), ("data",))
        g = jax.random.normal(jax.random.PRNGKey(0), (8, 64))

        @partial(shard_map, mesh=mesh, in_specs=P("data"),
                 out_specs=P("data"))
        def allreduce_q(gs):
            out, resid = compressed_psum(gs[0], "data")
            return (out + 0 * resid.sum())[None]

        approx = allreduce_q(g)[0]
        exact = g.mean(axis=0)
        err = float(jnp.abs(approx - exact).max())
        assert err < 0.05, err
        print("PSUM-OK", err)
    """)
    assert "PSUM-OK" in out


def test_elastic_reshard_across_meshes(tmp_path):
    out = run_sub(f"""
        from repro.configs import get_config, smoke_config
        from repro.launch.train import build
        from repro.train import checkpoint as C
        from repro.train.fault_tolerance import elastic_reshard
        from repro.nn.partitioning import param_rules, to_shardings
        from repro.train.step import train_state_specs
        from repro.data import SyntheticLMData
        import jax, numpy as np

        cfg = smoke_config(get_config("qwen2-1.5b"))
        data = SyntheticLMData(vocab=cfg.vocab, seq_len=8, global_batch=8)

        # train 2 steps on a (2,4) mesh, checkpoint
        meshA = jax.make_mesh((2, 4), ("data", "model"))
        state, step = build(cfg, meshA, lr=1e-2)
        for i in range(2):
            state, _ = step(state, data.batch_at(i))
        C.save({str(tmp_path)!r}, 2, state)

        # restore onto a (8,1) mesh — different DP/TP split — and continue
        meshB = jax.make_mesh((8, 1), ("data", "model"))
        stateB, stepB = build(cfg, meshB, lr=1e-2)
        shardingsB = jax.tree.map(lambda x: x.sharding, stateB)
        restored = elastic_reshard({str(tmp_path)!r}, 2, stateB, shardingsB)
        restored, m = stepB(restored, data.batch_at(2))

        # reference: continue on mesh A
        state, mA = step(state, data.batch_at(2))
        assert abs(float(m["loss"]) - float(mA["loss"])) < 2e-2
        print("ELASTIC-OK", float(m["loss"]), float(mA["loss"]))
    """)
    assert "ELASTIC-OK" in out
