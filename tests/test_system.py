"""End-to-end behaviour tests for the whole system.

1. CNN (the paper's own workload): tiny ResNet through GxM converges.
2. LM (assigned archs substrate): tiny transformer converges on the
   learnable synthetic stream, through the full trainer (sharding rules,
   optimizer, resilient loop).
3. Serving: prefill+decode generates coherently (greedy argmax of a
   trained next-token structure).
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, smoke_config
from repro.data import SyntheticLMData
from repro.graph import GxM, resnet50
from repro.launch.train import build
from repro.launch.mesh import make_host_mesh


def test_cnn_end_to_end_convergence(rng):
    nl = resnet50(num_classes=4, stages=(1, 1, 1, 1))
    m = GxM(nl, impl="xla", num_classes=4)
    params = m.init(jax.random.PRNGKey(0))
    # fixed tiny dataset: must be able to overfit
    x = jnp.asarray(rng.standard_normal((8, 32, 32, 3)), jnp.float32)
    y = jnp.asarray(rng.integers(0, 4, 8))
    step = jax.jit(m.sgd_train_step)
    first = None
    for i in range(25):
        params, loss = step(params, {"image": x, "label": y}, lr=0.03)
        first = first if first is not None else float(loss)
    assert float(loss) < first * 0.7, (first, float(loss))


def test_lm_end_to_end_convergence():
    cfg = smoke_config(get_config("smollm-360m"))
    mesh = make_host_mesh()
    state, step = build(cfg, mesh, lr=3e-3)
    data = SyntheticLMData(vocab=cfg.vocab, seq_len=32, global_batch=8)
    losses = []
    for i in range(30):
        state, m = step(state, data.batch_at(i % 4))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.3, (losses[0], losses[-1])
    assert all(np.isfinite(l) for l in losses)


def test_serve_generates():
    from repro.launch.serve import generate
    from repro.nn import transformer as T
    cfg = smoke_config(get_config("qwen2-1.5b"))
    params, _ = T.init_lm(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, size=5) for _ in range(2)]
    outs = generate(params, cfg, prompts, max_new=4, max_len=32)
    assert len(outs) == 2 and all(len(o) == 4 for o in outs)
    assert all(0 <= t < cfg.vocab for o in outs for t in o)
