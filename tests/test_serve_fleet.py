"""The resilient serving fleet (repro.serve, DESIGN.md §15): simtime
substrate, seeded chaos schedules, every FleetRouter policy (deadlines,
backoff retries, hedging, eviction + warm-cache respawn, load shed,
degrade-to-int8) on the modeled path, and a real-engine fleet — including
the mid-burst f32 -> int8 degrade flip keeping top-1 parity and padded-lane
bit-invariance."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.simtime import SimClock, seeded_rng
from repro.serve import (FlakyInfer, FleetRouter, Replica, ReplicaDeath,
                         RequestBurst, ServeChaosEngine, ServeChaosSchedule,
                         SlowReplica, poisson_arrivals)
from repro.tune.cache import TuneCache


# -- simtime ------------------------------------------------------------------

def test_simclock_advance_to_is_monotone():
    clk = SimClock()
    clk.sleep(2.0)
    clk.advance_to(5.0)
    assert clk.time() == 5.0
    clk.advance_to(3.0)                   # never rewinds
    assert clk.time() == 5.0


def test_seeded_rng_deterministic_and_component_sensitive():
    a = seeded_rng(0xABC, 7).standard_normal(4)
    b = seeded_rng(0xABC, 7).standard_normal(4)
    c = seeded_rng(0xABC, 8).standard_normal(4)
    np.testing.assert_array_equal(a, b)
    assert not np.array_equal(a, c)


def test_train_chaos_reexports_simclock():
    # the PR-8 import surface must survive the extraction to core/simtime
    from repro.train import chaos as cz
    assert cz.SimClock is SimClock and cz.seeded_rng is seeded_rng


# -- chaos schedules ----------------------------------------------------------

def test_generated_schedule_deterministic_and_replica0_immortal():
    kw = dict(horizon_s=200.0, replicas=["r0", "r1", "r2"])
    a = ServeChaosSchedule.generate(3, **kw)
    b = ServeChaosSchedule.generate(3, **kw)
    assert a == b
    c = ServeChaosSchedule.generate(4, **kw)
    assert a != c
    for seed in range(8):
        s = ServeChaosSchedule.generate(seed, **kw)
        deaths = [e for e in s.events if isinstance(e, ReplicaDeath)]
        assert all(e.replica != "r0" for e in deaths)
        assert len(deaths) <= 2           # the fleet never empties


def test_engine_death_is_per_incarnation():
    eng = ServeChaosEngine(ServeChaosSchedule((ReplicaDeath(10.0, "r1"),)))
    assert not eng.is_dead("r1", 9.0)
    assert eng.is_dead("r1", 10.0) and eng.is_dead("r1", 50.0)
    # a respawn born after the death event is a fresh, healthy process
    assert not eng.is_dead("r1", 50.0, born=20.0)
    assert not eng.is_dead("r0", 50.0)


def test_engine_slow_window_and_flaky_tokens():
    eng = ServeChaosEngine(ServeChaosSchedule((
        SlowReplica(5.0, "r1", factor=3.0, until=10.0),
        FlakyInfer(20.0, "r0", times=2),
    )))
    assert eng.slow_factor("r1", 4.0) == 1.0
    assert eng.slow_factor("r1", 7.0) == 3.0
    assert eng.slow_factor("r1", 10.0) == 1.0   # recovered at `until`
    assert eng.take_infer_fault("r0", 19.0) is None
    assert eng.take_infer_fault("r0", 21.0) is not None
    assert eng.take_infer_fault("r0", 22.0) is not None
    assert eng.take_infer_fault("r0", 23.0) is None   # tokens exhausted


# -- the modeled fleet --------------------------------------------------------

def _fleet(n=3, tmp_path=None, warm=6):
    def make(name, *, seed_warm=True):
        cache = None
        if tmp_path is not None:
            cache = TuneCache(str(tmp_path / f"{name}.json"))
            if seed_warm:
                cache.merge_entries(
                    {f"sig{i}": {"blocking": {"hb": 4}, "source": "t",
                                 "score_us": 1.0} for i in range(warm)},
                    persist=False)
        # the cold penalty only models something when caches exist (a
        # cacheless replica would otherwise charge it on every first hit)
        return Replica(name, cache=cache, service_s=1.0,
                       cold_service_s=3.0 if cache is not None else 0.0)

    replicas = [make(f"r{i}") for i in range(n)]
    return replicas, lambda name: make(name, seed_warm=False)


def _arrivals(n=30, rate=1.5):
    return poisson_arrivals(0, n=n, rate_per_s=rate)


def test_fault_free_run_meets_every_deadline():
    replicas, _ = _fleet()
    router = FleetRouter(replicas, deadline_s=6.0)
    rep = router.run(_arrivals())
    assert rep["offered"] == rep["completed"] == rep["in_deadline"] == 30
    assert rep["goodput"] == 1.0 and rep["shed"] == rep["failed"] == 0
    assert rep["evictions"] == rep["hedges"] == rep["retries"] == 0


def test_run_is_bit_deterministic():
    import json
    outs = []
    for _ in range(2):
        replicas, _ = _fleet()
        chaos = ServeChaosEngine(ServeChaosSchedule((
            SlowReplica(3.0, "r1", factor=4.0, until=12.0),
            FlakyInfer(6.0, "r2"), RequestBurst(9.0, 8))))
        router = FleetRouter(replicas, chaos=chaos, deadline_s=6.0)
        outs.append(json.dumps(router.run(_arrivals()), sort_keys=True))
    assert outs[0] == outs[1]


def test_dead_replica_evicted_and_respawned_with_warm_cache(tmp_path):
    replicas, factory = _fleet(tmp_path=tmp_path)
    chaos = ServeChaosEngine(ServeChaosSchedule((ReplicaDeath(5.0, "r1"),)))
    router = FleetRouter(replicas, chaos=chaos, deadline_s=8.0,
                         replica_factory=factory)
    rep = router.run(_arrivals(40))
    assert rep["evictions"] == 1 and rep["respawns"] == 1
    # the respawn was re-seeded from a survivor, never re-tunes cold
    assert rep["reseeded_entries"] == 6
    respawn = next(e for e in rep["events"] if e["kind"] == "respawn")
    assert respawn["warm"] and respawn["replica"] == "r1"
    assert router.live["r1"].warm_entries() == 6
    # the second incarnation serves again (health-armed, born reset)
    assert router.born["r1"] > 5.0
    assert rep["failed"] == 0 and rep["slo_handled_rate"] == 1.0


def test_cold_respawn_pays_tune_penalty_without_reseed(tmp_path):
    replicas, factory = _fleet(tmp_path=tmp_path)
    cold = factory("rX")
    assert cold.warm_entries() == 0
    assert cold.service_time() == 1.0 + 3.0       # cold first dispatch
    warm = replicas[0]
    assert warm.service_time() == 1.0             # warm never pays
    cold.seed_warm(warm.export_warm())
    assert cold.service_time() == 1.0             # reseed removes the penalty


def test_straggler_is_hedged_and_first_completion_wins():
    replicas, _ = _fleet()
    chaos = ServeChaosEngine(ServeChaosSchedule((
        SlowReplica(0.0, "r1", factor=10.0),)))
    router = FleetRouter(replicas, chaos=chaos, deadline_s=6.0,
                         hedge_after_s=1.5)
    rep = router.run(_arrivals(20))
    assert rep["hedges"] > 0
    cancels = [e for e in rep["events"] if e["kind"] == "hedge_cancel"]
    assert cancels, "the losing twin was never cancelled"
    hedged = [r for r in router.requests.values() if r.hedged]
    assert hedged and all(r.status == "done" for r in hedged)
    assert rep["goodput"] == 1.0


def test_flaky_dispatch_retries_with_backoff_on_other_replica():
    replicas, _ = _fleet()
    chaos = ServeChaosEngine(ServeChaosSchedule((FlakyInfer(0.0, "r0",
                                                            times=2),)))
    router = FleetRouter(replicas, chaos=chaos, deadline_s=6.0)
    rep = router.run(_arrivals(10))
    assert rep["retries"] == 2 and rep["failed"] == 0
    backoffs = [e for e in rep["events"] if e["kind"] == "retry_backoff"]
    assert [b["delay_s"] for b in backoffs] == [0.25, 0.25]
    retried = [r for r in router.requests.values() if r.retries]
    # the retry landed on a replica the request hadn't failed on
    for r in retried:
        assert r.status == "done"
        assert r.dispatches[-1][0] not in r.avoid


def test_retries_are_bounded():
    replicas, _ = _fleet(n=2)
    chaos = ServeChaosEngine(ServeChaosSchedule(
        tuple(FlakyInfer(0.0, f"r{i}", times=50) for i in range(2))))
    router = FleetRouter(replicas, chaos=chaos, deadline_s=30.0,
                         max_retries=2, hedge_after_s=None)
    rep = router.run([(0.0, None)])
    assert rep["failed"] == 1 and rep["retries"] == 3   # 1 + max_retries
    assert any(e["kind"] == "retries_exhausted" for e in rep["events"])


def test_overload_sheds_beyond_queue_bound_and_degrades_beyond_slo():
    replicas, _ = _fleet()
    chaos = ServeChaosEngine(ServeChaosSchedule((RequestBurst(5.0, 60),)))
    router = FleetRouter(replicas, chaos=chaos, deadline_s=6.0,
                         queue_bound=20)
    rep = router.run(_arrivals(30))
    assert rep["shed"] > 0 and rep["degraded_completed"] > 0
    assert rep["failed"] == 0
    # the §15 invariant: every admitted request completes in deadline or
    # rides the int8 degrade path — nothing silently busts its SLO
    assert rep["slo_handled_rate"] == 1.0
    kinds = {e["kind"] for e in rep["events"]}
    assert "shed" in kinds and "degrade_admission" in kinds


def test_degrade_disabled_rejects_nothing_but_busts_deadlines():
    replicas, _ = _fleet()
    chaos = ServeChaosEngine(ServeChaosSchedule((RequestBurst(5.0, 40),)))
    kw = dict(chaos=chaos, deadline_s=6.0, queue_bound=100)
    on = FleetRouter(_fleet()[0], degrade=True, **kw).run(_arrivals(20))
    off = FleetRouter(replicas, degrade=False, **kw).run(_arrivals(20))
    assert on["slo_handled_rate"] == 1.0
    assert off["degraded_completed"] == 0
    assert off["slo_handled_rate"] < 1.0     # deep arrivals bust deadlines
    assert on["goodput"] >= off["goodput"]


# -- the real-engine fleet ----------------------------------------------------

def _engine_pair(params):
    """f32 + quantized-twin CnnInferenceEngine pair on tiny topology."""
    from repro.graph import GxM, resnet50
    from repro.graph.serving import CnnInferenceEngine
    from repro.launch.mesh import make_host_mesh
    nl = resnet50(num_classes=10, stages=(1, 1, 1, 1))
    mesh = make_host_mesh()
    f32 = CnnInferenceEngine(GxM(nl, num_classes=10, impl="interpret"),
                             params, image_hw=(32, 32), mesh=mesh,
                             buckets=(2,))
    f32.warmup(autotune="off")
    q8 = CnnInferenceEngine(
        GxM(nl, num_classes=10, impl="interpret", quantized=True),
        params, image_hw=(32, 32), mesh=mesh, buckets=(2,))
    q8.warmup(autotune="off")
    return f32, q8


@pytest.fixture(scope="module")
def engine_pair():
    from repro.graph import GxM, resnet50
    nl = resnet50(num_classes=10, stages=(1, 1, 1, 1))
    params = GxM(nl, num_classes=10).init(jax.random.PRNGKey(0))
    return params, _engine_pair(params)


def test_real_fleet_degrade_flip_keeps_top1_and_lane_invariance(engine_pair,
                                                                rng):
    """Satellite 4: a request the router flips to the int8 twin mid-burst
    must agree with the f32 engine on top-1, and the q8 twin's padded lane
    must stay bit-invisible under the flip."""
    params, (f32, q8) = engine_pair
    replicas = [Replica(f"r{i}", infer_fn=f32.infer, q8_infer_fn=q8.infer,
                        service_s=1.0) for i in range(2)]
    images = rng.standard_normal((10, 32, 32, 3)).astype(np.float32)
    chaos = ServeChaosEngine(ServeChaosSchedule((RequestBurst(0.5, 6),)))
    router = FleetRouter(replicas, chaos=chaos, deadline_s=3.0,
                         queue_bound=64, slo_depth=2,
                         burst_image_fn=lambda i: images[4 + i])
    rep = router.run([(0.1 * i, images[i]) for i in range(4)])
    assert rep["completed"] == rep["offered"] == 10
    assert rep["degraded_completed"] > 0, "the burst never forced a degrade"
    assert rep["slo_handled_rate"] == 1.0

    ref = np.asarray(f32.gxm.forward(params, jnp.asarray(images),
                                     train=False))
    by_image = {4 + i: img for i, img in enumerate(images[4:])}
    by_image.update({i: images[i] for i in range(4)})
    for req in router.requests.values():
        assert req.result is not None
        # identify which source image this request carried
        idx = next(i for i, img in by_image.items()
                   if np.array_equal(img, req.image))
        assert int(np.argmax(req.result)) == int(np.argmax(ref[idx])), \
            (idx, req.degraded)
        if not req.degraded:
            # the router returned exactly what the f32 engine serves for
            # this image (same bucket shape: bit-exact by construction)
            np.testing.assert_array_equal(
                req.result, np.asarray(f32.infer(req.image[None]))[0])

    # padded-lane bit-invariance on the degrade path: the q8 twin serving
    # a single flipped request (pad 1 -> bucket 2) must match the same
    # image in a junk-padded lane bit for bit
    flipped = next(r for r in router.requests.values() if r.degraded)
    solo = np.asarray(q8.infer(np.asarray(flipped.image)[None]))[0]
    np.testing.assert_array_equal(solo, flipped.result)
    fn = q8.aot_executable(2)
    junk = 100 * rng.standard_normal((1, 32, 32, 3)).astype(np.float32)
    padded = fn(q8._run_params,
                jnp.asarray(np.stack([flipped.image, junk[0]])))
    np.testing.assert_array_equal(np.asarray(padded)[0], flipped.result)
