"""int8 quantization (§II-K analog) + analytic roofline sanity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, SHAPES, get_config, smoke_config
from repro.configs.shapes import applicable
from repro.core.quantize import dequantize, quantize_int8, quantized_specs
from repro.launch import analytic as A
from repro.nn import transformer as T


def test_quantize_roundtrip_error_bounded(rng):
    w = jnp.asarray(rng.standard_normal((64, 32)) * 3.0, jnp.float32)
    tree = {"w": w, "small": jnp.ones((4,))}
    q = quantize_int8(tree, min_size=64)
    assert q["w"]["q"].dtype == jnp.int8
    assert q["small"].dtype == jnp.float32          # passthrough
    deq = dequantize(q, jnp.float32)
    err = np.abs(np.asarray(deq["w"]) - np.asarray(w))
    per_col_scale = np.abs(np.asarray(w)).max(0) / 127.0
    assert (err <= per_col_scale[None, :] * 0.51 + 1e-6).all()


def test_quantized_model_logits_close():
    cfg = smoke_config(get_config("qwen2-1.5b"))
    params, specs = T.init_lm(jax.random.PRNGKey(0), cfg)
    qp = quantize_int8(params, min_size=64)
    qs = quantized_specs(specs, params, min_size=64)
    # spec tree mirrors the quantized structure (specs are tuple leaves)
    is_spec = lambda x: isinstance(x, tuple) and all(
        a is None or isinstance(a, str) for a in x)

    def paths(t, is_leaf=None):
        flat, _ = jax.tree_util.tree_flatten_with_path(t, is_leaf=is_leaf)
        return {tuple(str(p) for p in path) for path, _ in flat}
    assert paths(qp) == paths(qs, is_leaf=is_spec)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab)
    lf, _ = T.forward(params, cfg, tokens=toks)
    lq, _ = T.forward(dequantize(qp, jnp.float32), cfg, tokens=toks)
    drift = float(jnp.abs(jax.nn.softmax(lf) - jax.nn.softmax(lq)).max())
    assert drift < 0.05, drift


@pytest.mark.parametrize("mesh", [(256, 16, 16), (512, 32, 16)])
def test_analytic_terms_sane(mesh):
    chips, dp, mp = mesh
    for arch in sorted(ARCHS):
        cfg = get_config(arch)
        for shape in SHAPES.values():
            if not applicable(cfg, shape)[0]:
                continue
            t = A.analytic_roofline(cfg, shape, chips=chips, model_par=mp,
                                    data_par=dp)
            assert t.compute_s > 0 and t.memory_s > 0
            assert t.collective_s >= 0
            assert 0 < A.mfu(cfg, shape, t, chips) <= 1.0, (arch, shape.name)


def test_profiles_reduce_collectives():
    """The §Perf levers must move the analytic terms the claimed way."""
    import dataclasses
    shape = SHAPES["train_4k"]
    cfg = get_config("smollm-360m")
    base = A.analytic_roofline(cfg, shape, chips=256, model_par=16,
                               data_par=16)
    ddp = A.analytic_roofline(dataclasses.replace(cfg, sharding="ddp"),
                              shape, chips=256, model_par=16, data_par=16)
    assert ddp.collective_s < base.collective_s / 5
    assert ddp.dominant == "compute"

    dec = SHAPES["decode_32k"]
    cfgj = get_config("jamba-1.5-large-398b")
    b = A.analytic_roofline(cfgj, dec, chips=256, model_par=16, data_par=16)
    q = A.analytic_roofline(cfgj, dec, chips=256, model_par=16, data_par=16,
                            quantized=True)
    assert 1.8 < b.step_time_s / q.step_time_s < 2.2


def test_quantization_halves_weight_bytes():
    cfg = smoke_config(get_config("qwen3-8b"))
    params, _ = T.init_lm(jax.random.PRNGKey(0), cfg)
    def nbytes(t):
        return sum(x.size * x.dtype.itemsize
                   for x in jax.tree.leaves(t))
    full = nbytes(jax.tree.map(lambda x: x.astype(jnp.bfloat16), params))
    quant = nbytes(quantize_int8(params, min_size=64))
    assert quant < 0.65 * full
