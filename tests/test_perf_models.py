"""Property tests for the analytic models the perf gate trusts: the
block-refetch traffic model (tune.measure.conv_traffic), the band working
set (core.blocking.conv_working_set), and the roofline cost functions
(launch.roofline) — plus the stable-key contracts the perfci extractors
join on, and the depth-first chain pricing (chain_traffic / chain_roofline)
whose fallback rule makes "fused <= unfused" true on every shape."""
from hypothesis import given, settings, strategies as st

from repro.core.blocking import ConvBlocking, conv_working_set
from repro.launch.roofline import (CHAIN_ROOFLINE_KEYS,
                                   COMPOSITE_ROOFLINE_KEYS,
                                   KERNEL_ROOFLINE_KEYS, chain_roofline,
                                   composite_roofline, kernel_roofline)
from repro.tune.measure import (CHAIN_TRAFFIC_KEYS, CONV_TRAFFIC_KEYS,
                                chain_traffic, conv_traffic)

_shapes = st.tuples(
    st.integers(7, 28),            # h == w
    st.sampled_from([32, 64, 96]),  # c
    st.sampled_from([32, 64, 128]),  # k
    st.sampled_from([(1, 0), (3, 1)]),  # (r, padding)
    st.integers(1, 2),             # stride
)


def _shape(h, c, k, rs_pad, stride):
    r, pad = rs_pad
    return {"h": h, "w": h, "c": c, "k": k, "r": r, "s": r,
            "stride": stride, "padding": pad}


def _blk(shape):
    return ConvBlocking(rb_p=2, k_blk=min(shape["k"], 64),
                        c_blk=min(shape["c"], 32), order="nkpc",
                        vmem_bytes=0, rb_q=4)


@settings(max_examples=25)
@given(_shapes, st.integers(1, 4))
def test_traffic_nondecreasing_in_minibatch(draw, n):
    shape = _shape(*draw)
    blk = _blk(shape)
    small = conv_traffic(shape, blk, minibatch=n)
    big = conv_traffic(shape, blk, minibatch=n + 1)
    assert big["hbm_bytes"] >= small["hbm_bytes"]
    assert big["flops"] > small["flops"]
    assert big["n_steps"] >= small["n_steps"]


@settings(max_examples=25)
@given(_shapes, st.sampled_from(["fwd", "wu"]),
       st.booleans())
def test_traffic_nondecreasing_in_plane_size(draw, kind, whole_plane):
    """More pixels never means less modeled work, whatever the schedule."""
    shape = _shape(*draw)
    blk = _blk(shape)
    bigger = dict(shape, h=shape["h"] + 7, w=shape["w"] + 7)
    t0 = conv_traffic(shape, blk, kind=kind, whole_plane=whole_plane)
    t1 = conv_traffic(bigger, blk, kind=kind, whole_plane=whole_plane)
    assert t1["hbm_bytes"] >= t0["hbm_bytes"]
    assert t1["flops"] > t0["flops"]


@settings(max_examples=25)
@given(_shapes, st.integers(1, 4), st.integers(1, 8),
       st.sampled_from(["fwd", "wu"]))
def test_band_working_set_independent_of_plane(draw, rb_p, rb_q, kind):
    """The §II-B claim the tiling rests on: for a fixed (rb_p, rb_q, c_blk)
    band, per-step VMEM is the same at 7x7 and at 224x224 — only the
    whole-plane legacy schedule scales with H*W."""
    shape = _shape(*draw)
    kw = dict(c=shape["c"], k_blk=64, r=shape["r"], s=shape["s"],
              rb_p=rb_p, rb_q=rb_q, c_blk=32, padding=shape["padding"],
              stride=shape["stride"], kind=kind)
    q_of = lambda w: (w + 2 * shape["padding"] - shape["s"]) \
        // shape["stride"] + 1
    ws = conv_working_set(h=shape["h"], w=shape["w"], q=q_of(shape["w"]),
                          **kw)
    ws_big = conv_working_set(h=224, w=224, q=q_of(224), **kw)
    assert ws == ws_big
    # while the resident-plane model must grow with the image
    wp = conv_working_set(h=shape["h"], w=shape["w"], q=q_of(shape["w"]),
                          whole_plane=True, **kw)
    wp_big = conv_working_set(h=224, w=224, q=q_of(224), whole_plane=True,
                              **kw)
    assert wp_big > wp


@settings(max_examples=50)
@given(st.floats(1e6, 1e15), st.floats(1.0, 1e12),
       st.floats(0.05, 1.0), st.integers(0, 100000))
def test_kernel_roofline_efficiency_in_unit_interval(flops, hbm, util,
                                                     n_steps):
    roof = kernel_roofline(flops=flops, hbm_bytes=hbm, util=util,
                           n_steps=n_steps)
    assert 0.0 < roof["efficiency"] <= 1.0
    assert roof["cost_s"] >= roof["step_time_s"] > 0.0
    assert roof["dominant"] in ("compute", "memory")


@settings(max_examples=25)
@given(st.lists(st.tuples(st.floats(1e6, 1e12), st.floats(1.0, 1e9),
                          st.floats(0.05, 1.0), st.integers(0, 1000)),
                min_size=1, max_size=4),
       st.floats(0.0, 1e9))
def test_composite_roofline_efficiency_and_conservation(parts, extra):
    dicts = [{"flops": f, "hbm_bytes": b, "util": u, "n_steps": n}
             for f, b, u, n in parts]
    roof = composite_roofline(dicts, extra_hbm_bytes=extra)
    assert 0.0 < roof["efficiency"] <= 1.0
    assert roof["launches"] == len(dicts)
    assert abs(roof["flops"] - sum(d["flops"] for d in dicts)) < 1e-6
    assert roof["hbm_bytes"] >= extra
    # serialized launches: composite cost >= any single launch's cost
    solo = kernel_roofline(**{k: dicts[0][k] for k in
                              ("flops", "hbm_bytes", "util", "n_steps")})
    assert roof["cost_s"] >= solo["cost_s"] - 1e-12


def test_stable_key_contracts():
    """The perfci extractors join on these names; renaming any of them is a
    baseline-schema change (bump perfci.SCHEMA_VERSION)."""
    shape = _shape(14, 64, 64, (3, 1), 1)
    t = conv_traffic(shape, _blk(shape))
    assert set(CONV_TRAFFIC_KEYS) <= set(t)
    roof = kernel_roofline(flops=1e9, hbm_bytes=1e6)
    assert tuple(roof) == KERNEL_ROOFLINE_KEYS
    comp = composite_roofline([t])
    assert tuple(comp) == COMPOSITE_ROOFLINE_KEYS


# -- depth-first chain pricing (DESIGN.md §16) -------------------------------

_chain_layers = st.lists(
    st.tuples(st.sampled_from([1, 3]),          # r == s
              st.integers(1, 2),                # stride
              st.sampled_from([8, 16, 32])),    # k
    min_size=2, max_size=4)


def _chain_shapes(h0, layers):
    shapes, h, c = [], h0, 8
    for r, stride, k in layers:
        pad = r // 2
        shapes.append({"h": h, "w": h, "c": c, "k": k, "r": r, "s": r,
                       "stride": stride, "padding": pad})
        h = (h + 2 * pad - r) // stride + 1
        c = k
    return shapes


@settings(max_examples=30)
@given(st.integers(16, 40), _chain_layers,
       st.sampled_from([1 << 18, 1 << 20, None]))
def test_chain_fused_never_exceeds_unfused(h0, layers, budget):
    """The fallback rule makes "fused <= unfused HBM" true on *every*
    generated chain and budget — exactly equal when the chain falls back,
    with zero intermediate bytes whenever it fuses."""
    t = chain_traffic(_chain_shapes(h0, layers), vmem_budget=budget)
    assert t["hbm_bytes"] <= t["unfused_hbm_bytes"] + 1e-6
    assert t["n_layers"] == len(layers)
    if t["fused"]:
        assert t["fits_vmem"]
        assert t["intermediate_bytes"] == 0.0
        if all(stride == 1 for _, stride, _k in layers):
            # stride-1 chains: bands cover every intermediate row, so halo
            # recompute can only add FLOPs (a strided consumer may instead
            # *skip* trailing producer rows the unfused path computes)
            assert t["flops"] >= sum(p["flops"]
                                     for p in t["unfused_parts"]) - 1e-6
    else:
        assert t["hbm_bytes"] == t["unfused_hbm_bytes"]
        assert t["intermediate_bytes"] == t["unfused_intermediate_bytes"]
        assert t["intermediate_bytes"] > 0.0


@settings(max_examples=30)
@given(st.integers(16, 40), _chain_layers,
       st.sampled_from([1 << 18, 1 << 20, None]))
def test_chain_roofline_consistent_with_traffic(h0, layers, budget):
    t = chain_traffic(_chain_shapes(h0, layers), vmem_budget=budget)
    roof = chain_roofline(t)
    assert tuple(roof) == CHAIN_ROOFLINE_KEYS
    assert roof["hbm_bytes"] == t["hbm_bytes"]
    assert roof["fused"] == t["fused"]
    assert 0.0 < roof["efficiency"] <= 1.0
    if not t["fused"]:
        # fallback prices the identical launch list: speedup exactly 1
        assert roof["speedup"] == 1.0
        assert roof["cost_s"] == roof["unfused_cost_s"]


def test_chain_stable_key_contracts():
    """perfci joins on these names too (SCHEMA_VERSION bump on rename)."""
    shapes = _chain_shapes(28, [(1, 1, 16), (3, 2, 16), (1, 1, 32)])
    t = chain_traffic(shapes)
    assert set(CHAIN_TRAFFIC_KEYS) <= set(t)
    assert tuple(chain_roofline(t)) == CHAIN_ROOFLINE_KEYS
