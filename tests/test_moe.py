"""MoE dispatch invariants (hypothesis) + correctness vs a brute-force
token-loop reference."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.configs import get_config, smoke_config
from repro.nn import moe


def _cfg(e=4, k=2, cf=2.0):
    base = smoke_config(get_config("phi3.5-moe-42b-a6.6b"))
    return dataclasses.replace(
        base, moe=dataclasses.replace(base.moe, n_experts=e, top_k=k,
                                      capacity_factor=cf))


def test_moe_matches_bruteforce_at_high_capacity(rng):
    """With capacity >= tokens, nothing is dropped: the grouped dispatch
    must equal the naive per-token top-k mixture."""
    cfg = _cfg(e=4, k=2, cf=8.0)
    key = jax.random.PRNGKey(0)
    p, _ = moe.init(key, cfg, jnp.float32)
    x = jnp.asarray(rng.standard_normal((2, 8, cfg.d_model)), jnp.float32)
    out, _ = moe.apply(p, cfg, x)

    xt = np.asarray(x).reshape(-1, cfg.d_model)
    logits = xt @ np.asarray(p["router"])
    probs = jax.nn.softmax(jnp.asarray(logits), axis=-1)
    topv, topi = jax.lax.top_k(probs, 2)
    topv = np.asarray(topv / topv.sum(-1, keepdims=True))
    topi = np.asarray(topi)
    expect = np.zeros_like(xt)
    for t in range(xt.shape[0]):
        for s in range(2):
            e = topi[t, s]
            g = np.asarray(jax.nn.silu(xt[t] @ np.asarray(p["w_gate"][e])))
            u = xt[t] @ np.asarray(p["w_up"][e])
            expect[t] += topv[t, s] * ((g * u) @ np.asarray(p["w_down"][e]))
    np.testing.assert_allclose(np.asarray(out).reshape(-1, cfg.d_model),
                               expect, rtol=2e-3, atol=2e-3)


@settings(max_examples=10, deadline=None)
@given(e=st.sampled_from([2, 4]), k=st.integers(1, 2),
       cf=st.sampled_from([0.5, 1.0, 4.0]), seed=st.integers(0, 2**31 - 1))
def test_moe_dispatch_invariants(e, k, cf, seed):
    cfg = _cfg(e=e, k=k, cf=cf)
    rng = np.random.default_rng(seed)
    key = jax.random.PRNGKey(seed % 1000)
    p, _ = moe.init(key, cfg, jnp.float32)
    x = jnp.asarray(rng.standard_normal((1, 16, cfg.d_model)), jnp.float32)
    out, aux = moe.apply(p, cfg, x)
    assert out.shape == x.shape
    assert bool(jnp.isfinite(out).all())
    assert float(aux["lb_loss"]) >= 0.99  # >= 1 at optimum for uniform
    assert np.isfinite(float(aux["z_loss"]))


def test_moe_capacity_drops_overflow(rng):
    """With tiny capacity, output rows for dropped tokens are ~zero (they
    received no expert contribution)."""
    cfg = _cfg(e=2, k=1, cf=0.1)
    key = jax.random.PRNGKey(3)
    p, _ = moe.init(key, cfg, jnp.float32)
    x = jnp.asarray(rng.standard_normal((1, 32, cfg.d_model)), jnp.float32)
    out, _ = moe.apply(p, cfg, x)
    norms = np.linalg.norm(np.asarray(out)[0], axis=-1)
    # capacity = 0.1*32/2 -> 1 slot per expert: at most 2 non-zero rows
    assert (norms > 1e-6).sum() <= 2
