"""BENCH_train_scaling invariants: the modeled DP-training scaling table
(the acceptance bar of the paper's multi-node claim) — 2-device fp32
efficiency stays ≥ 0.8, int8 compression never scales worse than fp32, and
the committed JSON matches what the model generates (the file other
sessions diff against)."""
import json
import pathlib

from benchmarks.train_scaling_bench import (BYTES_PER_PARAM, DEVICE_COUNTS,
                                            OUT_PATH, REDUCTIONS,
                                            build_report, step_times_s)


def _cell(rows, devices, reduction):
    return next(r for r in rows
                if r["devices"] == devices and r["reduction"] == reduction)


def test_table_covers_device_and_reduction_grid():
    rows = build_report()["rows"]
    assert {(r["devices"], r["reduction"]) for r in rows} == \
        {(d, red) for d in DEVICE_COUNTS for red in REDUCTIONS}
    assert set(DEVICE_COUNTS) == {1, 2, 4}
    assert set(REDUCTIONS) == {"fp32", "int8"}


def test_scaling_efficiency_acceptance():
    rows = build_report()["rows"]
    # the acceptance bar: 2-device fp32 efficiency >= 0.8
    assert _cell(rows, 2, "fp32")["scaling_efficiency"] >= 0.8
    for red in REDUCTIONS:
        assert _cell(rows, 1, red)["scaling_efficiency"] == 1.0
    for d in DEVICE_COUNTS:
        f, q = _cell(rows, d, "fp32"), _cell(rows, d, "int8")
        # compressed reduction never scales worse, on either bound
        assert q["scaling_efficiency"] >= f["scaling_efficiency"], d
        assert q["no_overlap_efficiency"] >= f["no_overlap_efficiency"], d
        # efficiency is throughput/n normalized: consistent with images/s
        assert q["images_per_s"] >= f["images_per_s"], d


def test_int8_moves_quarter_the_bytes():
    assert BYTES_PER_PARAM["int8"] * 4 == BYTES_PER_PARAM["fp32"]
    rows = build_report()["rows"]
    for d in (2, 4):
        f, q = _cell(rows, d, "fp32"), _cell(rows, d, "int8")
        assert q["wire_bytes_per_step"] * 4 == f["wire_bytes_per_step"]
        _, t_ar_f, _ = step_times_s(d, "fp32")
        _, t_ar_q, _ = step_times_s(d, "int8")
        assert abs(t_ar_q * 4 - t_ar_f) < 1e-12


def test_committed_json_matches_model():
    committed = json.loads(pathlib.Path(OUT_PATH).read_text())
    assert committed == build_report(), \
        "regenerate with: python -m benchmarks.train_scaling_bench"
