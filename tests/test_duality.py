"""Backward-by-duality (§II-I/J): the custom-VJP training conv must match
jax autodiff of the reference conv for every scenario, on both the xla and
interpret (Pallas) backends."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import duality
from repro.core.conv import conv2d_train
from repro.kernels import ref

SCENARIOS = [
    # (h, c, k, r, stride, pad, label)
    (8, 8, 16, 3, 1, 1, "stride1"),
    (8, 8, 8, 1, 2, 0, "1x1_strided"),
    (16, 8, 8, 3, 2, 1, "generic"),
    (9, 8, 8, 3, 2, 1, "generic_odd"),
    (11, 8, 8, 5, 3, 2, "generic_aggressive"),
]


@pytest.mark.parametrize("impl", ["xla", "interpret"])
@pytest.mark.parametrize("case", SCENARIOS, ids=[c[-1] for c in SCENARIOS])
def test_custom_vjp_matches_autodiff(rng, impl, case):
    h, c, k, r, stride, pad, _ = case
    x = jnp.asarray(rng.standard_normal((2, h, h, c)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((r, r, c, k)) * 0.1, jnp.float32)

    def loss_kernel(x, w):
        return jnp.sum(jnp.sin(conv2d_train(x, w, stride, pad, impl)))

    def loss_ref(x, w):
        return jnp.sum(jnp.sin(ref.conv2d(x, w, stride=stride, padding=pad)))

    gx, gw = jax.grad(loss_kernel, argnums=(0, 1))(x, w)
    ex, ew = jax.grad(loss_ref, argnums=(0, 1))(x, w)
    np.testing.assert_allclose(np.asarray(gx), np.asarray(ex),
                               rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(gw), np.asarray(ew),
                               rtol=1e-3, atol=1e-3)


def test_weight_transform_involution(rng):
    """W'' == W: the duality transform is its own inverse."""
    w = jnp.asarray(rng.standard_normal((3, 3, 8, 16)), jnp.float32)
    wt = duality.transform_weights(duality.transform_weights(w))
    np.testing.assert_array_equal(np.asarray(wt), np.asarray(w))


def test_bwd_plan_scenarios():
    assert duality.bwd_data_plan(r=3, s=3, stride=1, padding=1,
                                 input_hw=(8, 8))[0] == "stride1"
    assert duality.bwd_data_plan(r=1, s=1, stride=2, padding=0,
                                 input_hw=(8, 8))[0] == "1x1"
    assert duality.bwd_data_plan(r=3, s=3, stride=2, padding=1,
                                 input_hw=(8, 8))[0] == "generic"


@settings(max_examples=15, deadline=None)
@given(h=st.integers(6, 14), r=st.sampled_from([1, 3]),
       stride=st.integers(1, 3), seed=st.integers(0, 2**31 - 1))
def test_duality_property(h, r, stride, seed):
    rng = np.random.default_rng(seed)
    pad = r // 2
    if h + 2 * pad < r:
        return
    x = jnp.asarray(rng.standard_normal((1, h, h, 8)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((r, r, 8, 8)) * 0.1, jnp.float32)

    def f_kernel(x):
        return jnp.sum(conv2d_train(x, w, stride, pad, "xla") ** 2)

    def f_ref(x):
        return jnp.sum(ref.conv2d(x, w, stride=stride, padding=pad) ** 2)

    gx = jax.grad(f_kernel)(x)
    ex = jax.grad(f_ref)(x)
    np.testing.assert_allclose(np.asarray(gx), np.asarray(ex),
                               rtol=1e-3, atol=1e-3)
