"""Backward-by-duality (§II-I/J): the custom-VJP training conv must match
jax autodiff of the reference conv for every scenario, on both the xla and
interpret (Pallas) backends; the phase-decomposed strided plan (zero-free)
must agree with the legacy dilate plan and must never materialize a dilated
dO on the default path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro import backend as be
from repro.core import duality
from repro.core.conv import conv2d_bwd_data_via_fwd, conv2d_train
from repro.kernels import ref

SCENARIOS = [
    # (h, c, k, r, stride, pad, label)
    (8, 8, 16, 3, 1, 1, "stride1"),
    (8, 8, 8, 1, 2, 0, "1x1_strided"),
    (16, 8, 8, 3, 2, 1, "generic"),
    (9, 8, 8, 3, 2, 1, "generic_odd"),
    (11, 8, 8, 5, 3, 2, "generic_aggressive"),
    (24, 8, 16, 7, 2, 3, "stem_7x7_s2"),
    (13, 24, 40, 3, 2, 1, "nondivisor_pck_tails"),
]


@pytest.mark.parametrize("impl", ["xla", "interpret"])
@pytest.mark.parametrize("case", SCENARIOS, ids=[c[-1] for c in SCENARIOS])
def test_custom_vjp_matches_autodiff(rng, impl, case):
    h, c, k, r, stride, pad, _ = case
    x = jnp.asarray(rng.standard_normal((2, h, h, c)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((r, r, c, k)) * 0.1, jnp.float32)

    def loss_kernel(x, w):
        return jnp.sum(jnp.sin(conv2d_train(x, w, stride, pad, impl)))

    def loss_ref(x, w):
        return jnp.sum(jnp.sin(ref.conv2d(x, w, stride=stride, padding=pad)))

    gx, gw = jax.grad(loss_kernel, argnums=(0, 1))(x, w)
    ex, ew = jax.grad(loss_ref, argnums=(0, 1))(x, w)
    np.testing.assert_allclose(np.asarray(gx), np.asarray(ex),
                               rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(gw), np.asarray(ew),
                               rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("impl", ["xla", "interpret"])
def test_custom_vjp_matches_autodiff_dilate_plan(rng, impl):
    """The A/B baseline plan (REPRO_BWD_DUALITY=dilate) stays a correct
    training path for the generic strided scenario."""
    h, c, k, r, stride, pad = 16, 8, 8, 3, 2, 1
    x = jnp.asarray(rng.standard_normal((1, h, h, c)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((r, r, c, k)) * 0.1, jnp.float32)

    def loss_kernel(x, w):
        return jnp.sum(conv2d_train(x, w, stride, pad, impl) ** 2)

    def loss_ref(x, w):
        return jnp.sum(ref.conv2d(x, w, stride=stride, padding=pad) ** 2)

    with be.use_bwd_duality("dilate"):
        gx = jax.grad(loss_kernel)(x, w)
    ex = jax.grad(loss_ref)(x, w)
    np.testing.assert_allclose(np.asarray(gx), np.asarray(ex),
                               rtol=1e-3, atol=1e-3)


def test_weight_transform_involution(rng):
    """W'' == W: the duality transform is its own inverse."""
    w = jnp.asarray(rng.standard_normal((3, 3, 8, 16)), jnp.float32)
    wt = duality.transform_weights(duality.transform_weights(w))
    np.testing.assert_array_equal(np.asarray(wt), np.asarray(w))


def test_bwd_plan_scenarios():
    assert duality.bwd_data_plan(r=3, s=3, stride=1, padding=1,
                                 input_hw=(8, 8))[0] == "stride1"
    assert duality.bwd_data_plan(r=1, s=1, stride=2, padding=0,
                                 input_hw=(8, 8))[0] == "1x1"
    # generic: "phase" by default, "dilate" via the knob / explicit mode
    assert duality.bwd_data_plan(r=3, s=3, stride=2, padding=1,
                                 input_hw=(8, 8))[0] == "phase"
    assert duality.bwd_data_plan(r=3, s=3, stride=2, padding=1,
                                 input_hw=(8, 8), mode="dilate")[0] == "dilate"
    with be.use_bwd_duality("dilate"):
        assert duality.bwd_data_plan(r=3, s=3, stride=2, padding=1,
                                     input_hw=(8, 8))[0] == "dilate"


def test_dilate_is_single_lax_pad(rng):
    """The dilate baseline builds the stride-dilated tensor with one
    scatter-free lax.pad — same values as the seed's zeros+scatter."""
    x = jnp.asarray(rng.standard_normal((2, 3, 4, 8)), jnp.float32)
    for stride in (1, 2, 3):
        got = duality.dilate(x, stride)
        n, p, q, k = x.shape
        exp = np.zeros((n, (p - 1) * stride + 1, (q - 1) * stride + 1, k),
                       np.float32)
        exp[:, ::stride, ::stride, :] = np.asarray(x)
        np.testing.assert_array_equal(np.asarray(got), exp)
    jaxpr = str(jax.make_jaxpr(lambda x: duality.dilate(x, 2))(x))
    assert "scatter" not in jaxpr and "pad" in jaxpr


@pytest.mark.parametrize("case", [c for c in SCENARIOS],
                         ids=[c[-1] for c in SCENARIOS])
def test_phase_matches_dilate_every_scenario(rng, case):
    """Phase-decomposition vs dilate duality: bit-exact on the Pallas
    (interpret) kernel path for every bwd_data_plan scenario — the
    single-conv scenarios trivially (same launch), the generic ones because
    the phase sub-convs accumulate the same taps in the same f32 chain."""
    h, c, k, r, stride, pad, _ = case
    p = (h + 2 * pad - r) // stride + 1
    do = jnp.asarray(rng.standard_normal((2, p, p, k)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((r, r, c, k)) * 0.1, jnp.float32)
    kw = dict(stride=stride, padding=pad, input_hw=(h, h))
    ph = conv2d_bwd_data_via_fwd(do, w, **kw, impl="interpret", mode="phase")
    di = conv2d_bwd_data_via_fwd(do, w, **kw, impl="interpret", mode="dilate")
    np.testing.assert_array_equal(np.asarray(ph), np.asarray(di))
    exp = ref.conv2d_bwd_data(do, w, stride=stride, padding=pad,
                              input_hw=(h, h))
    np.testing.assert_allclose(np.asarray(ph), np.asarray(exp),
                               rtol=1e-4, atol=1e-4)


def test_phase_plan_never_dilates(monkeypatch):
    """Acceptance: stride=2 backward-data on the default path allocates no
    dilated dO — duality.dilate must never run."""
    def boom(x, stride):
        raise AssertionError("dilate() materialized on the phase path")
    monkeypatch.setattr(duality, "dilate", boom)
    rng = np.random.default_rng(0)
    do = jnp.asarray(rng.standard_normal((1, 8, 8, 8)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((3, 3, 8, 8)) * 0.1, jnp.float32)
    out = conv2d_bwd_data_via_fwd(do, w, stride=2, padding=1,
                                  input_hw=(16, 16), impl="xla")
    assert out.shape == (1, 16, 16, 8)


def test_phase_plan_covers_taps():
    """Every filter tap lands in exactly one phase sub-conv, and the dual
    signatures mirror the padded dO planes the runtime launches."""
    for (r, s, stride, pad, h) in ((3, 3, 2, 1, 16), (7, 7, 2, 3, 24),
                                   (5, 5, 3, 2, 11), (3, 3, 4, 1, 10)):
        plans = duality.phase_plan(r=r, s=s, stride=stride, padding=pad,
                                   input_hw=(h, h),
                                   out_hw=((h + 2 * pad - r) // stride + 1,) * 2)
        assert len(plans) == stride * stride
        assert sum(ay.taps * ax.taps for ay, ax in plans) == r * s
        # every dI row is owned by exactly one phase
        assert sum(ay.count for ay, ax in plans if ax.res == 0) == h
        sigs = duality.dual_conv_signatures(r=r, s=s, c=8, k=16,
                                            stride=stride, padding=pad,
                                            input_hw=(h, h), mode="phase")
        assert all(sg["stride"] == 1 and sg["c"] == 16 and sg["k"] == 8
                   for sg in sigs)


def test_dual_signatures_single_conv_scenarios():
    # stride1: one dual conv over the (p, q) plane with swapped C/K
    (sg,) = duality.dual_conv_signatures(r=3, s=3, c=8, k=16, stride=1,
                                         padding=1, input_hw=(8, 8))
    assert sg == dict(h=8, w=8, c=16, k=8, r=3, s=3, stride=1, padding=1)
    # 1x1 strided
    (sg,) = duality.dual_conv_signatures(r=1, s=1, c=8, k=16, stride=2,
                                         padding=0, input_hw=(8, 8))
    assert sg == dict(h=4, w=4, c=16, k=8, r=1, s=1, stride=1, padding=0)
    # dilate mode: one conv over the dilated+padded plane
    (sg,) = duality.dual_conv_signatures(r=3, s=3, c=8, k=16, stride=2,
                                         padding=1, input_hw=(16, 16),
                                         mode="dilate")
    assert sg["h"] > 13 and sg["r"] == 3 and sg["stride"] == 1


@settings(max_examples=15, deadline=None)
@given(h=st.integers(6, 14), r=st.sampled_from([1, 3]),
       stride=st.integers(1, 3), seed=st.integers(0, 2**31 - 1))
def test_duality_property(h, r, stride, seed):
    rng = np.random.default_rng(seed)
    pad = r // 2
    if h + 2 * pad < r:
        return
    x = jnp.asarray(rng.standard_normal((1, h, h, 8)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((r, r, 8, 8)) * 0.1, jnp.float32)

    def f_kernel(x):
        return jnp.sum(conv2d_train(x, w, stride, pad, "xla") ** 2)

    def f_ref(x):
        return jnp.sum(ref.conv2d(x, w, stride=stride, padding=pad) ** 2)

    gx = jax.grad(f_kernel)(x)
    ex = jax.grad(f_ref)(x)
    np.testing.assert_allclose(np.asarray(gx), np.asarray(ex),
                               rtol=1e-3, atol=1e-3)
