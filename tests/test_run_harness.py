"""The benchmarks.run CLI harness: failure rows + non-zero exit when a
bench module blows up (both --dry and full mode), and the --out-dir /
REPRO_BENCH_OUT redirection that keeps --check runs from dirtying the
working tree."""
import os
import pathlib

import pytest

from benchmarks import common
from benchmarks import run as bench_run


class _FakeModule:
    def __init__(self, fail: bool):
        self.fail = fail
        self.calls = 0

    def main(self):
        self.calls += 1
        if self.fail:
            raise RuntimeError("synthetic bench failure")


def _boom():
    raise RuntimeError("synthetic bench failure")


def test_dry_mode_reports_failed_module(monkeypatch, capsys, tmp_path):
    monkeypatch.setenv("REPRO_TUNE_CACHE", str(tmp_path / "cache.json"))
    monkeypatch.setattr(bench_run, "MODULES",
                        [("alpha", _FakeModule(False))])
    monkeypatch.setattr(bench_run, "DRY_CALLS",
                        [("good", lambda: None), ("bad", _boom)])
    with pytest.raises(SystemExit, match="1 benchmark modules failed"):
        bench_run.main(["--dry"])
    out = capsys.readouterr().out
    assert "alpha,0,IMPORT_OK" in out
    assert "bad,0,FAILED" in out
    assert "good,0,FAILED" not in out


def test_full_mode_reports_failed_module(monkeypatch, capsys):
    ok, bad = _FakeModule(False), _FakeModule(True)
    monkeypatch.setattr(bench_run, "MODULES", [("ok", ok), ("bad", bad)])
    with pytest.raises(SystemExit, match="1 benchmark modules failed"):
        bench_run.main([])
    out = capsys.readouterr().out
    assert "bad,0,FAILED" in out
    # the failure does not short-circuit the suite: every module still ran
    assert ok.calls == 1 and bad.calls == 1


def test_dry_mode_all_green_exits_clean(monkeypatch, capsys, tmp_path):
    monkeypatch.setenv("REPRO_TUNE_CACHE", str(tmp_path / "cache.json"))
    monkeypatch.setattr(bench_run, "MODULES", [("alpha", _FakeModule(False))])
    monkeypatch.setattr(bench_run, "DRY_CALLS", [("good", lambda: None)])
    bench_run.main(["--dry"])                 # no SystemExit
    assert "name,us_per_call,derived" in capsys.readouterr().out


def test_out_dir_flag_redirects_artifacts(monkeypatch, tmp_path):
    monkeypatch.setenv("REPRO_TUNE_CACHE", str(tmp_path / "cache.json"))
    monkeypatch.setattr(bench_run, "MODULES", [])
    monkeypatch.setattr(bench_run, "DRY_CALLS", [])
    monkeypatch.setenv("REPRO_BENCH_OUT", "stale")   # flag must win
    bench_run.main(["--dry", "--out-dir", str(tmp_path)])
    assert os.environ["REPRO_BENCH_OUT"] == str(tmp_path)
    # and the writer helper lands artifacts there, by basename
    target = common.bench_out_path(pathlib.Path("/repo/BENCH_x.json"))
    assert target == tmp_path / "BENCH_x.json"


def test_check_without_out_dir_uses_tempdir(monkeypatch):
    """--check alone must never write into the repo root."""
    monkeypatch.delenv("REPRO_BENCH_OUT", raising=False)
    args = bench_run.parse_args(["--dry", "--check"])
    out_dir = bench_run._resolve_out_dir(args)
    assert out_dir is not None
    assert pathlib.Path(out_dir).name.startswith("repro-bench-")
    assert os.environ["REPRO_BENCH_OUT"] == out_dir
    monkeypatch.delenv("REPRO_BENCH_OUT")


def test_plain_run_writes_committed_locations(monkeypatch):
    monkeypatch.delenv("REPRO_BENCH_OUT", raising=False)
    args = bench_run.parse_args(["--dry"])
    assert bench_run._resolve_out_dir(args) is None
    assert "REPRO_BENCH_OUT" not in os.environ
    default = pathlib.Path("/repo/BENCH_x.json")
    assert common.bench_out_path(default) == default
