import os
import sys

# Tests run single-device ("xla"/"interpret" paths).  The 512-device flag is
# set ONLY inside launch/dryrun.py and the subprocess-based distributed
# tests — never globally here.
os.environ.setdefault("REPRO_BACKEND", "xla")

# `benchmarks/` is a repo-root module tree, not an installed package: make
# its import work under bare `pytest` too (python -m pytest prepends the
# CWD, plain pytest does not).
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if _ROOT not in sys.path:
    sys.path.insert(0, _ROOT)

# Isolate the autotuner cache: tests must never read or pollute the user's
# persistent ~/.cache tuner state (individual tests monkeypatch as needed).
import tempfile  # noqa: E402
os.environ.setdefault(
    "REPRO_TUNE_CACHE",
    os.path.join(tempfile.mkdtemp(prefix="repro-tune-test-"), "cache.json"))

# Offline environments have no `hypothesis` wheel; install the deterministic
# fixed-draw shim before collection so the property-test modules import.
try:
    import hypothesis  # noqa: F401
except ImportError:
    import _hypothesis_compat
    _hypothesis_compat.install()

import numpy as np  # noqa: E402
import pytest  # noqa: E402


@pytest.fixture
def rng():
    return np.random.default_rng(0)
