import os

# Tests run single-device ("xla"/"interpret" paths).  The 512-device flag is
# set ONLY inside launch/dryrun.py and the subprocess-based distributed
# tests — never globally here.
os.environ.setdefault("REPRO_BACKEND", "xla")

import numpy as np  # noqa: E402
import pytest  # noqa: E402


@pytest.fixture
def rng():
    return np.random.default_rng(0)
