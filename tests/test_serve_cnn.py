"""CNN serving path: bucket selection, pad-to-bucket bit-exactness, warmup
population of the blocking cache, and the continuous-batching scheduler."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import backend as be
from repro.graph import GxM, resnet50
from repro.graph.serving import (CnnInferenceEngine, cnn_model_flops,
                                 conv_shapes, distinct_conv_signatures,
                                 make_buckets, pick_bucket, round_buckets)
from repro.launch.mesh import make_host_mesh
from repro.launch.serve_cnn import ImageServer
from repro.tune.cache import TuneCache, conv_key


def _tiny(num_classes=10):
    nl = resnet50(num_classes=num_classes, stages=(1, 1, 1, 1))
    m = GxM(nl, num_classes=num_classes)
    params = m.init(jax.random.PRNGKey(0))
    return m, params


def _engine(m, params, **kw):
    kw.setdefault("image_hw", (32, 32))
    kw.setdefault("mesh", make_host_mesh())
    kw.setdefault("max_batch", 8)
    return CnnInferenceEngine(m, params, **kw)


# -- bucketing ---------------------------------------------------------------

def test_make_buckets_ladder_and_shard_multiples():
    assert make_buckets(16) == (1, 2, 4, 8, 16)
    assert make_buckets(12) == (1, 2, 4, 8, 16)       # next power of two
    assert make_buckets(16, num_shards=2) == (2, 4, 8, 16)
    assert all(b % 4 == 0 for b in make_buckets(32, num_shards=4))


def test_round_buckets_rounds_up_to_shard_multiples():
    # a caller ladder that doesn't divide num_shards rounds UP (never
    # truncates capacity) and dedups collisions
    assert round_buckets((2, 6), 4) == (4, 8)
    assert round_buckets((1, 2, 3, 4), 2) == (2, 4)
    assert round_buckets((3, 5, 8), 1) == (3, 5, 8)    # no-op on 1 shard


def test_engine_rounds_explicit_buckets_up(monkeypatch):
    m, params = _tiny()
    eng = _engine(m, params, buckets=(3, 6))
    # the host mesh's shard count varies by CI job (fake-device flags)
    assert eng.buckets == round_buckets((3, 6), eng.num_shards)
    # a 4-shard mesh must round the explicit ladder up, not assert
    import repro.launch.mesh as mesh_mod
    monkeypatch.setattr(mesh_mod, "data_axis_size", lambda mesh: 4)
    eng2 = _engine(m, params, buckets=(3, 6))
    assert eng2.buckets == (4, 8)


def test_pick_bucket_is_minimal():
    buckets = (2, 4, 8, 16)
    assert pick_bucket(1, buckets) == 2
    assert pick_bucket(2, buckets) == 2
    assert pick_bucket(3, buckets) == 4
    assert pick_bucket(5, buckets) == 8
    assert pick_bucket(16, buckets) == 16


def test_pick_bucket_rejects_oversized_batch():
    # silently serving at max(buckets) would truncate lanes — must raise
    with pytest.raises(ValueError, match="exceeds largest bucket"):
        pick_bucket(99, (2, 4, 8, 16))


# -- shape inference ---------------------------------------------------------

def test_conv_shapes_cover_every_conv_task():
    m, _ = _tiny()
    shapes = conv_shapes(m.etg, (32, 32))
    convs = [t for t in m.etg.tasks if t.op == "conv"]
    assert len(shapes) == len(convs)
    by_name = {s["name"]: s for s in shapes}
    # the stem conv sees the raw image plane
    assert by_name["conv1"]["h"] == 32 and by_name["conv1"]["c"] == 3
    # every spatial extent must be positive and strides propagate
    assert all(s["h"] > 0 and s["w"] > 0 for s in shapes)
    assert cnn_model_flops(m.etg, (32, 32), 4) == \
        2 * cnn_model_flops(m.etg, (32, 32), 2)


# -- padded lanes are invisible ----------------------------------------------

def test_padded_batch_bit_exact_vs_unbatched_forward(rng):
    m, params = _tiny()
    eng = _engine(m, params)
    eng.warmup(autotune="off")
    x = rng.standard_normal((3, 32, 32, 3)).astype(np.float32)
    got = np.asarray(eng.infer(x))                    # pads 3 -> bucket 4
    ref = np.asarray(m.forward(params, jnp.asarray(x), train=False))
    np.testing.assert_array_equal(got, ref)
    # lane independence: what fills the padded lane cannot leak into real
    # lanes (inference has no cross-batch ops — BN is folded)
    fn = eng.aot_executable(4)
    junk = 100 * rng.standard_normal((1, 32, 32, 3)).astype(np.float32)
    with_zeros = fn(params, jnp.asarray(np.concatenate([x, 0 * junk])))
    with_junk = fn(params, jnp.asarray(np.concatenate([x, junk])))
    np.testing.assert_array_equal(np.asarray(with_zeros)[:3],
                                  np.asarray(with_junk)[:3])


def test_infer_rejects_oversized_batch(rng):
    m, params = _tiny()
    eng = _engine(m, params, buckets=(2, 4))
    x = rng.standard_normal((5, 32, 32, 3)).astype(np.float32)
    with pytest.raises(ValueError):
        eng.infer(x)


# -- warmup ------------------------------------------------------------------

def test_warmup_populates_tune_cache_for_every_signature(tmp_path):
    m, params = _tiny()
    eng = _engine(m, params, buckets=(2, 4))
    cache = TuneCache(str(tmp_path / "cache.json"))
    report = eng.warmup(autotune="tune", cache=cache, compile_buckets=False)
    sigs = distinct_conv_signatures(eng.conv_shapes())
    assert report["conv_signatures"] == len(sigs)
    backend = be.resolve(m.impl)
    for sh in sigs:
        for bucket in eng.buckets:
            key = conv_key(kind="fwd", dtype_bytes=4, backend=backend,
                           minibatch=eng.local_batch(bucket), **sh)
            assert cache.lookup(key) is not None, key
    # one entry per signature × per-device bucket batch, all reported
    assert report["tune_entries"] == len(sigs) * len(eng.buckets)
    assert report["kernel_cache_entries"] == len(m.etg.kernel_cache)


def test_compiled_buckets_consult_tuner_cache(monkeypatch):
    """The request-path executables must be traced under the engine's
    autotune scope, so the blockings warmup persisted are actually used
    (not the analytic heuristic)."""
    import repro.tune as tune
    looked_up = []
    real = tune.lookup_conv

    def spy(**kw):
        looked_up.append(kw["minibatch"])
        return real(**kw)

    monkeypatch.setattr(tune, "lookup_conv", spy)
    m, params = _tiny()
    m.impl = "interpret"        # xla path never consults conv_blocking
    eng = _engine(m, params, buckets=(2,))
    eng.warmup(autotune="off")  # compile-only; engine scope is "cache"
    # lookups happen at the per-shard batch (bucket / data shards)
    assert looked_up and set(looked_up) == {eng.local_batch(2)}, looked_up


def test_warmup_compiles_every_bucket(rng):
    m, params = _tiny()
    eng = _engine(m, params, buckets=(2, 4))
    report = eng.warmup(autotune="off")
    assert set(report["compile_s"]) == {2, 4}
    for b in (2, 4):
        assert eng.aot_executable(b) is eng._compiled[b]


# -- quantized serving (§II-K end to end) ------------------------------------

def _tiny_q8(impl="interpret"):
    nl = resnet50(num_classes=10, stages=(1, 1, 1, 1))
    m = GxM(nl, num_classes=10, impl=impl, quantized=True)
    return m, m.init(jax.random.PRNGKey(0))


def test_quantized_engine_top1_stable_vs_f32(rng):
    """A quantized=True engine on the interpret backend (the real int8
    Pallas kernels) must keep the fp32 top-1 on a fixed batch, and stay
    within the calibration error band on the logits."""
    m32, params = _tiny()
    m32.impl = "interpret"
    x = rng.standard_normal((4, 32, 32, 3)).astype(np.float32)
    ref_logits = np.asarray(m32.forward(params, jnp.asarray(x), train=False))

    mq, _ = _tiny_q8()          # same init seed -> identical f32 weights
    eng = _engine(mq, params, buckets=(4,))
    assert eng.quantized
    report = eng.warmup(autotune="off")
    assert report["quantized"] and eng.qparams is not None
    got = np.asarray(eng.infer(x))
    np.testing.assert_array_equal(np.argmax(got, axis=-1),
                                  np.argmax(ref_logits, axis=-1))
    rel = np.max(np.abs(got - ref_logits)) / (np.max(np.abs(ref_logits))
                                              + 1e-9)
    assert rel < 0.1, rel


def test_quantized_padded_lanes_invisible(rng):
    """Pad-to-bucket on the q8 path: junk in the padded lane must not
    perturb a single bit of the real lanes (per-tensor activation scales
    are calibration constants, not batch statistics)."""
    mq, params = _tiny_q8()
    eng = _engine(mq, params, buckets=(4,))
    eng.warmup(autotune="off")
    x = rng.standard_normal((3, 32, 32, 3)).astype(np.float32)
    got = np.asarray(eng.infer(x))                   # pads 3 -> bucket 4
    fn = eng.aot_executable(4)
    junk = 100 * rng.standard_normal((1, 32, 32, 3)).astype(np.float32)
    with_zeros = fn(eng._run_params, jnp.asarray(np.concatenate([x, 0 * junk])))
    with_junk = fn(eng._run_params, jnp.asarray(np.concatenate([x, junk])))
    np.testing.assert_array_equal(np.asarray(with_zeros)[:3],
                                  np.asarray(with_junk)[:3])
    np.testing.assert_array_equal(got, np.asarray(with_zeros)[:3])


def test_calibration_deterministic_for_fixed_seed():
    """Same params + same synthetic calibration seed -> bit-equal scales
    (pure max-reduction over rng-seeded batches); a different seed must
    actually change the data the scales see."""
    mq, params = _tiny_q8(impl=None)   # calibration runs the f32 xla path
    a = _engine(mq, params, buckets=(2,)).calibrate(batches=2, batch=2,
                                                    seed=0)
    b = _engine(mq, params, buckets=(2,)).calibrate(batches=2, batch=2,
                                                    seed=0)
    assert set(a) == set(b) and len(a) > 0
    for name in a:
        np.testing.assert_array_equal(np.asarray(a[name]),
                                      np.asarray(b[name]))
    c = _engine(mq, params, buckets=(2,)).calibrate(batches=2, batch=2,
                                                    seed=1)
    assert any(float(a[n]) != float(c[n]) for n in a)


def test_quantized_engine_train_guard():
    """The quantized params tree is inference-only: the executor must
    refuse to run a training forward over w_q leaves."""
    mq, params = _tiny_q8(impl=None)
    eng = _engine(mq, params, buckets=(2,))
    eng.calibrate(batches=1, batch=2, seed=0)
    x = jnp.zeros((2, 32, 32, 3), jnp.float32)
    with pytest.raises(ValueError, match="inference-only"):
        mq.forward(eng.qparams, x, train=True)


# -- continuous-batching scheduler -------------------------------------------

def test_server_serves_all_requests_and_counts_padding(rng):
    m, params = _tiny()
    eng = _engine(m, params, buckets=(2, 4))
    eng.warmup(autotune="off")
    server = ImageServer(eng)
    images = rng.standard_normal((7, 32, 32, 3)).astype(np.float32)
    rids = [server.submit(img) for img in images]
    results = server.run()
    assert set(results) == set(rids)
    # 7 requests -> one bucket-4 batch (4 reqs) + bucket-4 batch (3 reqs,
    # 1 padded lane)
    st = server.stats()
    assert st["images"] == 7
    assert st["padded_lanes"] == 1
    # every request's enqueue->complete latency is recorded
    assert st["latency"]["count"] == 7
    assert st["latency"]["p99_ms"] >= st["latency"]["p50_ms"] >= 0.0
    # scheduler results match the direct forward
    logits = np.asarray(m.forward(params, jnp.asarray(images), train=False))
    for rid, img_logits in zip(rids, logits):
        top1, val = results[rid]
        assert top1 == int(np.argmax(img_logits))
        assert val == float(img_logits[top1])


def test_server_latency_includes_queue_wait(rng):
    """Latency is enqueue->complete under an injectable clock: a request
    stuck behind a full bucket waits one extra step, and stats() reports
    exactly that."""
    from repro.core.simtime import SimClock
    m, params = _tiny()
    eng = _engine(m, params, buckets=(2,))
    eng.warmup(autotune="off")
    clk = SimClock()
    server = ImageServer(eng, clock=lambda: (clk.sleep(1.0), clk.time())[1])
    for img in rng.standard_normal((3, 32, 32, 3)).astype(np.float32):
        server.submit(img)                 # enqueued at t=1, 2, 3
    server.run()
    st = server.stats()["latency"]
    assert st["count"] == 3
    # step 1 serves reqs 0,1 (clock reads at t=4 and t=5); step 2 serves
    # req 2 (reads at t=6 and t=7) -> latencies 4, 3, 4 seconds
    assert sorted(server.latencies_s) == [3.0, 4.0, 4.0]
