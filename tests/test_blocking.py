"""Blocking heuristics (§II-B/C/D on TPU constraints): VMEM budget
respected, MXU-aligned blocks, divisor mode, loop-order rule."""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.blocking import (VMEM_BUDGET, conv_blocking,
                                 conv_blocking_analytic, divisors,
                                 matmul_blocking)
from repro.core.wu_strategy import choose_wu_strategy, hybrid_copies
from repro.graph.topology import RESNET50_LAYERS


def test_resnet_layers_fit_vmem():
    for lid, l in RESNET50_LAYERS.items():
        if l["c"] < 8:
            continue  # conv1 takes the im2col path
        blk = conv_blocking(h=l["h"], w=l["w"], c=l["c"], k=l["k"],
                            r=l["r"], s=l["s"], stride=l["stride"],
                            padding=l["r"] // 2)
        assert blk.vmem_bytes <= VMEM_BUDGET, (lid, blk)
        assert l["k"] % blk.k_blk == 0


def test_loop_order_rule():
    b1 = conv_blocking(h=56, w=56, c=256, k=64, r=1, s=1, stride=1,
                       padding=0)
    b3 = conv_blocking(h=56, w=56, c=64, k=64, r=3, s=3, stride=1,
                       padding=1)
    assert b1.order == "npkc"   # paper §II-C: pull C_b in for 1x1
    assert b3.order == "nkpc"


@settings(max_examples=30, deadline=None)
@given(h=st.integers(7, 224), c=st.sampled_from([8, 64, 256, 1024]),
       k=st.sampled_from([8, 64, 256]), r=st.sampled_from([1, 3, 5, 7]),
       stride=st.integers(1, 2))
def test_conv_blocking_properties(h, c, k, r, stride):
    blk = conv_blocking(h=h, w=h, c=c, k=k, r=r, s=r, stride=stride,
                        padding=r // 2)
    p = (h + 2 * (r // 2) - r) // stride + 1
    assert 1 <= blk.rb_p <= max(p, 1)
    assert k % blk.k_blk == 0
    assert blk.k_blk <= 128


@settings(max_examples=20, deadline=None)
@given(h=st.integers(7, 56), r=st.sampled_from([1, 3]))
def test_divisor_mode(h, r):
    blk = conv_blocking(h=h, w=h, c=64, k=64, r=r, s=r, stride=1,
                        padding=r // 2, require_divisor=True)
    p = h + 2 * (r // 2) - r + 1
    assert p % blk.rb_p == 0


def test_analytic_vmem_model_matches_kernel_residency():
    """The VMEM model must charge what each kernel actually keeps resident:
    a row band for the tiled fwd, a C_blk plane slice for streams, the
    full-C plane for wu — not the (much smaller) band for all three."""
    big = dict(h=512, w=512, c=64, k=64, r=3, s=3, stride=1, padding=1)
    hp, wp = 512 + 2 + 3, 512 + 2
    plane = hp * wp * 64 * 4
    tiled = conv_blocking_analytic(**big)
    streams = conv_blocking_analytic(**big, whole_plane=True)
    wu = conv_blocking_analytic(**big, require_divisor=True)
    assert tiled.vmem_bytes < plane                   # band, not plane
    assert streams.vmem_bytes >= hp * wp * streams.c_blk * 4
    assert wu.vmem_bytes >= plane                     # full-C plane resident


def test_matmul_blocking_budget():
    blk = matmul_blocking(4096, 4096, 24576, dtype_bytes=2)
    assert blk.vmem_bytes <= VMEM_BUDGET
    assert 24576 % blk.bk == 0


def test_wu_strategy_tradeoff():
    """Small spatial layer (dW dominates) -> 'shared'; big spatial layer
    (activations dominate) -> 'copies' (paper §II-J)."""
    small = choose_wu_strategy(n=28, c=2048, k=512, h=7, w=7, p=7, q=7,
                               r=1, s=1, n_workers=64)
    big = choose_wu_strategy(n=28, c=64, k=64, h=56, w=56, p=56, q=56,
                             r=3, s=3, n_workers=64)
    assert small.strategy == "shared"
    assert big.strategy == "copies"


def test_hybrid_copies_bounds():
    m = hybrid_copies(n=64, dw_bytes=10_000, act_bytes=100_000_000,
                      n_workers=64)
    assert 1 <= m <= 64


def test_divisors():
    assert divisors(12) == [1, 2, 3, 4, 6, 12]
