"""Optimizer + gradient compression: AdamW reference step, factored second
moment, clipping, int8 error-feedback properties."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.optim.adamw import AdamW, clip_by_global_norm
from repro.optim.compress import compress_int8, decompress_int8


def test_adamw_matches_reference_step():
    opt = AdamW(b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.0)
    p = {"w": jnp.asarray([[1.0, -2.0], [0.5, 3.0]])}
    g = {"w": jnp.asarray([[0.1, 0.2], [-0.3, 0.4]])}
    state = opt.init(p)
    newp, _ = opt.update(g, state, p, lr=0.1)
    # hand-computed Adam step 1: m=0.1g... update = m_hat/(sqrt(v_hat)+eps)
    m_hat = np.asarray(g["w"])
    v_hat = np.asarray(g["w"]) ** 2
    expect = np.asarray(p["w"]) - 0.1 * m_hat / (np.sqrt(v_hat) + 1e-8)
    np.testing.assert_allclose(np.asarray(newp["w"]), expect, rtol=1e-5)


def test_factored_second_moment_shapes():
    opt = AdamW(factored=True, factored_min_size=4)
    p = {"w": jnp.ones((8, 16)), "b": jnp.ones((16,))}
    st_ = opt.init(p)
    assert st_["mu"]["w"]["vr"].shape == (8,)
    assert st_["mu"]["w"]["vc"].shape == (16,)
    assert "v" in st_["mu"]["b"]          # vectors stay unfactored
    g = {"w": jnp.full((8, 16), 0.1), "b": jnp.full((16,), 0.1)}
    newp, ns = opt.update(g, st_, p, lr=0.01)
    assert all(bool(jnp.isfinite(x).all()) for x in jax.tree.leaves(newp))


def test_factored_approximates_full():
    """Rank-1 v reconstruction ~ full v for rank-1 gradient structure."""
    opt_f = AdamW(factored=True, factored_min_size=4, weight_decay=0.0)
    opt_d = AdamW(weight_decay=0.0)
    row = jnp.linspace(0.5, 2.0, 8)[:, None]
    col = jnp.linspace(1.0, 3.0, 16)[None, :]
    g = {"w": row * col}
    p = {"w": jnp.zeros((8, 16))}
    pf, _ = opt_f.update(g, opt_f.init(p), p, lr=0.1)
    pd, _ = opt_d.update(g, opt_d.init(p), p, lr=0.1)
    np.testing.assert_allclose(np.asarray(pf["w"]), np.asarray(pd["w"]),
                               rtol=0.05, atol=0.01)


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 3.0), "b": jnp.full((4,), 4.0)}
    clipped, gn = clip_by_global_norm(g, 1.0)
    assert abs(float(gn) - 10.0) < 1e-4
    total = jnp.sqrt(sum(jnp.sum(x ** 2) for x in jax.tree.leaves(clipped)))
    assert abs(float(total) - 1.0) < 1e-4


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_int8_compression_error_feedback(seed):
    """Quantization error must be bounded by scale/2 per element, and the
    residual carries exactly the error (so it is fed back next step)."""
    rng = np.random.default_rng(seed)
    g = jnp.asarray(rng.standard_normal(64) * rng.uniform(0.1, 10),
                    jnp.float32)
    q, scale, resid = compress_int8(g)
    deq = decompress_int8(q, scale)
    err = np.abs(np.asarray(deq) - np.asarray(g))
    assert err.max() <= float(scale) * 0.5 + 1e-6
    np.testing.assert_allclose(np.asarray(resid),
                               np.asarray(g) - np.asarray(deq), rtol=1e-5,
                               atol=1e-6)


def test_error_feedback_converges():
    """With error feedback, the *accumulated* quantized signal tracks the
    accumulated true gradient (bias-free compression)."""
    rng = np.random.default_rng(0)
    true_sum = np.zeros(32)
    sent_sum = np.zeros(32)
    resid = None
    for _ in range(200):
        g = jnp.asarray(rng.standard_normal(32), jnp.float32)
        q, scale, resid = compress_int8(g, resid)
        sent_sum += np.asarray(decompress_int8(q, scale))
        true_sum += np.asarray(g)
    # residual is bounded, so sums differ by at most the residual magnitude
    np.testing.assert_allclose(sent_sum, true_sum, atol=0.2)
