"""Conformance wall for depth-first chain fusion (DESIGN.md §16).

The fused depth-first replay must be *bit-identical* — ``assert_array_equal``,
not allclose — to the unfused layer-by-layer execution, on both kernel
backends, across stride/filter sweeps, non-divisor tails, the 224² stem
geometry planned under a 1 MiB budget, and the full GxM ResNet bottleneck
with its residual add.  The anchor is the pinned full-shape blocking
(``kernels.conv2d_chain``): the per-element f32 reduction order depends only
on ``c_blk``, never on the band split.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import backend as be
from repro.core.conv import conv2d_chain_fwd, conv2d_fwd
from repro.tune.measure import chain_traffic

BACKENDS = ("interpret", "xla")


def _layer(rng, c, k, r, stride, *, bn=True, bias=False, relu=True):
    L = dict(w=jnp.asarray(rng.standard_normal((r, r, c, k)) * 0.1,
                           jnp.float32),
             stride=stride, padding=r // 2, relu=relu)
    if bn:
        L["scale"] = jnp.asarray(
            1.0 + 0.2 * rng.standard_normal(k), jnp.float32)
        L["shift"] = jnp.asarray(
            0.1 * rng.standard_normal(k), jnp.float32)
    if bias:
        L["bias"] = jnp.asarray(0.1 * rng.standard_normal(k), jnp.float32)
    return L


def _unfused(x, layers, impl):
    out = x
    for L in layers:
        out = conv2d_fwd(out, L["w"], stride=L["stride"],
                         padding=L["padding"], bias=L.get("bias"),
                         scale=L.get("scale"), shift=L.get("shift"),
                         residual=L.get("residual"),
                         relu=L.get("relu", False), impl=impl)
    return out


def _assert_chain_exact(x, layers, impl, rbs=(1, 3, 100)):
    want = np.asarray(_unfused(x, layers, impl))
    for rb in rbs:
        got = np.asarray(conv2d_chain_fwd(x, layers, rb=rb, impl=impl))
        np.testing.assert_array_equal(got, want, err_msg=f"rb={rb}")


@pytest.mark.parametrize("impl", BACKENDS)
@pytest.mark.parametrize("r1,s1,r2,s2", [
    (1, 1, 3, 1), (3, 1, 1, 2), (3, 2, 3, 1), (1, 2, 1, 1), (3, 2, 3, 2),
])
def test_two_layer_stride_filter_sweep(impl, r1, s1, r2, s2):
    """stride x filter sweep: every (r, stride) combination over a two-conv
    chain, odd plane dims so every band split hits clip/tail paths."""
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.standard_normal((2, 17, 13, 8)), jnp.float32)
    layers = [_layer(rng, 8, 16, r1, s1), _layer(rng, 16, 8, r2, s2)]
    _assert_chain_exact(x, layers, impl)


@pytest.mark.parametrize("impl", BACKENDS)
def test_non_divisor_pck_tails(impl):
    """C=24 / K=40 (8-aligned, not lane multiples) and P that no rb
    divides: ceil-div tails in every blocked dimension."""
    rng = np.random.default_rng(11)
    x = jnp.asarray(rng.standard_normal((1, 19, 11, 24)), jnp.float32)
    layers = [_layer(rng, 24, 40, 3, 1), _layer(rng, 40, 24, 3, 2,
                                                bias=True)]
    _assert_chain_exact(x, layers, impl, rbs=(1, 4, 7))


@pytest.mark.parametrize("impl", BACKENDS)
def test_ref_fallback_layer_in_chain(impl):
    """A non-lane-aligned layer (C=12) rides the XLA reference path inside
    the chain — the dispatch split must stay bit-exact too."""
    rng = np.random.default_rng(13)
    x = jnp.asarray(rng.standard_normal((1, 14, 10, 12)), jnp.float32)
    layers = [_layer(rng, 12, 16, 3, 1), _layer(rng, 16, 8, 3, 1)]
    _assert_chain_exact(x, layers, impl, rbs=(2, 5))


@pytest.mark.parametrize("impl", BACKENDS)
@pytest.mark.parametrize("stride", (1, 2))
def test_bottleneck_residual_bit_exact(impl, stride):
    """The ResNet bottleneck 1x1 -> 3x3(s) -> 1x1 with the residual added in
    the last layer's epilogue: residual bands are sliced per output band."""
    rng = np.random.default_rng(17)
    x = jnp.asarray(rng.standard_normal((1, 20, 20, 16)), jnp.float32)
    layers = [_layer(rng, 16, 8, 1, 1), _layer(rng, 8, 8, 3, stride),
              _layer(rng, 8, 16, 1, 1)]
    p_out = (20 + 2 - 3) // stride + 1
    layers[-1]["residual"] = jnp.asarray(
        rng.standard_normal((1, p_out, p_out, 16)), jnp.float32)
    _assert_chain_exact(x, layers, impl, rbs=(1, 3, 100))


def test_stem_224_planned_under_1mib():
    """224² stem geometry: the 1 MiB plan must fuse with a multi-band
    schedule, and replaying at the planned rb stays bit-exact."""
    shapes = [dict(h=224, w=224, c=8, k=16, r=3, s=3, stride=2, padding=1),
              dict(h=112, w=112, c=16, k=16, r=3, s=3, stride=1, padding=1)]
    t = chain_traffic(shapes, minibatch=1, vmem_budget=1 << 20)
    assert t["fused"] and t["fits_vmem"]
    assert t["n_bands"] > 1                      # banding actually engaged
    assert t["vmem_bytes"] <= 1 << 20
    assert t["intermediate_bytes"] == 0.0
    rng = np.random.default_rng(23)
    x = jnp.asarray(rng.standard_normal((1, 224, 224, 8)), jnp.float32)
    layers = [_layer(rng, 8, 16, 3, 2), _layer(rng, 16, 16, 3, 1)]
    _assert_chain_exact(x, layers, "xla", rbs=(int(t["rb"]),))


@pytest.mark.parametrize("impl", BACKENDS)
def test_gxm_resnet_bottlenecks_on_off(impl, monkeypatch):
    """Full GxM forward of a two-stage ResNet (bottleneck + projection +
    residual + downsampled stage): the chain-fusion knob must not change a
    single bit, and the fused path must actually run (once per chain)."""
    import repro.graph.executor as ex
    from repro.graph.topology import resnet50
    gxm = ex.GxM(resnet50(num_classes=10, stages=(1, 1)), impl=impl,
                 num_classes=10)
    assert len(gxm.etg.chains) == 2              # one bottleneck per stage
    params = gxm.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(29)
    x = jnp.asarray(rng.standard_normal((1, 56, 56, 3)), jnp.float32)
    with be.use_chain_fusion("off"):
        want = gxm.forward(params, x, train=False)
    calls = []
    orig = ex.conv2d_chain_fwd
    monkeypatch.setattr(ex, "conv2d_chain_fwd",
                        lambda *a, **k: (calls.append(1), orig(*a, **k))[1])
    with be.use_chain_fusion("on"):
        got = gxm.forward(params, x, train=False)
    assert len(calls) == len(gxm.etg.chains)     # every chain ran fused
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_gxm_training_forward_never_fuses(monkeypatch):
    """Chain fusion is inference-only: a train-mode forward must bypass the
    fused path even with the knob on (batch-norm needs batch stats)."""
    import repro.graph.executor as ex
    from repro.graph.topology import resnet50
    gxm = ex.GxM(resnet50(num_classes=10, stages=(1, 1)), impl="xla",
                 num_classes=10)
    params = gxm.init(jax.random.PRNGKey(1))
    x = jnp.zeros((1, 56, 56, 3), jnp.float32)
    monkeypatch.setattr(ex, "conv2d_chain_fwd",
                        lambda *a, **k: pytest.fail("fused path in train"))
    with be.use_chain_fusion("on"):
        gxm.forward(params, x, train=True)
