"""reduced_precision_bench invariants (Fig. 8 analog on LM serving): int8
weights must model a real speedup on memory-bound decode — strictly above
1x, bounded by the 2x weight-byte halving — for every pinned architecture.

The CNN half (``build_q8_report``, the paper's actual §II-K subject) is
cross-checked against the blocking-free ideal-traffic model: the measured
(schedule-resolved) speedup must realize at least half the ideal-bytes win
and never exceed it by more than the f32 schedule's own refetch factor —
so a stale analytic table can no longer drift away from what the tiled
kernels actually pay, which is exactly how the old bench went stale."""
from repro.core.blocking import VMEM_BUDGET

from benchmarks.reduced_precision_bench import (ARCHS, build_q8_report,
                                                build_report)


def test_int8_modeled_speedup_bounds():
    report = build_report()
    assert tuple(r["arch"] for r in report["rows"]) == ARCHS
    for row in report["rows"]:
        assert row["quantized_step_us"] < row["base_step_us"], row["arch"]
        assert 1.0 < row["modeled_speedup"] <= 2.0, row["arch"]
        # the speedup story only holds while decode is memory-bound
        assert row["base_dominant"] == "memory", row["arch"]
        assert row["quantized_dominant"] == "memory", row["arch"]


def test_q8_measured_table_cross_checks_analytic():
    """Every direct-path layer: int8 never models slower than f32, and the
    schedule-resolved speedup agrees with the ideal-traffic model within
    the drift band [0.5x, 8x] (below: the schedule throws the byte win
    away; above: the f32 baseline's refetch factor, bounded by its own
    working-set model)."""
    report = build_q8_report()
    assert report["vmem_budget"] == VMEM_BUDGET
    assert set(report["tables"]) == {"resnet50", "inception_v3"}
    for tname, recs in report["tables"].items():
        for rec in recs:
            if rec["path"] != "direct":
                continue
            lid = (tname, rec["layer"])
            assert rec["speedup"] >= 1.0, lid
            assert rec["analytic_speedup"] >= 1.0, lid
            ratio = rec["speedup"] / rec["analytic_speedup"]
            assert 0.5 <= ratio <= 8.0, (lid, ratio)
            # q8 must stay schedulable wherever f32 was
            if rec["f32"]["fits_vmem"]:
                assert rec["q8"]["fits_vmem"], lid


def test_q8_resnet50_bandwidth_bound_floor():
    """The PR acceptance bar, pinned where perfci also gates it: >= 1.6x
    on every bandwidth-bound ResNet-50 layer (HBM time the largest f32
    cost term — int8 cannot speed up launch overhead, so overhead-bound
    tails stay out of the denominator)."""
    report = build_q8_report()
    s = report["summary"]["resnet50"]
    assert s["bandwidth_bound_layers"] >= 5
    assert s["min_bw_speedup"] >= 1.6, s
