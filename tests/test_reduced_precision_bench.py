"""reduced_precision_bench invariants (Fig. 8 analog on LM serving): int8
weights must model a real speedup on memory-bound decode — strictly above
1x, bounded by the 2x weight-byte halving — for every pinned architecture."""
from benchmarks.reduced_precision_bench import ARCHS, build_report


def test_int8_modeled_speedup_bounds():
    report = build_report()
    assert tuple(r["arch"] for r in report["rows"]) == ARCHS
    for row in report["rows"]:
        assert row["quantized_step_us"] < row["base_step_us"], row["arch"]
        assert 1.0 < row["modeled_speedup"] <= 2.0, row["arch"]
        # the speedup story only holds while decode is memory-bound
        assert row["base_dominant"] == "memory", row["arch"]
        assert row["quantized_dominant"] == "memory", row["arch"]
