"""BENCH_bwd_wu invariants: the band-streamed update pass must dominate the
legacy whole-plane kernel on modeled HBM traffic and roofline cost, and the
phase-decomposed duality must dominate the dilate plan — per layer, across
the ResNet-50 (real shapes, 224x224 stem included) and Inception tables
(the PR-over-PR training-pass baseline other sessions diff against).

Cost is additionally pinned only where the dual conv actually runs on the
Pallas path (``lane_ok`` of the *transformed* problem): an im2col-path
layer's backward never launches the kernels being A/B'd, and grid-step
overhead can tip its modeled cost either way."""
import pytest

from benchmarks.bwd_wu_layers import MINIBATCH, bench_tables, build_report
from repro.core.conv import lane_ok


@pytest.fixture(scope="module")
def report():
    return build_report()


def test_tables_cover_real_shapes():
    tables = bench_tables()
    assert len(tables["resnet50"]) == 20          # paper Table I, uncapped
    assert len(tables["inception_v3"]) >= 10
    # the 224x224 stems are in (the seed bench capped h at 56)
    assert any(sh["h"] == 224 for sh in tables["resnet50"])
    assert any(sh["h"] == 224 for sh in tables["regression"])
    assert any(sh["h"] > 224 for sh in tables["inception_v3"])


def test_tiled_wu_dominates_legacy_everywhere(report):
    assert report["tables"]
    for tname, recs in report["tables"].items():
        for rec in recs:
            t, wp = rec["wu"]["tiled"], rec["wu"]["whole_plane"]
            lid = (tname, rec["layer"])
            assert t["hbm_bytes"] <= wp["hbm_bytes"], lid
            assert t["cost_us"] <= wp["cost_us"], lid
            assert t["fits_vmem"], lid


def test_phase_duality_dominates_dilate(report):
    for tname, recs in report["tables"].items():
        for rec in recs:
            ph, di = rec["bwd_data"]["phase"], rec["bwd_data"]["dilate"]
            lid = (tname, rec["layer"])
            # modeled traffic: the zero-free plan never moves more bytes
            assert ph["hbm_bytes"] <= di["hbm_bytes"], lid
            sh = rec["shape"]
            generic = sh["stride"] > 1 and not (sh["r"] == 1 and sh["s"] == 1)
            if generic:
                # phase convolves only real taps: ~stride^2 fewer FLOPs
                assert ph["flops"] < di["flops"], lid
                assert 1 <= ph["n_convs"] <= sh["stride"] ** 2, lid
                assert di["n_convs"] == 1, lid
            else:
                assert ph["cost_us"] == di["cost_us"], lid
            # dual-path layers (the kernels the knob actually A/Bs): the
            # phase plan must also win on modeled cost
            if lane_ok(sh["k"], sh["c"]):
                assert ph["cost_us"] <= di["cost_us"], lid


def test_stem_wu_regression_row(report):
    """The acceptance row: the 224x224 stem runs the tiled update pass under
    budget while the legacy plane does not even fit a 1 MiB CI budget."""
    (rec,) = report["tables"]["regression"]
    assert rec["shape"]["h"] == 224 and rec["shape"]["r"] == 7
    t, wp = rec["wu"]["tiled"], rec["wu"]["whole_plane"]
    assert t["fits_vmem"]
    # the legacy plane does not schedule under the 1 MiB CI budget at all —
    # the tiled band is what admits the stem to the training pass there
    assert wp["vmem_working_set"] > 1 << 20
    assert t["hbm_bytes"] <= wp["hbm_bytes"]
    assert t["cost_us"] < 0.8 * wp["cost_us"]     # occupancy + step-overhead win
    assert report["minibatch"] == MINIBATCH
