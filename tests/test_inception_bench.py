"""inception_bench invariants (§II-G GxM on a branchy topology): the split
nodes that make Inception interesting must survive graph construction,
fusion must fire across the branches, and JIT kernel reuse must collapse
the conv population onto its distinct signatures."""
from benchmarks.inception_bench import build_report


def test_branchy_graph_shape():
    report = build_report()
    assert report["topology"] == "inception_v3"
    assert report["split_nodes"] > 0               # the branch points
    assert report["stats"]["ops_fused"] > 0
    assert report["stats"]["nodes_after"] < report["stats"]["nodes_before"]


def test_kernel_reuse_across_branches():
    report = build_report()
    # many conv tasks, far fewer distinct compiled kernels: the GxM reuse
    # claim on a topology whose branches share shapes
    assert report["conv_tasks"] >= 2 * report["distinct_jit_kernels"]
    assert report["distinct_jit_kernels"] == \
        report["distinct_conv_signatures"]
    assert report["distinct_conv_signatures"] >= 10
