"""The serving-fleet bench (benchmarks/serve_fleet_bench.py): determinism
of the simulated-time replay, the perf-gate floors on the fresh report, the
extractor's metric surface, and the committed artifact staying in sync."""
import json
import pathlib

import pytest

from benchmarks import serve_fleet_bench as sfb
from repro import perfci

REPO = pathlib.Path(__file__).resolve().parents[1]


@pytest.fixture(scope="module")
def report():
    return sfb.build_report()


def test_report_is_bit_deterministic(report):
    again = sfb.build_report()
    assert json.dumps(report, sort_keys=True) == \
        json.dumps(again, sort_keys=True)


def test_fault_free_anchor_and_reference_floors(report):
    rows = {r["name"]: r for r in report["schedules"]}
    assert set(rows) == {"fault_free", "reference", "burst_overload"}
    ff = rows["fault_free"]
    assert ff["goodput"] == 1.0 and ff["shed"] == ff["failed"] == 0
    assert ff["evictions"] == ff["hedges"] == ff["retries"] == 0
    assert ff["events"] == []
    ref = rows["reference"]
    # the ISSUE floors: >= 0.9 goodput with zero operator intervention —
    # the dead replica is evicted and respawned with a warm cache, the
    # straggler is hedged around, the flaky dispatches retried
    assert ref["goodput"] >= 0.9
    assert ref["evictions"] == 1 and ref["respawns"] == 1
    assert ref["reseeded_entries"] == sfb.WARM_ENTRIES
    assert ref["hedges"] > 0 and ref["retries"] > 0
    assert ref["failed"] == 0


def test_slo_invariant_holds_on_every_schedule(report):
    # every admitted request completes within its deadline or was handed
    # to the int8 degrade path — even under burst overload
    for r in report["schedules"]:
        assert r["slo_handled_rate"] == 1.0, r["name"]
        assert r["failed"] == 0, r["name"]


def test_burst_overload_sheds_and_degrades(report):
    burst = next(r for r in report["schedules"]
                 if r["name"] == "burst_overload")
    assert burst["shed_rate"] > 0 and burst["degrade_rate"] > 0
    kinds = {e["kind"] for e in burst["events"]}
    assert "shed" in kinds and "degrade_admission" in kinds


def test_recovery_visible_in_reference_schedule(report):
    ref = next(r for r in report["schedules"] if r["name"] == "reference")
    kinds = [e["kind"] for e in ref["events"]]
    assert "eviction" in kinds and "respawn" in kinds
    respawn = next(e for e in ref["events"] if e["kind"] == "respawn")
    assert respawn["warm"], "the respawn came up cold (reseed failed)"
    assert "hedge" in kinds and "retry_backoff" in kinds


def test_tail_latency_ordering(report):
    for r in report["schedules"]:
        assert 0.0 < r["p50_ms"] <= r["p99_ms"] <= r["max_ms"], r["name"]
    ff = next(r for r in report["schedules"] if r["name"] == "fault_free")
    ref = next(r for r in report["schedules"] if r["name"] == "reference")
    # chaos cannot make the tail better than fault-free
    assert ref["p99_ms"] >= ff["p99_ms"]


def test_extractor_metric_surface(report):
    metrics = dict(perfci.extract_serve_fleet(report))
    for name in ("fault_free", "reference", "burst_overload"):
        for leaf in ("goodput", "slo_handled_rate", "shed_rate",
                     "degrade_rate", "p50_ms", "p99_ms", "failed",
                     "evictions", "respawns", "reseeded_entries",
                     "hedges", "retries"):
            assert f"serve_fleet/{name}/{leaf}" in metrics
    # every serve_fleet metric matches a fleet-specific policy, never
    # falling through to the generic catch-all drift guard
    for mid in metrics:
        pol = perfci.policy_for(mid)
        assert pol.pattern.startswith("serve_fleet/"), (mid, pol.pattern)
    # the gate's hard bars are wired: identity anchor, goodput floor,
    # SLO invariant, and the reference p99 ceiling
    assert perfci.policy_for("serve_fleet/fault_free/goodput").floor == 1.0
    assert perfci.policy_for("serve_fleet/reference/goodput").floor == 0.9
    assert perfci.policy_for(
        "serve_fleet/reference/slo_handled_rate").floor == 1.0
    assert perfci.policy_for("serve_fleet/reference/p99_ms").ceiling \
        is not None


def test_committed_artifact_matches_fresh_build(report):
    committed = json.loads((REPO / "BENCH_serve_fleet.json").read_text())
    fresh = json.loads(json.dumps(report))
    assert committed == fresh, \
        "BENCH_serve_fleet.json is stale — rerun benchmarks/serve_fleet_bench"
