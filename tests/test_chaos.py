"""The chaos harness + self-healing loop (train/chaos.py, DESIGN.md §14):
seeded-schedule determinism, the engine's fault mechanics against a
synthetic loop, and the ISSUE acceptance end-to-end on the DP CNN step
(subprocess, fake devices): a seeded schedule with a mid-run host death, a
straggler, and a corrupted newest checkpoint completes with zero operator
intervention, an eviction-triggered 4 -> 2 elastic re-scale conserving the
int8 residual's gradient mass, and params bit-identical to a fault-free
run for the pure restart-replay segment."""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.train import chaos as cz
from repro.train import checkpoint as C
from repro.train.fault_tolerance import ResilientLoop

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# -- schedule determinism ------------------------------------------------------

def test_schedule_generate_is_seed_deterministic():
    hosts = [f"host{i}" for i in range(6)]
    a = cz.ChaosSchedule.generate(7, n_steps=500, hosts=hosts)
    b = cz.ChaosSchedule.generate(7, n_steps=500, hosts=hosts)
    assert a.events == b.events and len(a.events) == 10    # 2% of 500
    c = cz.ChaosSchedule.generate(8, n_steps=500, hosts=hosts)
    assert a.events != c.events


def test_schedule_never_kills_host0_or_empties_fleet():
    for seed in range(20):
        sched = cz.ChaosSchedule.generate(seed, n_steps=2000,
                                          hosts=["host0", "host1", "host2"],
                                          intensity=5.0)
        deaths = [e for e in sched.events if isinstance(e, cz.HostDeath)]
        assert all(d.host != "host0" for d in deaths)
        assert len(deaths) <= 2
        assert len({d.host for d in deaths}) == len(deaths)


# -- engine mechanics ----------------------------------------------------------

def test_simclock_sleep_advances_not_blocks():
    clk = cz.SimClock()
    clk.sleep(3.5)
    clk.advance(1.5)
    assert clk.time() == 5.0


def test_step_fault_fires_exactly_once(tmp_path):
    eng = cz.ChaosEngine(cz.ChaosSchedule((cz.StepFault(2, cost_s=0.5),)),
                         hosts=["host0"], ckpt_dir=tmp_path)
    eng.failure_hook(0)
    with pytest.raises(cz.ChaosError, match="injected step fault"):
        eng.failure_hook(2)
    assert eng.clock.time() == 0.5
    eng.failure_hook(2)                    # fired: the retry goes through


def test_dead_host_fails_collective_until_evicted(tmp_path):
    eng = cz.ChaosEngine(cz.ChaosSchedule((cz.HostDeath(1, "host1"),)),
                         hosts=["host0", "host1"], ckpt_dir=tmp_path,
                         collective_timeout_s=2.0)
    eng.failure_hook(0)
    with pytest.raises(cz.ChaosError, match="host1"):
        eng.failure_hook(1)
    assert eng.clock.time() == 2.0
    assert eng.liveness(1) == ["host0"]    # pings exclude the dead
    # unbound engine falls back to its own host list; simulate the
    # post-eviction membership with a bound loop stand-in
    class FakeLoop:
        alive = ["host0"]
        checkpointer = C.AsyncCheckpointer(tmp_path)
    eng._loop = FakeLoop()
    eng.failure_hook(2)                    # dead host gone: collective heals
    assert eng.heartbeat_source(2, 1.0) == {"host0": 1.0}


def test_slow_host_durations_and_recovery(tmp_path):
    eng = cz.ChaosEngine(
        cz.ChaosSchedule((cz.SlowHost(0, "host1", factor=4.0, until=3),)),
        hosts=["host0", "host1"], ckpt_dir=tmp_path)
    eng.failure_hook(0)
    assert eng.heartbeat_source(0, 1.0) == {"host0": 1.0, "host1": 4.0}
    assert eng.heartbeat_source(3, 1.0) == {"host0": 1.0, "host1": 1.0}
    assert eng.clock.time() == 5.0         # max(1,4) + max(1,1)


def test_flaky_saves_inject_then_heal(tmp_path):
    eng = cz.ChaosEngine(cz.ChaosSchedule((cz.FlakySaves(0, times=2),)),
                         hosts=["host0"], ckpt_dir=tmp_path)
    inner = C.AsyncCheckpointer(tmp_path)
    flaky = cz._FlakyCheckpointer(inner, eng)
    eng.failure_hook(0)
    for _ in range(2):
        with pytest.raises(IOError, match="chaos"):
            flaky.save(1, {"x": np.ones(2)})
    flaky.save(1, {"x": np.ones(2)})       # outage over
    flaky.wait()
    assert C.latest_step(tmp_path) == 1
    assert flaky.keep == inner.keep        # proxy delegates attributes


def test_corrupt_and_torn_wait_for_a_checkpoint(tmp_path):
    assert cz.corrupt_latest(tmp_path) is None
    assert cz.torn_checkpoint(tmp_path) is None
    eng = cz.ChaosEngine(cz.ChaosSchedule((cz.CorruptCheckpoint(0),)),
                         hosts=["host0"], ckpt_dir=tmp_path)
    eng.failure_hook(0)                    # no checkpoint yet: stays armed
    assert not eng.injected
    C.save(tmp_path, 3, {"x": np.arange(6.0)})
    eng.failure_hook(1)                    # now it strikes
    assert [e["kind"] for e in eng.injected] == ["CorruptCheckpoint"]
    assert C.valid_steps(tmp_path) == []


# -- the synthetic full-vocabulary run ----------------------------------------

def test_synthetic_loop_survives_full_fault_vocabulary(tmp_path):
    """Every fault kind in one seeded run over a trivial state: the loop
    must finish all steps, evict the dead host and the straggler, retry the
    flaky saves, and never need operator input."""
    hosts = [f"host{i}" for i in range(4)]
    sched = cz.ChaosSchedule((
        cz.StepFault(5),
        cz.SlowHost(10, "host2", factor=4.0),
        cz.HostDeath(20, "host3"),
        cz.CorruptCheckpoint(28),
        cz.FlakySaves(33, times=2),
        cz.TornCheckpoint(36),
    ))
    eng = cz.ChaosEngine(sched, hosts=hosts, ckpt_dir=tmp_path)

    def step_fn(state, batch):
        return state + batch, {"loss": 0.0}

    class Data:
        def batch_at(self, step):
            return float(step)

    loop = ResilientLoop(step_fn=step_fn, state=0.0, data=Data(),
                         ckpt_dir=tmp_path, ckpt_every=10, policy_every=5,
                         min_hosts=2, chaos=eng,
                         heartbeat=eng.make_heartbeat())
    loop.run(50)
    s = loop.resilience_summary()
    assert s["evictions"] == 2 and sorted(loop.alive) == ["host0", "host1"]
    assert s["restarts"] >= 2              # the step fault + collective fails
    assert s["io_retries"] == 2            # both flaky saves retried through
    kinds = {e["kind"] for e in loop.events}
    assert {"step_failure", "eviction", "io_retry"} <= kinds
    # goodput stays sane even under the full vocabulary
    assert 50.0 / eng.clock.time() > 0.5


# -- the DP CNN end-to-end (subprocess, 4 fake devices) ------------------------

_PRELUDE = """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import sys
    sys.path.insert(0, %r)
    import jax, jax.numpy as jnp
    import numpy as np
    assert len(jax.devices()) == 4
    from repro.data import SyntheticImageData
    from repro.graph import GxM, resnet50
    from repro.launch.mesh import make_host_mesh
    from repro.train import chaos as cz
    from repro.train.distributed import (init_cnn_train_state_dp,
                                         make_cnn_train_step_dp,
                                         reshard_cnn_state)
    from repro.train.fault_tolerance import Heartbeat, ResilientLoop

    def tiny(hw=32):
        m = GxM(resnet50(num_classes=10, stages=(1, 1, 1, 1)),
                num_classes=10)
        return m, m.init(jax.random.PRNGKey(0))
""" % os.path.join(REPO, "src")


def run_sub(body: str) -> str:
    code = textwrap.dedent(_PRELUDE) + textwrap.dedent(body)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=420)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


def test_chaos_restart_replay_is_bit_identical(tmp_path):
    """Pure restart-replay segment: an injected step fault (no eviction)
    restores the last checkpoint and replays the exact failed batches —
    final params bit-identical to the fault-free run."""
    out = run_sub(f"""
        import tempfile
        m, params = tiny()
        data = SyntheticImageData(hw=32, n_classes=10, global_batch=4)
        mesh = make_host_mesh(data=2)
        dp = make_cnn_train_step_dp(m, mesh, lr=0.05)

        def run(ckpt_dir, chaos):
            loop = ResilientLoop(
                step_fn=dp, state=init_cnn_train_state_dp(params, mesh),
                data=data, ckpt_dir=ckpt_dir, ckpt_every=2, policy_every=0,
                chaos=chaos,
                heartbeat=chaos.make_heartbeat() if chaos else None)
            return loop, loop.run(8)

        eng = cz.ChaosEngine(cz.ChaosSchedule((cz.StepFault(5),)),
                             hosts=["host0", "host1"],
                             ckpt_dir={str(tmp_path / "a")!r})
        loop_f, final_f = run({str(tmp_path / "a")!r}, eng)
        loop_c, final_c = run({str(tmp_path / "b")!r}, None)
        assert loop_f.restarts == 1 and loop_f.lost_steps == 1
        assert int(final_f["step"]) == int(final_c["step"]) == 8
        for a, b in zip(jax.tree.leaves(final_f["params"]),
                        jax.tree.leaves(final_c["params"])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        print("REPLAY-BITEXACT-OK")
    """)
    assert "REPLAY-BITEXACT-OK" in out


def test_chaos_e2e_eviction_elastic_rescale_4_to_2(tmp_path):
    """The ISSUE acceptance run: seeded schedule with a straggler, a
    mid-run host death, and a corrupted newest checkpoint.  The loop must
    evict the dead host AND the straggler in one sweep (4 -> 2), fold the
    int8 residual with no gradient mass lost, walk back past the corrupt
    checkpoint, and finish all steps without intervention."""
    out = run_sub(f"""
        m, params = tiny()
        data = SyntheticImageData(hw=32, n_classes=10, global_batch=8)
        hosts = [f"host{{i}}" for i in range(4)]
        sched = cz.ChaosSchedule((
            cz.SlowHost(1, "host2", factor=3.0),
            cz.HostDeath(8, "host3"),
            cz.CorruptCheckpoint(13),
            cz.StepFault(13),
        ))
        eng = cz.ChaosEngine(sched, hosts=hosts, ckpt_dir={str(tmp_path)!r})
        mesh4 = make_host_mesh(data=4)
        dp4 = make_cnn_train_step_dp(m, mesh4, lr=0.05,
                                     grad_compress="int8")

        def elastic_fn(state, alive):
            n = len(alive)
            host = jax.device_get(state)
            before = jax.tree.map(lambda r: np.asarray(r).sum(axis=0),
                                  host["residual"])
            mesh_n = make_host_mesh(data=n)
            state2 = reshard_cnn_state(host, mesh_n)
            after = jax.tree.map(lambda r: np.asarray(r).sum(axis=0),
                                 jax.device_get(state2["residual"]))
            mass = sum(float(np.abs(a).sum()) for a in jax.tree.leaves(before))
            assert mass > 0, "residual empty: the mass check would be vacuous"
            for a, b in zip(jax.tree.leaves(before), jax.tree.leaves(after)):
                np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-7)
            print("FOLD-MASS-OK", n)
            return state2, make_cnn_train_step_dp(m, mesh_n, lr=0.05,
                                                  grad_compress="int8")

        loop = ResilientLoop(
            step_fn=dp4,
            state=init_cnn_train_state_dp(params, mesh4,
                                          grad_compress="int8"),
            data=data, ckpt_dir={str(tmp_path)!r}, ckpt_every=4,
            policy_every=0, min_hosts=2, chaos=eng, elastic_fn=elastic_fn,
            # tight dead-timeout: host3 is stale after ONE collective
            # timeout, so the first failure sweep evicts the dead host AND
            # the straggler together (4 -> 2 in a single fold; a 3-wide
            # mesh would not divide the batch)
            heartbeat=Heartbeat(window=8, threshold=1.5, timeout_s=1.5,
                                clock=eng.clock.time))
        final = loop.run(16)

        s = loop.resilience_summary()
        assert s["evictions"] == 2, s
        ev = next(e for e in loop.events if e["kind"] == "eviction")
        assert sorted(ev["hosts"]) == ["host2", "host3"], ev
        assert ev["dead"] == ["host3"] and ev["stragglers"] == ["host2"]
        assert sorted(loop.alive) == ["host0", "host1"]
        assert any(e["kind"] == "ckpt_skipped" for e in loop.events), \\
            "walk-back never skipped the corrupted checkpoint"
        for r in jax.tree.leaves(final["residual"]):
            assert r.shape[0] == 2, r.shape
        assert int(final["step"]) == 16
        assert all(np.isfinite(np.asarray(x)).all()
                   for x in jax.tree.leaves(final["params"]))
        print("E2E-OK", s["restarts"], s["lost_steps"])
    """)
    assert out.count("FOLD-MASS-OK") == 1
    assert "E2E-OK" in out
