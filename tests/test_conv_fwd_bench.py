"""BENCH_conv_fwd invariants: the tiled input strategy must dominate the
legacy whole-plane kernel — per layer, across both benchmark tables — on
modeled HBM traffic, roofline cost, and VMEM working set (the PR-over-PR
perf baseline other sessions diff against)."""
from benchmarks.conv_fwd_bench import build_report, layer_tables


def test_tables_cover_paper_topologies():
    tables = layer_tables()
    assert len(tables["resnet50"]) == 20          # paper Table I
    assert len(tables["inception_v3"]) >= 10
    for layers in tables.values():
        for sh in layers:
            for f in ("h", "w", "c", "k", "r", "s", "stride", "padding"):
                assert f in sh, (sh, f)


def test_tiled_dominates_whole_plane_everywhere():
    report = build_report()
    assert report["tables"]
    for tname, recs in report["tables"].items():
        for rec in recs:
            t, wp = rec["tiled"], rec["whole_plane"]
            lid = (tname, rec["layer"])
            assert t["hbm_bytes"] <= wp["hbm_bytes"], lid
            assert t["cost_us"] <= wp["cost_us"], lid
            assert t["vmem_working_set"] <= wp["vmem_working_set"], lid
            assert t["fits_vmem"], lid
            assert t["images_per_sec"] >= wp["images_per_sec"], lid
