"""The roofline perf gate (repro.perfci, DESIGN.md §12): extractors over
the committed bench artifacts, tolerance-policy semantics, the comparison
engine's verdicts, the baseline/trajectory store round trip, and the
acceptance demo — a synthetic regression injected into a baseline copy
must flip the gate to a non-zero exit while the clean tree passes."""
import json
import pathlib

import pytest

from repro import perfci

ROOT = pathlib.Path(__file__).resolve().parents[1]


@pytest.fixture(scope="module")
def fresh():
    """(context, metrics) extracted from the committed bench artifacts."""
    return perfci.extract_all(ROOT)


@pytest.fixture(scope="module")
def committed_baselines():
    return perfci.load_baselines(ROOT / "BENCH_BASELINES.json")


# -- extractors ---------------------------------------------------------------

def test_extractors_cover_all_benches(fresh):
    context, metrics = fresh
    # committed artifacts are generated under the default budget; the
    # context comes from the files, not the environment
    assert context == perfci.DEFAULT_CONTEXT
    prefixes = {m.split("/")[0] for m in metrics}
    assert prefixes == {"conv_fwd", "bwd_wu", "train_scaling", "q8_infer",
                        "resilience", "serve_fleet", "chain_fusion"}
    assert len(metrics) > 300        # per-layer series, not a summary


def test_extracted_invariants_hold_on_committed_artifacts(fresh):
    _, metrics = fresh
    for mid, v in metrics.items():
        if mid.endswith("_margin"):
            assert v >= 1.0, mid      # tiled/phase never lose at 16 MiB
        if mid.endswith("/fits_vmem"):
            assert v == 1.0, mid
        if mid.endswith("roofline_efficiency"):
            assert 0.0 < v <= 1.0, mid
    assert metrics["train_scaling/d2/fp32/scaling_efficiency"] >= 0.8
    assert metrics["train_scaling/d1/fp32/scaling_efficiency"] == 1.0


def test_context_key_rejects_mixed_budget_artifacts():
    reports = {
        "conv_fwd": {"vmem_budget": 16 * 1024 * 1024},
        "bwd_wu": {"vmem_budget": 1 << 20},
    }
    with pytest.raises(ValueError, match="vmem_budget"):
        perfci.context_key(reports)


# -- policies -----------------------------------------------------------------

def test_policy_routing():
    pol = perfci.policy_for("train_scaling/d2/fp32/scaling_efficiency")
    assert pol.floor == 0.8 and pol.direction == "higher"
    assert perfci.policy_for(
        "train_scaling/d1/int8/scaling_efficiency").ceiling == 1.0
    pol = perfci.policy_for("conv_fwd/resnet50/L01/cost_margin")
    assert pol.floor == 1.0
    pol = perfci.policy_for("conv_fwd/resnet50/L01/tiled/roofline_efficiency")
    assert pol.ceiling == 1.0 and pol.direction == "higher"
    assert perfci.policy_for("bwd_wu/x/y/wu_tiled/cost_us").direction == \
        "lower"
    assert perfci.policy_for("something/unknown").pattern == "*"


def test_pressure_context_drops_margin_floor_only():
    default = perfci.policies_for_context(perfci.DEFAULT_CONTEXT)
    pressure = perfci.policies_for_context("vmem=1048576")
    assert default == perfci.DEFAULT_POLICIES
    d_margin = perfci.policy_for("a/b/cost_margin", default)
    p_margin = perfci.policy_for("a/b/cost_margin", pressure)
    assert d_margin.floor == 1.0 and p_margin.floor is None
    # every other rule is shared
    assert perfci.policy_for("a/b/fits_vmem", pressure).floor == 1.0
    assert perfci.policy_for("train_scaling/d2/fp32/scaling_efficiency",
                             pressure).floor == 0.8


# -- comparison engine --------------------------------------------------------

def test_compare_statuses():
    base = {"x/cost_us": 100.0, "x/roofline_efficiency": 0.5,
            "x/cost_margin": 1.5, "gone/cost_us": 1.0}
    cur = {"x/cost_us": 101.0,              # +1% — within 2%: ok
           "x/roofline_efficiency": 0.6,    # +20% the good way: improved
           "x/cost_margin": 0.9,            # below the 1.0 floor: fail
           "brand/new_metric": 3.0}         # no baseline: new (passes)
    v = perfci.compare(base, cur)
    by = {r.metric: r.status for r in v.results}
    assert by == {"x/cost_us": "ok", "x/roofline_efficiency": "improved",
                  "x/cost_margin": "floor", "gone/cost_us": "missing",
                  "brand/new_metric": "new"}
    assert not v.ok
    assert {r.metric for r in v.failures} == {"x/cost_margin", "gone/cost_us"}
    j = v.to_json()
    assert j["ok"] is False and j["n_metrics"] == 5
    assert "perf-gate: FAIL" in v.diff_table()


def test_compare_relative_drop_direction():
    # efficiency dropping 5% fails; cost rising 5% fails; both at 1% pass
    v = perfci.compare({"a/roofline_efficiency": 0.80, "a/cost_us": 100.0},
                       {"a/roofline_efficiency": 0.76, "a/cost_us": 105.0})
    assert {r.metric for r in v.failures} == {"a/roofline_efficiency",
                                              "a/cost_us"}
    v = perfci.compare({"a/roofline_efficiency": 0.80, "a/cost_us": 100.0},
                       {"a/roofline_efficiency": 0.792, "a/cost_us": 101.0})
    assert v.ok


def test_floor_fails_even_with_bad_baseline():
    # the hard floor is absolute: a bad committed baseline cannot grandfather
    # a below-bar value in
    v = perfci.compare({"train_scaling/d2/fp32/scaling_efficiency": 0.7},
                       {"train_scaling/d2/fp32/scaling_efficiency": 0.75})
    assert [r.status for r in v.results] == ["floor"]


def test_efficiency_above_one_is_a_model_bug():
    v = perfci.compare({"a/roofline_efficiency": 0.9},
                       {"a/roofline_efficiency": 1.2})
    assert [r.status for r in v.results] == ["ceiling"]


# -- baseline store + gate round trip -----------------------------------------

def test_committed_baseline_matches_committed_artifacts(fresh,
                                                        committed_baselines):
    """The clean-tree acceptance: committed artifacts vs committed baseline
    is all-ok under the committed context's policies."""
    context, metrics = fresh
    base = perfci.baseline_metrics(committed_baselines, context)
    assert base is not None, "run benchmarks.run --dry --update-baselines"
    v = perfci.compare(base, metrics, perfci.policies_for_context(context))
    assert v.ok, v.diff_table()
    assert v.counts == {"ok": len(metrics)}
    # both the default and the CI pressure context are pinned
    assert "vmem=1048576" in committed_baselines["contexts"]


def test_synthetic_regression_flips_the_gate(tmp_path, fresh,
                                             committed_baselines,
                                             monkeypatch):
    """The ISSUE acceptance demo: perturb one gated metric in a baseline
    copy past its tolerance and the check must exit non-zero."""
    context, _ = fresh
    doc = json.loads(json.dumps(committed_baselines))    # deep copy
    metrics = doc["contexts"][context]["metrics"]
    mid = "conv_fwd/resnet50/L01/tiled/roofline_efficiency"
    metrics[mid] *= 1.25          # baseline claims 25% more than we deliver
    bpath = tmp_path / "baselines.json"
    bpath.write_text(json.dumps(doc))
    lines = []
    verdict = perfci.run_check(ROOT, baseline_path=bpath, out=lines.append)
    assert not verdict.ok
    assert [r.metric for r in verdict.failures] == [mid]
    assert any("perf-gate: FAIL" in ln for ln in lines)
    # and the CLI surfaces it as a non-zero exit (benches stubbed out: the
    # committed artifacts under ROOT stand in for a fresh run)
    from benchmarks import run as bench_run
    monkeypatch.setattr(bench_run, "run_benches", lambda *, dry: 0)
    monkeypatch.setenv("REPRO_BENCH_OUT", str(ROOT))
    with pytest.raises(SystemExit, match="regressed"):
        bench_run.main(["--dry", "--check", "--baselines", str(bpath)])


def test_missing_baseline_context_is_actionable(tmp_path):
    bpath = tmp_path / "empty.json"
    with pytest.raises(perfci.MissingBaseline, match="update-baselines"):
        perfci.run_check(ROOT, baseline_path=bpath)


def test_update_appends_exactly_one_trajectory_record_per_run(tmp_path):
    bpath = tmp_path / "baselines.json"
    tpath = tmp_path / "trajectory.json"
    rec = perfci.run_update(ROOT, baseline_path=bpath, trajectory_path=tpath,
                            command="test", out=lambda *_: None)
    doc = json.loads(tpath.read_text())
    assert len(doc["records"]) == 1
    assert rec["summary"]["scaling_d2_fp32"] >= 0.8
    assert rec["provenance"]["command"] == "test"
    assert "vs_previous" not in rec          # first pin: nothing to diff
    # second run: one more record, now with the improved/regressed counts
    perfci.run_update(ROOT, baseline_path=bpath, trajectory_path=tpath,
                      command="test", out=lambda *_: None)
    doc = json.loads(tpath.read_text())
    assert len(doc["records"]) == 2
    assert doc["records"][1]["vs_previous"]["regressed"] == 0
    # the baseline store kept exactly one context, schema-versioned
    bdoc = perfci.load_baselines(bpath)
    assert bdoc["schema_version"] == perfci.SCHEMA_VERSION
    assert list(bdoc["contexts"]) == [perfci.DEFAULT_CONTEXT]


def test_baseline_schema_version_mismatch_rejected(tmp_path):
    bpath = tmp_path / "old.json"
    bpath.write_text(json.dumps({"schema_version": 0, "contexts": {}}))
    with pytest.raises(ValueError, match="schema"):
        perfci.load_baselines(bpath)
