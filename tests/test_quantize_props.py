"""Property tests for ``core.quantize`` — the §II-K numerics contract.

Runs under real ``hypothesis`` when installed, else the deterministic
fixed-draw shim (``tests/_hypothesis_compat.py``).  The properties:

  * round-trip: |x - q*scale| <= scale/2 per element for every in-range
    value (round-to-nearest against the calibrated scale);
  * symmetric clipping: |q| <= 127 always, out-of-range values saturate,
    and quantization is an odd function (q(-x) == -q(x));
  * small tensors pass through ``quantize_int8`` untouched;
  * scales are strictly positive — the ``+ 1e-12`` guard is pinned
    explicitly, so an all-zero tensor quantizes to zeros instead of
    dividing by zero.
"""
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.quantize import dequantize, quantize_act, quantize_int8

SCALE_GUARD = 1e-12      # the shared guard every scale in core.quantize adds


def _vals(seed: int, n: int, scale_pow: int) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return (rng.standard_normal(n) * 10.0 ** scale_pow).astype(np.float32)


@settings(max_examples=20)
@given(seed=st.integers(0, 2 ** 31 - 1), n=st.integers(1, 64),
       scale_pow=st.integers(-3, 3))
def test_act_roundtrip_error_at_most_half_scale(seed, n, scale_pow):
    x = _vals(seed, n, scale_pow)
    scale = float(np.abs(x).max()) / 127.0 + SCALE_GUARD
    q = np.asarray(quantize_act(jnp.asarray(x), jnp.float32(scale)))
    deq = q.astype(np.float32) * np.float32(scale)
    # round-to-nearest: half a quantization step, plus f32 division slop
    assert np.all(np.abs(x - deq) <= scale * 0.5001), \
        float(np.max(np.abs(x - deq)) / scale)


@settings(max_examples=20)
@given(seed=st.integers(0, 2 ** 31 - 1), n=st.integers(1, 64),
       blowup=st.floats(1.0, 100.0))
def test_act_clips_symmetrically_at_127(seed, n, blowup):
    x = _vals(seed, n, 0)
    # deliberately under-calibrated scale: values beyond ±127*scale saturate
    scale = jnp.float32(float(np.abs(x).max()) / (127.0 * blowup)
                        + SCALE_GUARD)
    q = np.asarray(quantize_act(jnp.asarray(x), scale), np.int32)
    assert np.all(np.abs(q) <= 127)
    over = np.abs(x) > 127.5 * float(scale)
    assert np.all(np.abs(q[over]) == 127)
    # odd function: jnp.round (half-to-even) is symmetric under negation
    q_neg = np.asarray(quantize_act(jnp.asarray(-x), scale), np.int32)
    np.testing.assert_array_equal(q_neg, -q)


@settings(max_examples=15)
@given(seed=st.integers(0, 2 ** 31 - 1), rows=st.integers(1, 7),
       cols=st.integers(1, 8))
def test_small_tensors_pass_through_unquantized(seed, rows, cols):
    rng = np.random.default_rng(seed)
    small = jnp.asarray(rng.standard_normal((rows, cols)), jnp.float32)
    vec = jnp.asarray(rng.standard_normal(1024), jnp.float32)  # 1-D: never
    out = quantize_int8({"w": small, "b": vec}, min_size=64)
    assert not isinstance(out["b"], dict)            # ndim < 2 passthrough
    if small.size < 64:
        assert not isinstance(out["w"], dict)        # size < min_size
        np.testing.assert_array_equal(np.asarray(out["w"]),
                                      np.asarray(small))
    else:
        assert set(out["w"]) == {"q", "s"}           # big enough: quantized


@settings(max_examples=15)
@given(seed=st.integers(0, 2 ** 31 - 1), rows=st.integers(8, 32),
       cols=st.integers(8, 32), scale_pow=st.integers(-6, 3))
def test_weight_scales_strictly_positive(seed, rows, cols, scale_pow):
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.standard_normal((rows, cols)) * 10.0 ** scale_pow,
                    jnp.float32)
    out = quantize_int8({"w": w}, min_size=1)
    s = np.asarray(out["w"]["s"], np.float64)
    assert np.all(s > 0)
    assert np.all(s >= SCALE_GUARD)


def test_zero_tensor_quantizes_to_zeros_via_guard():
    """The + 1e-12 guard, pinned: an all-zero matrix must produce exactly
    the guard as its scale (no division by zero) and reconstruct to exact
    zeros."""
    z = jnp.zeros((16, 16), jnp.float32)
    out = quantize_int8({"w": z}, min_size=1)
    np.testing.assert_array_equal(np.asarray(out["w"]["s"]),
                                  np.full(16, SCALE_GUARD, np.float32))
    np.testing.assert_array_equal(np.asarray(out["w"]["q"]),
                                  np.zeros((16, 16), np.int8))
    deq = dequantize(out, jnp.float32)
    np.testing.assert_array_equal(np.asarray(deq["w"]), np.asarray(z))
    # the activation side shares the same guard
    q = quantize_act(z, jnp.float32(0.0 / 127.0 + SCALE_GUARD))
    np.testing.assert_array_equal(np.asarray(q), np.zeros((16, 16), np.int8))
