"""The resilience bench (benchmarks/resilience_bench.py): determinism of
the simulated-time replay, the perf-gate floors on the fresh report, the
extractor's metric surface, and the committed artifact staying in sync."""
import json
import pathlib

import pytest

from benchmarks import resilience_bench as rb
from repro import perfci

REPO = pathlib.Path(__file__).resolve().parents[1]


@pytest.fixture(scope="module")
def report():
    return rb.build_report()


def test_report_is_bit_deterministic(report):
    again = rb.build_report()
    assert json.dumps(report, sort_keys=True) == \
        json.dumps(again, sort_keys=True)


def test_fault_free_anchor_and_reference_floor(report):
    rows = {r["name"]: r for r in report["schedules"]}
    assert set(rows) == {"fault_free", "reference", "restart_heavy"}
    ff = rows["fault_free"]
    assert ff["goodput_ratio"] == 1.0
    assert ff["restarts"] == ff["lost_steps"] == ff["evictions"] == 0
    assert ff["events"] == []
    ref = rows["reference"]
    # the ISSUE floor: >= 0.9 goodput under the reference schedule
    assert ref["goodput_ratio"] >= 0.9
    assert ref["evictions"] == 2 and ref["n_hosts_final"] == 2
    assert ref["io_retries"] == 2            # the FlakySaves outage, retried
    heavy = rows["restart_heavy"]
    assert heavy["restarts"] >= 3 and heavy["goodput_ratio"] >= 0.9


def test_every_fold_conserves_mass(report):
    folds = [f for r in report["schedules"] for f in r["folds"]]
    folds += report["fold"]
    assert folds, "no elastic folds exercised"
    assert all(f["mass_conserved"] == 1.0 for f in folds)
    # zero lost gradient mass is also a per-schedule scalar the gate floors
    assert all(r["fold_mass_conserved"] == 1.0 for r in report["schedules"])


def test_events_are_sanitized(report):
    for row in report["schedules"]:
        for ev in row["events"]:
            assert set(ev) == {"kind", "step", "t"}, ev


def test_walkback_visible_in_reference_schedule(report):
    ref = next(r for r in report["schedules"] if r["name"] == "reference")
    kinds = [e["kind"] for e in ref["events"]]
    assert "ckpt_skipped" in kinds, \
        "the corrupted checkpoint never forced a walk-back"
    assert "eviction" in kinds and "restart" in kinds


def test_extractor_metric_surface(report):
    metrics = dict(perfci.extract_resilience(report))
    for name in ("fault_free", "reference", "restart_heavy"):
        for leaf in ("goodput_ratio", "recovery_overhead_steps",
                     "lost_steps", "restarts", "evictions",
                     "fold_mass_conserved"):
            assert f"resilience/{name}/{leaf}" in metrics
    assert metrics["resilience/fold/4to2/mass_conserved"] == 1.0
    # every resilience metric matches a resilience-specific policy, never
    # falling through to the generic catch-all drift guard
    for mid in metrics:
        pol = perfci.policy_for(mid)
        assert pol.pattern.startswith("resilience/"), (mid, pol.pattern)


def test_committed_artifact_matches_fresh_build(report):
    committed = json.loads((REPO / "BENCH_resilience.json").read_text())
    committed.pop("provenance", None)
    fresh = json.loads(json.dumps(report))
    fresh.pop("provenance", None)
    assert committed == fresh, \
        "BENCH_resilience.json is stale — rerun benchmarks/resilience_bench"
