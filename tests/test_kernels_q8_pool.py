"""Quantized conv kernel (§II-K as a kernel) + pooling kernel vs oracles.

The tiled-q8 sections pin the PR-7 retile: tiled ≡ whole-plane bit-exact
(int32 accumulation is associative and both paths share one premultiplied
f32 dequant epilogue), q8 vs f32 within the analytic quantization bound
R·S·C·sx·sw·127.25 per element, and the 224x224 7x7 stem schedulable under
a 1 MiB budget with an H·W-independent working set (the int8 blocking
dividend).  "Both backends" = interpret-mode eager AND under ``jax.jit``.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.shapes import STEM_CONV, STEM_CONV_HALF
from repro.core.blocking import conv_blocking_analytic, conv_working_set
from repro.kernels import ref
from repro.kernels.conv2d_q8 import conv2d_q8, quantize_conv_inputs
from repro.kernels.pool2d import maxpool2d
from repro.tune.space import out_dim


@pytest.mark.parametrize("case", [
    (2, 8, 8, 8, 16, 3, 1, 1),
    (1, 9, 9, 8, 8, 3, 2, 1),
    (1, 8, 8, 16, 8, 1, 1, 0),
])
def test_conv2d_q8_close_to_f32(rng, case):
    n, h, w, c, k, r, stride, pad = case
    x = jnp.asarray(rng.standard_normal((n, h, w, c)), jnp.float32)
    wt = jnp.asarray(rng.standard_normal((r, r, c, k)) * 0.1, jnp.float32)
    xq, wq, sx, sw = quantize_conv_inputs(x, wt)
    out = conv2d_q8(xq, wq, x_scale=sx, w_scale=sw, stride=stride,
                    padding=pad, rb_p=4, interpret=True)
    exp = ref.conv2d(x, wt, stride=stride, padding=pad)
    # int8 quantization error bound: relative to output scale
    denom = float(jnp.abs(exp).max()) + 1e-6
    rel = float(jnp.abs(out - exp).max()) / denom
    assert rel < 0.05, rel


def test_conv2d_q8_int32_accumulation_exact(rng):
    """With integer-valued inputs the int8 path must be EXACT (the paper's
    claim that the quantized kernel computes the same chained GEMMs)."""
    n, h, c, k = 1, 6, 8, 8
    x = jnp.asarray(rng.integers(-3, 4, (n, h, h, c)), jnp.float32)
    wt = jnp.asarray(rng.integers(-3, 4, (3, 3, c, k)), jnp.float32)
    xq = x.astype(jnp.int8)
    wq = wt.astype(jnp.int8)
    out = conv2d_q8(xq, wq, x_scale=jnp.float32(1.0),
                    w_scale=jnp.ones((k,), jnp.float32), stride=1,
                    padding=1, rb_p=3, interpret=True)
    exp = ref.conv2d(x, wt, stride=1, padding=1)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(exp))


def test_conv2d_q8_relu_epilogue(rng):
    x = jnp.asarray(rng.standard_normal((1, 8, 8, 8)), jnp.float32)
    wt = jnp.asarray(rng.standard_normal((3, 3, 8, 8)) * 0.1, jnp.float32)
    xq, wq, sx, sw = quantize_conv_inputs(x, wt)
    out = conv2d_q8(xq, wq, x_scale=sx, w_scale=sw, stride=1, padding=1,
                    relu=True, rb_p=4, interpret=True)
    assert float(out.min()) >= 0.0


# -- tiled q8: band streaming, C/K blocking, ceil-div tails ------------------

TILED_Q8_CASES = [
    # n, h, w, c, k, r, stride, pad, blocking kwargs
    (2, 12, 12, 16, 16, 3, 1, 1, dict(rb_p=5, rb_q=5, c_blk=8)),
    (1, 13, 13, 8, 16, 3, 2, 1, dict(rb_p=3, rb_q=4, k_blk=8)),
    (1, 11, 11, 8, 24, 1, 1, 0, dict(rb_p=4, rb_q=3, k_blk=8)),
    (1, 24, 24, 8, 16, 7, 2, 3, dict(rb_p=4, rb_q=6, c_blk=8)),
    (1, 10, 10, 16, 8, 3, 1, 1, dict(rb_p=4, rb_q=10, c_blk=8,
                                     order="npkc")),
]


def _q8_case_data(rng, case):
    n, h, w, c, k, r, stride, pad, kw = case
    x = jnp.asarray(rng.standard_normal((n, h, w, c)), jnp.float32)
    wt = jnp.asarray(rng.standard_normal((r, r, c, k)) * 0.1, jnp.float32)
    return (x, wt, quantize_conv_inputs(x, wt),
            dict(stride=stride, padding=pad), kw)


@pytest.mark.parametrize("jit", [False, True], ids=["eager", "jit"])
@pytest.mark.parametrize("case", TILED_Q8_CASES)
def test_conv2d_q8_tiled_equals_whole_plane_bitexact(rng, case, jit):
    """The retile must not change a single output bit: int32 accumulation
    is associative, and both kernels apply the identical premultiplied-deq
    f32 epilogue — on the eager interpret path AND under jax.jit."""
    x, wt, (xq, wq, sx, sw), conv_kw, blk_kw = _q8_case_data(rng, case)

    def run(whole):
        fn = lambda a, b: conv2d_q8(a, b, x_scale=sx, w_scale=sw, **conv_kw,
                                    **blk_kw, whole_plane=whole,
                                    interpret=True)
        return (jax.jit(fn) if jit else fn)(xq, wq)

    np.testing.assert_array_equal(np.asarray(run(False)),
                                  np.asarray(run(True)))


@pytest.mark.parametrize("jit", [False, True], ids=["eager", "jit"])
@pytest.mark.parametrize("case", TILED_Q8_CASES)
def test_conv2d_q8_within_analytic_bound(rng, case, jit):
    """|q8 - f32| <= R*S*C*sx*sw_k*127.25 per element: each product term
    errs by at most |x̂||ŵ-w| + |w||x̂-x| <= 127*sx*sw (plus f32 slop),
    summed over the R*S*C accumulation chain."""
    x, wt, (xq, wq, sx, sw), conv_kw, blk_kw = _q8_case_data(rng, case)
    r, _, c, _ = wt.shape
    fn = lambda a, b: conv2d_q8(a, b, x_scale=sx, w_scale=sw, **conv_kw,
                                **blk_kw, whole_plane=False, interpret=True)
    out = np.asarray((jax.jit(fn) if jit else fn)(xq, wq))
    exp = np.asarray(ref.conv2d(x, wt, **conv_kw))
    bound = r * r * c * float(sx) * np.asarray(sw, np.float32) * 127.25
    assert np.all(np.abs(out - exp) <= bound), \
        float(np.max(np.abs(out - exp) / bound))


def test_q8_stem_tiled_under_pressure_budget(rng):
    """The serving acceptance bar: the 224x224 7x7 stride-2 stem is
    un-schedulable whole-plane under the 1 MiB CI budget, but the int8
    band fits with room to grow — and the tiled working set is independent
    of H*W (same band for the 224 and 112 image)."""
    sh = STEM_CONV
    blk = conv_blocking_analytic(
        h=sh["h"], w=sh["w"], c=sh["c"], k=sh["k"], r=sh["r"], s=sh["s"],
        stride=sh["stride"], padding=sh["padding"], dtype_bytes=1,
        kind="q8")

    def ws(shape, whole):
        q = out_dim(shape["w"], shape["s"], shape["stride"],
                    shape["padding"])
        return conv_working_set(
            h=shape["h"], w=shape["w"], c=shape["c"], k_blk=blk.k_blk,
            r=shape["r"], s=shape["s"], q=q, rb_p=blk.rb_p,
            padding=shape["padding"], stride=shape["stride"],
            c_blk=None if whole else blk.c_blk,
            rb_q=None if whole else 16, whole_plane=whole,
            dtype_bytes=1, kind="q8")

    small_budget = 1 << 20            # the CI q8-smoke budget
    assert ws(STEM_CONV, whole=True) > small_budget        # legacy: too big
    assert ws(STEM_CONV, whole=False) <= small_budget      # tiled: fits
    assert ws(STEM_CONV, whole=False) == ws(STEM_CONV_HALF, whole=False)
    # the int8 band is 4x smaller than the f32 one, so the same budget
    # admits a taller row block than the f32 blocking gets
    f32_blk = conv_blocking_analytic(
        h=sh["h"], w=sh["w"], c=sh["c"], k=sh["k"], r=sh["r"], s=sh["s"],
        stride=sh["stride"], padding=sh["padding"], dtype_bytes=4)
    assert blk.rb_p >= f32_blk.rb_p

    x = jnp.asarray(rng.standard_normal(
        (sh["n"], sh["h"], sh["w"], sh["c"])), jnp.float32)
    wt = jnp.asarray(rng.standard_normal(
        (sh["r"], sh["s"], sh["c"], sh["k"])) * 0.1, jnp.float32)
    xq, wq, sx, sw = quantize_conv_inputs(x, wt)
    out = conv2d_q8(xq, wq, x_scale=sx, w_scale=sw, stride=sh["stride"],
                    padding=sh["padding"], rb_p=blk.rb_p, rb_q=16,
                    c_blk=sh["c"], whole_plane=False, interpret=True)
    exp = np.asarray(ref.conv2d(x, wt, stride=sh["stride"],
                                padding=sh["padding"]))
    assert out.shape == (1, 112, 112, sh["k"])
    bound = sh["r"] * sh["s"] * sh["c"] * float(sx) \
        * np.asarray(sw, np.float32) * 127.25
    assert np.all(np.abs(np.asarray(out) - exp) <= bound)


@pytest.mark.parametrize("window,stride,pad,h", [
    (3, 2, 1, 12), (2, 2, 0, 8), (3, 1, 1, 7),
])
def test_maxpool2d_matches_lax(rng, window, stride, pad, h):
    x = jnp.asarray(rng.standard_normal((2, h, h, 8)), jnp.float32)
    out = maxpool2d(x, window=window, stride=stride, padding=pad, rb_p=3,
                    interpret=True)
    exp = jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, window, window, 1),
        (1, stride, stride, 1),
        [(0, 0), (pad, pad), (pad, pad), (0, 0)])
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp))
