"""Quantized conv kernel (§II-K as a kernel) + pooling kernel vs oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.conv2d_q8 import conv2d_q8, quantize_conv_inputs
from repro.kernels.pool2d import maxpool2d


@pytest.mark.parametrize("case", [
    (2, 8, 8, 8, 16, 3, 1, 1),
    (1, 9, 9, 8, 8, 3, 2, 1),
    (1, 8, 8, 16, 8, 1, 1, 0),
])
def test_conv2d_q8_close_to_f32(rng, case):
    n, h, w, c, k, r, stride, pad = case
    x = jnp.asarray(rng.standard_normal((n, h, w, c)), jnp.float32)
    wt = jnp.asarray(rng.standard_normal((r, r, c, k)) * 0.1, jnp.float32)
    xq, wq, sx, sw = quantize_conv_inputs(x, wt)
    out = conv2d_q8(xq, wq, x_scale=sx, w_scale=sw, stride=stride,
                    padding=pad, rb_p=4, interpret=True)
    exp = ref.conv2d(x, wt, stride=stride, padding=pad)
    # int8 quantization error bound: relative to output scale
    denom = float(jnp.abs(exp).max()) + 1e-6
    rel = float(jnp.abs(out - exp).max()) / denom
    assert rel < 0.05, rel


def test_conv2d_q8_int32_accumulation_exact(rng):
    """With integer-valued inputs the int8 path must be EXACT (the paper's
    claim that the quantized kernel computes the same chained GEMMs)."""
    n, h, c, k = 1, 6, 8, 8
    x = jnp.asarray(rng.integers(-3, 4, (n, h, h, c)), jnp.float32)
    wt = jnp.asarray(rng.integers(-3, 4, (3, 3, c, k)), jnp.float32)
    xq = x.astype(jnp.int8)
    wq = wt.astype(jnp.int8)
    out = conv2d_q8(xq, wq, x_scale=jnp.float32(1.0),
                    w_scale=jnp.ones((k,), jnp.float32), stride=1,
                    padding=1, rb_p=3, interpret=True)
    exp = ref.conv2d(x, wt, stride=1, padding=1)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(exp))


def test_conv2d_q8_relu_epilogue(rng):
    x = jnp.asarray(rng.standard_normal((1, 8, 8, 8)), jnp.float32)
    wt = jnp.asarray(rng.standard_normal((3, 3, 8, 8)) * 0.1, jnp.float32)
    xq, wq, sx, sw = quantize_conv_inputs(x, wt)
    out = conv2d_q8(xq, wq, x_scale=sx, w_scale=sw, stride=1, padding=1,
                    relu=True, rb_p=4, interpret=True)
    assert float(out.min()) >= 0.0


@pytest.mark.parametrize("window,stride,pad,h", [
    (3, 2, 1, 12), (2, 2, 0, 8), (3, 1, 1, 7),
])
def test_maxpool2d_matches_lax(rng, window, stride, pad, h):
    x = jnp.asarray(rng.standard_normal((2, h, h, 8)), jnp.float32)
    out = maxpool2d(x, window=window, stride=stride, padding=pad, rb_p=3,
                    interpret=True)
    exp = jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, window, window, 1),
        (1, stride, stride, 1),
        [(0, 0), (pad, pad), (pad, pad), (0, 0)])
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp))
