"""Autotuner (repro.tune): cache round-trip, cold-cache fallback, candidate
space invariants, and numerical parity of tuned vs heuristic blockings."""
import dataclasses
import json

import jax.numpy as jnp
import numpy as np
import pytest

from repro import backend as be
from repro import tune
from repro.core.blocking import (VMEM_BUDGET, conv_blocking,
                                 conv_blocking_analytic, matmul_blocking,
                                 matmul_blocking_analytic)
from repro.graph.topology import RESNET50_LAYERS
from repro.kernels import ref
from repro.kernels.conv2d_direct import conv2d_direct

L4 = RESNET50_LAYERS[4]            # 56x56 c64 k64 3x3 — the sample layer


def _cache(tmp_path):
    return tune.TuneCache(str(tmp_path / "blockings.json"))


# -- cache -------------------------------------------------------------------

def test_cache_roundtrip(tmp_path):
    c = _cache(tmp_path)
    key = tune.conv_key(kind="fwd", h=14, w=14, c=256, k=256, r=3, s=3,
                        stride=1, padding=1, dtype_bytes=4, backend="xla")
    c.store(key, dict(rb_p=4, k_blk=128, c_blk=128, order="nkpc",
                      vmem_bytes=123), source="model", score_us=7.5)
    # a fresh instance over the same file must see the entry
    c2 = tune.TuneCache(c.path)
    entry = c2.lookup(key)
    assert entry is not None
    assert entry["blocking"]["rb_p"] == 4
    assert entry["source"] == "model"
    assert entry["version"] == tune.CACHE_VERSION


def test_cache_version_mismatch_discarded(tmp_path):
    c = _cache(tmp_path)
    c.store("some|key", dict(rb_p=1), source="model", score_us=1.0)
    blob = json.loads(open(c.path).read())
    blob["version"] = tune.CACHE_VERSION + 1
    open(c.path, "w").write(json.dumps(blob))
    assert tune.TuneCache(c.path).lookup("some|key") is None


def test_cache_torn_file_is_cold(tmp_path):
    path = tmp_path / "blockings.json"
    path.write_text("{not json")
    assert tune.TuneCache(str(path)).lookup("k") is None


def test_autotune_conv_persists_and_hits(tmp_path):
    c = _cache(tmp_path)
    kw = dict(h=L4["h"], w=L4["w"], c=L4["c"], k=L4["k"], r=L4["r"],
              s=L4["s"], stride=L4["stride"], padding=1, kind="fwd",
              backend="xla")
    assert tune.lookup_conv(**kw, cache=c) is None          # cold
    blk = tune.autotune_conv(**kw, cache=c)
    assert tune.lookup_conv(**kw, cache=c) == blk           # warm, same proc
    assert tune.TuneCache(c.path).lookup(                   # warm, "new proc"
        tune.conv_key(dtype_bytes=4, **kw)) is not None


def test_cached_entry_rejected_under_forced_budget(tmp_path, monkeypatch):
    """The cache key has no VMEM-budget coordinate: an entry tuned under the
    default 16 MiB must not serve a process with REPRO_VMEM_BUDGET forced
    smaller — lookup revalidates vmem_bytes and falls back to analytic."""
    c = _cache(tmp_path)
    kw = dict(h=14, w=14, c=256, k=256, r=3, s=3, stride=1, padding=1,
              kind="fwd", backend="xla")
    key = tune.conv_key(dtype_bytes=4, **kw)
    c.store(key, dict(rb_p=4, k_blk=128, c_blk=256, order="nkpc",
                      vmem_bytes=2 << 20, rb_q=14), source="model",
            score_us=1.0)
    assert tune.lookup_conv(**kw, cache=c) is not None
    monkeypatch.setattr(tune, "VMEM_BUDGET", 1 << 20)
    assert tune.lookup_conv(**kw, cache=c) is None


# -- blocking integration ----------------------------------------------------

def test_cold_cache_falls_back_to_heuristic(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_TUNE_CACHE", str(tmp_path / "cold.json"))
    kw = dict(h=28, w=28, c=128, k=128, r=3, s=3, stride=1, padding=1)
    with be.use_autotune("cache"):
        got = conv_blocking(**kw)
    assert got == conv_blocking_analytic(**kw)
    mm = matmul_blocking(256, 256, 1024)
    with be.use_autotune("cache"):
        assert matmul_blocking(256, 256, 1024) == mm


def test_autotune_off_is_seed_behavior():
    kw = dict(h=56, w=56, c=64, k=256, r=1, s=1, stride=1, padding=0)
    assert conv_blocking(**kw) == conv_blocking_analytic(**kw)
    assert (matmul_blocking(512, 512, 2048)
            == matmul_blocking_analytic(512, 512, 2048))


def test_tune_mode_used_by_conv_blocking(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_TUNE_CACHE", str(tmp_path / "t.json"))
    kw = dict(h=14, w=14, c=256, k=256, r=3, s=3, stride=1, padding=1)
    with be.use_autotune("tune"):
        tuned = conv_blocking(**kw, backend="interpret")
        # the persisted winner must now serve "cache" mode too
    with be.use_autotune("cache"):
        assert conv_blocking(**kw, backend="interpret") == tuned


# -- candidate space ---------------------------------------------------------

def test_candidates_respect_constraints():
    cands = tune.conv_candidates(h=L4["h"], w=L4["w"], c=L4["c"], k=L4["k"],
                                 r=L4["r"], s=L4["s"], stride=L4["stride"],
                                 padding=1, kind="streams")
    assert len(cands) > 1
    assert cands[0] == conv_blocking_analytic(
        h=L4["h"], w=L4["w"], c=L4["c"], k=L4["k"], r=L4["r"], s=L4["s"],
        stride=L4["stride"], padding=1,
        whole_plane=True)       # seed first, under the streams VMEM model
    for b in cands:
        assert b.vmem_bytes <= VMEM_BUDGET
        assert L4["k"] % b.k_blk == 0
        assert L4["c"] % b.c_blk == 0
        assert b.order in tune.space.ORDERS


def test_wu_candidates_free_cblk_rbq_and_tails():
    """The tiled update pass freed the wu space: rb_p is ceil-div (tails are
    masked in-kernel, so non-divisors of P are legal candidates) and
    c_blk / rb_q are search coordinates — all within the VMEM budget under
    the band-based wu residency model."""
    p = 14
    cands = tune.conv_candidates(h=14, w=14, c=256, k=256, r=3, s=3,
                                 stride=1, padding=1, kind="wu")
    assert any(p % b.rb_p for b in cands)               # non-divisor rb_p
    assert len({b.c_blk for b in cands}) > 1            # C_b freed
    assert len({b.rb_q or p for b in cands}) > 1        # RB_Q freed
    from repro.core.blocking import conv_working_set
    for b in cands:
        assert 256 % b.c_blk == 0 and 256 % b.k_blk == 0
        ws = conv_working_set(h=14, w=14, c=256, k_blk=b.k_blk, r=3, s=3,
                              q=p, rb_p=b.rb_p, padding=1, c_blk=b.c_blk,
                              rb_q=b.rb_q, kind="wu")
        assert ws <= VMEM_BUDGET


def test_bwd_kind_candidates_and_key_namespace():
    """Kind "bwd" (the dual forward conv) searches the fwd space but keys a
    separate cache namespace."""
    kw = dict(h=14, w=14, c=256, k=64, r=3, s=3, stride=1, padding=2)
    cands = tune.conv_candidates(**kw, kind="bwd")
    assert cands[0] == conv_blocking_analytic(**kw)     # fwd-model seed
    assert tune.conv_key(kind="bwd", **kw, dtype_bytes=4, backend="xla") \
        != tune.conv_key(kind="fwd", **kw, dtype_bytes=4, backend="xla")


def test_cost_model_orders_by_occupancy():
    """A 1-row M-tile must never beat a full-height tile on a big layer."""
    shape = dict(h=28, w=28, c=128, k=512, r=1, s=1, stride=1, padding=0,
                 dtype_bytes=4)
    small = dataclasses.replace(conv_blocking_analytic(**shape), rb_p=1)
    tall = dataclasses.replace(small, rb_p=28)
    assert (tune.conv_cost_us(shape, tall)
            < tune.conv_cost_us(shape, small))


# -- numerical parity --------------------------------------------------------

def test_tuned_blocking_parity_resnet_layer(tmp_path, monkeypatch, rng):
    """Tuned blockings are a pure performance knob: outputs must be
    bit-identical to the heuristic blocking on a ResNet-50 layer sample."""
    monkeypatch.setenv("REPRO_TUNE_CACHE", str(tmp_path / "p.json"))
    h, c, k, r, stride, pad = 14, 64, 64, 3, 1, 1   # L13-family, thinned
    x = jnp.asarray(rng.standard_normal((1, h, h, c)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((r, r, c, k)) * 0.1, jnp.float32)

    heur = conv_blocking_analytic(h=h, w=h, c=c, k=k, r=r, s=r,
                                  stride=stride, padding=pad)
    tuned = tune.autotune_conv(h=h, w=h, c=c, k=k, r=r, s=r, stride=stride,
                               padding=pad, kind="fwd", backend="interpret")
    blockings = {(heur.rb_p, heur.k_blk): heur,
                 (tuned.rb_p, tuned.k_blk): tuned}
    # also pin one deliberately different candidate so the check bites even
    # when the tuner agrees with the heuristic
    alt = tune.conv_candidates(h=h, w=h, c=c, k=k, r=r, s=r, stride=stride,
                               padding=pad, kind="fwd")[-1]
    blockings.setdefault((alt.rb_p, alt.k_blk), alt)
    assert len(blockings) >= 2

    expect = np.asarray(ref.conv2d(x, w, stride=stride, padding=pad))
    outs = [np.asarray(conv2d_direct(x, w, stride=stride, padding=pad,
                                     rb_p=b.rb_p, k_blk=b.k_blk,
                                     interpret=True))
            for b in blockings.values()]
    for o in outs[1:]:
        np.testing.assert_array_equal(outs[0], o)           # bit-identical
    np.testing.assert_allclose(outs[0], expect, rtol=1e-4, atol=1e-4)


def test_streams_auto_consumes_tuned_blocking(tmp_path, monkeypatch, rng):
    """conv2d_streams_auto under autotune="tune" must still match the
    oracle — the tuned c_blk/order feed the dryrun schedule."""
    from repro.kernels.conv2d_streams import conv2d_streams_auto

    monkeypatch.setenv("REPRO_TUNE_CACHE", str(tmp_path / "s.json"))
    x = jnp.asarray(rng.standard_normal((1, 8, 8, 16)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((3, 3, 16, 16)) * 0.1, jnp.float32)
    out = conv2d_streams_auto(x, w, stride=1, padding=1, autotune="tune",
                              interpret=True)
    expect = ref.conv2d(x, w, stride=1, padding=1)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-4, atol=1e-4)
    assert len(tune.TuneCache(str(tmp_path / "s.json"))) == 1
