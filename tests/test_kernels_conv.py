"""Per-kernel allclose vs the pure-jnp oracle (interpret mode), swept over
shapes / strides / dtypes, plus hypothesis property sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ref
from repro.kernels.conv2d_direct import conv2d_direct
from repro.kernels.conv2d_streams import conv2d_streams_auto
from repro.kernels.conv2d_wu import conv2d_wu

CASES = [
    # n, h, w, c, k, r, stride, pad, rb_p
    (2, 8, 8, 8, 16, 3, 1, 1, 4),
    (1, 14, 14, 16, 32, 1, 1, 0, 7),
    (2, 16, 16, 8, 8, 3, 2, 1, 4),
    (1, 7, 7, 8, 16, 3, 1, 1, 7),
    (1, 9, 9, 8, 8, 3, 1, 1, 4),      # ceil-div row grid
    (1, 8, 8, 8, 8, 1, 2, 0, 2),
    (1, 12, 12, 8, 8, 5, 1, 2, 3),    # 5x5 filter
]


def _data(rng, n, h, w, c, k, r, dtype=np.float32):
    x = rng.standard_normal((n, h, w, c)).astype(dtype)
    wt = (rng.standard_normal((r, r, c, k)) * 0.1).astype(dtype)
    return jnp.asarray(x), jnp.asarray(wt)


@pytest.mark.parametrize("case", CASES)
def test_conv2d_direct_matches_ref(rng, case):
    n, h, w, c, k, r, stride, pad, rb_p = case
    x, wt = _data(rng, n, h, w, c, k, r)
    out = conv2d_direct(x, wt, stride=stride, padding=pad, rb_p=rb_p,
                        interpret=True)
    exp = ref.conv2d(x, wt, stride=stride, padding=pad)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=1e-4, atol=1e-4)


def test_conv2d_direct_bf16(rng):
    x, wt = _data(rng, 1, 8, 8, 8, 16, 3, dtype=np.float32)
    x, wt = x.astype(jnp.bfloat16), wt.astype(jnp.bfloat16)
    out = conv2d_direct(x, wt, stride=1, padding=1, rb_p=4, interpret=True)
    exp = ref.conv2d(x, wt, stride=1, padding=1)
    assert out.dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32),
                               rtol=0.05, atol=0.05)


def test_conv2d_fused_epilogue(rng):
    x, wt = _data(rng, 1, 8, 8, 8, 16, 3)
    b = jnp.asarray(rng.standard_normal(16), jnp.float32)
    sc = jnp.asarray(rng.standard_normal(16), jnp.float32)
    sh = jnp.asarray(rng.standard_normal(16), jnp.float32)
    res = jnp.asarray(rng.standard_normal((1, 8, 8, 16)), jnp.float32)
    out = conv2d_direct(x, wt, stride=1, padding=1, bias=b, scale=sc,
                        shift=sh, residual=res, relu=True, rb_p=4,
                        interpret=True)
    exp = ref.conv2d_fused(x, wt, stride=1, padding=1, bias=b, scale=sc,
                           shift=sh, residual=res, relu=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("case", [c for c in CASES if c[1] != 9])
def test_conv2d_wu_matches_vjp(rng, case):
    n, h, w, c, k, r, stride, pad, bp = case
    p = (h + 2 * pad - r) // stride + 1
    if p % bp:
        bp = 1
    x, _ = _data(rng, n, h, w, c, k, r)
    do = jnp.asarray(rng.standard_normal((n, p, p, k)), jnp.float32)
    out = conv2d_wu(x, do, stride=stride, padding=pad, filter_rs=(r, r),
                    b_p=bp, interpret=True)
    exp = ref.conv2d_bwd_weights(x, do, stride=stride, padding=pad,
                                 filter_rs=(r, r))
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("order", ["nkpc", "npkc", "knpc"])
def test_conv2d_streams_matches_ref(rng, order):
    x, wt = _data(rng, 2, 8, 8, 16, 16, 3)
    b = jnp.asarray(rng.standard_normal(16), jnp.float32)
    out = conv2d_streams_auto(x, wt, stride=1, padding=1, bias=b, relu=True,
                              rb_p=4, k_blk=8, c_blk=8, order=order,
                              interpret=True)
    exp = ref.conv2d_fused(x, wt, stride=1, padding=1, bias=b, relu=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=1e-4, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(
    n=st.integers(1, 2), hw=st.integers(6, 12),
    c=st.sampled_from([8, 16]), k=st.sampled_from([8, 16]),
    r=st.sampled_from([1, 3]), stride=st.integers(1, 2),
    rb_p=st.integers(1, 4), seed=st.integers(0, 2**31 - 1),
)
def test_conv2d_direct_property(n, hw, c, k, r, stride, rb_p, seed):
    rng = np.random.default_rng(seed)
    pad = r // 2
    x = jnp.asarray(rng.standard_normal((n, hw, hw, c)), jnp.float32)
    wt = jnp.asarray(rng.standard_normal((r, r, c, k)) * 0.1, jnp.float32)
    out = conv2d_direct(x, wt, stride=stride, padding=pad, rb_p=rb_p,
                        interpret=True)
    exp = ref.conv2d(x, wt, stride=stride, padding=pad)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=1e-3, atol=1e-3)
