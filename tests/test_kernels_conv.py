"""Per-kernel allclose vs the pure-jnp oracle (interpret mode), swept over
shapes / strides / dtypes, plus hypothesis property sweeps.

The forward kernel runs *tiled* by default (row-band streaming, C_b
accumulation, RB_Q column blocks — DESIGN.md §9); the legacy whole-plane
variant is pinned explicitly so both input strategies stay bit-exact."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs.shapes import STEM_CONV, STEM_CONV_HALF
from repro.core.blocking import conv_blocking_analytic, conv_working_set
from repro.tune.space import out_dim
from repro.kernels import ref
from repro.kernels.conv2d_direct import conv2d_direct, pad_input
from repro.kernels.conv2d_streams import conv2d_streams_auto
from repro.kernels.conv2d_wu import conv2d_wu

CASES = [
    # n, h, w, c, k, r, stride, pad, rb_p
    (2, 8, 8, 8, 16, 3, 1, 1, 4),
    (1, 14, 14, 16, 32, 1, 1, 0, 7),
    (2, 16, 16, 8, 8, 3, 2, 1, 4),
    (1, 7, 7, 8, 16, 3, 1, 1, 7),
    (1, 9, 9, 8, 8, 3, 1, 1, 4),      # ceil-div row grid
    (1, 8, 8, 8, 8, 1, 2, 0, 2),
    (1, 12, 12, 8, 8, 5, 1, 2, 3),    # 5x5 filter
]


def _data(rng, n, h, w, c, k, r, dtype=np.float32):
    x = rng.standard_normal((n, h, w, c)).astype(dtype)
    wt = (rng.standard_normal((r, r, c, k)) * 0.1).astype(dtype)
    return jnp.asarray(x), jnp.asarray(wt)


@pytest.mark.parametrize("case", CASES)
def test_conv2d_direct_matches_ref(rng, case):
    n, h, w, c, k, r, stride, pad, rb_p = case
    x, wt = _data(rng, n, h, w, c, k, r)
    out = conv2d_direct(x, wt, stride=stride, padding=pad, rb_p=rb_p,
                        interpret=True)
    exp = ref.conv2d(x, wt, stride=stride, padding=pad)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=1e-4, atol=1e-4)


def test_conv2d_direct_bf16(rng):
    x, wt = _data(rng, 1, 8, 8, 8, 16, 3, dtype=np.float32)
    x, wt = x.astype(jnp.bfloat16), wt.astype(jnp.bfloat16)
    out = conv2d_direct(x, wt, stride=1, padding=1, rb_p=4, interpret=True)
    exp = ref.conv2d(x, wt, stride=1, padding=1)
    assert out.dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32),
                               rtol=0.05, atol=0.05)


def test_conv2d_fused_epilogue(rng):
    x, wt = _data(rng, 1, 8, 8, 8, 16, 3)
    b = jnp.asarray(rng.standard_normal(16), jnp.float32)
    sc = jnp.asarray(rng.standard_normal(16), jnp.float32)
    sh = jnp.asarray(rng.standard_normal(16), jnp.float32)
    res = jnp.asarray(rng.standard_normal((1, 8, 8, 16)), jnp.float32)
    out = conv2d_direct(x, wt, stride=1, padding=1, bias=b, scale=sc,
                        shift=sh, residual=res, relu=True, rb_p=4,
                        interpret=True)
    exp = ref.conv2d_fused(x, wt, stride=1, padding=1, bias=b, scale=sc,
                           shift=sh, residual=res, relu=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("case", [c for c in CASES if c[1] != 9])
def test_conv2d_wu_matches_vjp(rng, case):
    n, h, w, c, k, r, stride, pad, bp = case
    p = (h + 2 * pad - r) // stride + 1
    if p % bp:
        bp = 1
    x, _ = _data(rng, n, h, w, c, k, r)
    do = jnp.asarray(rng.standard_normal((n, p, p, k)), jnp.float32)
    out = conv2d_wu(x, do, stride=stride, padding=pad, filter_rs=(r, r),
                    b_p=bp, interpret=True)
    exp = ref.conv2d_bwd_weights(x, do, stride=stride, padding=pad,
                                 filter_rs=(r, r))
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("order", ["nkpc", "npkc", "knpc"])
def test_conv2d_streams_matches_ref(rng, order):
    x, wt = _data(rng, 2, 8, 8, 16, 16, 3)
    b = jnp.asarray(rng.standard_normal(16), jnp.float32)
    out = conv2d_streams_auto(x, wt, stride=1, padding=1, bias=b, relu=True,
                              rb_p=4, k_blk=8, c_blk=8, order=order,
                              interpret=True)
    exp = ref.conv2d_fused(x, wt, stride=1, padding=1, bias=b, relu=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=1e-4, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(
    n=st.integers(1, 2), hw=st.integers(6, 12),
    c=st.sampled_from([8, 16]), k=st.sampled_from([8, 16]),
    r=st.sampled_from([1, 3]), stride=st.integers(1, 2),
    rb_p=st.integers(1, 4), seed=st.integers(0, 2**31 - 1),
)
def test_conv2d_direct_property(n, hw, c, k, r, stride, rb_p, seed):
    rng = np.random.default_rng(seed)
    pad = r // 2
    x = jnp.asarray(rng.standard_normal((n, hw, hw, c)), jnp.float32)
    wt = jnp.asarray(rng.standard_normal((r, r, c, k)) * 0.1, jnp.float32)
    out = conv2d_direct(x, wt, stride=stride, padding=pad, rb_p=rb_p,
                        interpret=True)
    exp = ref.conv2d(x, wt, stride=stride, padding=pad)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=1e-3, atol=1e-3)


# -- tiled-input path (row-band streaming, C_b accumulation, RB_Q) -----------

TILED_CASES = [
    # n, h, w, c, k, r, stride, pad, rb_p, rb_q, c_blk, order
    (2, 8, 8, 16, 16, 3, 1, 1, 4, None, 8, "nkpc"),   # C_b accumulation
    (1, 9, 9, 8, 16, 3, 1, 1, 4, 4, 8, "npkc"),       # P and Q ceil-div tails
    (2, 16, 16, 8, 8, 3, 2, 1, 4, 3, 8, "knpc"),      # stride 2 + Q tail
    (1, 14, 14, 16, 32, 1, 1, 0, 7, 5, 8, "pknc"),    # 1x1, every axis free
    (1, 12, 12, 8, 8, 5, 1, 2, 3, 6, 8, "nkpc"),      # 5x5 halo
    (1, 24, 24, 8, 16, 7, 2, 3, 4, 6, 8, "npkc"),     # 7x7 stride-2 halo
]


@pytest.mark.parametrize("case", TILED_CASES)
def test_conv2d_tiled_blocking_sweep(rng, case):
    """Every freed axis — c_blk, rb_q, loop order — stays bit-exact vs the
    oracle, including the ceil-div spatial tails."""
    n, h, w, c, k, r, stride, pad, rb_p, rb_q, c_blk, order = case
    x, wt = _data(rng, n, h, w, c, k, r)
    out = conv2d_direct(x, wt, stride=stride, padding=pad, rb_p=rb_p,
                        rb_q=rb_q, c_blk=c_blk, order=order, interpret=True)
    exp = ref.conv2d(x, wt, stride=stride, padding=pad)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=1e-4, atol=1e-4)


def test_conv2d_tail_with_fused_residual(rng):
    """Ceil-div P tail + full fused epilogue: the residual BlockSpec reads a
    (1, rb_p, rb_q, k_blk) block at the tail, so p % rb_p != 0 with
    relu+residual must stay bit-exact (pallas masks the out-of-range rows)."""
    n, h, c, k, r, pad = 1, 9, 8, 16, 3, 1
    rb_p = 4                                    # p = 9 -> tail block of 1
    x, wt = _data(rng, n, h, h, c, k, r)
    res = jnp.asarray(rng.standard_normal((n, 9, 9, k)), jnp.float32)
    b = jnp.asarray(rng.standard_normal(k), jnp.float32)
    exp = ref.conv2d_fused(x, wt, stride=1, padding=pad, bias=b,
                           residual=res, relu=True)
    for kwargs in (dict(),                          # C unblocked, full row
                   dict(c_blk=8, rb_q=4),           # C_b passes + Q tail
                   dict(c_blk=8, rb_q=4, order="npkc")):
        out = conv2d_direct(x, wt, stride=1, padding=pad, bias=b,
                            residual=res, relu=True, rb_p=rb_p,
                            interpret=True, **kwargs)
        np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                                   rtol=1e-4, atol=1e-4)


def test_conv2d_whole_plane_legacy_path(rng):
    """The A/B knob: the legacy whole-plane kernel must agree bit-for-bit
    with the tiled default."""
    x, wt = _data(rng, 2, 9, 9, 8, 16, 3)
    tiled = conv2d_direct(x, wt, stride=1, padding=1, rb_p=4, interpret=True)
    whole = conv2d_direct(x, wt, stride=1, padding=1, rb_p=4,
                          whole_plane=True, interpret=True)
    np.testing.assert_array_equal(np.asarray(tiled), np.asarray(whole))


def test_resnet_stem_tiled_regression(rng):
    """ResNet conv1 (224x224 input, 7x7 stride-2 -> 112x112): the padded
    input plane exceeds a small VMEM budget on the whole-plane path — the
    shape only runs blocked.  Pin bit-exactness of the tiled kernel and
    H*W-independence of its working set."""
    sh = STEM_CONV
    blk = conv_blocking_analytic(
        h=sh["h"], w=sh["w"], c=sh["c"], k=sh["k"], r=sh["r"], s=sh["s"],
        stride=sh["stride"], padding=sh["padding"])

    def ws(shape, whole):
        q = out_dim(shape["w"], shape["s"], shape["stride"],
                    shape["padding"])
        # rb_q pinned: with a fixed (rb_p, rb_q, c_blk) tile the tiled
        # working set must not see the image size at all
        return conv_working_set(
            h=shape["h"], w=shape["w"], c=shape["c"], k_blk=blk.k_blk,
            r=shape["r"], s=shape["s"], q=q, rb_p=blk.rb_p,
            padding=shape["padding"], stride=shape["stride"],
            c_blk=None if whole else blk.c_blk,
            rb_q=None if whole else 16, whole_plane=whole)

    small_budget = 1 << 20            # the CI kernel-tiling smoke budget
    assert ws(STEM_CONV, whole=True) > small_budget        # legacy: too big
    assert ws(STEM_CONV, whole=False) <= small_budget      # tiled: fits
    # tiled working set is independent of the image size (same band)
    assert ws(STEM_CONV, whole=False) == ws(STEM_CONV_HALF, whole=False)
    assert ws(STEM_CONV_HALF, whole=True) < ws(STEM_CONV, whole=True)

    x, wt = _data(rng, sh["n"], sh["h"], sh["w"], sh["c"], sh["k"], sh["r"])
    out = conv2d_direct(x, wt, stride=sh["stride"], padding=sh["padding"],
                        rb_p=blk.rb_p, rb_q=16, c_blk=sh["c"],
                        interpret=True)
    exp = ref.conv2d(x, wt, stride=sh["stride"], padding=sh["padding"])
    assert out.shape == (1, 112, 112, sh["k"])
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=1e-3, atol=1e-3)


# -- tiled update pass (band streaming, C/Q blocking, ceil-div tails) --------

TILED_WU_CASES = [
    # n, h, w, c, k, r, stride, pad, b_p, rb_q, c_blk
    (2, 8, 8, 16, 16, 3, 1, 1, 4, None, 8),    # C_b accumulation
    (2, 9, 9, 8, 16, 3, 1, 1, 4, 4, 8),        # P and Q ceil-div tails
    (1, 16, 16, 16, 8, 3, 2, 1, 3, 5, 8),      # stride 2 + non-divisor tails
    (1, 12, 12, 8, 8, 5, 1, 2, 5, 6, None),    # 5x5 halo + tails
    (1, 24, 24, 8, 16, 7, 2, 3, 4, 6, 8),      # 7x7 stride-2 halo
    (1, 14, 14, 16, 32, 1, 1, 0, 7, 5, 8),     # 1x1, every axis free
]


@pytest.mark.parametrize("case", TILED_WU_CASES)
def test_conv2d_wu_tiled_blocking_sweep(rng, case):
    """The band-streamed update pass: every freed axis — c_blk, rb_q, and
    ceil-div P/Q tails (masked in-kernel) — stays correct vs the VJP
    oracle.  No divisibility of P is required any more."""
    n, h, w, c, k, r, stride, pad, bp, rq, cb = case
    x, _ = _data(rng, n, h, w, c, k, r)
    p = (h + 2 * pad - r) // stride + 1
    do = jnp.asarray(rng.standard_normal((n, p, p, k)), jnp.float32)
    out = conv2d_wu(x, do, stride=stride, padding=pad, filter_rs=(r, r),
                    b_p=bp, rb_q=rq, c_blk=cb, whole_plane=False,
                    interpret=True)
    exp = ref.conv2d_bwd_weights(x, do, stride=stride, padding=pad,
                                 filter_rs=(r, r))
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=1e-3, atol=1e-3)


def test_conv2d_wu_whole_plane_legacy_path(rng):
    """The A/B knob: on a divisor-friendly layer the legacy resident-plane
    update kernel must agree bit-for-bit with the tiled default."""
    x, _ = _data(rng, 2, 8, 8, 8, 16, 3)
    do = jnp.asarray(rng.standard_normal((2, 8, 8, 16)), jnp.float32)
    kw = dict(stride=1, padding=1, filter_rs=(3, 3), b_p=4, interpret=True)
    tiled = conv2d_wu(x, do, whole_plane=False, **kw)
    whole = conv2d_wu(x, do, whole_plane=True, **kw)
    np.testing.assert_array_equal(np.asarray(tiled), np.asarray(whole))


def test_wu_stem_tiled_regression(rng):
    """The training-pass acceptance bar: the update pass of the 224x224 7x7
    stride-2 stem — un-schedulable for the legacy resident-plane kernel
    under a 1 MiB budget, and P=112 has awkward divisors — runs band-
    streamed with a working set independent of H*W."""
    sh = STEM_CONV
    p = out_dim(sh["h"], sh["r"], sh["stride"], sh["padding"])
    blk = conv_blocking_analytic(
        h=sh["h"], w=sh["w"], c=sh["c"], k=sh["k"], r=sh["r"], s=sh["s"],
        stride=sh["stride"], padding=sh["padding"], kind="wu")

    def ws(shape, whole):
        q = out_dim(shape["w"], shape["s"], shape["stride"],
                    shape["padding"])
        return conv_working_set(
            h=shape["h"], w=shape["w"], c=shape["c"], k_blk=blk.k_blk,
            r=shape["r"], s=shape["s"], q=q, rb_p=blk.rb_p,
            padding=shape["padding"], stride=shape["stride"],
            c_blk=None if whole else blk.c_blk,
            rb_q=None if whole else 16, whole_plane=whole, kind="wu")

    small_budget = 1 << 20            # the CI training-pass smoke budget
    assert ws(STEM_CONV, whole=True) > small_budget        # legacy: too big
    assert ws(STEM_CONV, whole=False) <= small_budget      # tiled: fits
    # tiled working set is independent of the image size (same band)
    assert ws(STEM_CONV, whole=False) == ws(STEM_CONV_HALF, whole=False)
    assert ws(STEM_CONV_HALF, whole=True) < ws(STEM_CONV, whole=True)

    x, _ = _data(rng, sh["n"], sh["h"], sh["w"], sh["c"], sh["k"], sh["r"])
    do = jnp.asarray(rng.standard_normal((sh["n"], p, p, sh["k"])),
                     jnp.float32)
    out = conv2d_wu(x, do, stride=sh["stride"], padding=sh["padding"],
                    filter_rs=(sh["r"], sh["s"]), b_p=blk.rb_p, rb_q=16,
                    c_blk=sh["c"], whole_plane=False, interpret=True)
    exp = ref.conv2d_bwd_weights(x, do, stride=sh["stride"],
                                 padding=sh["padding"],
                                 filter_rs=(sh["r"], sh["s"]))
    assert out.shape == (7, 7, sh["c"], sh["k"])
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=1e-2, atol=1e-2)


def test_pad_input_no_overpad_stride2():
    """pad_input must stop at the last row/col the grid can touch: for
    stride > 1 the symmetric bottom pad used to inflate the plane past it."""
    h = w = p = 12
    r, stride, padding = 3, 2, 1
    p_out = (h + 2 * padding - r) // stride + 1           # 6
    for rb_p in (2, 3, 6):                                 # rb_p | p cases
        x = jnp.zeros((1, h, w, 8), jnp.float32)
        q = p_out
        xp = pad_input(x, padding=padding, stride=stride, rb_p=rb_p, r=r,
                       p=p_out, rb_q=q, s=r, q=q)
        rows_needed = (int(np.ceil(p_out / rb_p)) * rb_p - 1) * stride + r
        assert xp.shape[1] == max(rows_needed, h + padding)
        assert xp.shape[1] < h + 2 * padding              # strictly tighter
    # ceil-div tail still covered: rb_p = 4 -> 2 blocks of 4 rows over p=6
    xp = pad_input(jnp.zeros((1, h, w, 8), jnp.float32), padding=padding,
                   stride=stride, rb_p=4, r=r, p=p_out, rb_q=4, s=r, q=p_out)
    assert xp.shape[1] >= (2 * 4 - 1) * stride + r
