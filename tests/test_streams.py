"""Kernel-streams framework (§II-H): schedule construction, RLE segments,
prefetch-offset property, loop orders."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.streams import (FLAG_EPILOGUE, FLAG_INIT, FLAG_RELU,
                                build_conv_schedule, decode_segments,
                                prefetch_streams, rle_segments)


def test_schedule_covers_iteration_space():
    s = build_conv_schedule(n=2, k_b=3, p_b=4, c_b=2, order="nkpc")
    assert len(s) == 2 * 3 * 4 * 2
    cells = set(zip(s.n_ids, s.kb_ids, s.pb_ids, s.cb_ids))
    assert len(cells) == len(s)          # every cell exactly once


def test_init_epilogue_flags():
    s = build_conv_schedule(n=1, k_b=2, p_b=2, c_b=3, order="nkpc",
                            relu=True)
    flags = s.flags
    cb = s.cb_ids
    assert ((flags[cb == 0] & FLAG_INIT) != 0).all()
    assert ((flags[cb == 2] & FLAG_EPILOGUE) != 0).all()
    assert ((flags[cb == 2] & FLAG_RELU) != 0).all()
    assert ((flags[cb == 1] & (FLAG_INIT | FLAG_EPILOGUE)) == 0).all()


def test_c_innermost_required():
    with pytest.raises(AssertionError):
        build_conv_schedule(n=1, k_b=1, p_b=1, c_b=2, order="nckp")


@pytest.mark.parametrize("order", ["nkpc", "npkc", "knpc", "pknc"])
def test_orders_permute_but_cover(order):
    s = build_conv_schedule(n=2, k_b=2, p_b=2, c_b=2, order=order)
    assert len(set(zip(s.n_ids, s.kb_ids, s.pb_ids, s.cb_ids))) == 16


@settings(max_examples=25, deadline=None)
@given(st.lists(st.integers(0, 7), min_size=1, max_size=200))
def test_rle_roundtrip(flags):
    flags = np.asarray(flags, np.int32)
    segs = rle_segments(flags)
    out = decode_segments(segs, len(flags))
    np.testing.assert_array_equal(out, flags)
    # segments are maximal: adjacent segments have different values
    vals = [v for v, _, _ in segs]
    assert all(a != b for a, b in zip(vals, vals[1:]))


def test_prefetch_offsets_are_next_invocation():
    """Fig. 1 property: pi_off_i == i_off_{i+1} (etc.)."""
    s = build_conv_schedule(n=2, k_b=2, p_b=3, c_b=2, order="nkpc")
    pn, pk, pp, pc = prefetch_streams(s)
    np.testing.assert_array_equal(pn[:-1], s.n_ids[1:])
    np.testing.assert_array_equal(pk[:-1], s.kb_ids[1:])
    np.testing.assert_array_equal(pp[:-1], s.pb_ids[1:])
    np.testing.assert_array_equal(pc[:-1], s.cb_ids[1:])
    # last step prefetches itself (no-op)
    assert pn[-1] == s.n_ids[-1]


def test_segment_compression_on_conv_streaks():
    """A schedule whose steps share a kernel variant compresses into
    CONV-STREAK segments (paper Fig. 2): O(1) segments for O(N) steps."""
    s = build_conv_schedule(n=4, k_b=4, p_b=8, c_b=1, order="nkpc",
                            relu=True)
    assert len(s) == 128
    assert len(s.segments) == 1          # one uniform CONV-STREAK
    # multi-C_b schedules segment per (init / streak / epilogue) phase:
    s4 = build_conv_schedule(n=4, k_b=4, p_b=8, c_b=4, order="nkpc",
                             relu=True)
    assert len(s4.segments) <= 3 * 128   # bounded by 3 per output tile
