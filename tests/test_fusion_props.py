"""Property tests for the graph fusion passes (core.fusion / graph.etg).

Random network lists (conv towers, bn/relu epilogues, residual blocks,
chain-breaking pools) drive the invariants the depth-first chain pass
depends on: idempotence, the single-consumer rule, topological validity of
the fused task list, the closed-form halo algebra, and the prebuilt
users-index matching the naive per-node rescan it replaced.
"""
import dataclasses

from hypothesis import given, settings, strategies as st

from repro.core.fusion import (Node, chain_band_rows, consumers,
                               detect_chains, fuse_network, users_index)
from repro.graph.etg import build_etg, extend_nl, toposort

# segment draws: (kind, r, stride, depth)
_SEG = st.tuples(st.sampled_from(["conv", "res", "pool"]),
                 st.sampled_from([1, 3, 5]), st.integers(1, 2),
                 st.integers(1, 3))
_SEGS = st.lists(_SEG, min_size=1, max_size=8)


def build_nl(segs) -> list[Node]:
    """Random-but-valid network list: a conv tower with optional bn/relu
    epilogues, residual sub-blocks (multi-consumer edges), and pools."""
    nodes = [Node("input", "input", [], {})]
    cur, c, uid = "input", 8, 0

    def conv(inp, r, stride, k):
        nonlocal uid
        name = f"c{uid}"
        uid += 1
        nodes.append(Node(name, "conv", [inp],
                          dict(c=c, k=k, r=r, s=r, stride=stride,
                               padding=r // 2)))
        return name, k

    for kind, r, stride, depth in segs:
        uid += 1
        if kind == "pool":
            name = f"p{uid}"
            nodes.append(Node(name, "maxpool", [cur],
                              dict(window=2, stride=2, padding=0)))
            cur = name
        elif kind == "conv":
            cur, c = conv(cur, r, stride, 8 * depth)
            if depth >= 2:
                nodes.append(Node(f"b{uid}", "bn", [cur], dict(k=c)))
                cur = f"b{uid}"
            if depth == 3:
                nodes.append(Node(f"r{uid}", "relu", [cur], {}))
                cur = f"r{uid}"
        else:                                   # residual block, stride 1
            start = cur
            for _ in range(depth):
                cur, c = conv(cur, r, 1, c)
            nodes.append(Node(f"a{uid}", "add", [cur, start], {}))
            cur = f"a{uid}"
    return nodes


def _sig(nodes):
    return tuple((n.name, n.op, tuple(n.inputs),
                  tuple(sorted((k, str(v)) for k, v in n.attrs.items())),
                  tuple(k for k, _ in n.fused))
                 for n in nodes)


def _copy(nodes):
    return [dataclasses.replace(n, inputs=list(n.inputs),
                                attrs=dict(n.attrs), fused=list(n.fused))
            for n in nodes]


@settings(max_examples=30, deadline=None)
@given(_SEGS)
def test_fuse_network_idempotent(segs):
    enl = extend_nl(build_nl(segs))
    once = fuse_network(_copy(enl))
    twice = fuse_network(_copy(once))
    assert _sig(twice) == _sig(once)


@settings(max_examples=30, deadline=None)
@given(_SEGS)
def test_detect_chains_pure_and_idempotent(segs):
    tasks = toposort(fuse_network(extend_nl(build_nl(segs))))
    before = _sig(tasks)
    first = detect_chains(tasks)
    assert _sig(tasks) == before                # pure: no rewriting
    assert detect_chains(tasks) == first        # deterministic / idempotent


@settings(max_examples=30, deadline=None)
@given(_SEGS)
def test_chains_never_cross_multi_consumer_edges(segs):
    etg = build_etg(build_nl(segs))
    users = users_index(etg.tasks)
    by_name = {t.name: t for t in etg.tasks}
    for ch in etg.chains:
        assert len(ch) >= 2
        for prod, cons in zip(ch.names, ch.names[1:]):
            uses = users.get(prod, [])
            assert len(uses) == 1, (prod, [u.name for u in uses])
            assert uses[0].name == cons
            # the link is the *data* edge, never the residual slot
            assert by_name[cons].inputs[0] == prod
            assert by_name[cons].op == "conv" and by_name[prod].op == "conv"


@settings(max_examples=30, deadline=None)
@given(_SEGS)
def test_fused_graph_stays_topologically_valid(segs):
    etg = build_etg(build_nl(segs))
    alias = {}
    for t in etg.tasks:
        if "output_name" in t.attrs:
            alias[t.attrs["output_name"]] = t.name
    seen = set()
    for t in etg.tasks:
        for i in t.inputs:
            i = alias.get(i, i)
            assert i == "input" or i in seen, (t.name, i)
        seen.add(t.name)
    # chain stamping covers exactly the chained convs, in order
    for ci, ch in enumerate(etg.chains):
        for pos, name in enumerate(ch.names):
            t = next(x for x in etg.tasks if x.name == name)
            assert t.attrs["chain_id"] == ci and t.attrs["chain_pos"] == pos


@settings(max_examples=30, deadline=None)
@given(_SEGS, st.integers(1, 17))
def test_halo_growth_closed_form(segs, rows_out):
    etg = build_etg(build_nl(segs))
    for ch in etg.chains:
        assert ch.halo_growth == tuple((r - 1) * s for r, s, _ in ch.rs)
        rows = chain_band_rows(ch.rs, rows_out)
        assert len(rows) == len(ch) + 1 and rows[-1] == rows_out
        for l, (r, stride, _pad) in enumerate(ch.rs):
            assert rows[l] == (rows[l + 1] - 1) * stride + r
            # halo is a fixed cost: growing the output band by one row grows
            # layer l's input band by exactly the product of the downstream
            # strides — the (r-1)·stride halo terms never compound with rb
            prod = 1
            for _, s2, _ in ch.rs[l:]:
                prod *= s2
            assert chain_band_rows(ch.rs, rows_out + 1)[l] - rows[l] == prod


@settings(max_examples=30, deadline=None)
@given(_SEGS)
def test_users_index_matches_naive_rescan(segs):
    """The O(edges) prebuilt index (the PR-10 fix for fuse_network's O(n²)
    rescan) must agree with the per-name fallback scan on every tensor."""
    nodes = extend_nl(build_nl(segs))
    idx = users_index(nodes)
    for n in nodes:
        with_idx = consumers(nodes, n.name, index=idx)
        naive = consumers(nodes, n.name)
        assert [u.name for u in with_idx] == [u.name for u in naive]
