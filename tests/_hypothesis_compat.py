"""Offline fallback for ``hypothesis``.

The property tests import ``from hypothesis import given, settings,
strategies as st``.  When the real wheel is absent (air-gapped CI, minimal
containers), ``install()`` registers this module as ``hypothesis`` in
``sys.modules`` *before collection* (see conftest.py), providing the same
surface over deterministic fixed example draws:

  * each ``@given`` test runs ``max_examples`` times with values drawn from
    a ``random.Random`` seeded by the test's qualified name — stable across
    runs and machines, so failures reproduce;
  * the falsifying draw is printed before the exception propagates;
  * ``assume(False)`` skips just that draw, like the real library.

No shrinking, no database, no health checks — this is a shim, not a
replacement; with the real package installed it is never activated.
"""
from __future__ import annotations

import random
import zlib

DEFAULT_MAX_EXAMPLES = 10


class _Unsatisfied(Exception):
    """Raised by assume() to discard the current draw."""


def assume(condition) -> bool:
    if not condition:
        raise _Unsatisfied
    return True


class SearchStrategy:
    def __init__(self, draw):
        self._draw = draw

    def example(self, rnd: random.Random):
        return self._draw(rnd)

    def map(self, fn):
        return SearchStrategy(lambda r: fn(self._draw(r)))

    def filter(self, pred):
        def draw(r):
            for _ in range(100):
                v = self._draw(r)
                if pred(v):
                    return v
            raise _Unsatisfied
        return SearchStrategy(draw)


def integers(min_value: int, max_value: int) -> SearchStrategy:
    return SearchStrategy(lambda r: r.randint(min_value, max_value))


def sampled_from(elements) -> SearchStrategy:
    elements = list(elements)
    return SearchStrategy(lambda r: r.choice(elements))


def booleans() -> SearchStrategy:
    return SearchStrategy(lambda r: r.random() < 0.5)


def floats(min_value=0.0, max_value=1.0, **_ignored) -> SearchStrategy:
    return SearchStrategy(lambda r: r.uniform(min_value, max_value))


def just(value) -> SearchStrategy:
    return SearchStrategy(lambda r: value)


def one_of(*strategies) -> SearchStrategy:
    return SearchStrategy(lambda r: r.choice(strategies).example(r))


def lists(elements: SearchStrategy, *, min_size: int = 0,
          max_size: int = 10, **_ignored) -> SearchStrategy:
    def draw(r):
        n = r.randint(min_size, max_size)
        return [elements.example(r) for _ in range(n)]
    return SearchStrategy(draw)


def tuples(*strategies) -> SearchStrategy:
    return SearchStrategy(lambda r: tuple(s.example(r) for s in strategies))


def settings(max_examples: int = DEFAULT_MAX_EXAMPLES, deadline=None,
             **_ignored):
    """Decorator recording max_examples on the (given-wrapped) test."""
    def deco(fn):
        fn._hc_max_examples = max_examples
        return fn
    return deco


def given(*arg_strategies, **kw_strategies):
    """Run the test over deterministic fixed draws.

    The wrapper takes no parameters (pytest must not mistake the strategy
    names for fixtures), so @given cannot be combined with fixtures here —
    none of this repo's property tests do.
    """
    def deco(fn):
        def runner():
            n = getattr(runner, "_hc_max_examples", DEFAULT_MAX_EXAMPLES)
            rnd = random.Random(zlib.crc32(
                getattr(fn, "__qualname__", fn.__name__).encode()))
            for i in range(n):
                pos = [s.example(rnd) for s in arg_strategies]
                kw = {name: s.example(rnd)
                      for name, s in kw_strategies.items()}
                try:
                    fn(*pos, **kw)
                except _Unsatisfied:
                    continue
                except Exception:
                    print(f"\nFalsifying example ({fn.__name__}, "
                          f"draw {i + 1}/{n}): args={pos} kwargs={kw}")
                    raise
        runner.__name__ = fn.__name__
        runner.__doc__ = fn.__doc__
        runner.__module__ = fn.__module__
        runner.__qualname__ = getattr(fn, "__qualname__", fn.__name__)
        return runner
    return deco


def install() -> None:
    """Register this module as `hypothesis` (+`.strategies`) in sys.modules."""
    import sys
    import types

    if "hypothesis" in sys.modules:
        return
    mod = sys.modules[__name__]
    strategies = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "sampled_from", "booleans", "floats", "just",
                 "one_of", "lists", "tuples"):
        setattr(strategies, name, getattr(mod, name))
    shim = types.ModuleType("hypothesis")
    shim.given = given
    shim.settings = settings
    shim.assume = assume
    shim.strategies = strategies
    shim.__is_repro_shim__ = True
    sys.modules["hypothesis"] = shim
    sys.modules["hypothesis.strategies"] = strategies
