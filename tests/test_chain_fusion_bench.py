"""BENCH_chain_fusion invariants: every chain's fused HBM traffic must stay
at-or-below the unfused sum (the fallback rule makes this structural), fused
chains move zero intermediate bytes, and at least one bottleneck chain fuses
in both the live-budget and the 1 MiB pressure tables — the depth-first
dividend other sessions diff against."""
from benchmarks.chain_fusion_bench import (PRESSURE_BUDGET, build_report,
                                           network_chains)
from repro.graph.topology import inception_v3, resnet50


def test_chains_cover_both_topologies():
    resnet = network_chains(resnet50, (224, 224))
    incep = network_chains(inception_v3, (299, 299))
    assert sum(sp["count"] for sp in resnet) == 16   # one per bottleneck
    assert len(resnet) >= 4                          # distinct geometries
    assert incep                                     # tower chains exist
    for sp in resnet + incep:
        assert len(sp["layers"]) >= 2
        assert len(sp["shapes"]) == len(sp["layers"])
        assert len(sp["halo_growth"]) == len(sp["layers"])


def test_fused_dominates_unfused_everywhere():
    report = build_report()
    assert set(report["tables"]) == {"resnet50", "resnet50_1mib",
                                     "inception_v3", "inception_v3_1mib"}
    for tname, table in report["tables"].items():
        s = table["summary"]
        assert s["n_fused"] >= 1, tname
        assert s["min_traffic_margin"] >= 1.0, tname
        assert s["fused_intermediate_bytes"] == 0, tname
        assert s["hbm_saved_bytes"] >= 0, tname
        for rec in table["chains"]:
            cid = (tname, rec["chain"])
            assert rec["hbm_bytes"] <= rec["unfused_hbm_bytes"], cid
            assert rec["traffic_margin"] >= 1.0, cid
            if rec["fused"]:
                assert rec["intermediate_bytes"] == 0, cid
                assert rec["fits_vmem"], cid
                assert rec["vmem_working_set"] <= table["vmem_budget"], cid
            else:
                # fallback prices the unfused execution exactly
                assert rec["hbm_bytes"] == rec["unfused_hbm_bytes"], cid
                assert rec["traffic_margin"] == 1.0, cid
                assert rec["speedup"] == 1.0, cid


def test_pressure_tables_use_1mib_budget():
    report = build_report()
    assert report["pressure_budget"] == PRESSURE_BUDGET == 1 << 20
    for net in ("resnet50", "inception_v3"):
        assert report["tables"][f"{net}_1mib"]["vmem_budget"] == 1 << 20
        assert report["tables"][net]["vmem_budget"] == report["vmem_budget"]
        # pressure never fuses *more* coarsely than the roomy context: every
        # chain that fuses at 1 MiB fuses at >= 1 MiB budgets too
        if report["vmem_budget"] >= 1 << 20:
            roomy = {r["chain"]: r["fused"]
                     for r in report["tables"][net]["chains"]}
            for r in report["tables"][f"{net}_1mib"]["chains"]:
                if r["fused"]:
                    assert roomy[r["chain"]], r["chain"]
