"""Data-parallel CNN training over GxM (train/distributed.py, DESIGN.md
§11).  Multi-device behaviour runs in *subprocesses* with
XLA_FLAGS=--xla_force_host_platform_device_count=8 (the
tests/test_distributed.py pattern) so the main test process keeps seeing
exactly 1 device.

Pinned semantics:
  * fp32 reduction introduces ZERO numerical deviation: an n-shard step
    whose shards see identical local batches is bit-identical to the
    single-device step (psum of equal values / n is exact for power-of-two
    n), and distinct shards match the host-side average-of-shard-grads
    reference;
  * the int8 compressed psum path converges on the tiny-ResNet loss with
    the residual carrying quantization error across steps;
  * accum_steps=k equals accum_steps=1 when the microbatches are
    duplicates (the identity the semantics are defined by);
  * the sharded train state round-trips through checkpoint save/restore
    and elastic-reshards onto a narrower mesh with no residual mass lost.
"""
import os
import subprocess
import sys
import textwrap

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_PRELUDE = """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, %r)
    import jax, jax.numpy as jnp
    import numpy as np
    assert len(jax.devices()) == 8
    from repro.graph import GxM, resnet50
    from repro.launch.mesh import make_host_mesh
    from repro.train.distributed import (init_cnn_train_state_dp,
                                         make_cnn_train_step_dp,
                                         shard_cnn_batch)

    def tiny(hw=32):
        m = GxM(resnet50(num_classes=10, stages=(1, 1, 1, 1)),
                num_classes=10)
        return m, m.init(jax.random.PRNGKey(0))

    def images(rng, n, hw=32):
        return {"image": jnp.asarray(rng.standard_normal((n, hw, hw, 3)),
                                     jnp.float32),
                "label": jnp.asarray(rng.integers(0, 10, size=(n,)))}
""" % os.path.join(REPO, "src")


def run_sub(body: str) -> str:
    code = textwrap.dedent(_PRELUDE) + textwrap.dedent(body)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=420)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


def test_dp_step_bit_exact_vs_single_device():
    """2-shard fp32 DP step with identical local batches == single-device
    step, bitwise: replicated-params spec + exact psum/2 reduction means
    the sharded path adds no numerics of its own."""
    out = run_sub("""
        from repro.train.step import make_cnn_train_step
        m, params = tiny()
        rng = np.random.default_rng(0)
        mb = images(rng, 2)
        batch = jax.tree.map(lambda x: jnp.concatenate([x, x]), mb)
        mesh = make_host_mesh(data=2)
        state = init_cnn_train_state_dp(params, mesh)
        dp = make_cnn_train_step_dp(m, mesh, lr=0.1)
        ref = make_cnn_train_step(m, lr=0.1)
        ref_params = params
        for _ in range(2):
            state, metrics = dp(state, shard_cnn_batch(batch, mesh))
            ref_params, ref_loss = ref(ref_params, mb)
        assert float(metrics["loss"]) == float(ref_loss), \\
            (float(metrics["loss"]), float(ref_loss))
        for a, b in zip(jax.tree.leaves(state["params"]),
                        jax.tree.leaves(ref_params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert int(state["step"]) == 2
        print("BITEXACT-OK", float(metrics["loss"]))
    """)
    assert "BITEXACT-OK" in out


def test_dp_step_distinct_shards_match_host_reference():
    """Distinct per-shard data: the step must equal the defined semantics —
    per-shard grads/BN-stats averaged across shards, then one SGD update."""
    out = run_sub("""
        from repro.graph.executor import apply_bn_updates
        m, params = tiny()
        rng = np.random.default_rng(0)
        batch = images(rng, 4)
        mesh = make_host_mesh(data=2)
        state = init_cnn_train_state_dp(params, mesh)
        dp = make_cnn_train_step_dp(m, mesh, lr=0.1)
        got, metrics = dp(state, shard_cnn_batch(batch, mesh))

        lf = lambda p, b: m.loss(p, b, collect_stats=True)
        halves = [jax.tree.map(lambda x: x[:2], batch),
                  jax.tree.map(lambda x: x[2:], batch)]
        outs = [jax.value_and_grad(lf, has_aux=True)(params, h)
                for h in halves]
        gavg = jax.tree.map(lambda a, b: (a + b) / 2,
                            outs[0][1], outs[1][1])
        savg = jax.tree.map(lambda a, b: (a + b) / 2,
                            outs[0][0][1], outs[1][0][1])
        exp = jax.tree.map(lambda p, g: p - 0.1 * g, params, gavg)
        apply_bn_updates(exp, savg, 0.9)
        for a, b in zip(jax.tree.leaves(got["params"]),
                        jax.tree.leaves(exp)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-5, atol=1e-5)
        loss_exp = (float(outs[0][0][0]) + float(outs[1][0][0])) / 2
        assert abs(float(metrics["loss"]) - loss_exp) < 1e-5
        print("SEMANTICS-OK")
    """)
    assert "SEMANTICS-OK" in out


def test_dp_int8_compressed_psum_converges():
    """REPRO_GRAD_COMPRESS=int8: error-feedback compressed reduction must
    still converge on the tiny-ResNet batch, with a live (nonzero, sharded)
    residual carrying the quantization error between steps."""
    out = run_sub("""
        m, params = tiny()
        rng = np.random.default_rng(0)
        batch = images(rng, 4)
        mesh = make_host_mesh(data=2)
        state = init_cnn_train_state_dp(params, mesh, grad_compress="int8")
        r0 = jax.tree.leaves(state["residual"])[0]
        assert r0.shape[0] == 2                      # one accumulator/shard
        assert "data" in str(r0.sharding.spec)
        dp = make_cnn_train_step_dp(m, mesh, lr=0.02, grad_compress="int8")
        sb = shard_cnn_batch(batch, mesh)
        losses = []
        for _ in range(8):
            state, metrics = dp(state, sb)
            losses.append(float(metrics["loss"]))
        assert np.isfinite(losses).all(), losses
        assert losses[-1] < losses[0], losses
        rmax = max(float(jnp.abs(r).max())
                   for r in jax.tree.leaves(state["residual"]))
        assert rmax > 0, "residual never carried any quantization error"
        print("INT8-OK", losses[0], losses[-1], rmax)
    """)
    assert "INT8-OK" in out


def test_dp_accum_steps_identity():
    """accum_steps=2 == accum_steps=1 when each shard's local batch is two
    copies of the same microbatch (64x64 images keep the last-stage BN
    statistics well-conditioned, so the identity is tight in f32)."""
    out = run_sub("""
        m, params = tiny(hw=64)
        rng = np.random.default_rng(0)
        ab, cd = images(rng, 2, hw=64), images(rng, 2, hw=64)
        local0 = jax.tree.map(lambda a: jnp.concatenate([a, a]), ab)
        local1 = jax.tree.map(lambda a: jnp.concatenate([a, a]), cd)
        batch = jax.tree.map(lambda a, b: jnp.concatenate([a, b]),
                             local0, local1)
        mesh = make_host_mesh(data=2)
        state = init_cnn_train_state_dp(params, mesh)
        s1 = make_cnn_train_step_dp(m, mesh, lr=0.1, accum_steps=1)
        s2 = make_cnn_train_step_dp(m, mesh, lr=0.1, accum_steps=2)
        sb = shard_cnn_batch(batch, mesh)
        a1, m1 = s1(state, sb)
        a2, m2 = s2(state, sb)
        assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-5
        for a, b in zip(jax.tree.leaves(a1["params"]),
                        jax.tree.leaves(a2["params"])):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-4, atol=1e-4)
        print("ACCUM-OK", float(m1["loss"]), float(m2["loss"]))
    """)
    assert "ACCUM-OK" in out


def test_dp_checkpoint_roundtrip_sharded_state(tmp_path):
    """The sharded train state (int8: residual split over the data axis)
    round-trips through checkpoint save/restore-with-shardings: leaves are
    gathered on save and land back on their mesh axes on restore."""
    out = run_sub(f"""
        from repro.train import checkpoint as C
        from repro.train.distributed import cnn_state_shardings
        m, params = tiny()
        rng = np.random.default_rng(0)
        batch = images(rng, 4)
        mesh = make_host_mesh(data=2)
        state = init_cnn_train_state_dp(params, mesh, grad_compress="int8")
        dp = make_cnn_train_step_dp(m, mesh, lr=0.02, grad_compress="int8")
        sb = shard_cnn_batch(batch, mesh)
        state, _ = dp(state, sb)
        C.save({str(tmp_path)!r}, 1, state)
        template = jax.device_get(state)
        shardings = cnn_state_shardings(mesh, template)
        restored = C.restore({str(tmp_path)!r}, 1, template,
                             shardings=shardings)
        for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        r = jax.tree.leaves(restored["residual"])[0]
        assert "data" in str(r.sharding.spec), r.sharding
        s1, m1 = dp(state, sb)
        s2, m2 = dp(restored, sb)
        assert float(m1["loss"]) == float(m2["loss"])
        print("CKPT-OK", int(restored["step"]))
    """)
    assert "CKPT-OK 1" in out


def test_dp_elastic_rescale_to_smaller_mesh(tmp_path):
    """Capacity shrinks 4 -> 2: elastic_reshard_cnn restores the checkpoint
    onto the narrower mesh, sum-folding the per-shard residual so the total
    un-applied gradient mass is preserved, and training continues."""
    out = run_sub(f"""
        from repro.train import checkpoint as C
        from repro.train.fault_tolerance import elastic_reshard_cnn
        m, params = tiny()
        rng = np.random.default_rng(0)
        batch8 = images(rng, 8)
        mesh4 = make_host_mesh(data=4)
        state = init_cnn_train_state_dp(params, mesh4, grad_compress="int8")
        dp4 = make_cnn_train_step_dp(m, mesh4, lr=0.02, grad_compress="int8")
        state, _ = dp4(state, shard_cnn_batch(batch8, mesh4))
        C.save({str(tmp_path)!r}, 1, state)

        old_res_sum = jax.tree.map(lambda r: np.asarray(r).sum(axis=0),
                                   jax.device_get(state["residual"]))
        mesh2 = make_host_mesh(data=2)
        state2 = elastic_reshard_cnn({str(tmp_path)!r}, 1,
                                     jax.device_get(state), mesh2)
        for r in jax.tree.leaves(state2["residual"]):
            assert r.shape[0] == 2, r.shape
        new_res_sum = jax.tree.map(lambda r: np.asarray(r).sum(axis=0),
                                   jax.device_get(state2["residual"]))
        for a, b in zip(jax.tree.leaves(old_res_sum),
                        jax.tree.leaves(new_res_sum)):
            np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-7)
        for a, b in zip(jax.tree.leaves(state["params"]),
                        jax.tree.leaves(state2["params"])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

        dp2 = make_cnn_train_step_dp(m, mesh2, lr=0.02, grad_compress="int8")
        batch4 = jax.tree.map(lambda x: x[:4], batch8)
        state2, metrics = dp2(state2, shard_cnn_batch(batch4, mesh2))
        assert np.isfinite(float(metrics["loss"]))
        print("ELASTIC-CNN-OK", float(metrics["loss"]))
    """)
    assert "ELASTIC-CNN-OK" in out


def test_warmup_dp_tunes_once_and_broadcasts(tmp_path, monkeypatch):
    """Host-0 warmup tunes the per-shard-batch entries once and exports a
    payload; install_warmup_entries on a cold cache (another host) serves
    every key without re-tuning.  Single-device: the mesh is degenerate but
    the per-shard batch division and the export/merge path are real."""
    monkeypatch.setenv("REPRO_TUNE_CACHE", str(tmp_path / "host0.json"))
    import jax

    from repro.graph import GxM, resnet50
    from repro.launch.mesh import make_host_mesh
    from repro.train.distributed import (install_warmup_entries,
                                         warmup_cnn_train_dp)
    from repro.tune.cache import TuneCache

    m = GxM(resnet50(num_classes=10, stages=(1, 1, 1, 1)), num_classes=10)
    mesh = make_host_mesh()
    host0 = TuneCache(str(tmp_path / "host0.json"))
    report, payload = warmup_cnn_train_dp(m, mesh, global_batch=2,
                                          image_hw=(32, 32),
                                          backend="interpret", cache=host0)
    assert all(e["cached"] for e in report)
    assert set(payload) == {e["key"] for e in report}
    assert {e["kind"] for e in report} == {"fwd", "bwd", "wu"}

    host1 = TuneCache(str(tmp_path / "host1.json"))
    assert install_warmup_entries(payload, host1) == len(payload)
    for key, entry in payload.items():
        got = host1.lookup(key)
        assert got is not None and got["blocking"] == entry["blocking"], key
