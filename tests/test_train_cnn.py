"""CNN training path over GxM: the jitted SGD step routes every conv
through conv2d_train's custom VJP (tiled fwd, phase-duality dI,
band-streamed dW), and training warmup pre-tunes the fwd + bwd (dual) + wu
blocking-cache signatures so the first step never tunes inline."""
import jax
import jax.numpy as jnp
import numpy as np

from repro import backend as be
from repro import tune
from repro.core.duality import dual_conv_signatures
from repro.graph import GxM, resnet50
from repro.graph.serving import conv_shapes, distinct_conv_signatures
from repro.train.step import make_cnn_train_step, warmup_cnn_train
from repro.tune.cache import TuneCache, conv_key


def _tiny(num_classes=10):
    nl = resnet50(num_classes=num_classes, stages=(1, 1, 1, 1))
    m = GxM(nl, num_classes=num_classes)
    params = m.init(jax.random.PRNGKey(0))
    return m, params


def _batch(rng, n=2, hw=32, num_classes=10):
    return {
        "image": jnp.asarray(rng.standard_normal((n, hw, hw, 3)),
                             jnp.float32),
        "label": jnp.asarray(rng.integers(0, num_classes, size=(n,))),
    }


def test_cnn_train_step_runs_and_updates(rng):
    m, params = _tiny()
    w0 = np.asarray(params["conv1"]["w"]).copy()
    step = make_cnn_train_step(m, lr=0.01)
    batch = _batch(rng)
    losses = []
    for _ in range(3):
        params, loss = step(params, batch)
        losses.append(float(loss))
    assert np.isfinite(losses).all()
    # gradients flowed through every conv's custom VJP
    assert np.abs(np.asarray(params["conv1"]["w"]) - w0).max() > 0
    # BN running stats (fused into the conv params) update outside the
    # gradient path
    assert np.abs(np.asarray(params["conv1"]["mean"])).max() > 0


def test_cnn_train_step_matches_plain_sgd(rng):
    """The builder is a routing wrapper: one step must equal the raw
    gxm.sgd_train_step numerics."""
    m, params = _tiny()
    batch = _batch(rng)
    step = make_cnn_train_step(m, lr=0.1)
    got, loss_got = step(params, batch)
    exp, loss_exp = m.sgd_train_step(params, batch, 0.1)
    np.testing.assert_allclose(float(loss_got), float(loss_exp), rtol=1e-5)
    for name in got:
        for k in got[name]:
            np.testing.assert_allclose(np.asarray(got[name][k]),
                                       np.asarray(exp[name][k]),
                                       rtol=1e-4, atol=1e-5)


def test_warmup_cnn_train_covers_bwd_and_wu(tmp_path, monkeypatch):
    """Training warmup must populate, per conv signature, the fwd key, the
    wu key, and every dual-conv bwd key its backward-data plan launches —
    the keys the first training step will look up."""
    monkeypatch.setenv("REPRO_TUNE_CACHE", str(tmp_path / "train.json"))
    m, _ = _tiny()
    cache = TuneCache(str(tmp_path / "train.json"))
    report = warmup_cnn_train(m, image_hw=(32, 32), minibatch=2,
                              backend="interpret", cache=cache)
    kinds = {e["kind"] for e in report}
    assert kinds == {"fwd", "bwd", "wu"}
    assert all(e["cached"] for e in report)

    sigs = distinct_conv_signatures(conv_shapes(m.etg, (32, 32)))
    assert len(sigs) >= 5
    for sg in sigs:
        for kind in ("fwd", "wu"):
            key = conv_key(kind=kind, **sg, dtype_bytes=4,
                           backend="interpret", minibatch=2)
            assert cache.lookup(key) is not None, (kind, sg)
        for dual in dual_conv_signatures(
                r=sg["r"], s=sg["s"], c=sg["c"], k=sg["k"],
                stride=sg["stride"], padding=sg["padding"],
                input_hw=(sg["h"], sg["w"])):
            key = conv_key(kind="bwd", **dual, dtype_bytes=4,
                           backend="interpret", minibatch=2)
            assert cache.lookup(key) is not None, (sg, dual)


def test_train_step_consults_warmed_cache(tmp_path, monkeypatch, rng):
    """An autotune="cache" training step after warmup must produce the same
    result as the analytic path up to f32 accumulation order (tuned
    blockings are a pure perf knob — they reorder the C/pixel accumulation
    chains, nothing else)."""
    monkeypatch.setenv("REPRO_TUNE_CACHE", str(tmp_path / "c.json"))
    m, params = _tiny()
    batch = _batch(rng)
    with be.use_backend("interpret"):
        base, loss_base = m.sgd_train_step(params, batch, 0.1)
        warmup_cnn_train(m, image_hw=(32, 32), minibatch=2,
                         backend="interpret")
        step = make_cnn_train_step(m, lr=0.1, autotune="cache")
        got, loss_got = step(params, batch)
    np.testing.assert_allclose(float(loss_got), float(loss_base), rtol=1e-4)
    w_base = np.asarray(base["conv1"]["w"])
    np.testing.assert_allclose(np.asarray(got["conv1"]["w"]), w_base,
                               rtol=5e-2, atol=5e-3)
