"""LM-side kernels vs oracles: fused matmul, causal conv1d, flash
attention, streams-driven MoE grouped matmul."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ref
from repro.kernels.attention import flash_attention
from repro.kernels.conv1d_causal import conv1d_causal
from repro.kernels.matmul_fused import matmul_fused
from repro.kernels.moe_gmm import moe_gmm, route_dryrun


@pytest.mark.parametrize("act", ["none", "relu", "gelu", "silu"])
def test_matmul_fused(rng, act):
    a = jnp.asarray(rng.standard_normal((64, 96)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((96, 32)), jnp.float32)
    bias = jnp.asarray(rng.standard_normal(32), jnp.float32)
    res = jnp.asarray(rng.standard_normal((64, 32)), jnp.float32)
    out = matmul_fused(a, b, bias=bias, act=act, residual=res,
                       bm=32, bn=16, bk=32, interpret=True)
    exp = ref.matmul_fused(a, b, bias=bias, act=act, residual=res)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("kw,d,l", [(4, 32, 16), (2, 16, 8), (4, 64, 32)])
def test_conv1d_causal(rng, kw, d, l):
    x = jnp.asarray(rng.standard_normal((2, l, d)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((kw, d)), jnp.float32)
    b = jnp.asarray(rng.standard_normal(d), jnp.float32)
    out = conv1d_causal(x, w, bias=b, d_blk=16, interpret=True)
    exp = ref.conv1d_causal(x, w, bias=b)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("hq,hkv", [(4, 4), (4, 2), (8, 2)])
def test_flash_attention(rng, causal, hq, hkv):
    q = jnp.asarray(rng.standard_normal((2, hq, 32, 16)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((2, hkv, 32, 16)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((2, hkv, 32, 16)), jnp.float32)
    out = flash_attention(q, k, v, causal=causal, bq=8, bk=8, interpret=True)
    exp = ref.attention(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=1e-4, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(l=st.sampled_from([16, 32, 64]), chunk=st.sampled_from([8, 16, 32]),
       seed=st.integers(0, 2**31 - 1))
def test_attention_chunked_property(l, chunk, seed):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.standard_normal((1, 2, l, 8)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 2, l, 8)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 2, l, 8)), jnp.float32)
    a = ref.attention(q, k, v, causal=True)
    b = ref.attention_chunked(q, k, v, causal=True, chunk=chunk)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-4, atol=1e-4)


def test_moe_gmm_routing_roundtrip(rng):
    t_tokens, d, f, e, cap, bm = 64, 32, 48, 4, 32, 16
    tok = rng.standard_normal((t_tokens, d)).astype(np.float32)
    wts = (rng.standard_normal((e, d, f)) * 0.1).astype(np.float32)
    eid = rng.integers(0, e, size=t_tokens).astype(np.int32)
    gi, tile_eid, keep = route_dryrun(jnp.asarray(eid), e, cap, bm)
    grouped = jnp.asarray(tok)[gi] * keep[:, None]
    out = moe_gmm(grouped, jnp.asarray(wts), tile_eid, bm=bm, bn=16, bk=16,
                  interpret=True)
    exp_full = np.einsum("td,tdf->tf", tok, wts[eid])
    out_np = np.asarray(out)
    gi_np, keep_np = np.asarray(gi), np.asarray(keep)
    recovered = np.zeros((t_tokens, f), np.float32)
    for i in range(len(gi_np)):
        if keep_np[i]:
            recovered[gi_np[i]] = out_np[i]
    np.testing.assert_allclose(recovered, exp_full, rtol=1e-4, atol=1e-4)


def test_route_dryrun_capacity_property(rng):
    """No expert receives more than `capacity` tokens; kept tokens preserve
    order within their expert group (the §II-H stream ordering)."""
    e, cap, bm = 4, 16, 8
    eid = jnp.asarray(rng.integers(0, e, size=128), jnp.int32)
    gi, tile_eid, keep = route_dryrun(eid, e, cap, bm)
    gi, keep = np.asarray(gi), np.asarray(keep)
    assert gi.shape == (e * cap,)
    assert np.asarray(tile_eid).shape == (e * cap // bm,)
    for g in range(e):
        rows = gi[g * cap:(g + 1) * cap][keep[g * cap:(g + 1) * cap]]
        assert len(rows) <= cap
        assert all(np.asarray(eid)[r] == g for r in rows)
        assert list(rows) == sorted(rows)   # stream order preserved
