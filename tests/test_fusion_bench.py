"""fusion_bench invariants (§II-G operator fusion on the ETG): fusing the
elementwise tail into the conv must only *remove* HBM round trips — fused
traffic strictly below unfused, savings exactly accounted, and the graph
stats consistent with the node merges that produced them."""
from benchmarks.fusion_bench import build_report
from repro.core.fusion import FUSABLE


def test_fusion_saves_traffic_and_accounts_for_it():
    report = build_report()
    tr = report["traffic"]
    assert tr["fused_hbm_bytes"] < tr["unfused_hbm_bytes"]
    assert tr["saved_hbm_bytes"] == \
        tr["unfused_hbm_bytes"] - tr["fused_hbm_bytes"]
    # every saved byte is attributed to a specific conv's fused tail
    assert tr["saved_hbm_bytes"] == \
        sum(c["saved_bytes"] for c in report["convs"])


def test_graph_stats_consistent_with_merges():
    report = build_report()
    stats = report["stats"]
    assert stats["ops_fused"] > 0
    # each fused elementwise op is one node folded away
    assert stats["nodes_before"] - stats["nodes_after"] == stats["ops_fused"]
    assert report["distinct_jit_kernels"] <= len(report["convs"])


def test_per_conv_records_are_well_formed():
    report = build_report()
    assert report["topology"] == "resnet50"
    assert len(report["convs"]) >= 50              # ResNet-50's conv count
    fused_total = 0
    for c in report["convs"]:
        assert set(c["fused_ops"]) <= set(FUSABLE), c["layer"]
        # each fused op saves one round trip of the conv's output tensor
        assert c["saved_bytes"] == 2.0 * c["out_bytes"] * len(c["fused_ops"])
        fused_total += len(c["fused_ops"])
    assert fused_total == report["stats"]["ops_fused"]
