"""GxM graph layer: fusion pass, ETG construction, executor equivalence
(fused vs unfused must be numerically identical in inference mode)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.graph import GxM, inception_v3, resnet50
from repro.graph.etg import build_etg
from repro.graph.topology import RESNET50_LAYERS


def test_resnet50_table_matches_paper():
    # Spot-check paper Table I entries
    assert RESNET50_LAYERS[1] == dict(c=3, k=64, h=224, w=224, r=7, s=7,
                                      stride=2)
    assert RESNET50_LAYERS[13] == dict(c=256, k=256, h=14, w=14, r=3, s=3,
                                       stride=1)
    assert len(RESNET50_LAYERS) == 20


def test_fusion_reduces_nodes():
    nl = resnet50()
    etg = build_etg(nl)
    assert etg.stats["ops_fused"] > 100          # BN+ReLU+add folded away
    # kernel dedup: far fewer distinct conv kernels than conv nodes
    convs = [t for t in etg.tasks if t.op == "conv"]
    assert len(etg.kernel_cache) < len(convs)


def test_fused_equals_unfused_inference(rng):
    nl = resnet50(num_classes=10, stages=(1, 1, 1, 1))
    m_fused = GxM(nl, impl="xla", num_classes=10)
    m_plain = GxM(resnet50(num_classes=10, stages=(1, 1, 1, 1)),
                  impl="xla", fuse=False, num_classes=10)
    params = m_fused.init(jax.random.PRNGKey(0))
    # plain executor keys params by unfused node names; rebuild its params
    # from the same rng to compare *shapes of computation*, then compare the
    # fused executor's two modes instead (train-mode BN differs by design).
    x = jnp.asarray(rng.standard_normal((2, 32, 32, 3)), jnp.float32)
    y1 = m_fused.forward(params, x, train=False)
    y2 = m_fused.forward(params, x, train=False)
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))
    assert y1.shape == (2, 10)


def test_train_step_decreases_loss(rng):
    nl = resnet50(num_classes=4, stages=(1, 1, 1, 1))
    m = GxM(nl, impl="xla", num_classes=4)
    params = m.init(jax.random.PRNGKey(0))
    x = jnp.asarray(rng.standard_normal((4, 32, 32, 3)), jnp.float32)
    batch = {"image": x, "label": jnp.asarray([0, 1, 2, 3])}
    step = jax.jit(m.sgd_train_step)
    losses = []
    for _ in range(8):
        params, loss = step(params, batch, lr=0.05)
        losses.append(float(loss))
    assert losses[-1] < losses[0]


def test_inception_branches_and_split_nodes():
    nl = inception_v3(num_classes=10)
    etg = build_etg(nl)
    assert any(t.op == "split" for t in etg.tasks)   # NL Extender ran
    assert any(t.op == "concat" for t in etg.tasks)
    m = GxM(nl, impl="xla", num_classes=10)
    params = m.init(jax.random.PRNGKey(1))
    out = m.forward(params, jnp.ones((1, 48, 48, 3)), train=False)
    assert out.shape == (1, 10)
    assert bool(jnp.isfinite(out).all())


def test_extend_nl_pure_and_indexed():
    """The NL Extender must rewire *copies*: the caller's nodes keep their
    original inputs (they may be re-used to build another ETG), every
    multi-consumer tensor gets exactly one split node with the right
    fanout, and single-consumer/input tensors are left alone."""
    from repro.core.fusion import Node
    from repro.graph.etg import extend_nl
    nodes = [
        Node("input", "input", [], {}),
        Node("a", "conv", ["input"], {}),
        Node("u1", "relu", ["a"], {}),
        Node("u2", "relu", ["a"], {}),
        Node("u3", "add", ["u1", "u2"], {}),
    ]
    before = {n.name: list(n.inputs) for n in nodes}
    out = extend_nl(nodes)
    # caller's nodes untouched (copies were rewired, not the originals)
    for n in nodes:
        assert n.inputs == before[n.name], (n.name, n.inputs)
    by_name = {n.name: n for n in out}
    assert by_name["a_split"].attrs["fanout"] == 2
    assert by_name["u1"].inputs == ["a_split"]
    assert by_name["u2"].inputs == ["a_split"]
    assert by_name["u3"].inputs == ["u1", "u2"]      # single consumers
    assert sum(1 for n in out if n.op == "split") == 1
    # a consumer listing the same tensor twice still counts as one user
    twice = [Node("input", "input", [], {}),
             Node("a", "conv", ["input"], {}),
             Node("u", "add", ["a", "a"], {})]
    assert all(n.op != "split" for n in extend_nl(twice))


def test_toposort_detects_cycles():
    import pytest
    from repro.core.fusion import Node
    from repro.graph.etg import toposort
    nodes = [Node("a", "relu", ["b"], {}), Node("b", "relu", ["a"], {})]
    with pytest.raises(ValueError):
        toposort(nodes)


def test_folded_bn_inference_consistent_with_training(rng):
    """After training, the fused inference path (BN folded from running
    stats into the conv epilogue — §II-G) must agree with the train-mode
    predictions on the training distribution."""
    nl = resnet50(num_classes=4, stages=(1, 1, 1, 1))
    m = GxM(nl, impl="xla", num_classes=4)
    params = m.init(jax.random.PRNGKey(0))
    x = jnp.asarray(rng.standard_normal((8, 32, 32, 3)), jnp.float32)
    y = jnp.asarray([0, 1, 2, 3, 0, 1, 2, 3])
    step = jax.jit(m.sgd_train_step)
    for _ in range(25):
        params, loss = step(params, {"image": x, "label": y}, lr=0.03)
    logits = m.forward(params, x, train=False)
    acc = float((jnp.argmax(logits, -1) == y).mean())
    assert acc >= 0.75, acc
