"""The paper's §II-H kernel streams, end to end on one convolution:

  dryrun  -> record the offset/variant streams + RLE segments
  replay  -> one scalar-prefetch-driven Pallas kernel executes the schedule
             (interpret mode on CPU; Mosaic on a real TPU)

  PYTHONPATH=src python examples/kernel_streams_demo.py
"""
import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.blocking import conv_blocking
from repro.core.streams import build_conv_schedule, prefetch_streams
from repro.kernels import ref
from repro.kernels.conv2d_streams import conv2d_streams

N, H, C, K, R, STRIDE, PAD = 2, 16, 16, 32, 3, 1, 1


def main():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((N, H, H, C)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((R, R, C, K)) * 0.1, jnp.float32)
    bias = jnp.asarray(rng.standard_normal(K), jnp.float32)

    blk = conv_blocking(h=H, w=H, c=C, k=K, r=R, s=R, stride=STRIDE,
                        padding=PAD)
    p = (H + 2 * PAD - R) // STRIDE + 1
    print(f"blocking: rb_p={blk.rb_p} k_blk={blk.k_blk} c_blk={blk.c_blk} "
          f"order={blk.order} (vmem={blk.vmem_bytes/1024:.0f}KiB)")

    # --- dryrun ------------------------------------------------------------
    k_blk, c_blk = min(K, 8), min(C, 8)   # small blocks for the demo
    sched = build_conv_schedule(
        n=N, k_b=K // k_blk, p_b=math.ceil(p / blk.rb_p), c_b=C // c_blk,
        order=blk.order, relu=True)
    print(f"dryrun: {len(sched)} microkernel invocations, "
          f"{len(sched.segments)} RLE segments")
    pn, pk, pp, pc = prefetch_streams(sched)
    print(f"prefetch property holds: "
          f"{bool((pn[:-1] == sched.n_ids[1:]).all())}")

    # --- replay ------------------------------------------------------------
    out = conv2d_streams(x, w, schedule=sched, stride=STRIDE, padding=PAD,
                         bias=bias, rb_p=blk.rb_p, k_blk=k_blk, c_blk=c_blk,
                         interpret=True).astype(x.dtype)
    expect = ref.conv2d_fused(x, w, stride=STRIDE, padding=PAD, bias=bias,
                              relu=True)
    err = float(jnp.abs(out - expect).max())
    print(f"replay matches fused reference: max err = {err:.2e}")


if __name__ == "__main__":
    main()
