"""CNN serving example: continuous-batching image recognition over the GxM
executor (see launch/serve_cnn.py for the scheduler and DESIGN.md §8 for the
request lifecycle).  Warmup pre-tunes the per-shape blocking cache and
AOT-compiles every bucket before the first request is served.

  PYTHONPATH=src python examples/serve_cnn.py --smoke
  XLA_FLAGS=--xla_force_host_platform_device_count=2 \
      PYTHONPATH=src python examples/serve_cnn.py --smoke   # 2-way sharding
"""
import sys

from repro.launch.serve_cnn import main

if __name__ == "__main__":
    argv = sys.argv[1:] or ["--arch", "resnet50", "--smoke",
                            "--requests", "24", "--max-batch", "8"]
    main(argv)
