"""Quickstart: train a small LM end-to-end on CPU and generate from it.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, smoke_config
from repro.data import SyntheticLMData
from repro.launch.mesh import make_host_mesh
from repro.launch.serve import generate
from repro.launch.train import build


def main():
    cfg = smoke_config(get_config("qwen2-1.5b"))
    print(f"model: {cfg.name}  params={cfg.param_count()/1e6:.2f}M")

    mesh = make_host_mesh()
    state, step = build(cfg, mesh, lr=3e-3)
    data = SyntheticLMData(vocab=cfg.vocab, seq_len=32, global_batch=8)

    for i in range(40):
        state, metrics = step(state, data.batch_at(i % 8))
        if i % 10 == 0:
            print(f"step {i:3d}  loss={float(metrics['loss']):.3f}  "
                  f"|g|={float(metrics['grad_norm']):.3f}")

    params = state["params"]
    prompts = [np.asarray(data.batch_at(0)["tokens"][0, :8])]
    out = generate(params, cfg, prompts, max_new=12, max_len=64)
    print("prompt :", list(prompts[0]))
    print("genout :", out[0])


if __name__ == "__main__":
    main()
