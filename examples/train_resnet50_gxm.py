"""The paper's own workload: ResNet-50 training through the GxM execution
task graph — conv kernels with the §II-I/J backward pipeline (tiled update
pass, phase-decomposed strided duality — DESIGN.md §10), §II-G fusion at
inference.  Training warmup pre-tunes the fwd + bwd (dual) + wu blocking
cache so the first step never tunes inline.

  PYTHONPATH=src python examples/train_resnet50_gxm.py [--full] [--warmup]
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.graph import GxM, resnet50
from repro.graph.etg import build_etg
from repro.train.step import make_cnn_train_step, warmup_cnn_train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="full 50-layer topology (slow on CPU)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--warmup", action="store_true",
                    help="pre-tune fwd/bwd/wu blockings before stepping")
    args = ap.parse_args()

    stages = (3, 4, 6, 3) if args.full else (1, 1, 1, 1)
    nl = resnet50(num_classes=10, stages=stages)
    etg = build_etg(nl)
    print(f"ETG: {etg.stats['nodes_before']} ops -> "
          f"{etg.stats['nodes_after']} tasks after fusion; "
          f"{len(etg.kernel_cache)} distinct JIT conv kernels")

    m = GxM(nl, impl="xla", num_classes=10)
    params = m.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((8, 64, 64, 3)), jnp.float32)
    y = jnp.asarray(rng.integers(0, 10, 8))
    if args.warmup:
        report = warmup_cnn_train(m, image_hw=(64, 64), minibatch=8)
        print(f"warmup: {sum(e['cached'] for e in report)} blocking-cache "
              f"entries across kinds "
              f"{sorted({e['kind'] for e in report})}")
    step = make_cnn_train_step(m, lr=0.05,
                               autotune="cache" if args.warmup else None)
    for i in range(args.steps):
        params, loss = step(params, {"image": x, "label": y})
        if i % 5 == 0:
            print(f"step {i:3d}  loss={float(loss):.4f}")

    # inference with everything fused into conv epilogues (§II-G)
    logits = m.forward(params, x, train=False)
    acc = float((jnp.argmax(logits, -1) == y).mean())
    print(f"train-set accuracy after {args.steps} steps: {acc:.2f}")


if __name__ == "__main__":
    main()
