"""The paper's own workload: ResNet-50 training through the GxM execution
task graph — conv kernels with the §II-I/J backward pipeline (tiled update
pass, phase-decomposed strided duality — DESIGN.md §10), §II-G fusion at
inference.  Training warmup pre-tunes the fwd + bwd (dual) + wu blocking
cache so the first step never tunes inline.

``--devices N`` materializes N fake host devices (the flag must be set
before jax imports, so argument parsing happens first) and runs the
*data-parallel* step — ``train.distributed.make_cnn_train_step_dp`` under
``shard_map`` over the mesh's data axis, gradient psum between the update
pass and the optimizer, optional ``--compress int8`` error-feedback
reduction (DESIGN.md §11).

  PYTHONPATH=src python examples/train_resnet50_gxm.py [--full] [--warmup]
  PYTHONPATH=src python examples/train_resnet50_gxm.py --devices 2
  PYTHONPATH=src python examples/train_resnet50_gxm.py --devices 2 \\
      --compress int8 --warmup
"""
import argparse
import os


def parse_args():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="full 50-layer topology (slow on CPU)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--warmup", action="store_true",
                    help="pre-tune fwd/bwd/wu blockings before stepping")
    ap.add_argument("--devices", type=int, default=1,
                    help="data-parallel width (fake host devices)")
    ap.add_argument("--compress", choices=("off", "int8"), default="off",
                    help="gradient-reduction wire format (DP only)")
    ap.add_argument("--batch", type=int, default=8,
                    help="global batch (split across --devices)")
    return ap.parse_args()


def main():
    args = parse_args()
    if args.devices > 1:
        flags = os.environ.get("XLA_FLAGS", "")
        if "xla_force_host_platform_device_count" not in flags:
            os.environ["XLA_FLAGS"] = (
                flags + f" --xla_force_host_platform_device_count="
                f"{args.devices}").strip()

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.graph import GxM, resnet50
    from repro.graph.etg import build_etg
    from repro.train.step import make_cnn_train_step, warmup_cnn_train

    stages = (3, 4, 6, 3) if args.full else (1, 1, 1, 1)
    nl = resnet50(num_classes=10, stages=stages)
    etg = build_etg(nl)
    print(f"ETG: {etg.stats['nodes_before']} ops -> "
          f"{etg.stats['nodes_after']} tasks after fusion; "
          f"{len(etg.kernel_cache)} distinct JIT conv kernels")

    m = GxM(nl, impl="xla", num_classes=10)
    params = m.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    assert args.batch % args.devices == 0, (args.batch, args.devices)
    x = jnp.asarray(rng.standard_normal((args.batch, 64, 64, 3)),
                    jnp.float32)
    y = jnp.asarray(rng.integers(0, 10, args.batch))
    batch = {"image": x, "label": y}

    if args.devices > 1:
        from repro.launch.mesh import make_host_mesh
        from repro.train.distributed import (init_cnn_train_state_dp,
                                             make_cnn_train_step_dp,
                                             shard_cnn_batch,
                                             warmup_cnn_train_dp)
        mesh = make_host_mesh(data=args.devices)
        print(f"data-parallel over mesh {dict(mesh.shape)}; "
              f"gradient reduction: {args.compress}")
        if args.warmup:
            report, payload = warmup_cnn_train_dp(
                m, mesh, global_batch=args.batch, image_hw=(64, 64))
            print(f"warmup: {sum(e['cached'] for e in report)} "
                  f"blocking-cache entries (per-shard batch), "
                  f"{len(payload)} broadcastable")
        state = init_cnn_train_state_dp(params, mesh,
                                        grad_compress=args.compress)
        step = make_cnn_train_step_dp(
            m, mesh, lr=0.05, grad_compress=args.compress,
            autotune="cache" if args.warmup else None)
        batch = shard_cnn_batch(batch, mesh)
        for i in range(args.steps):
            state, metrics = step(state, batch)
            if i % 5 == 0:
                print(f"step {i:3d}  loss={float(metrics['loss']):.4f}")
        params = jax.device_get(state["params"])
    else:
        if args.warmup:
            report = warmup_cnn_train(m, image_hw=(64, 64),
                                      minibatch=args.batch)
            print(f"warmup: {sum(e['cached'] for e in report)} "
                  f"blocking-cache entries across kinds "
                  f"{sorted({e['kind'] for e in report})}")
        step = make_cnn_train_step(m, lr=0.05,
                                   autotune="cache" if args.warmup else None)
        for i in range(args.steps):
            params, loss = step(params, batch)
            if i % 5 == 0:
                print(f"step {i:3d}  loss={float(loss):.4f}")

    # inference with everything fused into conv epilogues (§II-G)
    logits = m.forward(params, x, train=False)
    acc = float((jnp.argmax(logits, -1) == y).mean())
    print(f"train-set accuracy after {args.steps} steps: {acc:.2f}")


if __name__ == "__main__":
    main()
