"""Batched serving example: prefill + lockstep decode over a request batch
(see launch/serve.py for the scheduler).

  PYTHONPATH=src python examples/serve_batched.py
"""
from repro.launch.serve import main

if __name__ == "__main__":
    main(["--arch", "qwen2-1.5b", "--smoke", "--requests", "4",
          "--max-new", "12"])
