"""Paper Fig. 8 (§II-K reduced precision), TPU serving edition: int8
weights with f32 accumulation.  Measures quantization error on a real
smoke model and reports the modeled decode speedup per arch (bytes-bound
roofline: < 2x because KV/activations stay bf16 — the same reason the
paper's int16 kernels got 1.6x, not 2x)."""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_call
from repro.configs import SHAPES, get_config, smoke_config
from repro.core.quantize import dequantize, quantize_int8
from repro.launch import analytic as A
from repro.nn import transformer as T


def main():
    # numerical error on a real (smoke) model + decode logits drift
    cfg = smoke_config(get_config("qwen2-1.5b"))
    params, _ = T.init_lm(jax.random.PRNGKey(0), cfg)
    qp = quantize_int8(params, min_size=64)
    deq = dequantize(qp, jnp.float32)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab)
    lf, _ = T.forward(params, cfg, tokens=toks)
    lq, _ = T.forward(deq, cfg, tokens=toks)
    drift = float(jnp.abs(jax.nn.softmax(lf) - jax.nn.softmax(lq)).max())
    f = jax.jit(lambda p, t: T.forward(p, cfg, tokens=t)[0])
    us = time_call(f, deq, toks)
    emit("int8_weights_fwd", us, f"softmax_drift={drift:.4f}")

    # modeled decode speedup per arch (memory-roofline ratio)
    shape = SHAPES["decode_32k"]
    for arch in ("qwen3-8b", "jamba-1.5-large-398b", "dbrx-132b"):
        c = get_config(arch)
        base = A.analytic_roofline(c, shape, chips=256, model_par=16,
                                   data_par=16)
        q = A.analytic_roofline(c, shape, chips=256, model_par=16,
                                data_par=16, quantized=True)
        emit(f"int8_decode_model_{arch}", q.step_time_s * 1e6,
             f"speedup={base.step_time_s/q.step_time_s:.2f}x;"
             f"dominant={q.dominant}")


if __name__ == "__main__":
    main()
