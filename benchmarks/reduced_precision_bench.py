"""Paper Fig. 8 (§II-K reduced precision), TPU serving edition: int8
weights with f32 accumulation.  Measures quantization error on a real
smoke model and reports the modeled decode speedup per arch (bytes-bound
roofline: < 2x because KV/activations stay bf16 — the same reason the
paper's int16 kernels got 1.6x, not 2x).

``build_report()`` is the machine-checkable half (pinned by
``tests/test_reduced_precision_bench.py``): the analytic per-arch decode
roofline, quantized vs not — modeled speedup must be > 1 (halving weight
bytes always helps a bytes-bound decode) and < 2 (only the weights
shrink).  ``main()`` additionally runs the numerical-drift measurement on
a real smoke model.
"""
from repro.configs import SHAPES, get_config
from repro.launch import analytic as A

ARCHS = ("qwen3-8b", "jamba-1.5-large-398b", "dbrx-132b")
SHAPE_NAME = "decode_32k"
CHIPS = 256
MODEL_PAR = 16
DATA_PAR = 16


def build_report() -> dict:
    shape = SHAPES[SHAPE_NAME]
    rows = []
    for arch in ARCHS:
        c = get_config(arch)
        base = A.analytic_roofline(c, shape, chips=CHIPS,
                                   model_par=MODEL_PAR, data_par=DATA_PAR)
        q = A.analytic_roofline(c, shape, chips=CHIPS, model_par=MODEL_PAR,
                                data_par=DATA_PAR, quantized=True)
        rows.append({
            "arch": arch,
            "base_step_us": round(base.step_time_s * 1e6, 3),
            "quantized_step_us": round(q.step_time_s * 1e6, 3),
            "modeled_speedup": round(base.step_time_s / q.step_time_s, 4),
            "base_dominant": base.dominant,
            "quantized_dominant": q.dominant,
        })
    return {"shape": SHAPE_NAME, "chips": CHIPS, "model_par": MODEL_PAR,
            "data_par": DATA_PAR, "rows": rows}


def main():
    import jax
    import jax.numpy as jnp

    from benchmarks.common import emit, time_call
    from repro.configs import smoke_config
    from repro.core.quantize import dequantize, quantize_int8
    from repro.nn import transformer as T

    # numerical error on a real (smoke) model + decode logits drift
    cfg = smoke_config(get_config("qwen2-1.5b"))
    params, _ = T.init_lm(jax.random.PRNGKey(0), cfg)
    qp = quantize_int8(params, min_size=64)
    deq = dequantize(qp, jnp.float32)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab)
    lf, _ = T.forward(params, cfg, tokens=toks)
    lq, _ = T.forward(deq, cfg, tokens=toks)
    drift = float(jnp.abs(jax.nn.softmax(lf) - jax.nn.softmax(lq)).max())
    f = jax.jit(lambda p, t: T.forward(p, cfg, tokens=t)[0])
    us = time_call(f, deq, toks)
    emit("int8_weights_fwd", us, f"softmax_drift={drift:.4f}")

    # modeled decode speedup per arch (memory-roofline ratio)
    for r in build_report()["rows"]:
        emit(f"int8_decode_model_{r['arch']}", r["quantized_step_us"],
             f"speedup={r['modeled_speedup']:.2f}x;"
             f"dominant={r['quantized_dominant']}")


if __name__ == "__main__":
    main()
