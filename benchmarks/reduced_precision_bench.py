"""Paper Fig. 8 (§II-K reduced precision), TPU serving edition: int8
weights with f32 accumulation.  Measures quantization error on a real
smoke model and reports the modeled decode speedup per arch (bytes-bound
roofline: < 2x because KV/activations stay bf16 — the same reason the
paper's int16 kernels got 1.6x, not 2x).

``build_report()`` is the machine-checkable half (pinned by
``tests/test_reduced_precision_bench.py``): the analytic per-arch decode
roofline, quantized vs not — modeled speedup must be > 1 (halving weight
bytes always helps a bytes-bound decode) and < 2 (only the weights
shrink).  ``main()`` additionally runs the numerical-drift measurement on
a real smoke model.

``build_q8_report()`` is the CNN half (the paper's actual Fig. 8 subject):
the schedule-resolved tiled int8 forward vs the tiled f32 forward over the
ResNet-50 / Inception-v3 conv tables, under each path's own analytic
blocking — int8 bands are 4x smaller, so the q8 blocking re-spends the
freed VMEM on taller row bands (``kind="q8"`` grow-to-budget) on top of
the 4x input/weight byte shrink.  Written to ``BENCH_q8_infer.json`` and
gated by ``repro.perfci`` (the ISSUE floor: >= 1.6x on every
bandwidth-bound ResNet-50 layer).  A layer counts as *bandwidth-bound*
only when HBM time is the largest term of its f32 modeled cost — above
compute time *and* above the aggregate grid-step overhead: int8 cannot
speed up launch overhead, so overhead-bound 7x7 tails (L19) report their
honest ratio but stay out of the floor's denominator.
"""
import json
import pathlib

from repro.configs import SHAPES, get_config
from repro.launch import analytic as A

ARCHS = ("qwen3-8b", "jamba-1.5-large-398b", "dbrx-132b")
SHAPE_NAME = "decode_32k"
CHIPS = 256
MODEL_PAR = 16
DATA_PAR = 16

Q8_OUT_PATH = pathlib.Path(__file__).resolve().parents[1] \
    / "BENCH_q8_infer.json"


def build_report() -> dict:
    shape = SHAPES[SHAPE_NAME]
    rows = []
    for arch in ARCHS:
        c = get_config(arch)
        base = A.analytic_roofline(c, shape, chips=CHIPS,
                                   model_par=MODEL_PAR, data_par=DATA_PAR)
        q = A.analytic_roofline(c, shape, chips=CHIPS, model_par=MODEL_PAR,
                                data_par=DATA_PAR, quantized=True)
        rows.append({
            "arch": arch,
            "base_step_us": round(base.step_time_s * 1e6, 3),
            "quantized_step_us": round(q.step_time_s * 1e6, 3),
            "modeled_speedup": round(base.step_time_s / q.step_time_s, 4),
            "base_dominant": base.dominant,
            "quantized_dominant": q.dominant,
        })
    return {"shape": SHAPE_NAME, "chips": CHIPS, "model_par": MODEL_PAR,
            "data_par": DATA_PAR, "rows": rows}


def _q8_variant(args: dict, minibatch: int, *, kind: str,
                dtype_bytes: int) -> tuple[dict, dict]:
    """(record, roofline) for one layer under one precision's own analytic
    blocking — the same model stack as ``conv_fwd_bench._variant``."""
    from repro.core.blocking import (VMEM_BUDGET, conv_blocking_analytic,
                                     conv_working_set)
    from repro.launch.roofline import kernel_roofline
    from repro.tune.measure import STEP_OVERHEAD_US, conv_traffic
    from repro.tune.space import out_dim
    blk = conv_blocking_analytic(**args, dtype_bytes=dtype_bytes, kind=kind)
    t = conv_traffic(dict(args, dtype_bytes=dtype_bytes), blk,
                     minibatch=minibatch, kind=kind)
    roof = kernel_roofline(flops=t["flops"], hbm_bytes=t["hbm_bytes"],
                           util=t["util"], n_steps=t["n_steps"],
                           step_overhead_s=STEP_OVERHEAD_US * 1e-6)
    q = out_dim(args["w"], args["s"], args["stride"], args["padding"])
    vmem = conv_working_set(
        h=args["h"], w=args["w"], c=args["c"], k_blk=blk.k_blk, r=args["r"],
        s=args["s"], q=q, rb_p=blk.rb_p, padding=args["padding"],
        stride=args["stride"], c_blk=blk.c_blk, rb_q=blk.rb_q,
        dtype_bytes=dtype_bytes, kind=kind)
    rec = {
        "cost_us": round(roof["cost_s"] * 1e6, 3),
        "hbm_bytes": int(t["hbm_bytes"]),
        "roofline_efficiency": round(roof["efficiency"], 4),
        "dominant": roof["dominant"],
        "vmem_working_set": int(vmem),
        "fits_vmem": bool(vmem <= VMEM_BUDGET),
        "grid_steps": int(t["n_steps"]),
        "rb_p": blk.rb_p,
    }
    return rec, roof


def _analytic_q8_speedup(args: dict, minibatch: int) -> float:
    """Blocking-free ideal-traffic speedup: minimal x/w/o bytes at each
    precision (f32 out in both), rooflined with no refetch, no overhead.
    The measured table must agree with this up to schedule effects — the
    drift band ``tests/test_reduced_precision_bench.py`` pins."""
    from repro.launch.roofline import kernel_roofline
    from repro.tune.space import out_dim
    p = out_dim(args["h"], args["r"], args["stride"], args["padding"])
    q = out_dim(args["w"], args["s"], args["stride"], args["padding"])
    x_e = minibatch * args["h"] * args["w"] * args["c"]
    w_e = args["r"] * args["s"] * args["c"] * args["k"]
    o_e = minibatch * p * q * args["k"]
    flops = 2.0 * o_e * args["c"] * args["r"] * args["s"]
    f32 = kernel_roofline(flops=flops, hbm_bytes=4 * (x_e + w_e + o_e),
                          n_steps=0, step_overhead_s=0.0)
    q8 = kernel_roofline(flops=flops, hbm_bytes=x_e + w_e + 4 * o_e,
                         n_steps=0, step_overhead_s=0.0)
    return f32["cost_s"] / q8["cost_s"]


def build_q8_report() -> dict:
    from benchmarks.conv_fwd_bench import MINIBATCH, layer_tables
    from repro.core.blocking import VMEM_BUDGET
    from repro.core.conv import lane_ok
    tables = {}
    summary = {}
    for tname, layers in layer_tables().items():
        recs, bw_speedups = [], []
        for sh in layers:
            args = {f: sh[f] for f in ("h", "w", "c", "k", "r", "s",
                                       "stride", "padding")}
            if not lane_ok(sh["c"], sh["k"]):
                # small-C stem: the q8 kernel never runs (im2col fallback)
                recs.append({"layer": sh["name"], "shape": args,
                             "path": "im2col"})
                continue
            f32, f32_roof = _q8_variant(args, MINIBATCH, kind="fwd",
                                        dtype_bytes=4)
            q8, q8_roof = _q8_variant(args, MINIBATCH, kind="q8",
                                      dtype_bytes=1)
            overhead_s = f32_roof["cost_s"] - f32_roof["step_time_s"]
            bandwidth_bound = (f32_roof["dominant"] == "memory"
                               and f32_roof["memory_s"] >= overhead_s)
            speedup = round(f32_roof["cost_s"] / q8_roof["cost_s"], 4)
            if bandwidth_bound:
                bw_speedups.append(speedup)
            recs.append({
                "layer": sh["name"], "shape": args, "path": "direct",
                "f32": f32, "q8": q8, "speedup": speedup,
                "analytic_speedup": round(
                    _analytic_q8_speedup(args, MINIBATCH), 4),
                "bandwidth_bound": bandwidth_bound,
            })
        tables[tname] = recs
        summary[tname] = {
            "min_bw_speedup": round(min(bw_speedups), 4) if bw_speedups
            else None,
            "bandwidth_bound_layers": len(bw_speedups),
        }
    return {
        "minibatch": MINIBATCH,
        "vmem_budget": VMEM_BUDGET,
        "model": "tpu-v5e roofline (repro.tune.measure.conv_traffic, "
                 "int8 x/w bytes, f32 out)",
        "tables": tables,
        "summary": summary,
    }


def main_q8(argv=None) -> None:
    """Emit the CNN int8-vs-f32 table + write BENCH_q8_infer.json."""
    from benchmarks.common import bench_out_path, emit
    report = build_q8_report()
    out_path = bench_out_path(Q8_OUT_PATH)
    out_path.write_text(json.dumps(report, indent=1, sort_keys=True) + "\n")
    for tname, recs in report["tables"].items():
        for rec in recs:
            if rec.get("path") != "direct":
                continue
            emit(f"q8_infer_{tname}_{rec['layer']}", rec["q8"]["cost_us"],
                 f"speedup={rec['speedup']:.2f}x;"
                 f"analytic={rec['analytic_speedup']:.2f}x;"
                 f"bw_bound={int(rec['bandwidth_bound'])};"
                 f"rbp={rec['f32']['rb_p']}->{rec['q8']['rb_p']}")
    for tname, s in report["summary"].items():
        emit(f"q8_infer_{tname}_summary", 0,
             f"min_bw_speedup={s['min_bw_speedup']};"
             f"bw_layers={s['bandwidth_bound_layers']}")
    emit("q8_infer_bench_json", 0, f"wrote={out_path}")


def main():
    import jax
    import jax.numpy as jnp

    from benchmarks.common import emit, time_call
    from repro.configs import smoke_config
    from repro.core.quantize import dequantize, quantize_int8
    from repro.nn import transformer as T

    # numerical error on a real (smoke) model + decode logits drift
    cfg = smoke_config(get_config("qwen2-1.5b"))
    params, _ = T.init_lm(jax.random.PRNGKey(0), cfg)
    qp = quantize_int8(params, min_size=64)
    deq = dequantize(qp, jnp.float32)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab)
    lf, _ = T.forward(params, cfg, tokens=toks)
    lq, _ = T.forward(deq, cfg, tokens=toks)
    drift = float(jnp.abs(jax.nn.softmax(lf) - jax.nn.softmax(lq)).max())
    f = jax.jit(lambda p, t: T.forward(p, cfg, tokens=t)[0])
    us = time_call(f, deq, toks)
    emit("int8_weights_fwd", us, f"softmax_drift={drift:.4f}")

    # modeled decode speedup per arch (memory-roofline ratio)
    for r in build_report()["rows"]:
        emit(f"int8_decode_model_{r['arch']}", r["quantized_step_us"],
             f"speedup={r['modeled_speedup']:.2f}x;"
             f"dominant={r['quantized_dominant']}")

    # the CNN tiled-int8 table (§II-K proper) + its perf-gate artifact
    main_q8()


if __name__ == "__main__":
    main()
