"""Forward-conv perf trajectory: tiled vs whole-plane, machine-readable.

Writes ``BENCH_conv_fwd.json`` at the repo root — per-layer images/sec and
roofline efficiency for the ResNet-50 (paper Table I) and Inception-v3
conv tables, under the *same* per-shape blocking, for both forward input
strategies:

  tiled   row-band streaming + C_b accumulation + RB_Q (the default kernel)
  whole   the legacy whole-plane kernel (input plane shipped per grid step)

Numbers come from the schedule-resolved roofline model
(``repro.tune.measure.conv_traffic`` + ``launch.roofline.kernel_roofline``)
so the file is reproducible on any host; ``--measure`` additionally
wall-clocks the XLA reference path per layer for a host-speed column.
Subsequent PRs diff this file to prove regressions/improvements.
"""
import json
import pathlib
import sys

from benchmarks.common import bench_out_path, emit
from repro.core.blocking import VMEM_BUDGET, conv_blocking_analytic, \
    conv_working_set
from repro.core.conv import lane_ok
from repro.graph.serving import conv_shapes, distinct_conv_signatures
from repro.graph.topology import RESNET50_LAYERS, inception_v3
from repro.launch.roofline import kernel_roofline
from repro.tune.measure import STEP_OVERHEAD_US, conv_traffic
from repro.tune.space import out_dim

MINIBATCH = 4
INCEPTION_IMAGE = (299, 299)
OUT_PATH = pathlib.Path(__file__).resolve().parents[1] / "BENCH_conv_fwd.json"


def layer_tables() -> dict[str, list[dict]]:
    """The two benchmark conv tables as tuning-shape dicts."""
    resnet = []
    for lid, l in sorted(RESNET50_LAYERS.items()):
        resnet.append(dict(name=f"L{lid:02d}", h=l["h"], w=l["w"], c=l["c"],
                           k=l["k"], r=l["r"], s=l["s"], stride=l["stride"],
                           padding=l["r"] // 2))
    from repro.graph.etg import build_etg
    etg = build_etg(inception_v3(num_classes=1000))
    sigs = distinct_conv_signatures(conv_shapes(etg, INCEPTION_IMAGE))
    inception = [dict(name=f"I{i:02d}", **sg) for i, sg in enumerate(sigs)]
    return {"resnet50": resnet, "inception_v3": inception}


def _variant(shape: dict, blk, *, whole: bool) -> dict:
    """Modeled cost/traffic/efficiency of one layer under one input
    strategy (same blocking — the runtime A/B the tiling knob performs)."""
    t = conv_traffic(shape, blk, minibatch=MINIBATCH, kind="fwd",
                     whole_plane=whole)
    roof = kernel_roofline(flops=t["flops"], hbm_bytes=t["hbm_bytes"],
                           util=t["util"], n_steps=t["n_steps"],
                           step_overhead_s=STEP_OVERHEAD_US * 1e-6)
    q = out_dim(shape["w"], shape["s"], shape["stride"], shape["padding"])
    vmem = conv_working_set(
        h=shape["h"], w=shape["w"], c=shape["c"], k_blk=blk.k_blk,
        r=shape["r"], s=shape["s"], q=q, rb_p=blk.rb_p,
        padding=shape["padding"], stride=shape["stride"],
        c_blk=None if whole else blk.c_blk, rb_q=None if whole else blk.rb_q,
        whole_plane=whole)
    return {
        "cost_us": round(roof["cost_s"] * 1e6, 3),
        "images_per_sec": round(MINIBATCH / roof["cost_s"], 1),
        "hbm_bytes": int(t["hbm_bytes"]),
        "hbm_input_bytes": int(t["x_bytes"]),
        "hbm_output_bytes": int(t["o_bytes"]),
        "roofline_efficiency": round(roof["efficiency"], 4),
        "dominant": roof["dominant"],
        "vmem_working_set": int(vmem),
        "fits_vmem": bool(vmem <= VMEM_BUDGET),
        "grid_steps": int(t["n_steps"]),
    }


def layer_record(shape: dict, *, measure: bool = False) -> dict:
    blk = conv_blocking_analytic(
        h=shape["h"], w=shape["w"], c=shape["c"], k=shape["k"], r=shape["r"],
        s=shape["s"], stride=shape["stride"], padding=shape["padding"])
    rec = {
        "layer": shape["name"],
        "shape": {f: shape[f] for f in ("h", "w", "c", "k", "r", "s",
                                        "stride", "padding")},
        "path": "direct" if lane_ok(shape["c"], shape["k"]) else "im2col",
        "blocking": {"rb_p": blk.rb_p, "rb_q": blk.rb_q, "k_blk": blk.k_blk,
                     "c_blk": blk.c_blk, "order": blk.order},
        "tiled": _variant(shape, blk, whole=False),
        "whole_plane": _variant(shape, blk, whole=True),
    }
    if measure:
        import jax
        import jax.numpy as jnp
        import numpy as np

        from benchmarks.common import time_call
        from repro.kernels import ref
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.standard_normal(
            (MINIBATCH, shape["h"], shape["w"], shape["c"])), jnp.float32)
        w = jnp.asarray(rng.standard_normal(
            (shape["r"], shape["s"], shape["c"], shape["k"])) * 0.1,
            jnp.float32)
        fn = jax.jit(lambda x, w: ref.conv2d(
            x, w, stride=shape["stride"], padding=shape["padding"]))
        rec["host_xla_us"] = round(time_call(fn, x, w), 1)
    return rec


def build_report(*, measure: bool = False) -> dict:
    tables = {}
    for tname, layers in layer_tables().items():
        tables[tname] = [layer_record(sh, measure=measure) for sh in layers]
    return {
        "minibatch": MINIBATCH,
        "vmem_budget": VMEM_BUDGET,
        "model": "tpu-v5e roofline (repro.tune.measure.conv_traffic)",
        "inception_image": list(INCEPTION_IMAGE),
        "tables": tables,
    }


def main(argv=None) -> None:
    argv = sys.argv[1:] if argv is None else (argv or [])
    report = build_report(measure="--measure" in argv)
    out_path = bench_out_path(OUT_PATH)
    out_path.write_text(json.dumps(report, indent=1, sort_keys=True) + "\n")
    for tname, recs in report["tables"].items():
        for rec in recs:
            t, wp = rec["tiled"], rec["whole_plane"]
            emit(f"conv_fwd_{tname}_{rec['layer']}_tiled", t["cost_us"],
                 f"imgs_s={t['images_per_sec']};eff={t['roofline_efficiency']};"
                 f"hbm_ratio={t['hbm_bytes'] / max(wp['hbm_bytes'], 1):.3f};"
                 f"ws_ratio={t['vmem_working_set'] / wp['vmem_working_set']:.3f};"
                 f"whole_fits_vmem={int(wp['fits_vmem'])}")
    emit("conv_fwd_bench_json", 0, f"wrote={out_path}")


if __name__ == "__main__":
    main()
