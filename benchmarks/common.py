import os
import pathlib
import time

import jax


def bench_out_path(default_path) -> "pathlib.Path":
    """Where a bench writes its JSON artifact.

    Default: the committed repo-root location (``default_path``).  When
    ``REPRO_BENCH_OUT`` names a directory (``benchmarks.run --out-dir`` /
    ``--check`` set it), the artifact lands there instead, so a perf-gate
    run can generate fresh output to diff against the committed baselines
    without dirtying the working tree.
    """
    out_dir = os.environ.get("REPRO_BENCH_OUT")
    default_path = pathlib.Path(default_path)
    if not out_dir:
        return default_path
    d = pathlib.Path(out_dir)
    d.mkdir(parents=True, exist_ok=True)
    return d / default_path.name


def time_call(fn, *args, warmup: int = 2, iters: int = 5):
    """Median wall time in us (jit'd fn, blocked until ready)."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2] * 1e6


def emit(name: str, us: float, derived: str):
    print(f"{name},{us:.1f},{derived}")
