import time

import jax


def time_call(fn, *args, warmup: int = 2, iters: int = 5):
    """Median wall time in us (jit'd fn, blocked until ready)."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2] * 1e6


def emit(name: str, us: float, derived: str):
    print(f"{name},{us:.1f},{derived}")
