"""Paper §II-H kernel streams: dryrun cost, segment compression, and the
branch-elimination accounting (branchy-loop conditionals vs replay
segments) across the ResNet-50 layer set."""
import math

from benchmarks.common import emit
from repro.core.blocking import conv_blocking
from repro.core.streams import build_conv_schedule
from repro.graph.topology import RESNET50_LAYERS

MINIBATCH = 28   # the paper's SKX minibatch


def main():
    import time
    total_steps = 0
    total_segments = 0
    t0 = time.perf_counter()
    for lid, l in sorted(RESNET50_LAYERS.items()):
        if l["c"] < 8:
            continue
        blk = conv_blocking(h=l["h"], w=l["w"], c=l["c"], k=l["k"],
                            r=l["r"], s=l["s"], stride=l["stride"],
                            padding=l["r"] // 2)
        p = (l["h"] + 2 * (l["r"] // 2) - l["r"]) // l["stride"] + 1
        sched = build_conv_schedule(
            n=MINIBATCH, k_b=l["k"] // blk.k_blk,
            p_b=math.ceil(p / blk.rb_p), c_b=l["c"] // blk.c_blk,
            order=blk.order, relu=True)
        total_steps += len(sched)
        total_segments += len(sched.segments)
    dry_us = (time.perf_counter() - t0) * 1e6
    emit("streams_dryrun_resnet50", dry_us,
         f"steps={total_steps};segments={total_segments};"
         f"branch_elim={total_steps * 3}->segments({total_segments})")


if __name__ == "__main__":
    main()
