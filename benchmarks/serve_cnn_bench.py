"""Paper §III serving tables: image throughput of the GxM inference path
for ResNet-50 and Inception — images/sec vs batch size and device count,
with efficiency relative to the three-term roofline model
(``launch/roofline.py``).

Each device count runs in a fresh subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` (set before jax
imports, like ``tests/test_distributed.py``), so the multi-device column is
reproducible on any host.  Per (arch, batch, devices) cell the worker
builds a ``CnnInferenceEngine`` over ``make_host_mesh``, warms it up
(blocking cache + AOT compile), times the bucket executable, and reads the
roofline terms off the compiled HLO.  Output: CSV rows for the harness plus
one ``RESULT {json}`` document with every cell.

  PYTHONPATH=src python -m benchmarks.serve_cnn_bench          # full table
  PYTHONPATH=src python -m benchmarks.serve_cnn_bench --dry    # CI smoke
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

ARCHS = ("resnet50", "inception")
DEVICE_COUNTS = (1, 2)
FULL_BATCHES = (4, 8, 16)
DRY_BATCHES = (2, 4, 8)


def _worker(args) -> None:
    """Runs inside a subprocess whose XLA_FLAGS pinned the device count."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from benchmarks.common import time_call
    from repro.graph.serving import cnn_model_flops
    from repro.launch import roofline as rl
    from repro.launch.mesh import make_host_mesh
    from repro.launch.serve_cnn import build_model
    from repro.graph.serving import CnnInferenceEngine

    ndev = len(jax.devices())
    assert ndev == args.devices, (ndev, args.devices)
    m, image = build_model(args.arch, smoke=args.dry,
                           num_classes=10 if args.dry else 1000,
                           image=args.image)
    params = m.init(jax.random.PRNGKey(0))
    mesh = make_host_mesh()
    batches = [b for b in args.batches if b % ndev == 0]
    engine = CnnInferenceEngine(m, params, image_hw=(image, image),
                                mesh=mesh, buckets=tuple(batches))
    engine.warmup(autotune="off")        # compile-only: timings, not tuning

    rng = np.random.default_rng(0)
    rows = []
    for batch in batches:
        x = jnp.asarray(rng.standard_normal((batch, image, image, 3)),
                        jnp.float32)
        compiled = engine.aot_executable(batch)
        us = time_call(lambda v: compiled(params, v), x)
        flops = cnn_model_flops(m.etg, (image, image), batch)
        roof = rl.analyze(compiled, chips=ndev, model_flops_global=flops)
        roof_ips = batch / roof.step_time_s if roof.step_time_s else 0.0
        measured_ips = batch / (us / 1e6)
        rows.append({
            "arch": args.arch, "devices": ndev, "batch": batch,
            "image": image, "us_per_batch": round(us, 1),
            "images_per_s": round(measured_ips, 2),
            "roofline_images_per_s": round(roof_ips, 2),
            "roofline_efficiency": round(measured_ips / roof_ips, 6)
            if roof_ips else 0.0,
            "roofline_dominant": roof.dominant,
            "model_gflops_per_batch": round(flops / 1e9, 3),
        })
    print("RESULT " + json.dumps({"arch": args.arch, "devices": ndev,
                                  "rows": rows}))


def _spawn(arch: str, devices: int, batches, *, dry: bool,
           image: int) -> list[dict]:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["JAX_PLATFORMS"] = "cpu"
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.path.join(repo, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    cmd = [sys.executable, "-m", "benchmarks.serve_cnn_bench", "--worker",
           "--arch", arch, "--devices", str(devices),
           "--batches", ",".join(map(str, batches)), "--image", str(image)]
    if dry:
        cmd.append("--dry")
    out = subprocess.run(cmd, capture_output=True, text=True, env=env,
                         cwd=repo, timeout=1800)
    if out.returncode != 0:
        raise RuntimeError(f"worker {arch}x{devices} failed:\n"
                           + out.stderr[-4000:])
    for line in out.stdout.splitlines():
        if line.startswith("RESULT "):
            return json.loads(line[len("RESULT "):])["rows"]
    raise RuntimeError(f"worker {arch}x{devices} emitted no RESULT line:\n"
                       + out.stdout[-2000:])


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dry", action="store_true",
                    help="tiny topologies/images (CI smoke)")
    ap.add_argument("--worker", action="store_true")
    ap.add_argument("--arch", choices=ARCHS, default="resnet50")
    ap.add_argument("--devices", type=int, default=1)
    ap.add_argument("--batches", type=str, default="")
    ap.add_argument("--image", type=int, default=0)
    args = ap.parse_args(argv)
    args.batches = tuple(int(b) for b in args.batches.split(",") if b) or \
        (DRY_BATCHES if args.dry else FULL_BATCHES)

    if args.worker:
        _worker(args)
        return

    from benchmarks.common import emit
    table = {"batches": list(args.batches), "rows": []}
    for arch in ARCHS:
        for devices in DEVICE_COUNTS:
            rows = _spawn(arch, devices, args.batches, dry=args.dry,
                          image=args.image)
            table["rows"].extend(rows)
            for r in rows:
                emit(f"serve_{arch}_d{devices}_b{r['batch']}",
                     r["us_per_batch"],
                     f"images_per_s={r['images_per_s']};"
                     f"roofline_eff={r['roofline_efficiency']};"
                     f"dominant={r['roofline_dominant']}")
    print("RESULT " + json.dumps(table))


if __name__ == "__main__":
    main()
