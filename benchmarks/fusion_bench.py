"""Paper §II-G / GxM fusion contribution: fused vs unfused ResNet
bottleneck inference, plus the graph-level fusion statistics (nodes before
/ after, distinct JIT kernels after dedupe — the combinatorial-explosion
answer)."""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_call
from repro.graph import GxM, resnet50
from repro.graph.etg import build_etg


def main():
    rng = np.random.default_rng(0)
    nl = resnet50(num_classes=100, stages=(1, 1, 1, 1))
    x = jnp.asarray(rng.standard_normal((2, 64, 64, 3)), jnp.float32)

    m_fused = GxM(resnet50(num_classes=100, stages=(1, 1, 1, 1)),
                  impl="xla", fuse=True, num_classes=100)
    m_plain = GxM(resnet50(num_classes=100, stages=(1, 1, 1, 1)),
                  impl="xla", fuse=False, num_classes=100)
    pf = m_fused.init(jax.random.PRNGKey(0))
    pp = m_plain.init(jax.random.PRNGKey(0))
    f_fused = jax.jit(lambda p, x: m_fused.forward(p, x, train=False))
    f_plain = jax.jit(lambda p, x: m_plain.forward(p, x, train=False))
    us_f = time_call(f_fused, pf, x)
    us_p = time_call(f_plain, pp, x)
    emit("gxm_infer_fused", us_f, f"speedup_vs_unfused={us_p/us_f:.2f}x")

    etg = build_etg(resnet50())
    emit("gxm_fusion_stats", 0.0,
         f"nodes_before={etg.stats['nodes_before']};"
         f"nodes_after={etg.stats['nodes_after']};"
         f"distinct_jit_kernels={len(etg.kernel_cache)}")


if __name__ == "__main__":
    main()
