"""Paper §II-G / GxM fusion contribution: fused vs unfused ResNet
bottleneck inference, plus the graph-level fusion statistics (nodes before
/ after, distinct JIT kernels after dedupe — the combinatorial-explosion
answer).

``build_report()`` is the machine-checkable half (pinned by
``tests/test_fusion_bench.py``): it walks the ETG symbolically and prices
the §II-G rule — every L() op fused into a conv epilogue saves one HBM
round trip (read + write) of the intermediate N·P·Q·K activation that an
unfused graph would pay — so modeled fused traffic <= unfused traffic is
an invariant, not a wall-clock accident.  ``main()`` additionally
wall-clocks the fused vs unfused jitted models on a tiny topology.
"""
from repro.graph.etg import build_etg
from repro.graph.serving import conv_shapes
from repro.graph.topology import resnet50
from repro.tune.space import out_dim

IMAGE_HW = (224, 224)
MINIBATCH = 1
DTYPE_BYTES = 4


def build_report(*, image_hw=IMAGE_HW, minibatch: int = MINIBATCH) -> dict:
    """Modeled fused-vs-unfused HBM traffic + graph fusion statistics."""
    etg = build_etg(resnet50(num_classes=1000))
    h0, w0 = image_hw
    by_name = {t.name: t for t in etg.tasks}
    convs = []
    base_traffic = 0.0          # conv in+weight+out bytes, single-pass model
    saved = 0.0                 # round trips the fused epilogues avoid
    for sh in conv_shapes(etg, image_hw):
        p = out_dim(sh["h"], sh["r"], sh["stride"], sh["padding"])
        q = out_dim(sh["w"], sh["s"], sh["stride"], sh["padding"])
        out_bytes = minibatch * p * q * sh["k"] * DTYPE_BYTES
        in_bytes = minibatch * sh["h"] * sh["w"] * sh["c"] * DTYPE_BYTES
        w_bytes = sh["r"] * sh["s"] * sh["c"] * sh["k"] * DTYPE_BYTES
        fused_ops = [op for op, _ in by_name[sh["name"]].fused]
        # each fused L() op would otherwise read + rewrite the intermediate
        layer_saved = 2.0 * out_bytes * len(fused_ops)
        base_traffic += in_bytes + w_bytes + out_bytes
        saved += layer_saved
        convs.append({
            "layer": sh["name"],
            "shape": {f: sh[f] for f in ("h", "w", "c", "k", "r", "s",
                                         "stride", "padding")},
            "fused_ops": fused_ops,
            "out_bytes": int(out_bytes),
            "saved_bytes": int(layer_saved),
        })
    return {
        "topology": "resnet50",
        "image": list(image_hw),
        "minibatch": minibatch,
        "stats": dict(etg.stats),
        "distinct_jit_kernels": len(etg.kernel_cache),
        "traffic": {
            "fused_hbm_bytes": int(base_traffic),
            "unfused_hbm_bytes": int(base_traffic + saved),
            "saved_hbm_bytes": int(saved),
        },
        "convs": convs,
    }


def main():
    import jax
    import jax.numpy as jnp
    import numpy as np

    from benchmarks.common import emit, time_call

    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((2, 64, 64, 3)), jnp.float32)

    from repro.graph import GxM
    m_fused = GxM(resnet50(num_classes=100, stages=(1, 1, 1, 1)),
                  impl="xla", fuse=True, num_classes=100)
    m_plain = GxM(resnet50(num_classes=100, stages=(1, 1, 1, 1)),
                  impl="xla", fuse=False, num_classes=100)
    pf = m_fused.init(jax.random.PRNGKey(0))
    pp = m_plain.init(jax.random.PRNGKey(0))
    f_fused = jax.jit(lambda p, x: m_fused.forward(p, x, train=False))
    f_plain = jax.jit(lambda p, x: m_plain.forward(p, x, train=False))
    us_f = time_call(f_fused, pf, x)
    us_p = time_call(f_plain, pp, x)
    emit("gxm_infer_fused", us_f, f"speedup_vs_unfused={us_p/us_f:.2f}x")

    report = build_report()
    tr = report["traffic"]
    emit("gxm_fusion_stats", 0.0,
         f"nodes_before={report['stats']['nodes_before']};"
         f"nodes_after={report['stats']['nodes_after']};"
         f"distinct_jit_kernels={report['distinct_jit_kernels']};"
         f"modeled_traffic_ratio="
         f"{tr['fused_hbm_bytes'] / max(tr['unfused_hbm_bytes'], 1):.3f}")


if __name__ == "__main__":
    main()
