"""Kernel-streams applied to MoE (DESIGN.md §2): routing dryrun + grouped
replay vs the dense every-expert loop, on host."""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_call
from repro.kernels.moe_gmm import route_dryrun


def main():
    rng = np.random.default_rng(0)
    t_tokens, d, f, e, cap, bm = 512, 128, 256, 8, 128, 64
    tok = jnp.asarray(rng.standard_normal((t_tokens, d)), jnp.float32)
    wts = jnp.asarray(rng.standard_normal((e, d, f)) * 0.05, jnp.float32)
    eid = jnp.asarray(rng.integers(0, e, size=t_tokens), jnp.int32)

    @jax.jit
    def grouped(tok, wts, eid):
        gi, tile_eid, keep = route_dryrun(eid, e, cap, bm)
        g = tok[gi] * keep[:, None]
        ge = g.reshape(e, cap, d)
        return jnp.einsum("ecd,edf->ecf", ge, wts)

    @jax.jit
    def dense_all_experts(tok, wts, eid):
        # every token through every expert, mask after (the no-streams way)
        y = jnp.einsum("td,edf->etf", tok, wts)
        mask = jax.nn.one_hot(eid, e, dtype=tok.dtype).T[:, :, None]
        return (y * mask).sum(0)

    us_g = time_call(grouped, tok, wts, eid)
    us_d = time_call(dense_all_experts, tok, wts, eid)
    emit("moe_streams_grouped", us_g,
         f"dense_loop_speedup={us_d/us_g:.2f}x;experts={e};cap={cap}")


if __name__ == "__main__":
    main()
