"""Benchmark harness — one module per paper table/figure (+ beyond-paper
tables).  Prints ``name,us_per_call,derived`` CSV rows.

  Fig 4 / Table I  -> resnet50_layers       (fwd per-layer, im2col vs direct)
  §II-B..E tiling  -> conv_fwd_bench        (tiled vs whole-plane fwd ->
                                             BENCH_conv_fwd.json baseline)
  Fig 5 (a)(b)     -> bwd_wu_layers         (tiled vs legacy update pass +
                                             phase vs dilate duality ->
                                             BENCH_bwd_wu.json baseline)
  Fig 8            -> reduced_precision_bench (int8 weights, §II-K analog)
  Fig 9            -> scaling_bench         (strong scaling, overlap model)
  §II-G/GxM        -> fusion_bench          (fused vs unfused + ETG stats)
  §II-H            -> streams_bench         (dryrun/segments accounting)
  §II-D            -> autotune_bench        (tuned vs heuristic blocking)
  §III serving     -> serve_cnn_bench       (images/sec × batch × devices)
  §III multi-node  -> train_scaling_bench   (DP training: devices × psum
                                             wire format ->
                                             BENCH_train_scaling.json)
  DESIGN.md §7     -> moe_streams_bench     (streams GMM vs dense loop)
  beyond-paper     -> lm_roofline_table     (40-cell arch × shape roofline)

``--dry`` is the CI smoke mode: it imports every module (catching bit-rot in
the benchmark code itself) and runs only the cheap fast-path tables — the
model-based autotune table on a few layers and the tiny-topology serving
throughput table — instead of the full timed sweep.
"""
import os
import sys
import tempfile
import traceback

from benchmarks import (autotune_bench, bwd_wu_layers, conv_fwd_bench,
                        fusion_bench, inception_bench, lm_roofline_table,
                        moe_streams_bench, reduced_precision_bench,
                        resnet50_layers, scaling_bench, serve_cnn_bench,
                        streams_bench, train_scaling_bench)

MODULES = [
    ("conv_fwd_bench", conv_fwd_bench),
    ("resnet50_layers", resnet50_layers),
    ("bwd_wu_layers", bwd_wu_layers),
    ("fusion_bench", fusion_bench),
    ("inception_bench", inception_bench),
    ("streams_bench", streams_bench),
    ("reduced_precision_bench", reduced_precision_bench),
    ("scaling_bench", scaling_bench),
    ("moe_streams_bench", moe_streams_bench),
    ("lm_roofline_table", lm_roofline_table),
    ("autotune_bench", autotune_bench),
    ("serve_cnn_bench", serve_cnn_bench),
    ("train_scaling_bench", train_scaling_bench),
]


def main(argv=None) -> None:
    argv = sys.argv[1:] if argv is None else argv
    dry = "--dry" in argv
    print("name,us_per_call,derived")
    failures = 0
    if dry:
        for name, _ in MODULES:
            print(f"{name},0,IMPORT_OK")
        if "REPRO_TUNE_CACHE" not in os.environ:
            # smoke runs must not pollute the user's persistent tuner cache
            # (that would pre-satisfy autotune_bench's miss->hit round trip)
            os.environ["REPRO_TUNE_CACHE"] = os.path.join(
                tempfile.mkdtemp(prefix="repro-dry-"), "cache.json")
        try:
            autotune_bench.main(limit=4)
        except Exception:  # noqa: BLE001
            failures += 1
            print("autotune_bench,0,FAILED", file=sys.stdout)
            traceback.print_exc()
        # fast-path tables that still run in smoke mode (conv_fwd_bench and
        # bwd_wu_layers are model-based, so the dry run also refreshes
        # BENCH_conv_fwd.json / BENCH_bwd_wu.json)
        for name, call in (("serve_cnn_bench",
                            lambda: serve_cnn_bench.main(["--dry"])),
                           ("conv_fwd_bench",
                            lambda: conv_fwd_bench.main([])),
                           ("bwd_wu_layers",
                            lambda: bwd_wu_layers.main([])),
                           # model-based: refreshes BENCH_train_scaling.json
                           ("train_scaling_bench",
                            lambda: train_scaling_bench.main([]))):
            try:
                call()
            except Exception:  # noqa: BLE001
                failures += 1
                print(f"{name},0,FAILED", file=sys.stdout)
                traceback.print_exc()
    else:
        for name, mod in MODULES:
            try:
                mod.main()
            except Exception:  # noqa: BLE001
                failures += 1
                print(f"{name},0,FAILED", file=sys.stdout)
                traceback.print_exc()
    if failures:
        raise SystemExit(f"{failures} benchmark modules failed")


if __name__ == "__main__":
    main()
