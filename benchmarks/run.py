"""Benchmark harness — one module per paper table/figure (+ beyond-paper
tables).  Prints ``name,us_per_call,derived`` CSV rows.

  Fig 4 / Table I  -> resnet50_layers       (fwd per-layer, im2col vs direct)
  §II-B..E tiling  -> conv_fwd_bench        (tiled vs whole-plane fwd ->
                                             BENCH_conv_fwd.json baseline)
  Fig 5 (a)(b)     -> bwd_wu_layers         (tiled vs legacy update pass +
                                             phase vs dilate duality ->
                                             BENCH_bwd_wu.json baseline)
  Fig 8            -> reduced_precision_bench (int8 weights, §II-K analog)
  Fig 9            -> scaling_bench         (strong scaling, overlap model)
  §II-G/GxM        -> fusion_bench          (fused vs unfused + ETG stats)
  DESIGN.md §16    -> chain_fusion_bench    (depth-first conv chains, fused
                                             vs unfused traffic ->
                                             BENCH_chain_fusion.json)
  §II-H            -> streams_bench         (dryrun/segments accounting)
  §II-D            -> autotune_bench        (tuned vs heuristic blocking)
  §III serving     -> serve_cnn_bench       (images/sec × batch × devices)
  §III multi-node  -> train_scaling_bench   (DP training: devices × psum
                                             wire format ->
                                             BENCH_train_scaling.json)
  DESIGN.md §14    -> resilience_bench      (goodput under canned fault
                                             schedules ->
                                             BENCH_resilience.json)
  DESIGN.md §15    -> serve_fleet_bench     (serving SLOs under replica
                                             chaos -> BENCH_serve_fleet.json)
  DESIGN.md §7     -> moe_streams_bench     (streams GMM vs dense loop)
  beyond-paper     -> lm_roofline_table     (40-cell arch × shape roofline)

``--dry`` is the CI smoke mode: it imports every module (catching bit-rot in
the benchmark code itself) and runs only the cheap fast-path tables — the
model-based autotune table on a few layers, the tiny-topology serving
throughput table, and every JSON-emitting model bench — instead of the
full timed sweep.

Perf-gate flags (DESIGN.md §12, ``repro.perfci``):

  --out-dir DIR        write bench JSON artifacts under DIR instead of the
                       committed repo-root locations (env: REPRO_BENCH_OUT)
  --check              after the run, extract (metric, value) series from
                       the fresh artifacts and compare them against
                       BENCH_BASELINES.json under per-metric tolerance
                       policies; exit non-zero on any regression.  With no
                       --out-dir the fresh artifacts go to a temp dir so the
                       working tree stays clean.
  --update-baselines   re-pin BENCH_BASELINES.json for the current context
                       (REPRO_VMEM_BUDGET) from this run's artifacts, stamp
                       provenance, and append one BENCH_TRAJECTORY.json
                       record.  Artifacts also refresh the committed
                       BENCH_*.json files unless --out-dir says otherwise.
  --baselines PATH     compare/update against PATH instead of the committed
                       BENCH_BASELINES.json (tests inject copies here)
"""
import argparse
import os
import sys
import tempfile
import traceback

from benchmarks import (autotune_bench, bwd_wu_layers, chain_fusion_bench,
                        conv_fwd_bench, fusion_bench, inception_bench,
                        lm_roofline_table, moe_streams_bench,
                        reduced_precision_bench, resilience_bench,
                        resnet50_layers, scaling_bench, serve_cnn_bench,
                        serve_fleet_bench, streams_bench, train_scaling_bench)

MODULES = [
    ("conv_fwd_bench", conv_fwd_bench),
    ("resnet50_layers", resnet50_layers),
    ("bwd_wu_layers", bwd_wu_layers),
    ("fusion_bench", fusion_bench),
    ("chain_fusion_bench", chain_fusion_bench),
    ("inception_bench", inception_bench),
    ("streams_bench", streams_bench),
    ("reduced_precision_bench", reduced_precision_bench),
    ("scaling_bench", scaling_bench),
    ("moe_streams_bench", moe_streams_bench),
    ("lm_roofline_table", lm_roofline_table),
    ("autotune_bench", autotune_bench),
    ("serve_cnn_bench", serve_cnn_bench),
    ("train_scaling_bench", train_scaling_bench),
    ("resilience_bench", resilience_bench),
    ("serve_fleet_bench", serve_fleet_bench),
]

# the fast-path tables that still *run* in --dry smoke mode (every
# model-based JSON emitter is here: a dry run regenerates every
# perf-gate artifact).  Data, not code, so failure-path tests and the
# perf-gate can substitute their own lists.
DRY_CALLS = [
    ("autotune_bench", lambda: autotune_bench.main(limit=4)),
    ("serve_cnn_bench", lambda: serve_cnn_bench.main(["--dry"])),
    ("conv_fwd_bench", lambda: conv_fwd_bench.main([])),
    ("chain_fusion_bench", lambda: chain_fusion_bench.main([])),
    ("bwd_wu_layers", lambda: bwd_wu_layers.main([])),
    ("train_scaling_bench", lambda: train_scaling_bench.main([])),
    ("reduced_precision_q8", lambda: reduced_precision_bench.main_q8()),
    ("resilience_bench", lambda: resilience_bench.main([])),
    ("serve_fleet_bench", lambda: serve_fleet_bench.main([])),
]


def parse_args(argv) -> argparse.Namespace:
    ap = argparse.ArgumentParser(prog="benchmarks.run", description=__doc__)
    ap.add_argument("--dry", action="store_true")
    ap.add_argument("--check", action="store_true")
    ap.add_argument("--update-baselines", action="store_true",
                    dest="update_baselines")
    ap.add_argument("--out-dir", default=None)
    ap.add_argument("--baselines", default=None)
    ap.add_argument("--verbose", action="store_true",
                    help="print every gated metric, not just the changes")
    return ap.parse_args(argv)


def _resolve_out_dir(args) -> str | None:
    """Set REPRO_BENCH_OUT for this run; returns the artifact directory the
    perf-gate should read (None = committed repo-root locations)."""
    out_dir = args.out_dir or os.environ.get("REPRO_BENCH_OUT")
    if out_dir is None and args.check and not args.update_baselines:
        # --check must not dirty the tree: fresh artifacts go to a temp dir
        out_dir = tempfile.mkdtemp(prefix="repro-bench-")
    if out_dir is not None:
        os.environ["REPRO_BENCH_OUT"] = out_dir
    return out_dir


def run_benches(*, dry: bool) -> int:
    """Run the suite (or the --dry fast path); returns the failure count."""
    failures = 0
    if dry:
        for name, _ in MODULES:
            print(f"{name},0,IMPORT_OK")
        if "REPRO_TUNE_CACHE" not in os.environ:
            # smoke runs must not pollute the user's persistent tuner cache
            # (that would pre-satisfy autotune_bench's miss->hit round trip)
            os.environ["REPRO_TUNE_CACHE"] = os.path.join(
                tempfile.mkdtemp(prefix="repro-dry-"), "cache.json")
        for name, call in DRY_CALLS:
            try:
                call()
            except Exception:  # noqa: BLE001
                failures += 1
                print(f"{name},0,FAILED", file=sys.stdout)
                traceback.print_exc()
    else:
        for name, mod in MODULES:
            try:
                mod.main()
            except Exception:  # noqa: BLE001
                failures += 1
                print(f"{name},0,FAILED", file=sys.stdout)
                traceback.print_exc()
    return failures


def main(argv=None) -> None:
    args = parse_args(sys.argv[1:] if argv is None else argv)
    out_dir = _resolve_out_dir(args)
    print("name,us_per_call,derived")
    failures = run_benches(dry=args.dry)
    if failures:
        raise SystemExit(f"{failures} benchmark modules failed")

    if not (args.check or args.update_baselines):
        return
    from repro import perfci
    fresh_root = out_dir or os.path.dirname(
        os.path.dirname(os.path.abspath(__file__)))
    if args.update_baselines:
        cmd = "python -m benchmarks.run " + " ".join(
            a for a in (argv if argv is not None else sys.argv[1:]))
        perfci.run_update(fresh_root, baseline_path=args.baselines,
                          command=cmd)
    if args.check:
        try:
            verdict = perfci.run_check(fresh_root,
                                       baseline_path=args.baselines,
                                       verbose=args.verbose)
        except perfci.MissingBaseline as e:
            raise SystemExit(str(e))
        if not verdict.ok:
            raise SystemExit(
                f"perf-gate: {len(verdict.failures)} gated metrics "
                f"regressed — see table above (intentional change? "
                f"re-pin with --update-baselines)")


if __name__ == "__main__":
    main()
