"""Paper Fig. 5 (a)/(b): backward-data (via duality) and weight-update
passes per ResNet-50 layer.  `derived` reports the duality scenario chosen
(§II-I) and the §II-J weight-update parallelization pick for a 256-chip
worker pool."""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_call
from repro.core.conv import conv2d_bwd_data_via_fwd, conv2d_bwd_weights
from repro.core.duality import bwd_data_plan
from repro.core.wu_strategy import choose_wu_strategy
from repro.graph.topology import RESNET50_LAYERS

MINIBATCH = 4
SUBSET = [1, 2, 4, 6, 8, 13, 16, 18, 20]   # representative layer ids


def main():
    rng = np.random.default_rng(0)
    for lid in SUBSET:
        l = RESNET50_LAYERS[lid]
        h = min(l["h"], 56)
        scale = (l["h"] / h) ** 2
        r, stride = l["r"], l["stride"]
        pad = r // 2
        p = (h + 2 * pad - r) // stride + 1
        x = jnp.asarray(rng.standard_normal(
            (MINIBATCH, h, h, l["c"])), jnp.float32)
        do = jnp.asarray(rng.standard_normal(
            (MINIBATCH, p, p, l["k"])), jnp.float32)
        w = jnp.asarray(rng.standard_normal(
            (r, r, l["c"], l["k"])) * 0.05, jnp.float32)

        scen, _ = bwd_data_plan(r=r, s=r, stride=stride, padding=pad,
                                input_hw=(h, h))
        bwd = jax.jit(lambda do, w: conv2d_bwd_data_via_fwd(
            do, w, stride=stride, padding=pad, input_hw=(h, h), impl="xla"))
        us_b = time_call(bwd, do, w) * scale
        emit(f"resnet50_bwd_L{lid:02d}", us_b, f"duality={scen}")

        wu = jax.jit(lambda x, do: conv2d_bwd_weights(
            x, do, stride=stride, padding=pad, filter_rs=(r, r), impl="xla"))
        us_w = time_call(wu, x, do) * scale
        strat = choose_wu_strategy(n=256, c=l["c"], k=l["k"], h=l["h"],
                                   w=l["w"], p=p, q=p, r=r, s=r,
                                   n_workers=256)
        emit(f"resnet50_wu_L{lid:02d}", us_w,
             f"wu_strategy={strat.strategy}")


if __name__ == "__main__":
    main()
