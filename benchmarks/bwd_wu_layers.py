"""Paper Fig. 5 (a)/(b): backward-data (via duality) and weight-update
passes — machine-readable training-pass perf trajectory.

Writes ``BENCH_bwd_wu.json`` at the repo root — for the full ResNet-50
(paper Table I, *real* shapes, the 224×224 stem included — the seed bench
capped layers at h ≤ 56 and extrapolated) and Inception-v3 conv tables:

  wu        tiled (band-streamed, C/Q-blocked, ceil-div tails) vs legacy
            (whole padded plane shipped per grid step, rb_p | P) update
            pass, each under its own analytic blocking — the runtime A/B
            of the ``REPRO_CONV_TILING`` knob;
  bwd_data  phase-decomposed (stride² sub-convs over undilated dO) vs
            dilate (materialized dilated dO) duality plans — the runtime
            A/B of the ``REPRO_BWD_DUALITY`` knob.  Single-conv scenarios
            (stride 1 / 1x1) cost identically under both plans.

Numbers come from the schedule-resolved roofline model
(``repro.tune.measure.conv_traffic`` / ``bwd_data_traffic`` +
``launch.roofline.kernel_roofline`` / ``composite_roofline``) so the file is
reproducible on any host; ``--measure`` additionally wall-clocks the XLA
reference path per layer for a host-speed column.
``tests/test_bwd_wu_bench.py`` pins tiled ≤ legacy and phase ≤ dilate on
every benchmarked layer.
"""
import json
import pathlib
import sys

from benchmarks.common import bench_out_path, emit
from benchmarks.conv_fwd_bench import layer_tables
from repro.configs.shapes import STEM_CONV
from repro.core.blocking import (VMEM_BUDGET, conv_blocking_analytic,
                                 conv_working_set)
from repro.core.conv import lane_ok
from repro.core.duality import bwd_data_plan
from repro.core.wu_strategy import choose_wu_strategy
from repro.launch.roofline import composite_roofline, kernel_roofline
from repro.tune.measure import (STEP_OVERHEAD_US, bwd_data_traffic,
                                conv_traffic)
from repro.tune.space import out_dim

MINIBATCH = 4
OUT_PATH = pathlib.Path(__file__).resolve().parents[1] / "BENCH_bwd_wu.json"


def bench_tables() -> dict[str, list[dict]]:
    """The fwd-bench tables plus the lane-padded stem regression shape —
    the layer the seed bench could never run un-extrapolated."""
    tables = layer_tables()
    stem = {f: STEM_CONV[f] for f in ("h", "w", "c", "k", "r", "s",
                                      "stride", "padding")}
    tables["regression"] = [dict(name=STEM_CONV["name"], **stem)]
    return tables


def _wu_variant(shape: dict, blk, *, whole: bool) -> dict:
    """Modeled cost/traffic of the update pass under one input strategy,
    each with its own analytic blocking (what the knob actually runs)."""
    t = conv_traffic(shape, blk, minibatch=MINIBATCH, kind="wu",
                     whole_plane=whole)
    roof = kernel_roofline(flops=t["flops"], hbm_bytes=t["hbm_bytes"],
                           util=t["util"], n_steps=t["n_steps"],
                           step_overhead_s=STEP_OVERHEAD_US * 1e-6)
    q = out_dim(shape["w"], shape["s"], shape["stride"], shape["padding"])
    vmem = conv_working_set(
        h=shape["h"], w=shape["w"], c=shape["c"], k_blk=blk.k_blk,
        r=shape["r"], s=shape["s"], q=q, rb_p=blk.rb_p,
        padding=shape["padding"], stride=shape["stride"],
        c_blk=None if whole else blk.c_blk, rb_q=None if whole else blk.rb_q,
        whole_plane=whole, kind="wu")
    return {
        "blocking": {"rb_p": blk.rb_p, "rb_q": 0 if whole else blk.rb_q,
                     "k_blk": blk.k_blk, "c_blk": shape["c"] if whole
                     else blk.c_blk},
        "cost_us": round(roof["cost_s"] * 1e6, 3),
        "hbm_bytes": int(t["hbm_bytes"]),
        "hbm_input_bytes": int(t["x_bytes"]),
        "hbm_dout_bytes": int(t["w_bytes"]),
        "roofline_efficiency": round(roof["efficiency"], 4),
        "dominant": roof["dominant"],
        "vmem_working_set": int(vmem),
        "fits_vmem": bool(vmem <= VMEM_BUDGET),
        "grid_steps": int(t["n_steps"]),
    }


def _bwd_variant(shape: dict, *, mode: str) -> dict:
    t = bwd_data_traffic(shape, minibatch=MINIBATCH, mode=mode)
    roof = composite_roofline(t["parts"], extra_hbm_bytes=t["extra_hbm_bytes"],
                              step_overhead_s=STEP_OVERHEAD_US * 1e-6)
    return {
        "cost_us": round(roof["cost_s"] * 1e6, 3),
        "hbm_bytes": int(roof["hbm_bytes"]),
        "extra_hbm_bytes": int(t["extra_hbm_bytes"]),
        "flops": roof["flops"],
        "n_convs": t["n_convs"],
        "roofline_efficiency": round(roof["efficiency"], 4),
    }


def layer_record(shape: dict, *, measure: bool = False) -> dict:
    geom = dict(h=shape["h"], w=shape["w"], c=shape["c"], k=shape["k"],
                r=shape["r"], s=shape["s"], stride=shape["stride"],
                padding=shape["padding"])
    tiled_blk = conv_blocking_analytic(**geom, kind="wu")
    legacy_blk = conv_blocking_analytic(**geom, require_divisor=True,
                                        kind="wu")
    p = out_dim(shape["h"], shape["r"], shape["stride"], shape["padding"])
    q = out_dim(shape["w"], shape["s"], shape["stride"], shape["padding"])
    scen, _ = bwd_data_plan(r=shape["r"], s=shape["s"],
                            stride=shape["stride"],
                            padding=shape["padding"],
                            input_hw=(shape["h"], shape["w"]), mode="phase")
    strat = choose_wu_strategy(n=256, c=shape["c"], k=shape["k"],
                               h=shape["h"], w=shape["w"], p=p, q=q,
                               r=shape["r"], s=shape["s"], n_workers=256)
    rec = {
        "layer": shape["name"],
        "shape": geom,
        "path": "direct" if lane_ok(shape["c"], shape["k"]) else "im2col",
        "duality_scenario": scen,
        "wu_strategy": strat.strategy,
        "wu": {
            "tiled": _wu_variant(shape, tiled_blk, whole=False),
            "whole_plane": _wu_variant(shape, legacy_blk, whole=True),
        },
        "bwd_data": {
            "phase": _bwd_variant(shape, mode="phase"),
            "dilate": _bwd_variant(shape, mode="dilate"),
        },
    }
    if measure:
        import jax
        import jax.numpy as jnp
        import numpy as np

        from benchmarks.common import time_call
        from repro.core.conv import (conv2d_bwd_data_via_fwd,
                                     conv2d_bwd_weights)
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.standard_normal(
            (MINIBATCH, shape["h"], shape["w"], shape["c"])), jnp.float32)
        do = jnp.asarray(rng.standard_normal(
            (MINIBATCH, p, q, shape["k"])), jnp.float32)
        w = jnp.asarray(rng.standard_normal(
            (shape["r"], shape["s"], shape["c"], shape["k"])) * 0.05,
            jnp.float32)
        bwd = jax.jit(lambda do, w: conv2d_bwd_data_via_fwd(
            do, w, stride=shape["stride"], padding=shape["padding"],
            input_hw=(shape["h"], shape["w"]), impl="xla"))
        wu = jax.jit(lambda x, do: conv2d_bwd_weights(
            x, do, stride=shape["stride"], padding=shape["padding"],
            filter_rs=(shape["r"], shape["s"]), impl="xla"))
        rec["host_xla_bwd_us"] = round(time_call(bwd, do, w), 1)
        rec["host_xla_wu_us"] = round(time_call(wu, x, do), 1)
    return rec


def build_report(*, measure: bool = False) -> dict:
    tables = {}
    for tname, layers in bench_tables().items():
        tables[tname] = [layer_record(sh, measure=measure) for sh in layers]
    return {
        "minibatch": MINIBATCH,
        "vmem_budget": VMEM_BUDGET,
        "model": "tpu-v5e roofline (repro.tune.measure.conv_traffic / "
                 "bwd_data_traffic)",
        "tables": tables,
    }


def main(argv=None) -> None:
    argv = sys.argv[1:] if argv is None else (argv or [])
    report = build_report(measure="--measure" in argv)
    out_path = bench_out_path(OUT_PATH)
    out_path.write_text(json.dumps(report, indent=1, sort_keys=True) + "\n")
    for tname, recs in report["tables"].items():
        for rec in recs:
            wt, wl = rec["wu"]["tiled"], rec["wu"]["whole_plane"]
            bp, bd = rec["bwd_data"]["phase"], rec["bwd_data"]["dilate"]
            emit(f"bwd_wu_{tname}_{rec['layer']}_wu", wt["cost_us"],
                 f"legacy_us={wl['cost_us']};"
                 f"hbm_ratio={wt['hbm_bytes'] / max(wl['hbm_bytes'], 1):.4f};"
                 f"ws_ratio={wt['vmem_working_set'] / wl['vmem_working_set']:.3f};"
                 f"wu_strategy={rec['wu_strategy']}")
            emit(f"bwd_wu_{tname}_{rec['layer']}_bwd", bp["cost_us"],
                 f"dilate_us={bd['cost_us']};"
                 f"hbm_ratio={bp['hbm_bytes'] / max(bd['hbm_bytes'], 1):.4f};"
                 f"duality={rec['duality_scenario']};n_convs={bp['n_convs']}")
    emit("bwd_wu_bench_json", 0, f"wrote={out_path}")


if __name__ == "__main__":
    main()
