"""Paper §III multi-node table: data-parallel ResNet-50 *training* over GxM
— images/sec and scaling efficiency per (device count × gradient-reduction
wire format), the training sibling of ``serve_cnn_bench``.

Writes ``BENCH_train_scaling.json`` at the repo root.  The table is the
schedule-resolved *model* (same v5e roofline constants as
``benchmarks/scaling_bench.py``), so the file is reproducible on any host
and later PRs can diff it:

  t_comp     = local_batch · 3·4.1 GFLOP / (peak · kernel_eff)
  t_allreduce= ring all-reduce of the 25.6M-param gradient at the wire
               format's bytes/param (fp32: 4, int8 compressed psum: 1)
  exposed    = max(0, t_allreduce − overlap_fraction · t_comp)

where ``overlap_fraction`` is the backward share of the step (≈2/3): the
step reduces after the wu pass, so the XLA latency-hiding scheduler can
overlap layer i's dW reduction with the remaining backward compute, but
not with the forward of the *next* step.  ``scaling_efficiency`` is
ips(n) / (n · ips(1)); the no-overlap column is the pessimistic bound.

``--dry`` additionally *runs* the real ``train.distributed`` step end to
end — tiny ResNet, {1, 2} fake host devices × {fp32, int8} reduction, each
device count in a fresh subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` — and reports the
measured images/sec rows in the ``RESULT`` document (measured rows never
enter the committed JSON: wall clock is host-dependent).

  PYTHONPATH=src python -m benchmarks.train_scaling_bench          # model
  PYTHONPATH=src python -m benchmarks.train_scaling_bench --dry    # CI smoke
"""
from __future__ import annotations

import argparse
import json
import os
import pathlib
import subprocess
import sys

DEVICE_COUNTS = (1, 2, 4)
REDUCTIONS = ("fp32", "int8")
LIVE_DEVICE_COUNTS = (1, 2)

RESNET50_GFLOP = 4.1 * 3        # fwd+bwd+wu per image (GFLOP)
RESNET50_PARAMS = 25.6e6
LOCAL_BATCH = 32
EFF_COMPUTE = 0.55              # kernel-level efficiency (paper: 55-80%)
OVERLAP_FRACTION = 2 / 3        # bwd share of the step hides the reduction
BYTES_PER_PARAM = {"fp32": 4.0, "int8": 1.0}

OUT_PATH = pathlib.Path(__file__).resolve().parents[1] \
    / "BENCH_train_scaling.json"


def step_times_s(devices: int, reduction: str) -> tuple[float, float, float]:
    """-> (t_comp, t_allreduce, t_step) of one DP train step."""
    from repro.launch.roofline import ICI_BW, PEAK_FLOPS
    t_comp = LOCAL_BATCH * RESNET50_GFLOP * 1e9 / (PEAK_FLOPS * EFF_COMPUTE)
    if devices > 1:
        wire = RESNET50_PARAMS * BYTES_PER_PARAM[reduction]
        t_ar = (2 * (devices - 1) / devices) * wire / ICI_BW
    else:
        t_ar = 0.0
    exposed = max(0.0, t_ar - OVERLAP_FRACTION * t_comp)
    return t_comp, t_ar, t_comp + exposed


def build_report() -> dict:
    rows = []
    base_ips = {r: LOCAL_BATCH / step_times_s(1, r)[2] for r in REDUCTIONS}
    for reduction in REDUCTIONS:
        for devices in DEVICE_COUNTS:
            t_comp, t_ar, t = step_times_s(devices, reduction)
            ips = devices * LOCAL_BATCH / t
            no_overlap_ips = devices * LOCAL_BATCH / (t_comp + t_ar)
            rows.append({
                "devices": devices,
                "reduction": reduction,
                "images_per_s": round(ips, 1),
                "scaling_efficiency": round(
                    ips / (devices * base_ips[reduction]), 4),
                "no_overlap_efficiency": round(
                    no_overlap_ips / (devices * base_ips[reduction]), 4),
                "compute_ms": round(t_comp * 1e3, 4),
                "allreduce_ms": round(t_ar * 1e3, 4),
                "wire_bytes_per_step": int(
                    RESNET50_PARAMS * BYTES_PER_PARAM[reduction])
                if devices > 1 else 0,
            })
    return {
        "model": "resnet50",
        "local_batch": LOCAL_BATCH,
        "gflop_per_image": RESNET50_GFLOP,
        "params": RESNET50_PARAMS,
        "kernel_efficiency": EFF_COMPUTE,
        "overlap_fraction": round(OVERLAP_FRACTION, 4),
        "rows": rows,
    }


# -- live smoke: the real DP step on fake host devices -----------------------

def _worker(args) -> None:
    """Runs in a subprocess whose XLA_FLAGS pinned the device count."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from benchmarks.common import time_call
    from repro.graph import GxM, resnet50
    from repro.launch.mesh import make_host_mesh
    from repro.train.distributed import (init_cnn_train_state_dp,
                                         make_cnn_train_step_dp,
                                         shard_cnn_batch)

    ndev = len(jax.devices())
    assert ndev == args.devices, (ndev, args.devices)
    m = GxM(resnet50(num_classes=10, stages=(1, 1, 1, 1)), num_classes=10)
    params = m.init(jax.random.PRNGKey(0))
    mesh = make_host_mesh()
    rng = np.random.default_rng(0)
    n = args.local_batch * ndev
    batch = shard_cnn_batch(
        {"image": jnp.asarray(rng.standard_normal((n, 32, 32, 3)),
                              jnp.float32),
         "label": jnp.asarray(rng.integers(0, 10, size=(n,)))}, mesh)
    rows = []
    for reduction in REDUCTIONS:
        compress = "int8" if reduction == "int8" else "off"
        state = init_cnn_train_state_dp(params, mesh, grad_compress=compress)
        step = make_cnn_train_step_dp(m, mesh, lr=0.02,
                                      grad_compress=compress)
        state, metrics = step(state, batch)       # compile + correctness
        loss = float(metrics["loss"])
        assert np.isfinite(loss), (reduction, loss)
        us = time_call(step, state, batch, warmup=1, iters=3)
        rows.append({"devices": ndev, "reduction": reduction,
                     "global_batch": n, "loss": round(loss, 4),
                     "us_per_step": round(us, 1),
                     "images_per_s": round(n / (us / 1e6), 2)})
    print("RESULT " + json.dumps({"devices": ndev, "rows": rows}))


def _spawn(devices: int, *, local_batch: int) -> list[dict]:
    env = dict(os.environ)
    flags = [f for f in env.get("XLA_FLAGS", "").split()
             if "xla_force_host_platform_device_count" not in f]
    flags.append(f"--xla_force_host_platform_device_count={devices}")
    env["XLA_FLAGS"] = " ".join(flags)
    env["JAX_PLATFORMS"] = "cpu"
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.path.join(repo, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    cmd = [sys.executable, "-m", "benchmarks.train_scaling_bench",
           "--worker", "--devices", str(devices),
           "--local-batch", str(local_batch)]
    out = subprocess.run(cmd, capture_output=True, text=True, env=env,
                         cwd=repo, timeout=1800)
    if out.returncode != 0:
        raise RuntimeError(f"worker x{devices} failed:\n" + out.stderr[-4000:])
    for line in out.stdout.splitlines():
        if line.startswith("RESULT "):
            return json.loads(line[len("RESULT "):])["rows"]
    raise RuntimeError(f"worker x{devices} emitted no RESULT line:\n"
                       + out.stdout[-2000:])


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dry", action="store_true",
                    help="also run the live DP-step smoke on fake devices")
    ap.add_argument("--worker", action="store_true")
    ap.add_argument("--devices", type=int, default=1)
    ap.add_argument("--local-batch", type=int, default=2)
    args = ap.parse_args(argv)
    if args.worker:
        _worker(args)
        return

    from benchmarks.common import bench_out_path, emit
    report = build_report()
    out_path = bench_out_path(OUT_PATH)
    out_path.write_text(json.dumps(report, indent=1, sort_keys=True) + "\n")
    for r in report["rows"]:
        emit(f"train_scaling_model_n{r['devices']:02d}_{r['reduction']}", 0.0,
             f"imgs_per_s={r['images_per_s']};"
             f"eff={r['scaling_efficiency']};"
             f"no_overlap_eff={r['no_overlap_efficiency']}")
    emit("train_scaling_bench_json", 0, f"wrote={out_path}")

    measured = []
    if args.dry:
        base = None
        for devices in LIVE_DEVICE_COUNTS:
            rows = _spawn(devices, local_batch=args.local_batch)
            for r in rows:
                if r["devices"] == 1 and r["reduction"] == "fp32":
                    base = r["images_per_s"]
                if base:
                    r["measured_scaling_efficiency"] = round(
                        r["images_per_s"] / (r["devices"] * base), 4)
                measured.append(r)
                emit(f"train_scaling_live_d{r['devices']}_{r['reduction']}",
                     r["us_per_step"],
                     f"images_per_s={r['images_per_s']};loss={r['loss']}")
    print("RESULT " + json.dumps({**report, "measured": measured}))


if __name__ == "__main__":
    main()
