"""Tuned-vs-heuristic blocking on the paper's layer tables (§II-D empirical).

For every distinct conv shape in ResNet-50 (paper Table I) and Inception-v3
(derived from the topology graph) this bench:

  1. scores the analytic heuristic blocking with the tuner's cost model,
  2. autotunes the shape (persistent cache; real wall clock on TPU, cost
     model on CPU — see repro.tune.measure), and
  3. emits one CSV row with both scores, the modeled speedup, the chosen
     blocking delta, and whether the winner came from the persistent cache.

Run it twice: the second invocation must be all cache hits — that round trip
is the acceptance check for the dispatch-cache story.

  PYTHONPATH=src python -m benchmarks.autotune_bench [--layers N]
"""
import sys

from benchmarks.common import emit
from repro import backend as be
from repro import tune
from repro.core.blocking import conv_blocking_analytic
from repro.graph.topology import RESNET50_LAYERS, inception_v3

MINIBATCH = 28          # paper: 28 images per SKX socket


def inception_layers(input_hw: int = 299) -> dict:
    """Distinct conv shapes of the Inception-v3 topology, spatial dims
    propagated from `input_hw` through the stem/pool strides."""
    hw = {"input": (input_hw, input_hw)}
    layers = {}
    for node in inception_v3(num_classes=1000):
        if node.op in ("input", "fc"):
            continue
        src = node.inputs[0] if node.inputs else None
        h, w = hw.get(src, (0, 0))
        if node.op == "conv":
            a = node.attrs
            p = (h + 2 * a["padding"] - a["r"]) // a["stride"] + 1
            q = (w + 2 * a["padding"] - a["s"]) // a["stride"] + 1
            hw[node.name] = (p, q)
            key = (a["c"], a["k"], h, w, a["r"], a["s"], a["stride"])
            layers.setdefault(key, dict(c=a["c"], k=a["k"], h=h, w=w,
                                        r=a["r"], s=a["s"],
                                        stride=a["stride"]))
        elif node.op == "maxpool":
            a = node.attrs
            p = (h + 2 * a["padding"] - a["window"]) // a["stride"] + 1
            hw[node.name] = (p, p)
        else:                       # bn/relu/add/concat/avgpool: shape-keep
            hw[node.name] = (h, w)
    return {i + 1: l for i, l in enumerate(layers.values())}


def bench_table(table_name: str, layers: dict, *, limit: int | None = None):
    backend = be.get_backend()
    hits = total = 0
    gains = []
    # filter before slicing so --layers N yields N tunable rows
    items = [(lid, l) for lid, l in sorted(layers.items())
             if l["c"] % 8 == 0 and l["k"] % 8 == 0][:limit]
    for lid, l in items:
        pad = l["r"] // 2
        shape = dict(h=l["h"], w=l["w"], c=l["c"], k=l["k"], r=l["r"],
                     s=l["s"], stride=l["stride"], padding=pad,
                     dtype_bytes=4)
        kw = dict(h=l["h"], w=l["w"], c=l["c"], k=l["k"], r=l["r"], s=l["s"],
                  stride=l["stride"], padding=pad, kind="fwd",
                  backend=backend, minibatch=MINIBATCH)
        cached = tune.lookup_conv(**kw) is not None
        heur = conv_blocking_analytic(
            h=l["h"], w=l["w"], c=l["c"], k=l["k"], r=l["r"], s=l["s"],
            stride=l["stride"], padding=pad)
        tuned = tune.autotune_conv(**kw)
        heur_us = tune.conv_cost_us(shape, heur, minibatch=MINIBATCH)
        tuned_us = tune.conv_cost_us(shape, tuned, minibatch=MINIBATCH)
        speedup = heur_us / tuned_us if tuned_us else 1.0
        total += 1
        hits += cached
        gains.append(speedup)
        emit(f"autotune_{table_name}_L{lid:02d}", tuned_us,
             f"heur_us={heur_us:.1f};speedup={speedup:.2f}x;"
             f"cache={'hit' if cached else 'miss'};"
             f"rb_p={heur.rb_p}->{tuned.rb_p};"
             f"kblk={heur.k_blk}->{tuned.k_blk}")
    if gains:
        gains.sort()
        emit(f"autotune_{table_name}_summary", 0.0,
             f"layers={total};cache_hits={hits};"
             f"median_speedup={gains[len(gains) // 2]:.2f}x;"
             f"max_speedup={gains[-1]:.2f}x;"
             f"cache_path={tune.default_cache().path}")


def main(limit: int | None = None):
    bench_table("resnet50", RESNET50_LAYERS, limit=limit)
    bench_table("inception", inception_layers(), limit=limit)


if __name__ == "__main__":
    limit = None
    if "--layers" in sys.argv:
        limit = int(sys.argv[sys.argv.index("--layers") + 1])
    print("name,us_per_call,derived")
    main(limit=limit)
