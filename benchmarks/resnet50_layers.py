"""Paper Fig. 4 (+Table I): per-layer ResNet-50 forward conv performance.

Measured on this host: im2col-GEMM formulation vs direct convolution
(XLA path — the same loop structure our Pallas kernel implements for TPU),
reproducing the paper's central comparison.  `derived` carries the modeled
TPU-v5e efficiency from the blocking analysis (compute vs memory roofline
terms + MXU lane utilization) — the quantity Fig. 4's right axis reports
for SKX.
"""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_call
from repro.core.blocking import conv_blocking
from repro.graph.topology import RESNET50_LAYERS
from repro.kernels import ref
from repro.launch.roofline import HBM_BW, PEAK_FLOPS

MINIBATCH = 4   # per-call batch on this host (paper: 28 per SKX socket)


def im2col_conv(x, w, stride, pad):
    n, h, wd, c = x.shape
    r, s, _, k = w.shape
    patches = jax.lax.conv_general_dilated_patches(
        x, (r, s), (stride, stride), [(pad, pad), (pad, pad)],
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    p, q = patches.shape[1], patches.shape[2]
    return (patches.reshape(n * p * q, r * s * c)
            @ w.transpose(2, 0, 1, 3).reshape(r * s * c, k)
            ).reshape(n, p, q, k)


def modeled_v5e_efficiency(l, n: int = 28) -> float:
    """Roofline + MXU-alignment model for one conv layer on v5e (weights
    amortized over the paper's n=28 minibatch; cache blocking keeps the
    weight block resident across the P sweep — §II-C)."""
    c, k, r = l["c"], l["k"], l["r"]
    stride = l["stride"]
    p = (l["h"] + 2 * (r // 2) - r) // stride + 1
    flops = n * 2 * p * p * c * k * r * r
    in_b = n * l["h"] * l["w"] * c * 2
    out_b = n * p * p * k * 2
    w_b = r * r * c * k * 2                              # read once
    lane_util = min(c, 128) / 128 if c < 128 else 1.0
    t_comp = flops / (PEAK_FLOPS * lane_util)
    t_mem = (in_b + out_b + w_b) / HBM_BW
    return t_comp / max(t_comp, t_mem) * lane_util


def main():
    rng = np.random.default_rng(0)
    for lid, l in sorted(RESNET50_LAYERS.items()):
        h = min(l["h"], 56)          # cap spatial size for host timing
        scale = (l["h"] / h) ** 2
        x = jnp.asarray(rng.standard_normal(
            (MINIBATCH, h, h, l["c"])), jnp.float32)
        w = jnp.asarray(rng.standard_normal(
            (l["r"], l["s"], l["c"], l["k"])) * 0.05, jnp.float32)
        pad = l["r"] // 2
        direct = jax.jit(lambda x, w, s=l["stride"], p=pad:
                         ref.conv2d(x, w, stride=s, padding=p))
        i2c = jax.jit(lambda x, w, s=l["stride"], p=pad:
                      im2col_conv(x, w, s, p))
        us_d = time_call(direct, x, w) * scale
        us_i = time_call(i2c, x, w) * scale
        eff = modeled_v5e_efficiency(l)
        blk = conv_blocking(h=l["h"], w=l["w"], c=max(l["c"], 8),
                            k=l["k"], r=l["r"], s=l["s"],
                            stride=l["stride"], padding=pad)
        emit(f"resnet50_fwd_L{lid:02d}_direct", us_d,
             f"v5e_eff={eff:.2f};rb_p={blk.rb_p};kblk={blk.k_blk};"
             f"im2col_speedup={us_i/us_d:.2f}x")


if __name__ == "__main__":
    main()
