"""Paper §III (Inception-v3, the second benchmark topology): end-to-end
GxM step timing + fusion statistics for the branchy graph (Split nodes).

``build_report()`` is the machine-checkable half (pinned by
``tests/test_inception_bench.py``): the symbolic ETG walk — fusion
statistics, split-node count, conv-task count vs distinct JIT kernels
after dedupe (the combinatorial-explosion answer for the branchy graph) —
none of which needs a wall clock.  ``main()`` additionally times the
jitted forward and train step on a tiny image.
"""
from repro.graph import GxM
from repro.graph.etg import build_etg
from repro.graph.serving import conv_shapes, distinct_conv_signatures
from repro.graph.topology import inception_v3

IMAGE_HW = (299, 299)


def build_report(*, image_hw=IMAGE_HW, num_classes: int = 1000) -> dict:
    etg = build_etg(inception_v3(num_classes=num_classes))
    shapes = conv_shapes(etg, image_hw)
    return {
        "topology": "inception_v3",
        "image": list(image_hw),
        "stats": dict(etg.stats),
        "split_nodes": sum(1 for t in etg.tasks if t.op == "split"),
        "conv_tasks": len(shapes),
        "distinct_jit_kernels": len(etg.kernel_cache),
        "distinct_conv_signatures": len(distinct_conv_signatures(shapes)),
    }


def main():
    import jax
    import jax.numpy as jnp
    import numpy as np

    from benchmarks.common import emit, time_call

    rng = np.random.default_rng(0)
    nl = inception_v3(num_classes=100)
    report = build_report(num_classes=100, image_hw=(64, 64))
    m = GxM(nl, impl="xla", num_classes=100)
    params = m.init(jax.random.PRNGKey(0))
    x = jnp.asarray(rng.standard_normal((2, 64, 64, 3)), jnp.float32)
    batch = {"image": x, "label": jnp.asarray([1, 2])}

    fwd = jax.jit(lambda p, x: m.forward(p, x, train=False))
    us_f = time_call(fwd, params, x)
    step = jax.jit(m.sgd_train_step)
    us_t = time_call(step, params, batch)
    emit("inception_infer", us_f,
         f"fused_tasks={report['stats']['nodes_after']};"
         f"ops_fused={report['stats']['ops_fused']};"
         f"split_nodes={report['split_nodes']}")
    emit("inception_train_step", us_t,
         f"distinct_jit_kernels={report['distinct_jit_kernels']}")


if __name__ == "__main__":
    main()
