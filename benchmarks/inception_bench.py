"""Paper §III (Inception-v3, the second benchmark topology): end-to-end
GxM step timing + fusion statistics for the branchy graph (Split nodes)."""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_call
from repro.graph import GxM, inception_v3
from repro.graph.etg import build_etg


def main():
    rng = np.random.default_rng(0)
    nl = inception_v3(num_classes=100)
    etg = build_etg(inception_v3(num_classes=100))
    m = GxM(nl, impl="xla", num_classes=100)
    params = m.init(jax.random.PRNGKey(0))
    x = jnp.asarray(rng.standard_normal((2, 64, 64, 3)), jnp.float32)
    batch = {"image": x, "label": jnp.asarray([1, 2])}

    fwd = jax.jit(lambda p, x: m.forward(p, x, train=False))
    us_f = time_call(fwd, params, x)
    step = jax.jit(m.sgd_train_step)
    us_t = time_call(step, params, batch)
    n_split = sum(1 for t in etg.tasks if t.op == "split")
    emit("inception_infer", us_f,
         f"fused_tasks={etg.stats['nodes_after']};"
         f"ops_fused={etg.stats['ops_fused']};split_nodes={n_split}")
    emit("inception_train_step", us_t,
         f"distinct_jit_kernels={len(etg.kernel_cache)}")


if __name__ == "__main__":
    main()
