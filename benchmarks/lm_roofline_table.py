"""Beyond-paper table: the 40-cell (arch × shape) analytic roofline summary
(reads the dry-run evidence when present; pure-analytic otherwise)."""
import pathlib

from benchmarks.common import emit
from repro.configs import ARCHS, SHAPES, get_config
from repro.configs.shapes import applicable
from repro.launch import analytic as A


def main():
    for arch in sorted(ARCHS):
        cfg = get_config(arch)
        for sname, shape in SHAPES.items():
            ok, _ = applicable(cfg, shape)
            if not ok:
                emit(f"cell_{arch}_{sname}", 0.0, "skip=no-subquadratic")
                continue
            t = A.analytic_roofline(cfg, shape, chips=256, model_par=16,
                                    data_par=16)
            emit(f"cell_{arch}_{sname}", t.step_time_s * 1e6,
                 f"dominant={t.dominant};"
                 f"mfu={A.mfu(cfg, shape, t, 256):.3f}")


if __name__ == "__main__":
    main()
