"""Resilience bench — goodput under canned fault schedules (DESIGN.md §14).

Replays three fixed fault schedules through the real ``ResilientLoop`` +
real checkpoint I/O (temp dir) + the real ``fold_residual`` elastic path,
with a cheap deterministic step function standing in for the DP CNN step
and ``chaos.SimClock`` supplying time — so every number in
``BENCH_resilience.json`` is a pure function of the schedule:

  fault_free     no events — the goodput identity anchor (exactly 1.0)
  reference      the ISSUE acceptance schedule: a straggler, a mid-run host
                 death, a corrupted newest checkpoint + step fault (the
                 walk-back restore), and a transient save outage — the
                 perf-gate floors goodput here at 0.9
  restart_heavy  repeated step faults off checkpoint boundaries plus a torn
                 (mid-write crash) checkpoint — the replay-cost profile

Goodput is simulated-time ``t(fault_free) / t(schedule)``: successful steps
charge the slowest alive host's duration, collective timeouts and injected
faults charge their modeled cost, and backoff sleeps charge through the
SimClock.  ``recovery_overhead_steps`` counts replayed work
(``steps_run - n_steps``), and every elastic fold checks that the summed
residual is bit-equal before and after (``fold_mass_conserved`` — the
perf-gate floors it at 1.0; residuals are integer-valued so float32 sums
are exact).  The real-model counterpart — the DP CNN step under chaos on
fake devices — runs in ``tests/test_chaos.py``; this bench is the
committed, deterministic artifact the gate reads.
"""
from __future__ import annotations

import json
import pathlib
import tempfile

import numpy as np

OUT_PATH = pathlib.Path(__file__).resolve().parents[1] / \
    "BENCH_resilience.json"

N_STEPS = 400
N_HOSTS = 4
STEP_S = 1.0
COLLECTIVE_TIMEOUT_S = 2.0
CKPT_EVERY = 10
POLICY_EVERY = 5
SHAPE = (4, 4)


def schedules() -> dict[str, tuple]:
    from repro.train import chaos as cz
    return {
        "fault_free": (),
        "reference": (
            cz.SlowHost(50, "host2", factor=3.0),
            cz.HostDeath(200, "host3"),
            cz.FlakySaves(240, times=2),
            cz.CorruptCheckpoint(300),
            cz.StepFault(305),
        ),
        "restart_heavy": (
            cz.StepFault(63),
            cz.TornCheckpoint(150),
            cz.StepFault(156),
            cz.StepFault(333),
        ),
    }


class _CursorData:
    """batch = f(step): the pure data-cursor contract of data/pipeline.py."""

    def batch_at(self, step: int) -> dict:
        return {"step": np.float32(step)}


def make_state(n_hosts: int) -> dict:
    rng = np.random.default_rng(np.random.SeedSequence([0x5E51, n_hosts]))
    return {
        "params": rng.standard_normal(SHAPE).astype(np.float32),
        # integer-valued so elastic-fold sums are exact in float32
        "residual": rng.integers(-50, 50, size=(n_hosts, *SHAPE))
        .astype(np.float32),
    }


def make_step_fn(n_hosts: int):
    def step_fn(state, batch):
        params = state["params"] - np.float32(1e-3) * (batch["step"] + 1.0)
        residual = state["residual"] + np.float32(1.0)
        return ({"params": params, "residual": residual},
                {"loss": float(np.abs(params).mean())})
    return step_fn


def make_elastic_fn(fold_log: list):
    """elastic_fn(state, alive): fold the per-shard residual onto the
    narrower fleet (the DP CNN path's ``reshard_cnn_state`` analog) and
    record exact mass conservation."""
    from repro.optim.compress import fold_residual

    def elastic_fn(state, alive):
        new = len(alive)
        before = state["residual"].sum(axis=0)
        folded = np.asarray(fold_residual(state["residual"], new))
        after = folded.sum(axis=0)
        fold_log.append({
            "from": int(state["residual"].shape[0]), "to": new,
            "mass_conserved": float(np.array_equal(before, after)),
        })
        return ({"params": state["params"], "residual": folded},
                make_step_fn(new))
    return elastic_fn


def replay(name: str, events: tuple) -> dict:
    from repro.train import chaos as cz
    from repro.train.fault_tolerance import ResilientLoop
    hosts = [f"host{i}" for i in range(N_HOSTS)]
    fold_log: list = []
    with tempfile.TemporaryDirectory(prefix="repro-resilience-") as d:
        eng = cz.ChaosEngine(cz.ChaosSchedule(events), hosts=hosts,
                             ckpt_dir=d, step_s=STEP_S,
                             collective_timeout_s=COLLECTIVE_TIMEOUT_S)
        loop = ResilientLoop(
            step_fn=make_step_fn(N_HOSTS), state=make_state(N_HOSTS),
            data=_CursorData(), ckpt_dir=d, ckpt_every=CKPT_EVERY,
            policy_every=POLICY_EVERY, min_hosts=2, chaos=eng,
            heartbeat=eng.make_heartbeat(),
            elastic_fn=make_elastic_fn(fold_log))
        loop.run(N_STEPS)
        sim_time = eng.clock.time()
    summary = loop.resilience_summary()
    fault_free_time = N_STEPS * STEP_S
    return {
        "name": name,
        "n_steps": N_STEPS,
        "sim_time_s": round(sim_time, 6),
        "fault_free_time_s": fault_free_time,
        "goodput_ratio": round(fault_free_time / sim_time, 6),
        "recovery_overhead_steps": summary["steps_run"] - N_STEPS,
        "lost_steps": summary["lost_steps"],
        "restarts": summary["restarts"],
        "evictions": summary["evictions"],
        "io_retries": summary["io_retries"],
        "n_hosts_final": summary["n_hosts"],
        "fold_mass_conserved": min((f["mass_conserved"] for f in fold_log),
                                   default=1.0),
        "folds": fold_log,
        # sanitized event log (kinds/steps only: no host paths, no reprs)
        "events": [{"kind": e["kind"], "step": e.get("step"),
                    "t": round(e["t"], 6)} for e in loop.events],
    }


def fold_table() -> list[dict]:
    """Standalone elastic-fold conservation: divisible (4 -> 2) and
    non-divisor collapse (4 -> 3), exact in float32 by integer values."""
    from repro.optim.compress import fold_residual
    rng = np.random.default_rng(np.random.SeedSequence([0xF01D]))
    r = rng.integers(-100, 100, size=(4, 8, 8)).astype(np.float32)
    rows = []
    for new in (2, 3):
        folded = np.asarray(fold_residual(r, new))
        rows.append({
            "from": 4, "to": new,
            "mass_conserved": float(np.array_equal(r.sum(axis=0),
                                                   folded.sum(axis=0))),
        })
    return rows


def build_report() -> dict:
    return {
        "bench": "resilience",
        "model": {"n_steps": N_STEPS, "n_hosts": N_HOSTS, "step_s": STEP_S,
                  "collective_timeout_s": COLLECTIVE_TIMEOUT_S,
                  "ckpt_every": CKPT_EVERY, "policy_every": POLICY_EVERY},
        "schedules": [replay(name, ev) for name, ev in schedules().items()],
        "fold": fold_table(),
    }


def main(argv=None) -> dict:
    from benchmarks.common import bench_out_path, emit
    report = build_report()
    out_path = bench_out_path(OUT_PATH)
    out_path.write_text(json.dumps(report, indent=1) + "\n")
    for r in report["schedules"]:
        emit(f"resilience_{r['name']}", 0.0,
             f"goodput={r['goodput_ratio']:.4f} "
             f"overhead_steps={r['recovery_overhead_steps']} "
             f"evictions={r['evictions']}")
    for f in report["fold"]:
        emit(f"resilience_fold_{f['from']}to{f['to']}", 0.0,
             f"mass_conserved={f['mass_conserved']:.0f}")
    print(f"# wrote {out_path}")
    return report


if __name__ == "__main__":
    main()
