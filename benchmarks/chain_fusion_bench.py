"""Depth-first chain fusion trajectory: fused vs unfused, machine-readable.

Writes ``BENCH_chain_fusion.json`` at the repo root — for every detected
single-consumer conv->conv chain in ResNet-50 (the Table-I bottlenecks) and
Inception-v3 (the tower branches), the modeled HBM traffic and roofline cost
of the depth-first band-fused execution (DESIGN.md §16) against the unfused
layer-by-layer execution.

Two budget contexts per network:

  <net>        priced at the live ``REPRO_VMEM_BUDGET`` (the context the
               perf gate stamps and compares against its baselines)
  <net>_1mib   always priced at an explicit 1 MiB budget, so the committed
               16 MiB artifact also records the pressure story

Numbers come from the schedule-resolved models
(``repro.tune.measure.chain_traffic`` + ``launch.roofline.chain_roofline``)
so the file is reproducible on any host.  Invariants the perf gate holds
(repro.perfci): ``traffic_margin`` (unfused/fused HBM) >= 1 on every chain
in every context — the fallback rule prices an unprofitable chain at
exactly the unfused sum — and fused chains move 0 intermediate HBM bytes.
"""
import json
import pathlib

from benchmarks.common import bench_out_path, emit
from repro.core.blocking import VMEM_BUDGET
from repro.graph.etg import build_etg
from repro.graph.serving import conv_shapes
from repro.graph.topology import inception_v3, resnet50
from repro.launch.roofline import chain_roofline
from repro.tune.measure import chain_traffic

MINIBATCH = 1                      # serving-path feature: single image
PRESSURE_BUDGET = 1 << 20          # the always-on 1 MiB pressure context
SHAPE_FIELDS = ("h", "w", "c", "k", "r", "s", "stride", "padding")
OUT_PATH = pathlib.Path(__file__).resolve().parents[1] / \
    "BENCH_chain_fusion.json"

NETWORKS = {
    "resnet50": (resnet50, (224, 224)),
    "inception_v3": (inception_v3, (299, 299)),
}


def network_chains(build, image_hw) -> list[dict]:
    """Detected chains with resolved per-layer shapes, deduped by structure.

    ResNet-50's 16 bottlenecks collapse to the handful of distinct
    (shape-list) signatures; ``count`` records the multiplicity so totals
    can still be reconstructed."""
    etg = build_etg(build(num_classes=1000))
    by_name = {sh["name"]: sh for sh in conv_shapes(etg, image_hw)}
    distinct: dict[tuple, dict] = {}
    for ch in etg.chains:
        shapes = [{f: by_name[nm][f] for f in SHAPE_FIELDS}
                  for nm in ch.names]
        sig = tuple(tuple(sorted(sh.items())) for sh in shapes)
        if sig in distinct:
            distinct[sig]["count"] += 1
        else:
            distinct[sig] = dict(chain=ch.names[0], layers=list(ch.names),
                                 halo_growth=list(ch.halo_growth),
                                 shapes=shapes, count=1)
    return list(distinct.values())


def chain_record(spec: dict, *, vmem_budget: int) -> dict:
    t = chain_traffic(spec["shapes"], minibatch=MINIBATCH,
                      vmem_budget=vmem_budget)
    roof = chain_roofline(t)
    margin = t["unfused_hbm_bytes"] / max(t["hbm_bytes"], 1.0)
    return {
        "chain": spec["chain"],
        "layers": spec["layers"],
        "n_layers": len(spec["layers"]),
        "count": spec["count"],
        "halo_growth": spec["halo_growth"],
        "shapes": spec["shapes"],
        "fused": bool(t["fused"]),
        "fits_vmem": bool(t["fits_vmem"]),
        "rb": int(t["rb"]),
        "n_bands": int(t["n_bands"]),
        "vmem_working_set": int(t["vmem_bytes"]),
        "hbm_bytes": int(t["hbm_bytes"]),
        "unfused_hbm_bytes": int(t["unfused_hbm_bytes"]),
        "traffic_margin": round(margin, 4),
        "intermediate_bytes": int(t["intermediate_bytes"]),
        "unfused_intermediate_bytes": int(t["unfused_intermediate_bytes"]),
        "cost_us": round(roof["cost_s"] * 1e6, 3),
        "unfused_cost_us": round(roof["unfused_cost_s"] * 1e6, 3),
        "speedup": round(roof["speedup"], 4),
        "roofline_efficiency": round(roof["efficiency"], 4),
        "launches": int(roof["launches"]),
    }


def _table(specs: list[dict], *, vmem_budget: int) -> dict:
    recs = [chain_record(sp, vmem_budget=vmem_budget) for sp in specs]
    fused = [r for r in recs if r["fused"]]
    return {
        "vmem_budget": vmem_budget,
        "chains": recs,
        "summary": {
            "n_chains": len(recs),
            "n_fused": len(fused),
            "min_traffic_margin": round(min(r["traffic_margin"]
                                            for r in recs), 4),
            "fused_intermediate_bytes": sum(r["intermediate_bytes"]
                                            for r in fused),
            "hbm_saved_bytes": sum(r["unfused_hbm_bytes"] - r["hbm_bytes"]
                                   for r in recs),
        },
    }


def build_report() -> dict:
    tables = {}
    for net, (build, image_hw) in NETWORKS.items():
        specs = network_chains(build, image_hw)
        tables[net] = _table(specs, vmem_budget=VMEM_BUDGET)
        tables[f"{net}_1mib"] = _table(specs, vmem_budget=PRESSURE_BUDGET)
    return {
        "minibatch": MINIBATCH,
        "vmem_budget": VMEM_BUDGET,
        "pressure_budget": PRESSURE_BUDGET,
        "model": "tpu-v5e roofline (repro.tune.measure.chain_traffic)",
        "tables": tables,
    }


def main(argv=None) -> None:
    report = build_report()
    out_path = bench_out_path(OUT_PATH)
    out_path.write_text(json.dumps(report, indent=1, sort_keys=True) + "\n")
    for tname, table in report["tables"].items():
        for rec in table["chains"]:
            emit(f"chain_fusion_{tname}_{rec['chain']}", rec["cost_us"],
                 f"fused={int(rec['fused'])};rb={rec['rb']};"
                 f"margin={rec['traffic_margin']};"
                 f"inter_bytes={rec['intermediate_bytes']};"
                 f"speedup={rec['speedup']}")
        s = table["summary"]
        emit(f"chain_fusion_{tname}_summary", 0,
             f"n_chains={s['n_chains']};n_fused={s['n_fused']};"
             f"min_margin={s['min_traffic_margin']};"
             f"fused_inter_bytes={s['fused_intermediate_bytes']}")
    emit("chain_fusion_bench_json", 0, f"wrote={out_path}")


if __name__ == "__main__":
    main()
