"""Paper Fig. 9: end-to-end strong-scaling of ResNet-50 training.

The paper reports ~90% parallel efficiency at 16 nodes with MLSL's
overlapped all-reduce.  We reproduce the *model*: per-node step time =
max(compute, gradient-all-reduce) when overlapped, compute + all-reduce
when not — evaluated with the v5e roofline constants over 1..64 nodes, plus
a measured single-host data point (images/s of the tiny GxM trainer on this
CPU) as the absolute anchor."""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_call
from repro.graph import GxM, resnet50
from repro.launch.roofline import ICI_BW, PEAK_FLOPS

RESNET50_GFLOP = 4.1 * 3        # fwd+bwd+wu per image (GFLOP)
RESNET50_PARAMS = 25.6e6
LOCAL_BATCH = 32
EFF_COMPUTE = 0.55              # kernel-level efficiency (paper: 55-80%)


def modeled_imgs_per_s(nodes: int, overlap: bool) -> float:
    t_comp = LOCAL_BATCH * RESNET50_GFLOP * 1e9 \
        / (PEAK_FLOPS * EFF_COMPUTE)
    t_ar = (2 * (nodes - 1) / max(nodes, 1)) * RESNET50_PARAMS * 4 / ICI_BW \
        if nodes > 1 else 0.0
    t = max(t_comp, t_ar) if overlap else t_comp + t_ar
    return nodes * LOCAL_BATCH / t


def main():
    # measured single-host anchor (tiny config, CPU)
    rng = np.random.default_rng(0)
    m = GxM(resnet50(num_classes=10, stages=(1, 1, 1, 1)), impl="xla",
            num_classes=10)
    params = m.init(jax.random.PRNGKey(0))
    batch = {"image": jnp.asarray(rng.standard_normal((4, 32, 32, 3)),
                                  jnp.float32),
             "label": jnp.asarray([0, 1, 2, 3])}
    step = jax.jit(m.sgd_train_step)
    us = time_call(step, params, batch)
    emit("gxm_train_step_host", us, f"imgs_per_s_host={4/(us/1e6):.1f}")

    base = modeled_imgs_per_s(1, True)
    for nodes in (1, 2, 4, 8, 16, 32, 64):
        ov = modeled_imgs_per_s(nodes, overlap=True)
        nov = modeled_imgs_per_s(nodes, overlap=False)
        emit(f"scaling_model_n{nodes:02d}", 0.0,
             f"imgs_per_s={ov:.0f};par_eff={ov/(nodes*base):.2f};"
             f"no_overlap_eff={nov/(nodes*base):.2f}")


if __name__ == "__main__":
    main()
