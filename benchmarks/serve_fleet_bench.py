"""Serving-fleet bench — SLO metrics under canned fault schedules
(DESIGN.md §15).

Replays three fixed schedules through the real ``FleetRouter`` + real
``TuneCache`` warm-reseed plumbing (temp dir), with a service-time model
standing in for the CNN engine pair and ``core.simtime.SimClock``
supplying time — so every number in ``BENCH_serve_fleet.json`` is a pure
function of the seeded arrival + fault schedule:

  fault_free      Poisson arrivals, no faults — the goodput identity
                  anchor (exactly 1.0) and the p50/p99 reference tail
  reference       the ISSUE acceptance schedule: a straggler replica
                  (hedging), a mid-run replica death (health eviction +
                  warm-cache respawn), a flaky accelerator (bounded-backoff
                  retry), and a request burst — the perf-gate floors
                  goodput here at 0.9 and slo_handled_rate at 1.0
  burst_overload  a burst far beyond the SLO-feasible queue depth against
                  a tight queue bound — the load-shed + degrade-to-int8
                  profile (every admitted request still completes within
                  deadline or on the int8 twin)

Goodput is ``in_deadline / offered``; ``slo_handled_rate`` is the §15
invariant over *admitted* requests (done within deadline, or handed to the
int8 degrade path).  The real-engine counterpart — a fleet of
``CnnInferenceEngine`` pairs on fake devices — runs in
``tests/test_serve_fleet.py``; this bench is the committed, deterministic
artifact the perf-gate reads.
"""
from __future__ import annotations

import json
import pathlib
import tempfile

OUT_PATH = pathlib.Path(__file__).resolve().parents[1] / \
    "BENCH_serve_fleet.json"

N_REPLICAS = 3
SERVICE_S = 1.0
Q8_FACTOR = 0.55
COLD_SERVICE_S = 3.0
N_REQUESTS = 120
RATE_PER_S = 1.5
DEADLINE_S = 6.0
QUEUE_BOUND = 32
ARRIVAL_SEED = 0
WARM_ENTRIES = 6


def schedules() -> dict[str, dict]:
    from repro.serve import chaos as sz
    return {
        "fault_free": {"events": ()},
        "reference": {"events": (
            sz.SlowReplica(10.0, "r1", factor=3.0, until=30.0),
            sz.ReplicaDeath(30.0, "r2"),
            sz.FlakyInfer(45.0, "r0", times=2),
            sz.RequestBurst(55.0, 12),
        )},
        "burst_overload": {"events": (sz.RequestBurst(20.0, 60),),
                           "queue_bound": 24},
    }


def _warm_payload(replica: str) -> dict:
    """Synthetic blocking-cache entries standing in for warmup's tune
    output — identical across replicas (every replica tuned the same
    signatures), so the respawn reseed is survivor-agnostic."""
    return {f"conv/sig{i}": {"blocking": {"hb": 4, "wb": 8, "cb": 64},
                             "source": "bench-warm", "score_us": 10.0 + i,
                             "replica_agnostic": True}
            for i in range(WARM_ENTRIES)}


def make_fleet(tmpdir: str):
    """N modeled replicas with real (temp-dir) TuneCaches pre-seeded the
    way ``CnnInferenceEngine.warmup`` would, plus the respawn factory that
    spawns a *cold* cache (the reseed path must supply the warmth)."""
    from repro.serve import Replica
    from repro.tune.cache import TuneCache

    def make_replica(name: str, *, warm: bool) -> Replica:
        cache = TuneCache(str(pathlib.Path(tmpdir) / f"{name}.json"))
        if warm:
            cache.merge_entries(_warm_payload(name), persist=False)
        return Replica(name, cache=cache, service_s=SERVICE_S,
                       q8_service_factor=Q8_FACTOR,
                       cold_service_s=COLD_SERVICE_S)

    replicas = [make_replica(f"r{i}", warm=True) for i in range(N_REPLICAS)]
    return replicas, lambda name: make_replica(name, warm=False)


def replay(name: str, spec: dict) -> dict:
    from repro.serve import (FleetRouter, ServeChaosEngine,
                             ServeChaosSchedule, poisson_arrivals)
    arrivals = poisson_arrivals(ARRIVAL_SEED, n=N_REQUESTS,
                                rate_per_s=RATE_PER_S)
    with tempfile.TemporaryDirectory(prefix="repro-fleet-") as d:
        replicas, factory = make_fleet(d)
        router = FleetRouter(
            replicas,
            chaos=ServeChaosEngine(ServeChaosSchedule(spec["events"])),
            deadline_s=DEADLINE_S,
            queue_bound=spec.get("queue_bound", QUEUE_BOUND),
            replica_factory=factory)
        report = router.run(arrivals)
    # sanitized event log (kinds + fields only, no object reprs)
    events = report.pop("events")
    report["events"] = [e for e in events
                        if e["kind"] in ("shed", "degrade_admission",
                                         "degrade_deadline", "hedge",
                                         "retry_backoff", "eviction",
                                         "reassign", "respawn")]
    return {"name": name, **report}


def build_report() -> dict:
    return {
        "bench": "serve_fleet",
        "model": {"n_replicas": N_REPLICAS, "service_s": SERVICE_S,
                  "q8_service_factor": Q8_FACTOR,
                  "cold_service_s": COLD_SERVICE_S,
                  "n_requests": N_REQUESTS, "rate_per_s": RATE_PER_S,
                  "deadline_s": DEADLINE_S, "queue_bound": QUEUE_BOUND,
                  "arrival_seed": ARRIVAL_SEED,
                  "warm_entries": WARM_ENTRIES},
        "schedules": [replay(name, spec)
                      for name, spec in schedules().items()],
    }


def main(argv=None) -> dict:
    from benchmarks.common import bench_out_path, emit
    report = build_report()
    out_path = bench_out_path(OUT_PATH)
    out_path.write_text(json.dumps(report, indent=1) + "\n")
    for r in report["schedules"]:
        emit(f"serve_fleet_{r['name']}", 0.0,
             f"goodput={r['goodput']:.4f} p99_ms={r['p99_ms']:.1f} "
             f"shed_rate={r['shed_rate']:.4f} "
             f"degrade_rate={r['degrade_rate']:.4f} "
             f"slo_handled={r['slo_handled_rate']:.4f} "
             f"evictions={r['evictions']} respawns={r['respawns']}")
    print(f"# wrote {out_path}")
    return report


if __name__ == "__main__":
    main()
